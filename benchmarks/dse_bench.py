"""DSE engine benchmark: wall-clock + phase-call counts, three engines.

Measures the three-step DSE (Sec. V-A) on the workloads the repo's
quickstarts lead with — ``explore(zoo.resnet50(256))``, ``explore(zoo.vit(224))``,
``explore_multi([resnet50, vit])`` and a qwen3 decode ``explore`` — once per
engine:

* ``engine="batched"`` (default) — one vectorized scoring pass over the
  dense ``AnalysisTables`` export per graph (``repro.dse.batched``);
* ``engine="scalar"`` — the per-config ``place()`` fast engine (config-
  independent ``analyze`` shared across all Step-1 configs, lazy codegen,
  pruned Step-2 composition, O(n log n) Pareto);
* ``engine="reference"`` — the pre-caching engine: full recompile including
  eager instruction codegen per config, unpruned composition, O(n²) Pareto.

Every engine run is cold-vs-cold: ``repro.compiler.STATS``, the analysis
LRU *and* the cross-analysis SMOF shape cache are reset before each run
(``clear_analysis_cache`` clears both caches), so no engine inherits
another's warm state. For every case the artifact records:

  * wall-clock seconds for all three engines, ``speedup`` (reference over
    batched) and ``speedup_batched_vs_scalar`` (the vectorization win),
  * the ``repro.compiler.STATS`` phase-call counters per engine,
  * ``gate_batched_equal``: frontiers and DP-A/B/C (or the joint frontier
    and the ``balanced`` point) compare byte-equal across all three engines.

An ``incremental.*`` case additionally measures ``explore_multi(prev=...)``:
after a full co-exploration, one tenant is swapped and the re-exploration
reuses the surviving tenants' Step-1 caches plus the prior frontier as
incumbent seeds; ``incremental_ratio`` is its wall time over the
from-scratch wall time, each the best of three cold runs (frontier
equality is gated, the ratio is advisory wall-clock).

``--profile`` resets and records ``repro.dse.batched.PROFILE`` around each
batched-engine run, emitting per-phase timings (table build / partition DP /
reconstruction / SMOF solve / scoring) into the artifact.

The JSON artifact (``BENCH_dse.json``) seeds the perf trajectory; CI runs
``--ci`` (reduced model sizes) and **gates on the call counts and the
equivalence bits** — zero codegen during exploration, exactly one analysis
per distinct graph, all engines equal — while wall-clock numbers stay
advisory so runner jitter cannot flake the build::

    PYTHONPATH=src python benchmarks/dse_bench.py --ci --out BENCH_dse.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time

from repro.compiler import STATS, clear_analysis_cache, zoo
from repro.dse import batched, explore, explore_multi

PROFILE_PHASES = False  # set by --profile


def _timed(fn):
    """Cold run: the analysis LRU, the SMOF shape cache and the STATS
    counters are all reset so successive engine runs never share state."""
    clear_analysis_cache()
    STATS.reset()
    batched.reset_profile()
    t0 = time.perf_counter()
    res = fn()
    wall = time.perf_counter() - t0
    profile = dict(batched.PROFILE) if PROFILE_PHASES else None
    return res, wall, STATS.snapshot(), profile


def _single_equal(x, y) -> bool:
    return (
        x.single == y.single
        and x.single_frontier == y.single_frontier
        and x.multi_frontier == y.multi_frontier
        and x.dp_a == y.dp_a
        and x.dp_b == y.dp_b
        and x.dp_c == y.dp_c
    )


def _single_case(name: str, graph_fn, n_graphs: int = 1) -> dict:
    g = graph_fn()
    bat, t_bat, c_bat, prof = _timed(lambda: explore(g))
    scl, t_scl, c_scl, _ = _timed(lambda: explore(g, engine="scalar"))
    ref, t_ref, c_ref, _ = _timed(lambda: explore(g, engine="reference"))
    equal = _single_equal(bat, scl) and _single_equal(bat, ref)
    return _report(name, n_graphs, t_bat, c_bat, t_scl, c_scl, t_ref, c_ref,
                   equal, prof,
                   extra={"n_single": len(bat.single),
                          "n_multi_batched": len(bat.multi),
                          "n_multi_ref": len(ref.multi)})


def _multi_case(name: str, graphs_fn, n_graphs: int) -> dict:
    graphs = graphs_fn()
    bat, t_bat, c_bat, prof = _timed(lambda: explore_multi(graphs))
    scl, t_scl, c_scl, _ = _timed(lambda: explore_multi(graphs, engine="scalar"))
    ref, t_ref, c_ref, _ = _timed(lambda: explore_multi(graphs, engine="reference"))
    equal = (bat.frontier == scl.frontier == ref.frontier
             and bat.balanced == scl.balanced == ref.balanced)
    return _report(name, n_graphs, t_bat, c_bat, t_scl, c_scl, t_ref, c_ref,
                   equal, prof,
                   extra={"n_points_batched": len(bat.points),
                          "n_points_ref": len(ref.points),
                          "n_frontier": len(bat.frontier)})


def _incremental_case(name: str, graphs_fn, swap_fn, n_graphs: int,
                      repeats: int = 3) -> dict:
    """Co-explore, swap one tenant, re-explore with ``prev=`` vs from
    scratch. Frontier equality is the gate; the wall-time ratio of the
    incremental pass over the from-scratch pass is the headline number.
    Both passes take the best of ``repeats`` cold runs so scheduler jitter
    cannot swing the ratio."""
    graphs = graphs_fn()
    base, t_base, c_base, prof = _timed(lambda: explore_multi(graphs))
    swapped = swap_fn()
    # incremental pass: ``prev`` carries the surviving tenants' Step-1
    # caches, so only the *changed* tenant costs an analysis (the cache
    # clear + STATS reset keep every repeat cold and let the
    # analysis-count gate see exactly one fresh analysis).
    t_inc = math.inf
    for _ in range(repeats):
        clear_analysis_cache()
        STATS.reset()
        batched.reset_profile()
        t0 = time.perf_counter()
        inc = explore_multi(swapped, prev=base)
        t_inc = min(t_inc, time.perf_counter() - t0)
        c_inc = STATS.snapshot()
    t_scr = math.inf
    for _ in range(repeats):
        scr, t, c_scr, _ = _timed(lambda: explore_multi(swapped))
        t_scr = min(t_scr, t)
    equal = (inc.frontier == scr.frontier and inc.balanced == scr.balanced)
    rep = _report(name, n_graphs, t_inc, c_inc, t_scr, c_scr, t_scr, c_scr,
                  equal, prof,
                  extra={"wall_base_s": t_base,
                         "incremental_ratio": t_inc / t_scr if t_scr else 0.0,
                         "n_frontier": len(inc.frontier)})
    # the incremental pass re-analyzes only the swapped-in tenant
    rep["gate_one_analysis_per_graph"] = c_inc["analysis_misses"] == 1
    return rep


def _report(name, n_graphs, t_bat, c_bat, t_scl, c_scl, t_ref, c_ref, equal,
            profile, extra) -> dict:
    rep = {
        "name": name,
        "wall_batched_s": t_bat,
        "wall_scalar_s": t_scl,
        "wall_ref_s": t_ref,
        "speedup": t_ref / t_bat if t_bat else float("inf"),
        "speedup_batched_vs_scalar": t_scl / t_bat if t_bat else float("inf"),
        "counts_batched": c_bat,
        "counts_scalar": c_scl,
        "counts_ref": c_ref,
        "equal": equal,
        # the CI gates: the batched engine generated zero instructions and
        # ran one analysis (fuse+profile) per distinct graph; the reference
        # engine shows what was saved.
        "gate_zero_codegen": c_bat["codegen_calls"] == 0
        and c_bat["memory_plan_calls"] == 0,
        "gate_one_analysis_per_graph": c_bat["analysis_misses"] == n_graphs
        and c_bat["fuse_calls"] == n_graphs
        and c_bat["profile_calls"] == n_graphs,
        "gate_batched_equal": equal,
        **extra,
    }
    if profile is not None:
        rep["profile_batched"] = profile
    return rep


def full_cases() -> list[dict]:
    return [
        _single_case("explore.resnet50_256", lambda: zoo.resnet50(256)),
        _single_case("explore.vit_224", lambda: zoo.vit(224)),
        _multi_case("explore_multi.resnet50+vit",
                    lambda: [zoo.resnet50(256), zoo.vit(224)], n_graphs=2),
        _single_case(
            "explore.qwen3_decode_s256_t64",
            lambda: zoo.transformer_decoder("qwen3-0.6b", seq_len=256,
                                            decode_steps=64, depth=4)),
        _incremental_case(
            "incremental.vit+qwen3_enc16+tiny_cnn.swap_tiny",
            lambda: [zoo.vit(224),
                     zoo.transformer_encoder("qwen3-0.6b", seq_len=256,
                                             depth=16),
                     zoo.tiny_cnn(channels=(8, 16, 16), hw=16)],
            lambda: [zoo.vit(224),
                     zoo.transformer_encoder("qwen3-0.6b", seq_len=256,
                                             depth=16),
                     zoo.tiny_cnn(channels=(4, 8, 8), hw=8)],
            n_graphs=3),
    ]


def ci_cases() -> list[dict]:
    """Reduced sizes (same frontends, same gates) so the CI step stays in
    seconds: the call-count and equivalence gates are size-independent."""
    return [
        _single_case("explore.tiny_cnn",
                     lambda: zoo.tiny_cnn(channels=(16, 32, 32), hw=16)),
        _single_case(
            "explore.qwen3_enc1_s64",
            lambda: zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1)),
        _single_case(
            "explore.qwen3_dec_s64_t8",
            lambda: zoo.transformer_decoder("qwen3-0.6b", seq_len=64,
                                            decode_steps=8, depth=4)),
        _multi_case(
            "explore_multi.tiny_cnn+qwen3_enc",
            lambda: [zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
                     zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1)],
            n_graphs=2),
        _incremental_case(
            "incremental.tiny_cnn+qwen3_enc->tiny_cnn+qwen3_dec",
            lambda: [zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
                     zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1)],
            lambda: [zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
                     zoo.transformer_decoder("qwen3-0.6b", seq_len=64,
                                             decode_steps=8, depth=4)],
            n_graphs=2),
    ]


def main() -> int:
    global PROFILE_PHASES
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="reduced sizes; exit nonzero on call-count or "
                         "equivalence gate failures (wall-clock advisory)")
    ap.add_argument("--profile", action="store_true",
                    help="record repro.dse.batched per-phase wall times "
                         "(table build / DP / reconstruct / SMOF / score) "
                         "for each batched-engine run")
    ap.add_argument("--out", default="BENCH_dse.json",
                    help="artifact path")
    args = ap.parse_args()
    PROFILE_PHASES = args.profile

    cases = ci_cases() if args.ci else full_cases()
    ok = all(c["gate_zero_codegen"] and c["gate_one_analysis_per_graph"]
             and c["gate_batched_equal"] for c in cases)
    report = {
        "mode": "ci" if args.ci else "full",
        "cases": cases,
        "min_speedup": min(c["speedup"] for c in cases),
        "min_speedup_batched_vs_scalar": min(
            c["speedup_batched_vs_scalar"] for c in cases
            if not c["name"].startswith("incremental.")),
        "ok": ok,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for c in cases:
        gates = "ok" if (c["gate_zero_codegen"]
                         and c["gate_one_analysis_per_graph"]
                         and c["gate_batched_equal"]) else "FAIL"
        line = (f"{c['name']:44s} batched={c['wall_batched_s']:7.3f}s "
                f"scalar={c['wall_scalar_s']:7.3f}s "
                f"ref={c['wall_ref_s']:7.3f}s "
                f"x_scalar={c['speedup_batched_vs_scalar']:5.1f} "
                f"equal={int(c['equal'])} {gates}")
        if "incremental_ratio" in c:
            line += f" inc_ratio={c['incremental_ratio']:.2f}"
        print(line)
    print(f"min_speedup={report['min_speedup']:.1f}x "
          f"min_batched_vs_scalar="
          f"{report['min_speedup_batched_vs_scalar']:.1f}x -> {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
