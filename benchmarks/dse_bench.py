"""Fast-DSE engine benchmark: wall-clock + phase-call counts, fast vs brute.

Measures the three-step DSE (Sec. V-A) on the workloads the repo's quickstarts
lead with — ``explore(zoo.resnet50(256))``, ``explore(zoo.vit(224))``,
``explore_multi([resnet50, vit])`` and a qwen3 decode ``explore`` — once with
the default fast engine (config-independent ``analyze`` shared across all
Step-1 configs, lazy codegen, pruned Step-2 composition, O(n log n) Pareto)
and once with ``engine="reference"`` (the pre-caching engine: full recompile
including eager instruction codegen per config, unpruned composition, O(n²)
Pareto). For every case it records:

  * wall-clock seconds for both engines and the speedup,
  * the ``repro.compiler.STATS`` phase-call counters for both engines
    (fuse/profile/weight-schedule/partition/memory-plan/codegen calls),
  * an equivalence bit: frontiers and DP-A/B/C (or the joint frontier and
    the ``balanced`` point) compare equal between the engines.

The JSON artifact (``BENCH_dse.json``) seeds the perf trajectory; CI runs
``--ci`` (reduced model sizes) and **gates on the call counts and the
equivalence bit** — zero codegen during exploration, exactly one analysis
per distinct graph — while wall-clock numbers stay advisory so runner jitter
cannot flake the build::

    PYTHONPATH=src python benchmarks/dse_bench.py --ci --out BENCH_dse.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.compiler import STATS, clear_analysis_cache, zoo
from repro.dse import explore, explore_multi


def _timed(fn):
    clear_analysis_cache()
    STATS.reset()
    t0 = time.perf_counter()
    res = fn()
    wall = time.perf_counter() - t0
    return res, wall, STATS.snapshot()


def _single_case(name: str, graph_fn, n_graphs: int = 1) -> dict:
    g = graph_fn()
    fast, t_fast, c_fast = _timed(lambda: explore(g))
    ref, t_ref, c_ref = _timed(lambda: explore(g, engine="reference"))
    equal = (
        fast.single == ref.single
        and fast.single_frontier == ref.single_frontier
        and fast.multi_frontier == ref.multi_frontier
        and fast.dp_a == ref.dp_a
        and fast.dp_b == ref.dp_b
        and fast.dp_c == ref.dp_c
    )
    return _report(name, n_graphs, t_fast, c_fast, t_ref, c_ref, equal,
                   extra={"n_single": len(fast.single),
                          "n_multi_fast": len(fast.multi),
                          "n_multi_ref": len(ref.multi)})


def _multi_case(name: str, graphs_fn, n_graphs: int) -> dict:
    graphs = graphs_fn()
    fast, t_fast, c_fast = _timed(lambda: explore_multi(graphs))
    ref, t_ref, c_ref = _timed(lambda: explore_multi(graphs, engine="reference"))
    equal = fast.frontier == ref.frontier and fast.balanced == ref.balanced
    return _report(name, n_graphs, t_fast, c_fast, t_ref, c_ref, equal,
                   extra={"n_points_fast": len(fast.points),
                          "n_points_ref": len(ref.points),
                          "n_frontier": len(fast.frontier)})


def _report(name, n_graphs, t_fast, c_fast, t_ref, c_ref, equal, extra) -> dict:
    return {
        "name": name,
        "wall_fast_s": t_fast,
        "wall_ref_s": t_ref,
        "speedup": t_ref / t_fast if t_fast else float("inf"),
        "counts_fast": c_fast,
        "counts_ref": c_ref,
        "equal": equal,
        # the CI gates: the fast engine generated zero instructions and ran
        # one analysis (fuse+profile) per distinct graph; the reference
        # engine shows what was saved.
        "gate_zero_codegen": c_fast["codegen_calls"] == 0
        and c_fast["memory_plan_calls"] == 0,
        "gate_one_analysis_per_graph": c_fast["analysis_misses"] == n_graphs
        and c_fast["fuse_calls"] == n_graphs
        and c_fast["profile_calls"] == n_graphs,
        "gate_equal": equal,
        **extra,
    }


def full_cases() -> list[dict]:
    return [
        _single_case("explore.resnet50_256", lambda: zoo.resnet50(256)),
        _single_case("explore.vit_224", lambda: zoo.vit(224)),
        _multi_case("explore_multi.resnet50+vit",
                    lambda: [zoo.resnet50(256), zoo.vit(224)], n_graphs=2),
        _single_case(
            "explore.qwen3_decode_s256_t64",
            lambda: zoo.transformer_decoder("qwen3-0.6b", seq_len=256,
                                            decode_steps=64, depth=4)),
    ]


def ci_cases() -> list[dict]:
    """Reduced sizes (same frontends, same gates) so the CI step stays in
    seconds: the call-count gates are size-independent."""
    return [
        _single_case("explore.tiny_cnn",
                     lambda: zoo.tiny_cnn(channels=(16, 32, 32), hw=16)),
        _single_case(
            "explore.qwen3_enc1_s64",
            lambda: zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1)),
        _single_case(
            "explore.qwen3_dec_s64_t8",
            lambda: zoo.transformer_decoder("qwen3-0.6b", seq_len=64,
                                            decode_steps=8, depth=4)),
        _multi_case(
            "explore_multi.tiny_cnn+qwen3_enc",
            lambda: [zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
                     zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1)],
            n_graphs=2),
    ]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="reduced sizes; exit nonzero on call-count or "
                         "equivalence gate failures (wall-clock advisory)")
    ap.add_argument("--out", default="BENCH_dse.json",
                    help="artifact path")
    args = ap.parse_args()

    cases = ci_cases() if args.ci else full_cases()
    ok = all(c["gate_zero_codegen"] and c["gate_one_analysis_per_graph"]
             and c["gate_equal"] for c in cases)
    report = {
        "mode": "ci" if args.ci else "full",
        "cases": cases,
        "min_speedup": min(c["speedup"] for c in cases),
        "ok": ok,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for c in cases:
        gates = "ok" if (c["gate_zero_codegen"]
                         and c["gate_one_analysis_per_graph"]
                         and c["gate_equal"]) else "FAIL"
        print(f"{c['name']:34s} fast={c['wall_fast_s']:7.3f}s "
              f"ref={c['wall_ref_s']:7.3f}s speedup={c['speedup']:5.1f}x "
              f"codegen={c['counts_fast']['codegen_calls']} "
              f"equal={int(c['equal'])} {gates}")
    print(f"min_speedup={report['min_speedup']:.1f}x -> {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
