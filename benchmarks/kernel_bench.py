"""Pallas kernel structural benchmarks (no TPU in this container — metrics
are derived from the kernel's tiling, per the dry-run profiling approach):

  * VMEM working set per grid step (must fit ~16 MiB v5e VMEM),
  * arithmetic intensity of the block (FLOPs / HBM bytes moved),
  * MXU alignment of the contraction/lane dims (multiples of 128),
  * what the kernel buys vs the XLA-lowered reference.
"""
from __future__ import annotations

VMEM_LIMIT = 16 * 2**20


def _row(name: str, vmem: int, flops: float, hbm: float, aligned: bool,
         note: str) -> str:
    ai = flops / hbm if hbm else 0.0
    return (
        f"kernel.{name},,vmem_kib={vmem//1024};fits={int(vmem < VMEM_LIMIT)};"
        f"arith_intensity={ai:.1f};mxu_aligned={int(aligned)};{note}"
    )


def run() -> list[str]:
    rows = []

    # flash attention: block (bq=128, bk=128), hd up to 256
    for hd in (64, 128, 256):
        bq = bk = 128
        vmem = 4 * (bq * hd + 2 * bk * hd + bq * bk) + 4 * (2 * bq + bq * hd)
        flops = 2 * bq * bk * hd * 2  # qk + pv
        hbm = 2 * (bq * hd + 2 * bk * hd + bq * hd)  # bf16 in/out per step
        rows.append(_row(
            f"flash_attention_hd{hd}", vmem, flops, hbm,
            aligned=(bq % 128 == 0 and bk % 128 == 0),
            note="ref_materializes=score_tile_in_hbm",
        ))

    # gemm int8: (128,128,512) tiles
    bm, bn, bk = 128, 128, 512
    vmem = bm * bk + bk * bn + 4 * bm * bn + 4 * bn
    flops = 2 * bm * bn * bk
    hbm = bm * bk + bk * bn + bm * bn
    rows.append(_row("gemm_int8_128x128x512", vmem, flops, hbm,
                     aligned=True, note="epilogue=bias+po2shift+residual+relu"))

    # ssd scan: chunk 128, N=64, P=64..128
    for P in (64, 128):
        ch, N = 128, 64
        vmem = 4 * (ch * P + 2 * ch * N + ch * ch + N * P + ch)
        flops = 2 * ch * ch * N + 2 * ch * ch * P + 2 * ch * N * P * 2
        hbm = 4 * (ch * P + 2 * ch * N + ch * P)
        rows.append(_row(f"ssd_scan_P{P}", vmem, flops, hbm, aligned=(P % 64 == 0),
                         note="L_matrix=vmem_only(ref_puts_it_in_hbm)"))

    # rwkv6: chunk 64, P=64
    ch, P = 64, 64
    vmem = 4 * (4 * ch * P + P * P + P)
    flops = ch * (2 * P * P * 3)
    hbm = 4 * (4 * ch * P + ch * P)
    rows.append(_row("rwkv6_chunk64", vmem, flops, hbm, aligned=True,
                     note="state_resident_across_chunks"))
    return rows
