"""TPU-target DSE (the paper's Fig. 5 recipe over chip deployments):
enumerate (stages x replicas x tensor) factorizations of a 256-chip pod per
architecture, Pareto-filter, and report the paper's three canonical points
(pure pipeline / best hybrid / pure batch)."""
from __future__ import annotations

from repro.configs import get_config
from repro.dse.tpu_deploy import explore_tpu

ARCHS = ["qwen3-0.6b", "h2o-danube-3-4b", "starcoder2-15b", "internvl2-76b"]


def run() -> list[str]:
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        points, frontier = explore_tpu(cfg, chips=256)
        best = max(points, key=lambda p: p.throughput)
        pure_pipe = max((p for p in points if p.replicas == 1),
                        key=lambda p: p.throughput)
        pure_batch = max((p for p in points if p.stages == 1),
                         key=lambda p: p.throughput)
        rows.append(
            f"tpu_dse.{arch},,deployments={len(points)};frontier={len(frontier)};"
            f"best={best.label}:{best.throughput:.0f}seq_s;"
            f"pure_pipeline={pure_pipe.label}:{pure_pipe.throughput:.0f};"
            f"pure_batch={pure_batch.label}:{pure_batch.throughput:.0f};"
            f"hybrid_gain_vs_pipeline={best.throughput/pure_pipe.throughput:.2f}x"
        )
    return rows
