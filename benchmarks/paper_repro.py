"""Paper-reproduction benchmarks: one function per table/figure.

  fig2c_isu_latency    -- Fig. 2(c): PU-to-PU control-token latency matrix
  fig3_two_pu_pipeline -- Fig. 3: balanced / consumer-limited / producer-
                          limited pipeline cases on the simulator
  fig6a_single_batch   -- Fig. 6(a): 35 single-batch configs + Pareto front
  fig6b_multi_batch    -- Fig. 6(b): hybrid multi-batch schedules + DP-A/B/C
  table3_comparison    -- Table III: our design points vs prior accelerators
  simulated_design_points -- DP-A/B/C executed on the discrete-event
                          simulator (not just the analytic model)
  transformer_point    -- beyond the paper: the transformer frontend
                          (ViT-Base + qwen3 encoder stack) through the same
                          DSE, with compute efficiency and one simulated run
  multi_tenant_point   -- beyond the paper: FPGA-virtualization-style
                          multi-tenancy — ResNet-50 + ViT co-explored, the
                          max-min-fair split deployed, and a mid-session
                          switch from single-tenant DP-A to the two-tenant
                          deployment with no reconfiguration
  decode_point         -- beyond the paper: autoregressive decode serving —
                          the qwen3 decode graph (growing K/V caches via the
                          AddrLen/CYCLE_LEN length-advance instructions)
                          through the same DSE, one full decode window
                          simulated, and a prefill->decode hot swap

Run as a script for the CI conformance smoke::

    PYTHONPATH=src python benchmarks/paper_repro.py --ci --out BENCH_ci.json

``--ci`` executes a tiny fixed set of deployments (CNN, prefill transformer,
decode transformer), records per-point analytic-vs-simulated prediction
error into a JSON artifact, and exits nonzero if any point exceeds its
conformance tolerance.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.compiler import zoo
from repro.core import Group, MultiPUSimulator, latency_matrix, make_u50_system
from repro.core.demo import GemmShape, build_two_pu_pipeline
from repro.deploy import SLO, Strategy, System, compile_deployment
from repro.serve import Request, Server
from repro.dse import explore, explore_multi

GOPS_224EQ_PER_FRAME = 7.72  # canonical ResNet-50 GOPs (224x224, Table III)
SYSTEM_PEAK_TOPS = 4.608


def _gopf(g) -> float:
    return 2 * g.total_macs() / 1e9


def fig2c_isu_latency() -> list[str]:
    pus = make_u50_system()
    mat = latency_matrix(pus)
    rows = ["fig2c.header," + ",".join(f"PU{p.pid}" for p in pus)]
    for p, row in zip(pus, mat):
        rows.append(f"fig2c.PU{p.pid}," + ",".join(str(c) for c in row))
    same_slr = [mat[i][j] for i in range(10) for j in range(10)
                if i != j and pus[i].slr == pus[j].slr]
    cross = [mat[i][j] for i in range(10) for j in range(10) if pus[i].slr != pus[j].slr]
    rows.append(f"fig2c.summary,same_pu=2,same_slr={min(same_slr)}-{max(same_slr)},"
                f"cross_slr={min(cross)}-{max(cross)}")
    return rows


def fig3_two_pu_pipeline() -> list[str]:
    shape = GemmShape(m=64, n=1024, k=576)
    big = GemmShape(m=64, n=2048, k=576)
    cases = {
        "case1_balanced": (0, 1, shape, shape),
        "case2_consumer_limited": (0, 1, shape, big),
        "case3_producer_limited": (0, 1, big, shape),
        "heterogeneous_1x_2x": (0, 5, shape, big),
    }
    rows = []
    for name, (pa, pb, sa, sb) in cases.items():
        sim = MultiPUSimulator()
        t0 = time.perf_counter()
        res = sim.run(build_two_pu_pipeline(pa, pb, sa, sb, rounds=12))
        wall_us = (time.perf_counter() - t0) * 1e6
        fps = res.throughput_fps(warmup=3)
        st_wait = res.pu_stats[pa][Group.ST].sync_wait / res.end_cycles
        ld_wait = res.pu_stats[pb][Group.LD].sync_wait / res.end_cycles
        rows.append(
            f"fig3.{name},{wall_us:.0f},fps={fps:.1f};tokens={res.tokens_sent};"
            f"prod_st_wait={st_wait:.2f};cons_ld_wait={ld_wait:.2f}"
        )
    return rows


def fig6a_single_batch(dse=None) -> list[str]:
    g = zoo.resnet50(256)
    dse = dse or explore(g)
    gopf = _gopf(g)
    frontier = {p.config for p in dse.single_frontier}
    rows = []
    for p in sorted(dse.single, key=lambda p: (p.a, p.b)):
        rows.append(
            f"fig6a.cfg_{p.a}_{p.b},,fps224eq={p.fps * gopf / GOPS_224EQ_PER_FRAME:.1f};"
            f"latency_ms={p.latency*1e3:.2f};tops={p.tops:.3f};pbe={p.pbe:.3f};"
            f"pareto={int(p.config in frontier)}"
        )
    return rows


def fig6b_multi_batch(dse=None) -> list[str]:
    g = zoo.resnet50(256)
    dse = dse or explore(g, tolerance=0.01)
    gopf = _gopf(g)
    rows = [f"fig6b.schedules,,count={len(dse.multi)};frontier={len(dse.multi_frontier)}"]
    for name, dp in (("DP-A", dse.dp_a), ("DP-B", dse.dp_b), ("DP-C", dse.dp_c)):
        gops = dp.throughput * gopf
        rows.append(
            f"fig6b.{name},,batch={dp.batch};thr_fps224eq={gops / GOPS_224EQ_PER_FRAME:.1f};"
            f"latency_ms={dp.latency*1e3:.2f};gops={gops:.0f};ce={gops/ (SYSTEM_PEAK_TOPS*1e3):.3f};"
            f"configs={'+'.join(f'{a}x1_{b}x2' for a, b in dp.configs)}"
        )
    return rows


# Table III prior-work rows (FPS/TOPS and GOPS/W taken from the paper) for
# the ratio claims: 1.0-2.7x FPS/TOPS, CE 1.0-1.9x.
PRIOR_WORKS = {
    "DPU_XCU50": dict(fps_per_tops=77.7, ce=0.598),
    "ShortcutFuse": dict(fps_per_tops=47.7, ce=0.561),
    "FullStack": dict(fps_per_tops=120.4, ce=0.927),
    "Rotated": dict(fps_per_tops=94.6, ce=0.732),
    "xDNN": dict(fps_per_tops=65.2, ce=0.502),
    "UnifiedAcc": dict(fps_per_tops=93.0, ce=0.720),
    "Amoeba": dict(fps_per_tops=87.2, ce=0.699),
    "DCP": dict(fps_per_tops=126.9, ce=0.977),
}


def table3_comparison(dse=None) -> list[str]:
    g = zoo.resnet50(256)
    dse = dse or explore(g)
    gopf = _gopf(g)
    rows = []
    points = {
        "DP-A": (dse.dp_a.fps, dse.dp_a.latency, 1),
        "DP-B": (dse.dp_b.throughput, dse.dp_b.latency, dse.dp_b.batch),
        "DP-C": (dse.dp_c.throughput, dse.dp_c.latency, dse.dp_c.batch),
    }
    for name, (thr, lat, batch) in points.items():
        gops = thr * gopf
        fps224 = gops / GOPS_224EQ_PER_FRAME
        fps_per_tops = fps224 / SYSTEM_PEAK_TOPS
        ce = gops / (SYSTEM_PEAK_TOPS * 1e3)
        gops_per_dsp = gops / 3860.0
        rows.append(
            f"table3.{name},,batch={batch};latency_ms={lat*1e3:.2f};fps={fps224:.1f};"
            f"gops={gops:.0f};ce={ce:.3f};gops_per_dsp={gops_per_dsp:.2f};"
            f"fps_per_tops={fps_per_tops:.1f}"
        )
    # headline ratios for DP-B (the paper's focus configuration)
    thr, _, _ = points["DP-B"]
    fps_per_tops_b = thr * gopf / GOPS_224EQ_PER_FRAME / SYSTEM_PEAK_TOPS
    ce_b = thr * gopf / (SYSTEM_PEAK_TOPS * 1e3)
    r_min = min(fps_per_tops_b / w["fps_per_tops"] for w in PRIOR_WORKS.values())
    r_max = max(fps_per_tops_b / w["fps_per_tops"] for w in PRIOR_WORKS.values())
    c_min = min(ce_b / w["ce"] for w in PRIOR_WORKS.values())
    c_max = max(ce_b / w["ce"] for w in PRIOR_WORKS.values())
    rows.append(
        f"table3.ratios_DPB,,fps_per_tops_gain={r_min:.2f}x-{r_max:.2f}x;"
        f"ce_gain={c_min:.2f}x-{c_max:.2f}x (paper: 1.0x-2.7x, 1.0x-1.9x)"
    )
    return rows


def simulated_design_points(dse=None) -> list[str]:
    """Execute DP-A / DP-B / DP-C on one System session: each DSE design
    point compiles to a Deployment (disjoint PUs + HBM channel pools handled
    by the deploy layer) and the strategies are hot-swapped on the same
    fixed machine — the paper's runtime switching, measured."""
    g = zoo.resnet50(256)
    gopf = _gopf(g)
    dse = dse or explore(g)
    system = System()
    rows = []
    measured: dict[str, float] = {}

    plan = [
        ("DP-A_pipeline_all", dse.dp_a, 6),
        ("DP-B_hybrid", dse.dp_b, 5),
        ("DP-C_10_independent", dse.dp_c, 5),
    ]
    for label, point, rounds in plan:
        dep = dse.deploy(point, rounds=rounds)
        system.load(dep) if system.deployment is None else system.switch(dep)
        t0 = time.perf_counter()
        res = system.run()
        wall_us = (time.perf_counter() - t0) * 1e6
        fps = res.aggregate_fps(warmup=2)
        gops = fps * gopf
        measured[label] = fps
        rows.append(
            f"sim.{label},{wall_us:.0f},batch={dep.batch};"
            f"fps224eq={gops/GOPS_224EQ_PER_FRAME:.1f};gops={gops:.0f};"
            f"ce={gops/(SYSTEM_PEAK_TOPS*1e3):.3f};"
            f"latency_ms={res.member_latency_seconds()*1e3:.2f};"
            f"pred_err={abs(fps - dep.predicted_throughput)/dep.predicted_throughput:.3f};"
            f"deadlock={int(res.deadlocked)}"
        )

    # The switching story in one row: DP-A -> DP-C mid-session, both rates
    # measured on the unchanged PU array.
    rows.append(
        "sim.switch_DPA_to_DPC,,"
        f"fps224eq_before={measured['DP-A_pipeline_all'] * gopf / GOPS_224EQ_PER_FRAME:.1f};"
        f"fps224eq_after={measured['DP-C_10_independent'] * gopf / GOPS_224EQ_PER_FRAME:.1f};"
        f"loads={len(system.history)};reconfigured=0"
    )
    return rows


def transformer_point() -> list[str]:
    """The instruction compiler's transformer frontend on the same machine:
    ViT-Base/16 at 224 (the vision analogue of ResNet-50) and a qwen3-0.6b
    encoder stack, each through the full DSE. Reports analytic compute
    efficiency for DP-A/B/C plus one simulated DP-A deployment per graph as
    the conformance anchor."""
    rows = []
    graphs = [
        ("vit_base_224", zoo.vit(224)),
        ("qwen3_enc4_s256", zoo.transformer_encoder("qwen3-0.6b",
                                                    seq_len=256, depth=4)),
    ]
    for gname, g in graphs:
        gopf = _gopf(g)
        dse = explore(g)
        for name, dp in (("DP-A", dse.dp_a), ("DP-B", dse.dp_b), ("DP-C", dse.dp_c)):
            thr = dp.throughput
            gops = thr * gopf
            rows.append(
                f"transformer.{gname}.{name},,batch={dp.batch};"
                f"fps={thr:.1f};gops={gops:.0f};"
                f"ce={gops / (SYSTEM_PEAK_TOPS * 1e3):.3f};"
                f"latency_ms={dp.latency*1e3:.2f}"
            )
        dep = dse.deploy(dse.dp_a, rounds=5)
        t0 = time.perf_counter()
        sim = System().load(dep).run()
        wall_us = (time.perf_counter() - t0) * 1e6
        fps = sim.aggregate_fps(warmup=2)
        rows.append(
            f"transformer.{gname}.sim_DP-A,{wall_us:.0f},fps={fps:.1f};"
            f"ce={fps * gopf / (SYSTEM_PEAK_TOPS * 1e3):.3f};"
            f"pred_err={abs(fps - dep.predicted_throughput) / dep.predicted_throughput:.3f};"
            f"deadlock={int(sim.deadlocked)}"
        )
    return rows


def multi_tenant_point() -> list[str]:
    """Different models for different tenants on one fixed machine: ResNet-50
    and ViT-Base/16 co-explored (`explore_multi`), the max-min-fair joint
    placement compiled as a two-tenant deployment on disjoint PU/HBM slices,
    and a running single-tenant DP-A session hot-swapped to it — new
    instruction programs only, no reconfiguration."""
    g_res, g_vit = zoo.resnet50(256), zoo.vit(224)
    res = explore_multi([g_res, g_vit])
    pick = res.balanced
    rows = [f"mt.joint_space,,points={len(res.points)};pareto={len(res.frontier)}"]
    for i, g in enumerate((g_res, g_vit)):
        a, b = pick.configs[i]
        rows.append(
            f"mt.tenant_{g.name},,config={a}x1_{b}x2;fps={pick.fps[i]:.1f};"
            f"solo_frac={pick.fps[i] / res.best_solo_fps(i):.3f};"
            f"latency_ms={pick.latency[i]*1e3:.2f}"
        )

    system = System()
    best_solo = max(res.singles[0], key=lambda p: p.fps)
    sim_solo = system.load(
        compile_deployment(g_res, Strategy.single(*best_solo.config),
                           rounds=5)).run()
    dep = res.deploy(pick, rounds=4)
    t0 = time.perf_counter()
    sim = system.switch(dep).run()  # same PU array, two tenants now
    wall_us = (time.perf_counter() - t0) * 1e6
    errs = [
        abs(m.throughput_fps(warmup=2) - f) / f
        for m, f in zip(sim.members, pick.fps)
    ]
    tenant_rates = ";".join(
        f"{label}={fps:.1f}" for label, fps in sim.fps_by_workload(warmup=2).items())
    rows.append(
        f"mt.switch_single_to_two_tenant,{wall_us:.0f},"
        f"fps_before={sim_solo.aggregate_fps(warmup=2):.1f};{tenant_rates};"
        f"max_pred_err={max(errs):.3f};deadlock={int(sim.deadlocked)};"
        f"loads={len(system.history)};reconfigured=0"
    )
    return rows


def decode_point() -> list[str]:
    """Autoregressive decode serving on the same machine: the qwen3 decode
    graph (one token per round, K/V caches growing via CYCLE_LEN) through
    the full DSE, DP-A simulated over one complete decode window, and the
    prefill->decode hot swap measured on one fixed PU array."""
    seq, steps, depth = 256, 64, 4
    prefill = zoo.transformer_encoder("qwen3-0.6b", seq_len=seq, depth=depth)
    decode = zoo.transformer_decoder("qwen3-0.6b", seq_len=seq,
                                     decode_steps=steps, depth=depth)
    dse = explore(decode)
    rows = []
    for name, dp in (("DP-A", dse.dp_a), ("DP-B", dse.dp_b), ("DP-C", dse.dp_c)):
        rows.append(
            f"decode.{decode.name}.{name},,batch={dp.batch};"
            f"tok_s={dp.throughput:.1f};latency_ms={dp.latency*1e3:.3f}"
        )

    system = System()
    sim_pre = system.load(compile_deployment(prefill, Strategy.single(2, 2),
                                             rounds=4)).run()
    dep = dse.deploy(dse.dp_a)  # rounds default to the decode window
    t0 = time.perf_counter()
    sim = system.switch(dep).run()
    wall_us = (time.perf_counter() - t0) * 1e6
    tok_s = sim.aggregate_fps(warmup=2)
    rows.append(
        f"decode.switch_prefill_to_decode,{wall_us:.0f},"
        f"prefill_seq_s={sim_pre.aggregate_fps(warmup=2):.1f};"
        f"decode_tok_s={tok_s:.1f};steps={sim.members[0].rounds};"
        f"pred_err={abs(tok_s - dep.predicted_throughput)/dep.predicted_throughput:.3f};"
        f"deadlock={int(sim.deadlocked)};loads={len(system.history)};reconfigured=0"
    )
    return rows


def serving_point() -> list[str]:
    """Online serving control plane: two tenants with different SLOs share
    one machine, their decode sessions continuously batched into slot-packed
    members (per-slot AddrLen streams); a third tenant joins mid-service,
    triggering an incremental re-placement and a hot swap. Reported through
    the unified :class:`repro.deploy.RunReport` schema."""
    srv = Server()
    srv.join("chat", depth=2, max_slots=2, window=8,
             slo=SLO(min_tokens_per_s=100.0, priority=1))
    srv.join("batch", depth=2, max_slots=2, window=8)
    for p, n in ((128, 24), (64, 16), (96, 32)):
        srv.submit(Request("chat", prompt_tokens=p, max_new_tokens=n))
    for p, n in ((256, 32), (192, 16)):
        srv.submit(Request("batch", prompt_tokens=p, max_new_tokens=n))
    srv.step()  # serve one window before the third tenant arrives
    srv.join("burst", depth=2, max_slots=1, window=8)
    srv.submit(Request("burst", prompt_tokens=32, max_new_tokens=16,
                       arrival_s=srv.now))
    t0 = time.perf_counter()
    rep = srv.drain()
    wall_us = (time.perf_counter() - t0) * 1e6
    rows = []
    for name, t in sorted(rep.tenants.items()):
        attain = ("" if t.slo_attainment is None
                  else f";slo_attain={t.slo_attainment:.2f}")
        rows.append(
            f"serve.tenant_{name},,tok_s={t.token_rate:.1f};"
            f"tokens={t.tokens};p50_ms={t.latency_p50 * 1e3:.2f};"
            f"p95_ms={t.latency_p95 * 1e3:.2f}{attain}"
        )
    kinds = [e.kind for e in srv.events]
    completed = sum(r.completed for r in srv.requests)
    rows.append(
        f"serve.control_plane,{wall_us:.0f},"
        f"windows={srv.windows};swaps={kinds.count('swap')};"
        f"replans={kinds.count('replan')};evictions={kinds.count('evict')};"
        f"completed={completed}/{len(srv.requests)};"
        f"tokens={rep.total_tokens};wall_s={rep.wall_s:.4f}"
    )
    return rows


def run() -> list[str]:
    out = []
    g = zoo.resnet50(256)
    dse = explore(g, tolerance=0.01)
    out += fig2c_isu_latency()
    out += fig3_two_pu_pipeline()
    out += fig6a_single_batch(dse)
    out += fig6b_multi_batch(dse)
    out += table3_comparison(dse)
    out += simulated_design_points(dse)
    out += transformer_point()
    out += multi_tenant_point()
    out += decode_point()
    out += serving_point()
    return out


# ----------------------------------------------------------- CI conformance --
def ci_points() -> list[dict]:
    """Tiny fixed deployments spanning the three frontends (CNN, prefill
    transformer, decode transformer), each simulated on a fresh System and
    scored as analytic-vs-simulated relative error against the same fixed
    tolerances the conformance tests lock in (tests/test_deploy.py)."""
    from repro.configs import get_config

    dp_c = Strategy.multi([(1, 0)] * 5 + [(0, 1)] * 5)
    plan = [
        # (point name, graph, strategy, rounds override, tolerance)
        ("tiny_cnn.dp_a", zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
         Strategy.single(5, 5), 6, 0.08),
        ("tiny_cnn.dp_c", zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
         dp_c, 5, 0.03),
        # fixed (2,2)+(3,3) hybrid (not the explore-selected DP-B, which the
        # conformance tests lock at 4.5%): observed 5.1%, guarded at 6%
        ("tiny_cnn.hybrid", zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
         Strategy.multi([(2, 2), (3, 3)]), 5, 0.06),
        ("qwen3_enc.dp_a", zoo.transformer_encoder("qwen3-0.6b", seq_len=64,
                                                   depth=1),
         Strategy.single(2, 2), 5, 0.08),
        # decode points tightened 10% -> 5% with the pipeline coupling model
        # (residual serialization, HBM port contention, credit-loop bound)
        ("qwen3_dec.dp_a", zoo.transformer_decoder("qwen3-0.6b", seq_len=64,
                                                   decode_steps=8, depth=4),
         Strategy.single(5, 5), None, 0.05),
        ("qwen3_dec_reduced.dp_c",
         zoo.transformer_decoder(get_config("qwen3-0.6b").reduced(),
                                 seq_len=64, decode_steps=8, depth=4),
         dp_c, None, 0.05),
        # slot-packed decode: two sessions at different cache depths share
        # one member via per-slot AddrLen streams (continuous batching)
        ("qwen3_dec_packed.2slot",
         zoo.transformer_decoder("qwen3-0.6b", slots=(64, 32),
                                 decode_steps=8, depth=1),
         Strategy.single(2, 2), None, 0.05),
        # ten single-node tiny stages: the credit loop binds here — the
        # uncoupled model used to run 15-20% hot on this shape
        ("deep_chain.dp_a", zoo.linear_chain(10, ch=8, hw=8),
         Strategy.single(5, 5), 10, 0.03),
    ]
    points = []
    for name, g, strategy, rounds, tol in plan:
        dep = compile_deployment(g, strategy, rounds=rounds)
        t0 = time.perf_counter()
        sim = System().load(dep).run()
        wall_s = time.perf_counter() - t0
        meas = sim.aggregate_fps(warmup=2)
        pred = dep.predicted_throughput
        err = abs(meas - pred) / pred if pred else float("inf")
        points.append({
            "name": name,
            "graph": g.name,
            "batch": dep.batch,
            "analytic_fps": pred,
            "simulated_fps": meas,
            "rel_err": err,
            "tolerance": tol,
            "deadlocked": sim.deadlocked,
            "ok": (not sim.deadlocked) and err <= tol,
            "sim_wall_s": wall_s,
        })
    return points


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ci", action="store_true",
                    help="tiny conformance smoke: JSON artifact + pass/fail")
    ap.add_argument("--out", default="BENCH_ci.json",
                    help="artifact path for --ci mode")
    args = ap.parse_args()

    if not args.ci:
        for row in run():
            print(row)
        return 0

    points = ci_points()
    report = {
        "points": points,
        "max_rel_err": max(p["rel_err"] for p in points),
        "ok": all(p["ok"] for p in points),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for p in points:
        print(f"{p['name']:28s} analytic={p['analytic_fps']:9.1f} "
              f"simulated={p['simulated_fps']:9.1f} err={p['rel_err']:.3f} "
              f"tol={p['tolerance']:.3f} {'ok' if p['ok'] else 'FAIL'}")
    print(f"max_rel_err={report['max_rel_err']:.3f} -> {args.out}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
