"""Benchmark harness: one module per paper table/figure + roofline/kernel
reports. Prints ``name,us_per_call,derived`` CSV lines.

Usage:
    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only fig6 # substring filter
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _modules():
    # imported lazily so a failure in one bench doesn't kill the others
    names = [
        "benchmarks.paper_repro",
        "benchmarks.kernel_bench",
        "benchmarks.roofline_report",
        "benchmarks.tpu_dse",
    ]
    for name in names:
        try:
            __import__(name)
            yield name, sys.modules[name]
        except Exception:
            print(f"{name},ERROR,import_failed")
            traceback.print_exc()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on row names")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for name, mod in _modules():
        t0 = time.perf_counter()
        try:
            rows = mod.run()
        except Exception:
            print(f"{name},ERROR,run_failed")
            traceback.print_exc()
            continue
        for row in rows:
            if args.only and args.only not in row:
                continue
            print(row)
        dt = time.perf_counter() - t0
        print(f"{name}.total,{dt*1e6:.0f},ok")


if __name__ == "__main__":
    main()
