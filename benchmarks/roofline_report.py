"""Roofline table from the dry-run baseline (EXPERIMENTS.md section Roofline).

Reads dryrun_baseline.json (written by repro.launch.dryrun --out) and prints
the three per-chip roofline terms, the dominant bottleneck, and the
MODEL_FLOPS / HLO_FLOPS "useful compute" ratio per (arch x shape x mesh).
Falls back to a hint row if the dry-run artifact is absent.
"""
from __future__ import annotations

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
# prefer the post-§Perf artifacts; newest single-pod sweep overlays the
# both-mesh run; fall back to the baseline
CANDIDATES = [os.path.join(ROOT, "dryrun_optimized.json"),
              os.path.join(ROOT, "dryrun_baseline.json")]
BASELINE = next((c for c in CANDIDATES if os.path.exists(c)), CANDIDATES[-1])
OVERLAY = os.path.join(ROOT, "dryrun_optimized_sp.json")


def _load_cells() -> list:
    with open(BASELINE) as f:
        cells = json.load(f)
    if os.path.exists(OVERLAY):
        with open(OVERLAY) as f:
            over = {(c["mesh"], c["arch"], c["shape"]): c for c in json.load(f)}
        cells = [over.get((c["mesh"], c["arch"], c["shape"]), c) for c in cells]
    return cells


def run() -> list[str]:
    if not os.path.exists(BASELINE):
        return ["roofline.missing,,run `python -m repro.launch.dryrun --arch all "
                "--both-meshes --out dryrun_baseline.json` first"]
    cells = _load_cells()
    rows = []
    for c in cells:
        key = f"roofline.{c['mesh']}.{c['arch']}.{c['shape']}"
        if c["status"] == "skipped":
            rows.append(f"{key},,SKIPPED({c['reason'][:60]})")
            continue
        if c["status"] == "error":
            rows.append(f"{key},,ERROR({c['reason'][:80]})")
            continue
        rows.append(
            f"{key},{c['compile_s']*1e6:.0f},"
            f"t_compute_ms={c['t_compute']*1e3:.2f};"
            f"t_memory_ms={c['t_memory']*1e3:.2f};"
            f"t_collective_ms={c['t_collective']*1e3:.2f};"
            f"bottleneck={c['bottleneck']};useful={c['useful_ratio']:.2f};"
            f"args_gib={c['arg_bytes']/2**30:.2f};temp_gib={c['temp_bytes']/2**30:.2f}"
        )
    ok = [c for c in cells if c["status"] == "ok"]
    if ok:
        from collections import Counter
        bn = Counter(c["bottleneck"] for c in ok)
        rows.append(
            f"roofline.summary,,cells_ok={len(ok)};bottlenecks={dict(bn)}"
        )
    return rows
