"""Two-PU pipeline coordination (paper Sec. III-C, Fig. 3).

Case-1: balanced producer/consumer -> steady-state overlap, throughput ~=
        1 / t_stage, both CPs near-fully busy.
Case-2: consumer at half throughput -> producer throttled by ACK waits
        (ST -> CP -> LD back-pressure), throughput set by the consumer.
Case-3: producer slower -> consumer stalls in WAIT_REQ; ACKs unnecessary but
        instruction uniformity is maintained (same programs run all cases).
"""
import pytest

from repro.core import Group, MultiPUSimulator
from repro.core.demo import GemmShape, build_two_pu_pipeline
from repro.core.isu import latency_matrix
from repro.core.pu import make_u50_system

ROUNDS = 12
SHAPE = GemmShape(m=64, n=1024, k=576)
SHAPE_HALF = GemmShape(m=64, n=1024, k=288)  # half the compute, same tensors


def run_case(pid_a, pid_b, shape_a, shape_b):
    sim = MultiPUSimulator()
    programs = build_two_pu_pipeline(pid_a, pid_b, shape_a, shape_b, rounds=ROUNDS)
    res = sim.run(programs)
    assert not res.deadlocked
    assert res.rounds == ROUNDS
    return sim, res


def stage_seconds(sim, pid, shape):
    spec = sim.icus[pid].spec
    return spec.gemm_seconds(shape.m, shape.n, shape.k)


class TestBalancedPipeline:
    def test_case1_throughput_matches_stage_time(self):
        # Both PUs are PU1x with identical GEMMs: balanced pipeline.
        sim, res = run_case(0, 1, SHAPE, SHAPE)
        t_stage = stage_seconds(sim, 0, SHAPE)
        fps = res.throughput_fps(warmup=3)
        # steady state: one round per stage time (few % decode/ADM overhead)
        assert fps == pytest.approx(1.0 / t_stage, rel=0.08)

    def test_case1_pipelining_beats_serial(self):
        sim, res = run_case(0, 1, SHAPE, SHAPE)
        t_stage = stage_seconds(sim, 0, SHAPE)
        serial = 2 * t_stage * ROUNDS
        assert res.end_seconds < 0.65 * serial  # ~2x overlap

    def test_case1_latency_spans_stages_plus_prefetch(self):
        """Round latency = 2 pipeline stages + LD prefetch queueing (the
        double-buffered act slots admit ~2 rounds in flight per PU)."""
        sim, res = run_case(0, 1, SHAPE, SHAPE)
        t_stage = stage_seconds(sim, 0, SHAPE)
        lat = res.latency_seconds()
        assert 2 * t_stage <= lat <= 4.5 * t_stage


class TestUnbalancedPipelines:
    def test_case2_consumer_limits_throughput(self):
        # PU_b does 2x the work: producer must throttle to consumer rate.
        big = GemmShape(m=SHAPE.m, n=2 * SHAPE.n, k=SHAPE.k)
        sim, res = run_case(0, 1, SHAPE, big)
        t_slow = stage_seconds(sim, 1, big)
        assert res.throughput_fps(warmup=3) == pytest.approx(1.0 / t_slow, rel=0.08)
        # Producer's ST group spent significant time blocked awaiting ACKs.
        st_a = res.pu_stats[0][Group.ST]
        assert st_a.sync_wait > 0.25 * res.end_cycles

    def test_case2_backpressure_throttles_producer_cp(self):
        big = GemmShape(m=SHAPE.m, n=2 * SHAPE.n, k=SHAPE.k)
        sim, res = run_case(0, 1, SHAPE, big)
        # Producer CP busy fraction ~ 1/2 (it computes half the time).
        assert res.busy_fraction(0) == pytest.approx(0.5, abs=0.12)
        assert res.busy_fraction(1) > 0.85

    def test_case3_producer_limits_throughput(self):
        big = GemmShape(m=SHAPE.m, n=2 * SHAPE.n, k=SHAPE.k)
        sim, res = run_case(0, 1, big, SHAPE)
        t_slow = stage_seconds(sim, 0, big)
        assert res.throughput_fps(warmup=3) == pytest.approx(1.0 / t_slow, rel=0.08)
        # Consumer's LD group waits on REQ (data availability).
        ld_b = res.pu_stats[1][Group.LD]
        assert ld_b.sync_wait > 0.25 * res.end_cycles

    def test_instruction_uniformity_across_cases(self):
        """The same program images drive all three cases (only GEMM dims in
        the Compute instruction differ) — coordination needs no rewrite."""
        progs_bal = build_two_pu_pipeline(0, 1, SHAPE, SHAPE, rounds=ROUNDS)
        progs_unb = build_two_pu_pipeline(0, 1, SHAPE, SHAPE_HALF, rounds=ROUNDS)
        for pa, pb in zip(progs_bal, progs_unb):
            for ga, gb in zip((pa.ld, pa.st), (pb.ld, pb.st)):
                assert ga.encode() == gb.encode()  # LD/ST streams identical


class TestHeterogeneousPUs:
    def test_pu2x_twice_as_fast(self):
        pus = make_u50_system()
        assert pus[5].peak_tops == pytest.approx(2 * pus[0].peak_tops)
        t1 = pus[0].gemm_seconds(64, 1024, 576)
        t2 = pus[5].gemm_seconds(64, 1024, 576)
        assert t1 == pytest.approx(2 * t2, rel=0.01)

    def test_heterogeneous_pipeline_balances_with_2x_split(self):
        """PU1x paired with PU2x balances when the PU2x gets 2x the work."""
        big = GemmShape(m=SHAPE.m, n=2 * SHAPE.n, k=SHAPE.k)
        sim, res = run_case(0, 5, SHAPE, big)  # pid5 = PU2x
        t_a = stage_seconds(sim, 0, SHAPE)
        t_b = stage_seconds(sim, 5, big)
        assert t_a == pytest.approx(t_b, rel=0.01)
        assert res.throughput_fps(warmup=3) == pytest.approx(1.0 / t_a, rel=0.08)
        assert res.busy_fraction(0) > 0.85
        assert res.busy_fraction(5) > 0.85


class TestISUNetwork:
    def test_latency_matrix_ranges(self):
        pus = make_u50_system()
        mat = latency_matrix(pus)
        for i, src in enumerate(pus):
            for j, dst in enumerate(pus):
                lat = mat[i][j]
                if i == j:
                    assert lat == 2  # same-PU delivery bypasses the fabric
                elif src.slr == dst.slr:
                    assert 2 <= lat <= 3  # same-SLR hop
                else:
                    assert 15 <= lat <= 16  # 13-cycle SLR crossing penalty

    def test_token_count_matches_handshakes(self):
        sim, res = run_case(0, 1, SHAPE, SHAPE)
        # per round: 1 REQ + 1 ACK, plus the 2 prologue bypass ACKs.
        assert res.tokens_sent == 2 * ROUNDS + 2

    def test_tokens_negligible_vs_execution(self):
        """Paper claim: tokens complete in sub-us while PU rounds take
        hundreds of us -> contention effects negligible."""
        pus = make_u50_system()
        worst = max(max(row) for row in latency_matrix(pus))
        worst_s = worst / pus[0].sys_clk_hz
        assert worst_s < 1e-6
        t_stage = pus[0].gemm_seconds(SHAPE.m, SHAPE.n, SHAPE.k)
        assert t_stage > 100 * worst_s


class TestSteadyFpsFallback:
    """_steady_fps division fallbacks: completed rounds must never report
    0 fps just because the run-end timestamp is missing."""

    CLK = 300e6

    def test_round_based_estimate_when_end_cycles_zero(self):
        from repro.core.simulator import _steady_fps

        # 3 rounds completed, warmup eats them all, end_cycles never set:
        # fall back to the round-completion stream, not 0.0.
        ends = [100.0, 200.0, 300.0]
        fps = _steady_fps(ends, warmup=3, sys_clk_hz=self.CLK,
                          fallback_rounds=3, end_cycles=0.0)
        assert fps == pytest.approx(3 / (300.0 / self.CLK))

    def test_zero_when_no_rounds(self):
        from repro.core.simulator import _steady_fps

        assert _steady_fps([], warmup=1, sys_clk_hz=self.CLK,
                           fallback_rounds=0, end_cycles=0.0) == 0.0

    def test_zero_when_round_end_is_zero(self):
        from repro.core.simulator import _steady_fps

        # degenerate: a "round" ending at cycle 0 cannot produce a rate
        assert _steady_fps([0.0], warmup=1, sys_clk_hz=self.CLK,
                           fallback_rounds=1, end_cycles=0.0) == 0.0

    def test_end_cycles_fallback_still_used(self):
        from repro.core.simulator import _steady_fps

        fps = _steady_fps([100.0], warmup=1, sys_clk_hz=self.CLK,
                          fallback_rounds=4, end_cycles=600.0)
        assert fps == pytest.approx(4 / (600.0 / self.CLK))

    def test_steady_state_path_unchanged(self):
        from repro.core.simulator import _steady_fps

        ends = [100.0, 200.0, 300.0, 400.0]
        fps = _steady_fps(ends, warmup=1, sys_clk_hz=self.CLK,
                          fallback_rounds=4, end_cycles=400.0)
        assert fps == pytest.approx(3 / ((400.0 - 100.0) / self.CLK))
