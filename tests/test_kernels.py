"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
with shape/dtype sweeps and hypothesis property tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis extra")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.gemm_int8.kernel import gemm_int8_tpu
from repro.kernels.gemm_int8.ref import gemm_int8_reference
from repro.kernels.rwkv6.kernel import wkv6_tpu
from repro.kernels.rwkv6.ref import wkv6_reference
from repro.kernels.ssd_scan.kernel import ssd_scan_tpu
from repro.kernels.ssd_scan.ref import ssd_reference
from repro.models.ssm import ssd_chunked


def rng(*shape, key=0, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# -------------------------------------------------------- flash attention --
class TestFlashAttention:
    @pytest.mark.parametrize("s,H,G,hd", [
        (64, 4, 4, 32),   # MHA
        (64, 8, 2, 32),   # GQA 4:1
        (96, 4, 1, 64),   # MQA, ragged seq vs 32-blocks
        (128, 2, 2, 16),
    ])
    def test_matches_reference_causal(self, s, H, G, hd):
        q = rng(2, s, H, hd, key=1, scale=0.5)
        k = rng(2, s, G, hd, key=2, scale=0.5)
        v = rng(2, s, G, hd, key=3)
        out = flash_attention_tpu(q, k, v, causal=True, block_q=32, block_k=32,
                                  interpret=True)
        ref = mha_reference(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [16, 32, 100])
    def test_sliding_window(self, window):
        s, H, G, hd = 128, 4, 2, 32
        q, k, v = rng(1, s, H, hd, key=4), rng(1, s, G, hd, key=5), rng(1, s, G, hd, key=6)
        out = flash_attention_tpu(q, k, v, causal=True, window=window,
                                  block_q=32, block_k=32, interpret=True)
        ref = mha_reference(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_bf16_inputs(self):
        s, H, G, hd = 64, 4, 2, 32
        q = rng(1, s, H, hd, key=7, dtype=jnp.bfloat16)
        k = rng(1, s, G, hd, key=8, dtype=jnp.bfloat16)
        v = rng(1, s, G, hd, key=9, dtype=jnp.bfloat16)
        out = flash_attention_tpu(q, k, v, block_q=32, block_k=32, interpret=True)
        ref = mha_reference(q, k, v)
        np.testing.assert_allclose(
            out.astype(np.float32), ref.astype(np.float32), rtol=3e-2, atol=3e-2
        )

    @settings(max_examples=8, deadline=None)
    @given(
        s=st.sampled_from([32, 48, 64]),
        rep=st.sampled_from([1, 2, 4]),
        hd=st.sampled_from([16, 32]),
        bq=st.sampled_from([16, 32]),
    )
    def test_property_sweep(self, s, rep, hd, bq):
        G = 2
        q = rng(1, s, G * rep, hd, key=s * rep + hd)
        k = rng(1, s, G, hd, key=s + 1)
        v = rng(1, s, G, hd, key=s + 2)
        out = flash_attention_tpu(q, k, v, block_q=bq, block_k=bq, interpret=True)
        ref = mha_reference(q, k, v)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    def test_rows_sum_to_one_property(self):
        """softmax invariant: with v=ones, attention output must be ~1."""
        s, H, G, hd = 64, 2, 2, 32
        q, k = rng(1, s, H, hd, key=10), rng(1, s, G, hd, key=11)
        v = jnp.ones((1, s, G, hd), jnp.float32)
        out = flash_attention_tpu(q, k, v, block_q=32, block_k=32, interpret=True)
        np.testing.assert_allclose(out, np.ones_like(out), rtol=1e-5)


# -------------------------------------------------------------- gemm int8 --
class TestGemmInt8:
    def _rand_int8(self, *shape, key=0):
        return jax.random.randint(jax.random.PRNGKey(key), shape, -128, 128, jnp.int8)

    @pytest.mark.parametrize("m,n,k", [(64, 64, 64), (128, 128, 256), (100, 72, 300)])
    def test_matches_reference(self, m, n, k):
        a = self._rand_int8(m, k, key=1)
        w = self._rand_int8(k, n, key=2)
        bias = jax.random.randint(jax.random.PRNGKey(3), (n,), -1000, 1000, jnp.int32)
        out = gemm_int8_tpu(a, w, bias, shift=7, bm=32, bn=32, bk=64, interpret=True)
        ref = gemm_int8_reference(a, w, bias, shift=7)
        np.testing.assert_array_equal(out, ref)

    def test_fused_residual_relu(self):
        """The paper's FusedConvAdd(ReLU) epilogue."""
        m, n, k = 64, 64, 128
        a, w = self._rand_int8(m, k, key=4), self._rand_int8(k, n, key=5)
        bias = jnp.zeros((n,), jnp.int32)
        res = self._rand_int8(m, n, key=6)
        out = gemm_int8_tpu(a, w, bias, res, shift=7, relu=True,
                            bm=32, bn=32, bk=64, interpret=True)
        ref = gemm_int8_reference(a, w, bias, shift=7, relu=True, residual=res)
        np.testing.assert_array_equal(out, ref)
        assert int(out.min()) >= 0  # ReLU

    def test_saturation(self):
        a = jnp.full((32, 512), 127, jnp.int8)
        w = jnp.full((512, 32), 127, jnp.int8)
        bias = jnp.zeros((32,), jnp.int32)
        out = gemm_int8_tpu(a, w, bias, shift=0, bm=32, bn=32, bk=128, interpret=True)
        assert int(out.max()) == 127  # saturates instead of wrapping

    @settings(max_examples=6, deadline=None)
    @given(
        m=st.sampled_from([16, 32, 48]),
        k=st.sampled_from([64, 96]),
        shift=st.sampled_from([0, 4, 8]),
        relu=st.booleans(),
    )
    def test_property_sweep(self, m, k, shift, relu):
        a = self._rand_int8(m, k, key=m + k)
        w = self._rand_int8(k, 32, key=k + 1)
        bias = jax.random.randint(jax.random.PRNGKey(7), (32,), -64, 64, jnp.int32)
        out = gemm_int8_tpu(a, w, bias, shift=shift, relu=relu,
                            bm=16, bn=32, bk=32, interpret=True)
        ref = gemm_int8_reference(a, w, bias, shift=shift, relu=relu)
        np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------- ssd scan --
class TestSSDScan:
    def _inputs(self, b=1, s=64, H=2, P=16, N=8, key=0):
        ks = jax.random.split(jax.random.PRNGKey(key), 5)
        xh = jax.random.normal(ks[0], (b, s, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        B = jax.random.normal(ks[3], (b, s, N))
        C = jax.random.normal(ks[4], (b, s, N))
        return xh, dt, A, B, C

    @pytest.mark.parametrize("s,chunk", [(64, 16), (64, 64), (96, 32), (100, 32)])
    def test_kernel_matches_sequential_ref(self, s, chunk):
        xh, dt, A, B, C = self._inputs(s=s)
        y, _ = ssd_scan_tpu(xh, dt, A, B, C, chunk=chunk, interpret=True)
        ref = ssd_reference(xh, dt, A, B, C)
        np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)

    def test_chunked_jnp_matches_sequential_ref(self):
        """models.ssm.ssd_chunked (the XLA fallback) vs the recurrence."""
        xh, dt, A, B, C = self._inputs(s=80, key=1)
        y = ssd_chunked(xh, dt, A, B, C, chunk=32)
        ref = ssd_reference(xh, dt, A, B, C)
        np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)

    def test_final_state_matches(self):
        xh, dt, A, B, C = self._inputs(s=64, key=2)
        _, h = ssd_scan_tpu(xh, dt, A, B, C, chunk=16, interpret=True)
        # state via explicit recurrence
        b, s, H, P = xh.shape
        N = B.shape[-1]
        h_ref = np.zeros((b, H, N, P), np.float32)
        for t in range(s):
            decay = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])
            h_ref = h_ref * decay[:, :, None, None] + np.einsum(
                "bh,bn,bhp->bhnp", np.asarray(dt[:, t]), np.asarray(B[:, t]), np.asarray(xh[:, t])
            )
        np.testing.assert_allclose(h, h_ref, rtol=2e-4, atol=2e-4)

    @settings(max_examples=6, deadline=None)
    @given(s=st.sampled_from([32, 48, 64]), P=st.sampled_from([8, 16]),
           N=st.sampled_from([4, 8]))
    def test_property_sweep(self, s, P, N):
        xh, dt, A, B, C = self._inputs(s=s, P=P, N=N, key=s + P + N)
        y, _ = ssd_scan_tpu(xh, dt, A, B, C, chunk=16, interpret=True)
        ref = ssd_reference(xh, dt, A, B, C)
        np.testing.assert_allclose(y, ref, rtol=3e-4, atol=3e-4)


# ------------------------------------------------------------------- rwkv6 --
class TestWKV6:
    def _inputs(self, b=1, s=48, H=2, P=16, key=0):
        ks = jax.random.split(jax.random.PRNGKey(key), 5)
        r = jax.random.normal(ks[0], (b, s, H, P)) * 0.5
        k = jax.random.normal(ks[1], (b, s, H, P)) * 0.5
        v = jax.random.normal(ks[2], (b, s, H, P))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, H, P)) + 2.0)
        u = jax.random.normal(ks[4], (H, P)) * 0.5
        state = jnp.zeros((b, H, P, P), jnp.float32)
        return r, k, v, w, u, state

    @pytest.mark.parametrize("s,chunk", [(48, 16), (64, 64), (50, 16)])
    def test_kernel_matches_reference(self, s, chunk):
        r, k, v, w, u, state = self._inputs(s=s)
        y, s_out = wkv6_tpu(r, k, v, w, u, state, chunk=chunk, interpret=True)
        y_ref, s_ref = wkv6_reference(r, k, v, w, u, state)
        np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(s_out, s_ref, rtol=2e-4, atol=2e-4)

    def test_nonzero_initial_state(self):
        r, k, v, w, u, _ = self._inputs(s=32, key=3)
        state = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 16, 16))
        y, s_out = wkv6_tpu(r, k, v, w, u, state, chunk=16, interpret=True)
        y_ref, s_ref = wkv6_reference(r, k, v, w, u, state)
        np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(s_out, s_ref, rtol=2e-4, atol=2e-4)

    def test_chunking_invariance(self):
        """Different chunk sizes must give identical results."""
        r, k, v, w, u, state = self._inputs(s=64, key=4)
        y1, s1 = wkv6_tpu(r, k, v, w, u, state, chunk=8, interpret=True)
        y2, s2 = wkv6_tpu(r, k, v, w, u, state, chunk=32, interpret=True)
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)

    @settings(max_examples=5, deadline=None)
    @given(s=st.sampled_from([16, 32, 40]), P=st.sampled_from([8, 16]))
    def test_property_sweep(self, s, P):
        r, k, v, w, u, state = self._inputs(s=s, P=P, key=s + P)
        y, _ = wkv6_tpu(r, k, v, w, u, state, chunk=16, interpret=True)
        y_ref, _ = wkv6_reference(r, k, v, w, u, state)
        np.testing.assert_allclose(y, y_ref, rtol=3e-4, atol=3e-4)


# ------------------------------------------- chunked/banded XLA fallbacks --
class TestChunkedFallbacks:
    """The long-sequence XLA paths (what the dry-run lowers) vs dense oracle."""

    def test_chunked_attention_matches_dense(self):
        from repro.kernels.flash_attention.ref import chunked_attention
        q, k, v = rng(2, 200, 4, 32, key=1), rng(2, 200, 2, 32, key=2), rng(2, 200, 2, 32, key=3)
        ref = mha_reference(q, k, v, causal=True)
        out = chunked_attention(q, k, v, causal=True, block_q=64, block_k=32)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    def test_banded_attention_matches_windowed_dense(self):
        from repro.kernels.flash_attention.ref import banded_attention
        q, k, v = rng(2, 200, 4, 32, key=4), rng(2, 200, 2, 32, key=5), rng(2, 200, 2, 32, key=6)
        for w in (17, 64):
            ref = mha_reference(q, k, v, causal=True, window=w)
            out = banded_attention(q, k, v, window=w, block_q=64)
            np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    def test_wkv6_chunked_matches_sequential(self):
        from repro.kernels.rwkv6.ref import wkv6_chunked
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        b, s, H, P = 2, 100, 3, 16
        r = jax.random.normal(ks[0], (b, s, H, P)) * 0.5
        k = jax.random.normal(ks[1], (b, s, H, P)) * 0.5
        v = jax.random.normal(ks[2], (b, s, H, P))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, H, P)) + 2.0)
        u = jax.random.normal(ks[4], (H, P)) * 0.5
        st = jax.random.normal(jax.random.PRNGKey(9), (b, H, P, P)) * 0.3
        y1, s1 = wkv6_reference(r, k, v, w, u, st)
        for ch in (8, 16, 64):
            y2, s2 = wkv6_chunked(r, k, v, w, u, st, chunk=ch)
            np.testing.assert_allclose(y2, y1, rtol=3e-4, atol=3e-4)
            np.testing.assert_allclose(s2, s1, rtol=3e-4, atol=3e-4)

    def test_wkv6_chunked_strong_decay(self):
        """w ~ 0.05 (log cum ~ -48/chunk): within the documented regime, with
        f32 precision degradation under extreme exponent ranges."""
        from repro.kernels.rwkv6.ref import wkv6_chunked
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        b, s, H, P = 2, 64, 2, 16
        r = jax.random.normal(ks[0], (b, s, H, P)) * 0.5
        k = jax.random.normal(ks[1], (b, s, H, P)) * 0.5
        v = jax.random.normal(ks[2], (b, s, H, P))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, H, P)) - 3.0)
        u = jax.random.normal(ks[4], (H, P)) * 0.5
        st = jnp.zeros((b, H, P, P))
        y1, _ = wkv6_reference(r, k, v, w, u, st)
        y2, _ = wkv6_chunked(r, k, v, w, u, st, chunk=16)
        np.testing.assert_allclose(y2, y1, rtol=2e-2, atol=2e-2)
