"""Pipeline coupling model tests (paper Sec. IV + Sec. III-A handshakes):
credit-loop bounds on the steady-state rate, their calibration against the
ISU/ICU constants, simulator conformance on deep tiny-stage pipelines, and
the satellite regressions that rode along (multi-output store handshakes,
PBE capacity weighting from PUSpec, analysis-cache LRU order)."""
import dataclasses

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.compiler import (
    STATS,
    analyze,
    buffer_requirements,
    clear_analysis_cache,
    compile_model,
    fuse,
    partition,
    profile_graph,
    zoo,
)
from repro.compiler.compile import _ANALYSIS_CACHE_MAX
from repro.compiler.coupling import BoundaryBound, CouplingModel, coupling_bounds
from repro.compiler.graph import Graph, OpType
from repro.compiler.profiler import instruction_counts
from repro.core.icu import DECODE_CYCLES
from repro.core.isu import token_latency_cycles
from repro.core.pu import make_u50_system
from repro.deploy import System, compile_deployment

PUS = make_u50_system()
KINDS = {"PU1x": PUS[0], "PU2x": PUS[5]}


def proj_chain(dims, name="projchain"):
    """Chain of 1x1 projections d0 -> d1 -> ... (m=1-style tiny GEMMs when
    dims are small): the deep-pipeline regime where per-stage work drops to
    the scale of the REQ/ACK handshake round-trip."""
    g = Graph(name=f"{name}{len(dims) - 1}_{'x'.join(map(str, dims))}")
    t = g.add_tensor("input", (dims[0], 1))
    g.input_tensors = [t.tid]
    for i, d_out in enumerate(dims[1:]):
        out = g.add_tensor(f"h{i}", (d_out, 1))
        g.add_node(name=f"p{i}", op=OpType.PROJ, inputs=[t.tid],
                   outputs=[out.tid], m=d_out, n=1, k=dims[i])
        t = out
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g


def _sim_err(g, strat, rounds=12):
    dep = compile_deployment(g, strat, rounds=rounds)
    sim = System().load(dep).run()
    assert not sim.deadlocked
    meas = sim.aggregate_fps(warmup=2)
    return dep, (dep.predicted_throughput - meas) / meas


# ------------------------------------------------------------- unit model --
class TestCouplingModel:
    def _model(self, uncoupled, cycles_depths):
        return CouplingModel(
            uncoupled_seconds=uncoupled,
            bounds=tuple(
                BoundaryBound(tid=i, producer_stage=i, consumer_stage=i + 1,
                              depth=d, cycle_seconds=c,
                              req_latency_seconds=0.1 * c)
                for i, (c, d) in enumerate(cycles_depths)
            ),
        )

    def test_coupled_never_below_uncoupled_and_converges(self):
        m = self._model(10.0, [(30.0, 2), (12.0, 4)])
        assert m.round_seconds == pytest.approx(15.0)  # 30/2 binds
        assert m.binding is not None and m.binding.tid == 0
        # buffer depth -> infinity: credit loops stop binding
        deep = self._model(10.0, [(30.0, 1000), (12.0, 1000)])
        assert deep.round_seconds == pytest.approx(10.0)
        assert deep.binding is None
        # handshake latency / transfer time -> 0: same limit
        fast = self._model(10.0, [(0.0, 2), (0.0, 4)])
        assert fast.round_seconds == pytest.approx(10.0)

    if HAVE_HYPOTHESIS:

        @settings(max_examples=200, deadline=None)
        @given(
            uncoupled=st.floats(0.0, 1e3),
            loops=st.lists(
                st.tuples(st.floats(0.0, 1e4), st.integers(1, 64)),
                max_size=6,
            ),
            scale=st.integers(1, 1024),
        )
        def test_property_bounds(self, uncoupled, loops, scale):
            """coupled >= uncoupled always; monotone in buffer depth; and
            scaling every depth by k pulls the coupled time toward the
            uncoupled floor (convergence as depth -> infinity)."""
            m = self._model(uncoupled, loops)
            assert m.round_seconds >= m.uncoupled_seconds
            deeper = self._model(uncoupled, [(c, d * scale) for c, d in loops])
            assert deeper.round_seconds <= m.round_seconds + 1e-12
            assert deeper.round_seconds >= uncoupled

    def test_bounds_calibrated_from_isu_constants(self):
        """The same partition placed on same-SLR vs cross-SLR PU pairs must
        differ by exactly the ISU token-latency delta — the model reads the
        hardware constants, it is not hand-tuned."""
        g = fuse(proj_chain([8, 8, 8]))
        prof = profile_graph(g, KINDS)
        part = partition(g, prof, 2, 0)
        assert part.n_used == 2
        plans = buffer_requirements(g, part, n_io=4)
        specs = {p.pid: p for p in PUS}
        same = coupling_bounds(g, part, plans, {0: 0, 1: 1}, specs)
        cross = coupling_bounds(g, part, plans, {0: 0, 1: 5}, specs)
        (b_same,) = [b for b in same if b.producer_stage == 0]
        (b_cross,) = [b for b in cross if b.producer_stage == 0]
        lat = lambda a, b: token_latency_cycles(specs[a], specs[b])  # noqa: E731
        want = (lat(0, 5) + lat(5, 0) - lat(0, 1) - lat(1, 0)) / PUS[0].sys_clk_hz
        assert b_cross.cycle_seconds - b_same.cycle_seconds == pytest.approx(want)
        # four handshake instruction decodes ride on every loop
        assert b_same.cycle_seconds >= 4 * DECODE_CYCLES / PUS[0].sys_clk_hz

    def test_depth_follows_memory_plan_regions(self):
        """Credit depths come from the stage-distance buffer analysis: an
        adjacent-stage tensor couples at depth 2 (ping-pong)."""
        cm = compile_model(proj_chain([8] * 11), 5, 5)
        assert cm.coupling is not None
        for b in cm.coupling.bounds:
            assert b.depth == cm.mem.tensors[b.tid].n_regions

    def test_compiled_model_threads_coupling(self):
        cm = compile_model(proj_chain([8] * 11), 5, 5)
        assert cm.predicted_round_time == cm.coupling.round_seconds
        assert cm.predicted_round_time >= max(cm.stage_times.values())
        # the deep tiny-stage pipeline is credit-limited, not stage-limited
        assert cm.coupling.binding is not None
        assert cm.predicted_latency >= sum(cm.stage_times.values())


# ------------------------------------------------------- sim conformance --
class TestCouplingConformance:
    def test_deep_tiny_pipeline_within_2pct(self):
        """Ten single-node tiny stages: the credit loop binds (the uncoupled
        model runs >5% hot) and the coupled prediction lands within 2% of
        the discrete-event simulator."""
        dep, err = _sim_err(proj_chain([8] * 11), (5, 5))
        cpl = dep.members[0].compiled.coupling
        uncoupled_err = (1.0 / cpl.uncoupled_seconds) / (
            dep.predicted_throughput / (1 + err)) - 1.0
        assert cpl.binding is not None
        assert abs(err) <= 0.02
        assert uncoupled_err > 0.05

    def test_two_stage_unbalanced_within_2pct(self):
        """Fast producer feeding a ~4x slower consumer: the fast stage runs
        at the rate its neighbor returns credits, and the model tracks the
        simulator within 2%."""
        _, err = _sim_err(proj_chain([8, 8, 256]), (1, 1))
        assert abs(err) <= 0.02

    def test_two_stage_balanced_tiny_within_2pct(self):
        _, err = _sim_err(proj_chain([8, 8, 8]), (1, 1))
        assert abs(err) <= 0.02


# ------------------------------------------------- satellite regressions --
class TestMultiOutputHandshakes:
    """compiler/profiler.py used to count ST WAIT_ACK/SEND_REQ handshakes
    only for outputs[0] while charging store bytes for every output (and
    codegen silently dropped the extra stores entirely)."""

    def _fork_graph(self):
        g = Graph(name="fork2")
        x = g.add_tensor("input", (8, 1))
        g.input_tensors = [x.tid]
        t1 = g.add_tensor("t1", (8, 1))
        t2 = g.add_tensor("t2", (8, 1))
        g.add_node(name="src", op=OpType.PROJ, inputs=[x.tid],
                   outputs=[t1.tid, t2.tid], m=8, n=1, k=8)
        o1 = g.add_tensor("o1", (8, 1))
        o2 = g.add_tensor("o2", (8, 1))
        g.add_node(name="a", op=OpType.PROJ, inputs=[t1.tid],
                   outputs=[o1.tid], m=8, n=1, k=8)
        g.add_node(name="b", op=OpType.PROJ, inputs=[t2.tid],
                   outputs=[o2.tid], m=8, n=1, k=8)
        g.output_tensors = [o1.tid, o2.tid]
        g.validate_topological()
        return g

    def test_counts_every_output(self):
        g = self._fork_graph()
        src = g.nodes[0]
        _, _, st_count = instruction_counts(g, src)
        # two stores (DataMove + AddrCyc each) + one consumer handshake pair
        # (WAIT_ACK + SEND_REQ) per forwarded output
        assert st_count == 2 * 2 + 2 * 1 + 2 * 1

    def test_codegen_emits_matching_store_stream(self):
        g = self._fork_graph()
        cm = compile_model(g, 2, 0)
        stage_of = cm.part.stage_of_node()
        src = g.nodes[0]
        stage = stage_of[src.nid]
        prog = cm.programs[stage]
        expect = sum(instruction_counts(g, nd)[2]
                     for nd in g.nodes if stage_of[nd.nid] == stage)
        # ST body = the stage's concatenated store streams (+ ProgCtrl)
        assert len(prog.st.instructions) == expect + 1

    def test_fork_simulates_clean(self):
        dep, err = _sim_err(self._fork_graph(), (2, 0), rounds=8)
        assert abs(err) <= 0.05


class TestPbeCapacityWeights:
    def test_caps_follow_peak_tops(self):
        """pbe() derives stage capacity weights from PUSpec.peak_tops — a
        non-default PU array (4x-wide second kind) must not silently fall
        back to the 1:2 weighting of the U50 default."""
        pus = [dataclasses.replace(p, sa_cols=16) if p.kind == "PU2x" else p
               for p in make_u50_system()]
        g = zoo.tiny_cnn(channels=(8, 8, 8), hw=8)
        cm = compile_model(g, 1, 1, pus=pus)
        caps = {k: s.peak_tops for k, s in cm.analysis.pu_kinds.items()}
        assert caps["PU2x"] == pytest.approx(4 * caps["PU1x"])
        used = [s for s in cm.part.stages if s.nids]
        want = sum(cm.stage_times[s.index] * caps[s.pu_kind] for s in used) / (
            cm.predicted_round_time * sum(caps[s.pu_kind] for s in used))
        assert cm.pbe() == pytest.approx(want)
        # the default machine reproduces the historical 1:2 weighting
        cm_def = compile_model(g, 1, 1)
        caps_def = {"PU1x": 1.0, "PU2x": 2.0}
        want_def = sum(cm_def.stage_times[s.index] * caps_def[s.pu_kind]
                       for s in cm_def.part.stages if s.nids) / (
            cm_def.predicted_round_time
            * sum(caps_def[s.pu_kind] for s in cm_def.part.stages if s.nids))
        assert cm_def.pbe() == pytest.approx(want_def)


class TestAnalysisCacheLRU:
    def test_hit_refreshes_eviction_order(self):
        """A recently-hit analysis must survive eviction churn; before the
        fix the insertion-order pop evicted it as readily as a cold one."""
        clear_analysis_cache()
        # structurally distinct graphs (distinct fingerprints), one per slot
        graphs = [proj_chain([8, 8 + i], name=f"lru{i}")
                  for i in range(_ANALYSIS_CACHE_MAX + 1)]
        for g in graphs[:-1]:
            analyze(g)  # fill the cache exactly to capacity
        h0, m0 = STATS.analysis_hits, STATS.analysis_misses
        analyze(graphs[0])  # touch the oldest entry...
        assert (STATS.analysis_hits, STATS.analysis_misses) == (h0 + 1, m0)
        analyze(graphs[-1])  # ...then force one eviction
        assert STATS.analysis_misses == m0 + 1
        analyze(graphs[0])  # the touched entry survived (LRU popped graphs[1])
        assert STATS.analysis_hits == h0 + 2
        analyze(graphs[1])  # the untouched second-oldest one was evicted
        assert STATS.analysis_misses == m0 + 2
        clear_analysis_cache()
