"""Property-based encoding tests (hypothesis) with example-based fallback.

Covers what tests/test_isa.py spot-checks, exhaustively:

  * Instruction.encode/decode round-trips over *all* opcode families, with
    randomized in-range field values;
  * Program / PUProgram encode -> decode round-trips (BRAM image fidelity);
  * Program.validate() invariants for every graph in the zoo, compiled
    through the full framework (CNNs and the transformer frontend).

Without hypothesis the property tests skip and the example grid below keeps
the same checks alive on fixed vectors.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.compiler import compile_model, zoo
from repro.core.isa import (
    BEAT,
    AddrCyc,
    AddrLen,
    Compute,
    Config,
    DataMove,
    Group,
    Instruction,
    Opcode,
    ProgCtrl,
    Sync,
)
from repro.core.program import Program

CONFIG_OPS = [Opcode.IM2COL_PRM, Opcode.STRIDE_PRM, Opcode.URAM_PRM,
              Opcode.RES_ADD_STRIDE_PRM]
DATAMOVE_OPS = [Opcode.LINEAR_ADM, Opcode.IM2COL_ADM, Opcode.STRIDE_ADM,
                Opcode.WEIGHTS_ADM, Opcode.RES_ADD_ADM, Opcode.RES_ADD_STRIDE_ADM]
SYNC_OPS = [Opcode.SEND_REQ, Opcode.SEND_ACK, Opcode.WAIT_REQ, Opcode.WAIT_ACK]


def _bits(n):
    return (1 << n) - 1


# ------------------------------------------------- example fallback grid --
def _example_instructions():
    """Deterministic corner-value grid covering every opcode family: all-zero,
    all-max, and mixed field values."""
    out = [
        ProgCtrl(nr=0, icu_ba=0),
        ProgCtrl(nr=_bits(24), icu_ba=_bits(12), prg_end=True),
        AddrCyc(ba=0, aoffs=0, nc=0, ic=0),
        AddrCyc(ba=_bits(26) * BEAT, aoffs=_bits(17) * BEAT, nc=_bits(7), ic=_bits(7)),
        AddrLen(len_base=0, loffs=0, nc=0, ic=0),
        AddrLen(len_base=_bits(22) * BEAT, loffs=_bits(17) * BEAT,
                nc=_bits(9), ic=_bits(9), prg_end=True),
        AddrLen(len_base=65 * BEAT, loffs=16 * BEAT, nc=63, ic=63),
        Compute(m=0, n=0, k=0),
        Compute(m=_bits(12), n=_bits(16), k=_bits(14), relu=True, add_enable=True,
                scale_shift=_bits(5), rounds=1, wchunks=_bits(7), prg_end=True),
    ]
    for op in CONFIG_OPS:
        out.append(Config(op=op, param0=_bits(20), param1=_bits(14),
                          param2=_bits(12), param3=_bits(11)))
        out.append(Config(op=op, param0=1, param1=2, param2=3, param3=4))
    for op in DATAMOVE_OPS:
        out.append(DataMove(op=op, cur_ba=_bits(26) * BEAT,
                            length=_bits(22) * BEAT, channel=_bits(5)))
        out.append(DataMove(op=op, cur_ba=BEAT, length=BEAT, channel=1))
    for op in SYNC_OPS:
        out.append(Sync(op=op, pid=_bits(6), bid=_bits(12), base_bid=_bits(12),
                        nc=_bits(12), ic=_bits(12), prg_end=True))
        out.append(Sync(op=op, pid=0, bid=0, base_bid=0, nc=0, ic=0))
    return out


@pytest.mark.parametrize(
    "inst", _example_instructions(),
    ids=lambda i: f"{type(i).__name__}:{getattr(i, 'op', i.opcode).name}")
def test_roundtrip_examples(inst):
    word = inst.encode()
    assert 0 <= word < (1 << 64)
    assert Instruction.decode(word) == inst


# ----------------------------------------------------- hypothesis domain --
if HAVE_HYPOTHESIS:
    beats = lambda n: st.integers(0, _bits(n)).map(lambda b: b * BEAT)  # noqa: E731

    progctrl_s = st.builds(ProgCtrl, nr=st.integers(0, _bits(24)),
                           icu_ba=st.integers(0, _bits(12)),
                           prg_end=st.booleans())
    config_s = st.builds(Config, op=st.sampled_from(CONFIG_OPS),
                         param0=st.integers(0, _bits(20)),
                         param1=st.integers(0, _bits(14)),
                         param2=st.integers(0, _bits(12)),
                         param3=st.integers(0, _bits(11)),
                         prg_end=st.booleans())
    datamove_s = st.builds(DataMove, op=st.sampled_from(DATAMOVE_OPS),
                           cur_ba=beats(26), length=beats(22),
                           channel=st.integers(0, _bits(5)),
                           prg_end=st.booleans())
    addrcyc_s = st.builds(AddrCyc, ba=beats(26), aoffs=beats(17),
                          nc=st.integers(0, _bits(7)),
                          ic=st.integers(0, _bits(7)),
                          prg_end=st.booleans())
    addrlen_s = st.builds(AddrLen, len_base=beats(22), loffs=beats(17),
                          nc=st.integers(0, _bits(9)),
                          ic=st.integers(0, _bits(9)),
                          prg_end=st.booleans())
    sync_s = st.builds(Sync, op=st.sampled_from(SYNC_OPS),
                       pid=st.integers(0, _bits(6)),
                       bid=st.integers(0, _bits(12)),
                       base_bid=st.integers(0, _bits(12)),
                       nc=st.integers(0, _bits(12)),
                       ic=st.integers(0, _bits(12)),
                       prg_end=st.booleans())
    compute_s = st.builds(Compute, m=st.integers(0, _bits(12)),
                          n=st.integers(0, _bits(16)),
                          k=st.integers(0, _bits(14)),
                          relu=st.booleans(), add_enable=st.booleans(),
                          scale_shift=st.integers(0, _bits(5)),
                          rounds=st.integers(0, 1),
                          wchunks=st.integers(0, _bits(7)),
                          prg_end=st.booleans())
    instruction_s = st.one_of(progctrl_s, config_s, datamove_s, addrcyc_s,
                              addrlen_s, sync_s, compute_s)

    @given(instruction_s)
    def test_roundtrip_property(inst):
        word = inst.encode()
        assert 0 <= word < (1 << 64)
        assert Instruction.decode(word) == inst

    @given(sync_s)
    def test_sync_bid_cycling_stays_in_range(inst):
        """Table I(b): after any number of steps, BID stays within
        [BASE_BID, BASE_BID + NC] once the first reset has happened."""
        inst.ic = inst.nc  # offline-load convention
        if inst.nc:
            inst.bid = inst.base_bid
        start_bid = inst.bid
        for _ in range(3 * (inst.nc + 1)):
            inst.step()
            if inst.nc == 0:
                assert inst.bid == start_bid  # bypass mode never moves
            else:
                assert inst.base_bid <= inst.bid <= inst.base_bid + inst.nc
                assert 0 <= inst.ic <= inst.nc

    @given(addrcyc_s, beats(26))
    def test_addrcyc_returns_region_addresses(inst, pred_ba):
        """A full NC+1 cycle starting from reset visits exactly the region
        base addresses BA, BA+AOFFS, ..., BA+NC*AOFFS."""
        inst.ic = 0  # force reset on the first step
        cur = pred_ba
        seen = []
        for _ in range(inst.nc + 1):
            cur = inst.step(cur)
            seen.append(cur)
        assert seen == [inst.ba + i * inst.aoffs for i in range(inst.nc + 1)]

    @given(addrlen_s, beats(22))
    def test_addrlen_lengths_advance_then_wrap(inst, pred_len):
        """Length-advance mode (decode K/V caches): a full NC+1 cycle from
        reset yields LEN_BASE, LEN_BASE+LOFFS, ..., LEN_BASE+NC*LOFFS — the
        growing valid prefix of the cache region — and the *next* cycle
        repeats the identical sequence (new sequence, cache rewound)."""
        inst.ic = 0  # force reset on the first step
        cur = pred_len
        for _ in range(2):  # two full decode windows
            seen = []
            for _ in range(inst.nc + 1):
                cur = inst.step(cur)
                seen.append(cur)
            assert seen == [inst.len_base + i * inst.loffs
                            for i in range(inst.nc + 1)]

    @settings(deadline=None)
    @given(st.lists(compute_s, min_size=0, max_size=8))
    def test_cp_program_image_roundtrip(body):
        """Any assembled CP program survives the BRAM image round-trip."""
        for i in body:
            i.prg_end = False
        prog = Program.assemble(Group.CP, body, rounds=3, name="p")
        prog.validate()
        back = Program.decode(Group.CP, prog.encode(), name="p")
        assert back.instructions == prog.instructions


# ------------------------------------------- zoo-wide program invariants --
def _zoo_graphs():
    """Every family of graph the zoo can build, at test-friendly sizes."""
    return [
        zoo.tiny_cnn(),
        zoo.linear_chain(4),
        zoo.resnet50(64),
        zoo.vit(64, depth=2, d_model=192, heads=3, d_ff=384),
        zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=2),
        zoo.transformer_decoder("qwen3-0.6b", seq_len=64, decode_steps=8,
                                depth=2),
    ]


@pytest.mark.parametrize("graph", _zoo_graphs(), ids=lambda g: g.name)
@pytest.mark.parametrize("a,b", [(1, 0), (2, 2)])
def test_zoo_programs_validate_and_roundtrip(graph, a, b):
    """Compiled programs for every zoo graph: PUProgram.validate() passes,
    and the encoded BRAM images decode back to the identical programs."""
    cm = compile_model(graph, a, b, rounds=3)
    assert cm.programs
    for pu in cm.programs:
        pu.validate()
        img = pu.encode()
        for grp, prog in (("LD", pu.ld), ("CP", pu.cp), ("ST", pu.st)):
            words = img[grp]
            assert all(0 <= w < (1 << 64) for w in words)
            back = Program.decode(prog.group, words, name=prog.name)
            assert back.instructions == prog.instructions
            back.validate()


@pytest.mark.parametrize("graph", _zoo_graphs(), ids=lambda g: g.name)
def test_zoo_program_structural_invariants(graph):
    """Structural invariants the ICU decode FSM relies on: terminal ProgCtrl
    with PRG_END, in-range loop base, one Compute per compute node."""
    from repro.core.isa import Compute as ComputeInst

    cm = compile_model(graph, 1, 1, rounds=2)
    total_gemms = 0
    for pu in cm.programs:
        for prog in (pu.ld, pu.cp, pu.st):
            assert prog.instructions[-1].prg_end
            assert isinstance(prog.instructions[-1], ProgCtrl)
            assert 0 <= prog.progctrl.icu_ba < len(prog)
        total_gemms += sum(1 for i in pu.cp if isinstance(i, ComputeInst))
    assert total_gemms == len(cm.graph.nodes)
