"""Runtime tests: optimizer, data pipeline, checkpointing/fault tolerance,
serving engine, sharding policy, pipeline planner + ISA schedule simulation."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import DataConfig, DataState, TokenStream
from repro.runtime.optimizer import (
    AdafactorConfig,
    AdamWConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    lr_schedule,
)
from repro.runtime.train import make_train_step


# ---------------------------------------------------------------- optimizer --
class TestOptimizer:
    def _quad_problem(self):
        params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array(0.5)}
        loss = lambda p: jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])
        return params, loss

    def test_adamw_converges_on_quadratic(self):
        params, loss = self._quad_problem()
        c = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0, total_steps=200)
        opt = adamw_init(c, params)
        l0 = float(loss(params))
        for _ in range(150):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw_update(c, g, opt, params)
        assert float(loss(params)) < 1e-2 * l0

    def test_moment_dtype_bf16(self):
        params, loss = self._quad_problem()
        c = AdamWConfig(moment_dtype=jnp.bfloat16, lr=0.1, warmup_steps=0)
        opt = adamw_init(c, params)
        assert opt["m"]["w"].dtype == jnp.bfloat16
        g = jax.grad(loss)(params)
        params2, opt2, _ = adamw_update(c, g, opt, params)
        assert opt2["v"]["w"].dtype == jnp.bfloat16
        assert not jnp.allclose(params2["w"], params["w"])

    def test_grad_clipping(self):
        params, _ = self._quad_problem()
        c = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
        opt = adamw_init(c, params)
        huge = jax.tree.map(lambda p: 1e6 * jnp.ones_like(p), params)
        _, _, stats = adamw_update(c, huge, opt, params)
        assert float(stats["grad_norm"]) > 1e5  # measured pre-clip

    def test_lr_schedule_warmup_cosine(self):
        c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
        assert float(lr_schedule(c, jnp.int32(0))) == 0.0
        assert float(lr_schedule(c, jnp.int32(10))) == pytest.approx(1.0)
        assert float(lr_schedule(c, jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)

    def test_adafactor_converges(self):
        params = {"w": jnp.ones((4, 3)) * 2.0}
        loss = lambda p: jnp.sum(jnp.square(p["w"]))
        c = AdafactorConfig(lr=0.3)
        opt = adafactor_init(c, params)
        for _ in range(100):
            g = jax.grad(loss)(params)
            params, opt, _ = adafactor_update(c, g, opt, params)
        assert float(loss(params)) < 0.1

    def test_adafactor_memory_is_factored(self):
        params = {"w": jnp.ones((128, 64))}
        opt = adafactor_init(AdafactorConfig(), params)
        n = sum(x.size for x in jax.tree.leaves(opt["v"]))
        assert n == 128 + 64  # rank-1 factors, not 128*64


# --------------------------------------------------------------------- data --
class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=7)
        a = TokenStream(cfg).next()
        b = TokenStream(cfg).next()
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4)
        batch = TokenStream(cfg).next()
        assert batch["tokens"].shape == (4, 32)
        assert batch["labels"].shape == (4, 32)

    def test_host_sharding_partitions_batch(self):
        cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=8)
        full = TokenStream(cfg).next()
        parts = []
        for h in range(4):
            c = DataConfig(vocab_size=512, seq_len=16, global_batch=8, n_hosts=4, host_id=h)
            parts.append(TokenStream(c).next()["tokens"])
        np.testing.assert_array_equal(np.concatenate(parts, axis=0), full["tokens"])

    def test_state_resume_exact(self):
        cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=4)
        s1 = TokenStream(cfg)
        for _ in range(5):
            s1.next()
        state = DataState.from_dict(s1.state.as_dict())
        expect = s1.next()
        s2 = TokenStream(cfg, state)
        got = s2.next()
        np.testing.assert_array_equal(expect["tokens"], got["tokens"])


# --------------------------------------------------------------- checkpoint --
class TestCheckpoint:
    def _tree(self, key=0):
        k = jax.random.PRNGKey(key)
        return {
            "a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32), "c": jnp.float32(3.5)},
        }

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save_checkpoint(str(tmp_path), 7, tree)
        restored, step, _ = ckpt.restore_checkpoint(str(tmp_path), tree)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step_and_gc(self, tmp_path):
        tree = self._tree()
        for s in (1, 5, 9, 12):
            ckpt.save_checkpoint(str(tmp_path), s, tree, keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 12
        remaining = sorted(d for d in os.listdir(tmp_path) if d.startswith("ckpt_"))
        assert len(remaining) == 2  # gc keeps the latest 2

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        tree = self._tree()
        ckpt.save_checkpoint(str(tmp_path), 3, tree)
        # simulate a crash mid-write: directory without manifest
        os.makedirs(tmp_path / "ckpt_0000000009")
        assert ckpt.latest_step(str(tmp_path)) == 3
        restored, step, _ = ckpt.restore_checkpoint(str(tmp_path), tree)
        assert step == 3

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt.save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            ckpt.restore_checkpoint(str(tmp_path), {"a": jnp.zeros((5,))})

    def test_extra_metadata(self, tmp_path):
        ckpt.save_checkpoint(str(tmp_path), 2, self._tree(), extra={"data_step": 42})
        _, _, extra = ckpt.restore_checkpoint(str(tmp_path), self._tree())
        assert extra["data_step"] == 42


# --------------------------------------------- fault tolerance (end to end) --
class TestFaultTolerance:
    def test_crash_resume_bitexact(self, tmp_path):
        """Train 6 steps straight vs train 3 + 'crash' + resume 3: losses of
        steps 4-6 must match exactly (params + opt + data state captured)."""
        cfg = get_config("qwen3-0.6b").reduced()
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0)
        step_fn = jax.jit(make_train_step(cfg, None, opt_cfg, remat=False))

        def fresh():
            params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
            return params, adamw_init(opt_cfg, params), TokenStream(dcfg)

        # uninterrupted
        params, opt, stream = fresh()
        losses = []
        for _ in range(6):
            batch = jax.tree.map(jnp.asarray, stream.next())
            params, opt, m = step_fn(params, opt, batch)
            losses.append(float(m["nll"]))

        # interrupted at step 3
        params, opt, stream = fresh()
        for _ in range(3):
            batch = jax.tree.map(jnp.asarray, stream.next())
            params, opt, m = step_fn(params, opt, batch)
        ckpt.save_checkpoint(
            str(tmp_path), 3, {"params": params, "opt": opt},
            extra={"data": stream.state.as_dict()},
        )
        del params, opt, stream  # crash

        template = {"params": tf.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)}
        template["opt"] = adamw_init(opt_cfg, template["params"])
        restored, step, extra = ckpt.restore_checkpoint(str(tmp_path), template)
        stream = TokenStream(dcfg, DataState.from_dict(extra["data"]))
        params, opt = restored["params"], restored["opt"]
        resumed = []
        for _ in range(3):
            batch = jax.tree.map(jnp.asarray, stream.next())
            params, opt, m = step_fn(params, opt, batch)
            resumed.append(float(m["nll"]))
        assert resumed == pytest.approx(losses[3:], rel=1e-6)


# ------------------------------------------------------------ serving engine --
class TestServingEngine:
    def test_continuous_batching(self):
        from repro.runtime.serve import ServingEngine

        cfg = get_config("qwen3-0.6b").reduced()
        params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=64)
        for i in range(4):  # more requests than slots -> queueing + recycling
            eng.submit([1 + i, 2, 3], max_new_tokens=4)
        done = eng.run_until_drained(max_ticks=200)
        assert len(done) == 4
        assert all(len(r.generated) == 4 for r in done)
        assert all(0 <= t < cfg.vocab_size for r in done for t in r.generated)

    def test_deterministic_generation(self):
        from repro.runtime.serve import ServingEngine

        cfg = get_config("qwen3-0.6b").reduced()
        params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        outs = []
        for _ in range(2):
            eng = ServingEngine(cfg, params, batch_slots=1, max_len=64)
            eng.submit([5, 6, 7], max_new_tokens=5)
            outs.append(tuple(eng.run_until_drained()[0].generated))
        assert outs[0] == outs[1]


# ------------------------------------------------------------------ pipeline --
class TestPipelinePlanner:
    def test_plan_boundaries_cover_all_layers(self):
        from repro.runtime.pipeline import plan_pipeline

        cfg = get_config("h2o-danube-3-4b")
        plan = plan_pipeline(cfg, n_stages=4, microbatches=8, seq_len=2048,
                            microbatch_size=4)
        assert plan.boundaries[0] == 0 and plan.boundaries[-1] == cfg.num_layers
        sizes = np.diff(plan.boundaries)
        assert sizes.max() - sizes.min() <= 1  # balanced

    def test_stage_programs_validate_and_simulate(self):
        """The emitted coordination programs must execute deadlock-free on
        the discrete-event simulator (schedule verification)."""
        from repro.core import MultiPUSimulator
        from repro.core.pu import PUSpec
        from repro.runtime.pipeline import plan_pipeline

        cfg = get_config("qwen3-0.6b")
        plan = plan_pipeline(cfg, n_stages=4, microbatches=6, seq_len=1024,
                            microbatch_size=2)
        for p in plan.programs:
            p.validate()
        pus = [PUSpec(pid=i, kind="PU2x", sa_rows=64, sa_cols=8, slr=i // 2)
               for i in range(4)]
        sim = MultiPUSimulator(pus)
        res = sim.run(plan.programs, first_pid=0, last_pid=3)
        assert not res.deadlocked
        assert res.rounds == 6  # all microbatches drained

    def test_predicted_throughput_scales_with_stages(self):
        from repro.runtime.pipeline import plan_pipeline

        cfg = get_config("h2o-danube-3-4b")
        t1 = plan_pipeline(cfg, n_stages=1, microbatches=8, seq_len=2048,
                          microbatch_size=4).predicted_throughput
        t4 = plan_pipeline(cfg, n_stages=4, microbatches=8, seq_len=2048,
                          microbatch_size=4).predicted_throughput
        assert 3.0 <= t4 / t1 <= 4.01


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as tf
from repro.runtime.pipeline import (
    make_pipeline_forward, make_pipeline_mesh, plan_pipeline, stack_stage_params,
)

cfg = get_config("h2o-danube-3-4b").reduced()
params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
B, S, M = 4, 16, 2
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
ref, _ = tf.forward(cfg, params, {"tokens": toks})

plan = plan_pipeline(cfg, n_stages=4, microbatches=M, seq_len=S, microbatch_size=B // M)
mesh = make_pipeline_mesh(4, 1, 1)
sparams = stack_stage_params(cfg, params, plan)
fn = jax.jit(make_pipeline_forward(cfg, plan, mesh))
toks_mb = toks.reshape(M, B // M, S)
out = fn(sparams, toks_mb).reshape(B, S, cfg.vocab_size)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)
print("PIPELINE_EQUIVALENCE_OK")
"""


def test_pipeline_forward_matches_reference_subprocess():
    """4 'devices' (forced host platform), 4 pipeline stages: the shard_map +
    ppermute pipeline must reproduce the plain forward logits."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert "PIPELINE_EQUIVALENCE_OK" in out.stdout, out.stderr[-3000:]
