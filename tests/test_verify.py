"""Static program verification: clean zoo proofs + defect cross-validation.

Two directions, matching ROADMAP "Program verification":

* *soundness in practice* — every zoo model class (CNN, ViT, encoder,
  decoder, multi-tenant) compiles verifier-clean AND simulates to
  completion, so a clean report predicts a live deployment;
* *sensitivity* — each defect class of :mod:`repro.verify.mutate` is both
  statically caught (typed diagnostic) and dynamically confirmed (deadlock,
  trace-level corruption, or timing divergence) with verification bypassed.

With hypothesis installed the clean-compile property also runs over
randomized configs/rounds; without it those tests skip and the exhaustive
example grids below keep the same claims alive.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.compiler import zoo
from repro.core.events import Delay, Kernel, DeadlockError, WaitCond
from repro.core.isa import Sync
from repro.core.simulator import MultiPUSimulator
from repro.deploy import Strategy, Workload, compile_deployment
from repro.verify import Code, check_isolation, verify_deployment, verify_programs
from repro.verify.mutate import (
    drop_send_ack,
    hijack_channel,
    overflow_field,
    runtime_hazards,
    shrink_region,
    simulate_raw,
    stale_reads,
    swap_bids,
    verify_mutation,
)

# name -> (graph factory, (a, b) config, rounds) spanning every model class.
ZOO_TARGETS = {
    "tiny_cnn": (lambda: zoo.tiny_cnn(), (2, 1), 4),
    "resnet50": (lambda: zoo.resnet50(input_hw=64), (3, 3), 2),
    "vit": (lambda: zoo.vit(input_hw=64, depth=2), (2, 2), 2),
    "encoder": (lambda: zoo.transformer_encoder(seq_len=64, depth=2),
                (2, 2), 2),
    # rounds=None -> one full decode window (8 token steps)
    "decoder": (lambda: zoo.transformer_decoder(seq_len=64, depth=2,
                                                decode_steps=8),
                (2, 2), None),
}


def _deploy(name):
    build, cfg, rounds = ZOO_TARGETS[name]
    return compile_deployment(build(), Strategy.of(cfg), rounds=rounds)


# --------------------------------------------------------------- clean zoo --
class TestCleanZoo:
    """Verifier-clean programs simulate to completion (soundness witness)."""

    @pytest.mark.parametrize("name", sorted(ZOO_TARGETS))
    def test_clean_and_simulates(self, name):
        dep = _deploy(name)  # verify=True default: raises if not clean
        rep = verify_deployment(dep)
        assert rep.ok, rep.summary()
        res, trace = simulate_raw(dep.programs(), dep.pus, trace=True)
        assert not res.deadlocked
        assert res.end_cycles > 0
        assert not runtime_hazards(trace)
        assert not stale_reads(trace)

    def test_multi_tenant_clean(self):
        strat = Strategy.tenants([
            (Workload(zoo.tiny_cnn(), "cnn"), 1, 1),
            (Workload(zoo.transformer_encoder(seq_len=64, depth=2), "enc"),
             1, 1),
        ])
        dep = compile_deployment(None, strat, rounds=4)
        rep = verify_deployment(dep)
        assert rep.ok, rep.summary()
        member_of = {p.pid: m.index for m in dep.members
                     for p in m.compiled.programs}
        res, trace = simulate_raw(dep.programs(), dep.pus, trace=True)
        assert not res.deadlocked
        assert not runtime_hazards(trace, member_of=member_of)
        assert not stale_reads(trace)


# ---------------------------------------------------- defect cross-checks --
@pytest.fixture(scope="module")
def cnn_dep():
    return _deploy("tiny_cnn")


@pytest.fixture(scope="module")
def enc_dep():
    build, cfg, _ = ZOO_TARGETS["encoder"]
    return compile_deployment(build(), Strategy.of(cfg), rounds=8)


def _bundle(dep):
    m = dep.members[0]
    return m.compiled.programs, m.compiled.mem, m.compiled.pu_specs


class TestMutationDefects:
    """Each planted defect class: statically caught AND dynamically real."""

    def test_drop_send_ack(self, cnn_dep):
        programs, mem, specs = _bundle(cnn_dep)
        mut = drop_send_ack(programs)
        rep = verify_mutation(mut, mem=mem, pu_specs=specs)
        assert not rep.ok
        assert rep.has(Code.SYNC_TOKEN_STARVE)
        assert rep.has(Code.HAZ_UNGUARDED_READ)
        res, _ = simulate_raw(mut.programs, cnn_dep.pus)
        assert res.deadlocked

    def test_drop_send_ack_deadlock_names_channel(self, cnn_dep):
        # S1: the event kernel's blocked-process report names the parked
        # WAIT instruction and its (pid, bid) channel.
        programs, _, _ = _bundle(cnn_dep)
        mut = drop_send_ack(programs)
        sim = MultiPUSimulator(cnn_dep.pus)
        res = sim.run(mut.programs)
        assert res.deadlocked
        blocked = sim.kernel.blocked_procs()
        assert blocked
        assert any("channel (src_pid=" in b.desc for b in blocked)

    def test_swap_bids(self, cnn_dep):
        programs, mem, specs = _bundle(cnn_dep)
        mut = swap_bids(programs)
        rep = verify_mutation(mut, mem=mem, pu_specs=specs)
        assert not rep.ok
        assert rep.has(Code.HAZ_BID_MISMATCH)
        assert rep.has(Code.SYNC_STALL) or rep.has(Code.SYNC_TOKEN_STARVE)
        res, _ = simulate_raw(mut.programs, cnn_dep.pus)
        assert res.deadlocked

    def test_shrink_region(self, enc_dep):
        programs, mem, specs = _bundle(enc_dep)
        eligible = [p.tid for p in sorted(mem.tensors.values(),
                                          key=lambda p: p.tid)
                    if p.kind == "intermediate" and p.beta > 1]
        assert eligible
        # Statically every collapsed ping-pong is flagged; dynamically the
        # corruption only manifests on a tensor whose producer runs a round
        # ahead — scan for one (tid 7 is a known witness, try it first).
        manifested = False
        for tid in sorted(eligible, key=lambda t: t != 7):
            mut = shrink_region(programs, mem, tid=tid)
            rep = verify_mutation(mut, mem=mem, pu_specs=specs)
            assert not rep.ok
            assert rep.has(Code.HAZ_PINGPONG)
            _, trace = simulate_raw(mut.programs, enc_dep.pus, trace=True)
            if stale_reads(trace):
                manifested = True
                break
        assert manifested, "no shrunk tensor produced a stale read at runtime"

    def test_overflow_field(self, cnn_dep):
        programs, mem, specs = _bundle(cnn_dep)
        mut, truncated = overflow_field(programs)
        rep = verify_mutation(mut, mem=mem, pu_specs=specs)
        assert not rep.ok
        assert rep.has(Code.LINT_FIELD_OVERFLOW)
        # Hardware would wrap the field: the intended and the truncated
        # images compute different GEMMs, visible as timing divergence.
        res_i, _ = simulate_raw(mut.programs, cnn_dep.pus)
        res_t, _ = simulate_raw(truncated, cnn_dep.pus)
        assert res_i.end_cycles != res_t.end_cycles

    def test_hijack_channel(self):
        strat = Strategy.tenants([
            (Workload(zoo.tiny_cnn(), "cnn"), 1, 1),
            (Workload(zoo.transformer_encoder(seq_len=64, depth=2), "enc"),
             1, 1),
        ])
        dep = compile_deployment(None, strat, rounds=4)
        per_member = [m.compiled.programs for m in dep.members]
        muts, detail = hijack_channel(per_member)
        assert "redirected" in detail
        rep = check_isolation([
            (f"m{m.index}", progs, m.compiled.mem)
            for m, progs in zip(dep.members, muts)
        ])
        assert not rep.ok
        assert rep.has(Code.HAZ_CHANNEL_SHARED)
        assert rep.has(Code.HAZ_MEMBER_OVERLAP)
        member_of = {p.pid: m.index
                     for m, progs in zip(dep.members, muts) for p in progs}
        merged = [p for progs in muts for p in progs]
        _, trace = simulate_raw(merged, dep.pus, trace=True)
        assert runtime_hazards(trace, member_of=member_of)


# ----------------------------------------------------- deletion coverage --
def _sync_sites(programs):
    sites = []
    for pi, pu in enumerate(programs):
        for gname in ("ld", "cp", "st"):
            prog = getattr(pu, gname)
            for idx in range(prog.progctrl.icu_ba, len(prog.instructions)):
                if isinstance(prog.instructions[idx], Sync):
                    sites.append((pi, gname, idx))
    return sites


def _delete_site(programs, site):
    pi, gname, idx = site
    muts = [p.clone() for p in programs]
    del getattr(muts[pi], gname).instructions[idx]
    return muts


class TestSyncDeletionCoverage:
    """Deleting ANY loop-body handshake instruction is an error.

    This is the stress property behind the named mutators: no single SEND
    or WAIT in the steady state is redundant, and the verifier knows it —
    including the multi-consumer forks where the store still *looks*
    guarded but one consumer no longer throttles the producer."""

    def test_every_sync_deletion_caught(self, cnn_dep):
        programs, mem, specs = _bundle(cnn_dep)
        sites = _sync_sites(programs)
        assert len(sites) >= 8
        uncaught = [
            site for site in sites
            if verify_programs(_delete_site(programs, site),
                               mem=mem, pu_specs=specs).ok
        ]
        assert not uncaught


class TestDeadlockDiagnostics:
    """S1: DeadlockError carries structured blocked-process data."""

    def test_max_events_names_blocked_wait(self):
        k = Kernel()

        def parked():
            yield WaitCond("never-signalled",
                           desc="WAIT_ACK on channel (src_pid=1, bid=5)")

        def ticker():
            while True:
                yield Delay(1.0)

        k.spawn(parked(), name="pu0.ST.icu")
        k.spawn(ticker(), name="ticker")
        with pytest.raises(DeadlockError) as ei:
            k.run(max_events=50)
        err = ei.value
        assert any(b.name == "pu0.ST.icu"
                   and b.desc == "WAIT_ACK on channel (src_pid=1, bid=5)"
                   for b in err.blocked)
        assert "pu0.ST.icu" in str(err)
        assert "(src_pid=1, bid=5)" in str(err)


# ------------------------------------------------------------ properties --
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestProperties:
    if HAVE_HYPOTHESIS:

        @given(a=st.integers(1, 2), b=st.integers(1, 2),
               rounds=st.integers(1, 4))
        @settings(max_examples=8, deadline=None)
        def test_clean_compile_simulates(self, a, b, rounds):
            dep = compile_deployment(zoo.tiny_cnn(), Strategy.of((a, b)),
                                     rounds=rounds)
            assert verify_deployment(dep).ok
            res, _ = simulate_raw(dep.programs(), dep.pus)
            assert not res.deadlocked
            assert res.rounds == rounds

        @given(data=st.data())
        @settings(max_examples=16, deadline=None)
        def test_random_sync_deletion_caught(self, data):
            dep = _deploy("tiny_cnn")
            programs, mem, specs = _bundle(dep)
            sites = _sync_sites(programs)
            site = data.draw(st.sampled_from(sites))
            muts = _delete_site(programs, site)
            assert not verify_programs(muts, mem=mem, pu_specs=specs).ok
