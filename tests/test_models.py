"""Per-architecture smoke tests (reduced configs, CPU) + semantic
consistency: one-token decode must reproduce full-sequence forward."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import all_configs, get_config
from repro.models import transformer as tf

ARCHS = sorted(all_configs())


def make_batch(cfg, B, S, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    if cfg.frontend == "frame_embed":
        return {"frame_embeds": jax.random.normal(ks[0], (B, S, cfg.d_model)) * 0.02}
    batch = {"tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "patch_embed":
        batch["patch_embeds"] = (
            jax.random.normal(ks[2], (B, cfg.n_prefix_embeds, cfg.d_model)) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    """One forward + one train-style step per assigned architecture."""

    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch).reduced()
        params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        B, S = 2, 64
        batch = make_batch(cfg, B, S)
        logits, aux = jax.jit(lambda p, b: tf.forward(cfg, p, b))(params, batch)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert bool(jnp.isfinite(aux["moe_aux"]))

    def test_train_step_no_nans(self, arch):
        """One SGD step on next-token loss: finite loss, finite grads."""
        cfg = get_config(arch).reduced()
        params = tf.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
        B, S = 2, 32
        batch = make_batch(cfg, B, S, key=1)
        labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)

        def loss_fn(p):
            logits, aux = tf.forward(cfg, p, batch, remat=True)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
            return nll + 0.01 * aux["moe_aux"]

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert bool(jnp.isfinite(loss))
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
        assert any(float(jnp.abs(g).max()) > 0 for g in flat)

    def test_decode_step_shapes(self, arch):
        cfg = get_config(arch).reduced()
        params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        B = 2
        cache = tf.init_cache(cfg, B, max_len=128, dtype=jnp.float32)
        batch = make_batch(cfg, B, 1)
        step = jax.jit(lambda p, c, b, pos: tf.decode_step(cfg, p, c, b, pos))
        logits, cache = step(params, cache, batch, jnp.int32(0))
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize(
    "arch", ["qwen3-0.6b", "h2o-danube-3-4b", "gemma3-4b", "rwkv6-7b", "zamba2-7b",
             "musicgen-large"]
)
def test_decode_matches_forward(arch):
    """Replaying a sequence token-by-token through decode_step must match the
    full-sequence forward logits (cache semantics, ring buffers, SSM states,
    shared-block caches)."""
    cfg = get_config(arch).reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(3), jnp.float32)
    B, S = 1, 24
    batch = make_batch(cfg, B, S, key=7)
    full_logits, _ = jax.jit(lambda p, b: tf.forward(cfg, p, b))(params, batch)

    cache = tf.init_cache(cfg, B, max_len=S, dtype=jnp.float32)
    step = jax.jit(lambda p, c, b, pos: tf.decode_step(cfg, p, c, b, pos))
    outs = []
    for t in range(S):
        if cfg.frontend == "frame_embed":
            bt = {"frame_embeds": batch["frame_embeds"][:, t : t + 1]}
        else:
            bt = {"tokens": batch["tokens"][:, t : t + 1]}
        lg, cache = step(params, cache, bt, jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    if cfg.family == "ssm":
        # rwkv: forward uses the chunked WKV, decode the sequential
        # recurrence; their ~5e-6 fp reassociation gap compounds through the
        # per-head group norms (near-zero variance at init) into O(0.1)
        # logit deltas on <2% of entries — assert semantic agreement
        # (identical top-1, close distributions) instead of bitwise logits.
        p_dec = jax.nn.softmax(dec_logits, axis=-1)
        p_full = jax.nn.softmax(full_logits, axis=-1)
        np.testing.assert_allclose(np.asarray(p_dec), np.asarray(p_full), atol=2e-2)
        np.testing.assert_array_equal(
            np.argmax(np.asarray(dec_logits), -1),
            np.argmax(np.asarray(full_logits), -1),
        )
    else:
        np.testing.assert_allclose(
            np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-3, atol=2e-3
        )


def test_vlm_prefix_embeds_change_output():
    cfg = get_config("internvl2-76b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 1, 32
    batch = make_batch(cfg, B, S)
    l1, _ = tf.forward(cfg, params, batch)
    batch2 = dict(batch)
    batch2["patch_embeds"] = batch["patch_embeds"] + 1.0
    l2, _ = tf.forward(cfg, params, batch2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_gemma3_plan_five_to_one():
    cfg = get_config("gemma3-4b")
    plan = tf.layer_plan(cfg)
    n_local = sum(b.n for b in plan if b.local)
    n_global = sum(b.n for b in plan if not b.local)
    assert n_local + n_global == 34
    assert n_global == 5  # ~5:1 local:global at 34 layers
    assert all(not b.local for b in plan if b.n == 1 and not b.local)


def test_zamba2_plan_shared_blocks():
    cfg = get_config("zamba2-7b")
    plan = tf.layer_plan(cfg)
    mamba = sum(b.n for b in plan if b.kind == "mamba")
    shared = [b for b in plan if b.kind == "shared_attn"]
    assert mamba == 81
    assert len(shared) == 13  # one per full 6-mamba group
    assert {b.shared_idx for b in shared} == {0, 1}  # alternating


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.25, most tokens route (few drops on random data)."""
    cfg = get_config("dbrx-132b").reduced()
    import repro.models.moe as moe_mod

    params_moe = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.1
    y, aux = moe_mod.moe_mlp(params_moe, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # aux loss ~1 for a balanced router at init
    assert 0.5 < float(aux) < 4.0


def test_param_counts_match_pool():
    """Full configs land near the pool's nominal parameter counts."""
    expect = {
        "grok-1-314b": (260e9, 340e9),
        "dbrx-132b": (110e9, 145e9),
        "internvl2-76b": (62e9, 80e9),  # LM backbone of the 76B VLM
        "starcoder2-15b": (13e9, 17e9),
        "rwkv6-7b": (6e9, 9e9),
        "qwen3-0.6b": (0.4e9, 0.85e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B not in [{lo/1e9}, {hi/1e9}]"
