"""Sharding policy tests + small-mesh lower/compile smoke (subprocess with
forced host devices — the full 512-device dry-run is exercised by
repro.launch.dryrun; these tests keep the policy honest at test speed)."""
import os
import subprocess
import sys

import pytest
import jax

from repro.configs import all_configs, get_config


class TestPolicyRules:
    def _policy(self, arch, multi_pod=False):
        # policy construction only needs mesh *shape* metadata; build a
        # device-free mesh via the version-robust helper
        from repro.runtime.sharding import make_abstract_mesh, make_policy

        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        mesh = make_abstract_mesh(shape, axes)
        return make_policy(get_config(arch), mesh)

    def test_attn_mode_by_divisibility(self):
        assert self._policy("qwen3-0.6b").attn_mode == "heads"  # H=16
        assert self._policy("starcoder2-15b").attn_mode == "heads"  # H=48
        assert self._policy("gemma3-4b").attn_mode == "dmodel"  # H=8 < 16

    def test_fsdp_triggers_on_size(self):
        assert self._policy("grok-1-314b").fsdp  # 314B
        assert self._policy("dbrx-132b").fsdp
        assert not self._policy("qwen3-0.6b").fsdp
        assert not self._policy("rwkv6-7b").fsdp

    def test_multi_pod_batch_axes(self):
        p = self._policy("qwen3-0.6b", multi_pod=True)
        assert p.batch_axes == ("pod", "data")
        p1 = self._policy("qwen3-0.6b", multi_pod=False)
        assert p1.batch_axes == ("data",)

    @pytest.mark.parametrize("arch", sorted(all_configs()))
    def test_param_specs_divisible(self, arch):
        """Every emitted spec must evenly divide its tensor dimension."""
        from repro.launch import specs as lspecs

        policy = self._policy(arch)
        p = lspecs.params_specs(get_config(arch))
        shardings = policy.params_sharding(p)

        def check(leaf, sh):
            spec = sh.spec
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= policy.mesh.shape[a]
                assert leaf.shape[i] % n == 0, (arch, leaf.shape, spec)

        jax.tree.map(check, p, shardings)

    @pytest.mark.parametrize("arch", ["grok-1-314b", "internvl2-76b", "dbrx-132b"])
    def test_big_models_fit_per_chip(self, arch):
        """bf16 params sharded over the 256-chip pod must fit 16 GB/chip."""
        from repro.launch import specs as lspecs

        policy = self._policy(arch)
        p = lspecs.params_specs(get_config(arch))
        shardings = policy.params_sharding(p)
        per_chip = 0
        for leaf, sh in zip(jax.tree.leaves(p), jax.tree.leaves(shardings)):
            n = 1
            for ax in sh.spec:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    n *= policy.mesh.shape[a]
            per_chip += leaf.size * 2 / n
        assert per_chip < 10 * 2**30, f"{arch}: {per_chip/2**30:.1f} GiB/chip"


SMALL_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.launch import specs
from repro.runtime.sharding import make_policy
from repro.runtime.serve import make_serve_step, make_prefill

mesh = jax.make_mesh((2, 4), ("data", "model"))
arch = os.environ["TEST_ARCH"]
cfg = get_config(arch).reduced()
policy = make_policy(cfg, mesh)
p = specs.params_specs(cfg)
ps = policy.params_sharding(p)

shape = ShapeCfg("t", 64, 4, "prefill")
batch = specs.input_specs(cfg, shape)
with mesh:
    fn = jax.jit(make_prefill(cfg, policy), in_shardings=(ps, policy.inputs_sharding(batch)))
    fn.lower(p, batch).compile()
    c = specs.cache_specs(cfg, 4, 64)
    cs = policy.cache_sharding(c)
    db = specs.decode_input_specs(cfg, ShapeCfg("d", 64, 4, "decode"))
    sfn = jax.jit(make_serve_step(cfg, policy),
                  in_shardings=(ps, cs, policy.inputs_sharding(db),
                                jax.NamedSharding(mesh, jax.sharding.PartitionSpec())))
    sfn.lower(p, c, db, jax.ShapeDtypeStruct((), jnp.int32)).compile()
print("SMALL_MESH_OK")
"""


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "zamba2-7b", "rwkv6-7b", "dbrx-132b"])
def test_reduced_configs_compile_on_small_mesh(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["TEST_ARCH"] = arch
    out = subprocess.run(
        [sys.executable, "-c", SMALL_MESH_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert "SMALL_MESH_OK" in out.stdout, out.stderr[-3000:]
