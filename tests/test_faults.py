"""Fault-tolerant serving tests: seeded fault injection, watchdog
detection (every fault class comes back as a structured FaultReport naming
the exact PU / channel), and degraded-array recovery (quarantine ->
masked re-placement byte-equal to a from-scratch exploration -> session
replay), plus the kernel-level blocked-process diagnostics and the
hardened Server.drain() edge cases."""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

from repro.compiler import zoo
from repro.core.events import Delay, Kernel, WaitCond
from repro.deploy import SLO, Strategy, System, Workload, compile_deployment
from repro.dse import explore_multi
from repro.dse.replan import plan_placement
from repro.faults import (
    FaultCode,
    FaultSchedule,
    HBMStall,
    LinkSpike,
    PUHang,
    TokenCorrupt,
    TokenDrop,
    Watchdog,
    reports_from_blocked,
)
from repro.serve import DrainStuckError, Request, Server


@pytest.fixture(scope="module")
def cnn_dep():
    return compile_deployment(zoo.tiny_cnn(), Strategy.single(2, 1))


def _stage_pids(dep):
    """Pipeline-ordered pids of the first member."""
    cm = dep.members[0].compiled
    stages = sorted(s.index for s in cm.part.stages if s.nids)
    return [cm.pid_map[i] for i in stages]


def _used_channel(dep):
    """The HBM channel the deployment's DataMoves reference most — the
    member's channel *pool* can be wider than what its memory plan uses,
    and stalling an untouched channel is a no-op."""
    from collections import Counter

    from repro.core.isa import DataMove

    c = Counter()
    for p in dep.programs():
        for grp in (p.ld, p.cp, p.st):
            for inst in grp.instructions:
                if isinstance(inst, DataMove):
                    c[inst.channel] += 1
    return c.most_common(1)[0][0]


# ------------------------------------------------------- kernel diagnostics


class TestKernelDiagnostics:
    def test_blocked_proc_carries_cycle_and_member(self):
        k = Kernel()

        def parked():
            yield Delay(10)
            yield WaitCond("never-signalled", desc="stuck on nothing")

        k.spawn(parked(), name="p0", member="m0")
        k.run()
        assert k.deadlocked()
        (b,) = k.blocked_procs()
        assert b.name == "p0"
        assert b.desc == "stuck on nothing"
        assert b.cycle == 10
        assert b.member == "m0"

    def test_daemon_excluded_from_deadlock(self):
        k = Kernel()

        def ticker():
            while True:
                yield Delay(5)

        def worker():
            yield Delay(12)

        k.spawn(ticker(), name="tick", daemon=True)
        k.spawn(worker(), name="work")
        k.run()  # must terminate: the daemon alone keeps no heap alive
        assert not k.deadlocked()
        assert k.now >= 12

    def test_halt_stops_run(self):
        k = Kernel()

        def slow():
            yield Delay(1000)

        def halter():
            yield Delay(5)
            k.halt()

        k.spawn(slow(), name="slow")
        k.spawn(halter(), name="halter")
        k.run()
        assert k.now == 5

    def test_reports_from_blocked_parses_channel(self):
        k = Kernel()

        def parked():
            yield WaitCond(("lut", 1, "REQ", (0, 7)),
                           desc="WAIT_REQ on channel (src_pid=0, bid=7)")

        k.spawn(parked(), name="pu1.LD", member="t0")
        k.run()
        (r,) = reports_from_blocked(k.blocked_procs())
        assert r.code == FaultCode.DEADLOCK
        assert r.pid == 1 and r.group == "LD"
        assert r.channel == (0, 7)
        assert r.member == "t0"
        assert r.suspect_pid == 0  # the silent source, not the waiter


# ---------------------------------------------------------------- schedules


class TestFaultSchedule:
    def test_random_is_seed_deterministic(self):
        a = FaultSchedule.random(42, n=3)
        b = FaultSchedule.random(42, n=3)
        assert a == b
        assert a.describe() == b.describe()
        assert FaultSchedule.random(43, n=3) != a

    def test_describe_names_every_class(self):
        s = FaultSchedule(faults=(
            PUHang(pid=3, at_cycle=100),
            TokenDrop(src_pid=1),
            TokenCorrupt(src_pid=2),
            HBMStall(channel=4),
            LinkSpike(src_pid=0, dst_pid=5, extra_cycles=1000),
        ))
        d = s.describe()
        for tag in ("pu-hang", "token-drop", "token-corrupt", "hbm-stall",
                    "link-spike"):
            assert tag in d


# ---------------------------------------------------------------- detection


class TestDetection:
    """Every fault class -> a structured FaultReport naming the exact
    PU / sync channel / HBM channel, via the watchdog monitor."""

    def _run(self, cnn_dep, schedule):
        sys = System(cnn_dep.pus)
        sys.watchdog = Watchdog()
        sys.load(cnn_dep)
        sys.inject(schedule)
        return sys.run()

    def test_pu_hang(self, cnn_dep):
        pid = _stage_pids(cnn_dep)[-1]
        rep = self._run(cnn_dep, FaultSchedule(
            faults=(PUHang(pid=pid, at_cycle=2000.0),)))
        assert rep.faulted and not rep.deadlocked
        hangs = [r for r in rep.faults if r.code == FaultCode.PU_HANG]
        assert hangs and all(r.pid == pid for r in hangs)
        assert all(r.index is not None for r in hangs)

    def test_token_drop(self, cnn_dep):
        src = _stage_pids(cnn_dep)[0]
        rep = self._run(cnn_dep, FaultSchedule(
            faults=(TokenDrop(src_pid=src),)))
        assert rep.faulted
        sync = [r for r in rep.faults if r.code == FaultCode.SYNC_TIMEOUT]
        assert any(r.channel is not None and r.channel[0] == src
                   for r in sync)

    def test_token_corrupt(self, cnn_dep):
        src = _stage_pids(cnn_dep)[0]
        rep = self._run(cnn_dep, FaultSchedule(
            faults=(TokenCorrupt(src_pid=src),)))
        assert rep.faulted
        sync = [r for r in rep.faults if r.code == FaultCode.SYNC_TIMEOUT]
        assert any(r.channel is not None and r.channel[0] == src
                   for r in sync)

    def test_hbm_stall(self, cnn_dep):
        chan = _used_channel(cnn_dep)
        rep = self._run(cnn_dep, FaultSchedule(
            faults=(HBMStall(channel=chan, at_cycle=1000.0),)))
        assert rep.faulted
        hbm = [r for r in rep.faults if r.code == FaultCode.HBM_TIMEOUT]
        assert hbm and all(r.hbm_channel == chan for r in hbm)

    def test_link_spike(self, cnn_dep):
        pids = _stage_pids(cnn_dep)
        src, dst = pids[0], pids[1]
        rep = self._run(cnn_dep, FaultSchedule(
            faults=(LinkSpike(src_pid=src, dst_pid=dst,
                              extra_cycles=10_000_000.0),)))
        assert rep.faulted
        sync = [r for r in rep.faults if r.code == FaultCode.SYNC_TIMEOUT]
        assert any(r.channel is not None and r.channel[0] == src
                   for r in sync)

    def test_clean_run_unchanged_by_watchdog(self, cnn_dep):
        base = System(cnn_dep.pus).load(cnn_dep).run()
        sys = System(cnn_dep.pus)
        sys.watchdog = Watchdog()
        watched = sys.load(cnn_dep).run()
        assert not watched.faulted
        assert watched.aggregate_fps() == base.aggregate_fps()


# --------------------------------------------------------- reset regression


class TestResetClearsFaults:
    def test_clear_faults_restores_clean_behavior(self, cnn_dep):
        """A System reused after a faulted run starts clean (satellite:
        reset() clears injected-fault state)."""
        sys = System(cnn_dep.pus)
        sys.watchdog = Watchdog()
        sys.load(cnn_dep)
        clean = sys.run()
        pid = _stage_pids(cnn_dep)[-1]
        sys.inject(FaultSchedule(faults=(PUHang(pid=pid, at_cycle=2000.0),)))
        faulted = sys.run()
        assert faulted.faulted
        sys.clear_faults()
        again = sys.run()
        assert not again.faulted
        assert again.aggregate_fps() == clean.aggregate_fps()
        assert sys.sim.isu.fault_hook is None
        assert all(icu.hang_at is None for icu in sys.sim.icus.values())

    def test_schedule_rearms_identically_every_run(self, cnn_dep):
        sys = System(cnn_dep.pus)
        sys.watchdog = Watchdog()
        sys.load(cnn_dep)
        pid = _stage_pids(cnn_dep)[0]
        sys.inject(FaultSchedule(faults=(PUHang(pid=pid, at_cycle=3000.0),)))
        a = sys.run()
        b = sys.run()  # frozen schedule re-arms on reset: byte-equal
        assert [str(r) for r in a.faults] == [str(r) for r in b.faults]


# --------------------------------------------------------- masked placement


class TestMaskedPlacement:
    def test_masked_compile_avoids_quarantined_resources(self, cnn_dep):
        avail = [p.pid for p in cnn_dep.pus][1:]  # quarantine pid 0
        chans = list(range(4, 32))                # channels 0-3 dead
        dep = compile_deployment(
            zoo.tiny_cnn(), Strategy.single(2, 1), pus=cnn_dep.pus,
            available=avail, channels=chans)
        m = dep.members[0]
        assert set(m.pids) <= set(avail)
        assert set(m.channels) <= set(chans)
        # The machine itself is unchanged: still loadable into the full
        # System (quarantined units simply receive no programs).
        assert dep.pus == cnn_dep.pus
        rep = System(cnn_dep.pus).load(dep).run()
        assert not rep.deadlocked

    def test_all_masked_raises(self, cnn_dep):
        with pytest.raises(ValueError, match="no available PUs"):
            compile_deployment(zoo.tiny_cnn(), Strategy.single(2, 1),
                               pus=cnn_dep.pus, available=[])

    def test_whole_kind_masked_raises(self, cnn_dep):
        only_1x = [p.pid for p in cnn_dep.pus if p.kind == "PU1x"]
        with pytest.raises(ValueError, match="PU2x"):
            compile_deployment(zoo.tiny_cnn(), Strategy.single(2, 1),
                               pus=cnn_dep.pus, available=only_1x)

    def test_degraded_placement_equals_from_scratch(self, cnn_dep):
        """The acceptance property: a masked re-plan (threaded with the
        *unmasked* prev result) is byte-equal to a fresh explore_multi on
        the masked budget — the changed budget forces the safe
        from-scratch path."""
        ws = [Workload(zoo.tiny_cnn(), "a"),
              Workload(zoo.linear_chain(3), "b")]
        full = plan_placement(ws, pus=cnn_dep.pus)
        kinds = {p.pid: p.kind for p in cnn_dep.pus}
        dead = {_stage_pids(cnn_dep)[0]}
        avail = [p.pid for p in cnn_dep.pus if p.pid not in dead]
        n1 = sum(1 for pid in avail if kinds[pid] == "PU1x")
        n2 = sum(1 for pid in avail if kinds[pid] == "PU2x")
        masked = plan_placement(ws, pus=cnn_dep.pus, prev=full.result,
                                available=avail)
        fresh = explore_multi(ws, n_pu1x=n1, n_pu2x=n2, pus=cnn_dep.pus)
        assert masked.point == fresh.balanced
        assert masked.configs == fresh.balanced.configs

    def test_no_healthy_pus_raises(self, cnn_dep):
        with pytest.raises(ValueError, match="no available PUs"):
            plan_placement([Workload(zoo.tiny_cnn(), "a")],
                           pus=cnn_dep.pus, available=[])


# ---------------------------------------------------------- server recovery


def _serve_one_window():
    """A server with one tenant and two requests, stepped through its
    first clean window so the placement (and target pids) are known."""
    srv = Server(verify=False)
    srv.join("t", depth=1, max_slots=2, window=4)
    srv.submit(Request(tenant="t", prompt_tokens=8, max_new_tokens=8))
    srv.submit(Request(tenant="t", prompt_tokens=4, max_new_tokens=8))
    assert srv.step()
    return srv


def _schedule_for(klass, dep):
    pids = _stage_pids(dep)
    if klass == "pu-hang":
        return FaultSchedule(faults=(PUHang(pid=pids[-1], at_cycle=2000.0),))
    if klass == "token-drop":
        return FaultSchedule(faults=(TokenDrop(src_pid=pids[0]),))
    if klass == "token-corrupt":
        return FaultSchedule(faults=(TokenCorrupt(src_pid=pids[0]),))
    if klass == "hbm-stall":
        return FaultSchedule(
            faults=(HBMStall(channel=_used_channel(dep), at_cycle=1000.0),))
    if klass == "link-spike":
        return FaultSchedule(faults=(
            LinkSpike(src_pid=pids[0], dst_pid=pids[1],
                      extra_cycles=10_000_000.0),))
    raise ValueError(klass)


class TestServerRecovery:
    @pytest.mark.parametrize("klass", ["pu-hang", "token-drop",
                                       "token-corrupt", "hbm-stall",
                                       "link-spike"])
    def test_detect_quarantine_replay_complete(self, klass):
        srv = _serve_one_window()
        srv.inject(_schedule_for(klass, srv.system.deployment))
        srv.drain()
        # detected:
        assert srv.faults
        assert any(e.kind == "fault" for e in srv.events)
        # quarantined + replayed:
        assert srv.quarantined or srv.dead_channels
        assert any(e.kind == "quarantine" for e in srv.events)
        assert any(e.kind == "replay" for e in srv.events)
        # recovered: every request completes on the degraded array.
        assert all(r.completed for r in srv.requests)
        assert not any(r.evicted for r in srv.requests)

    def test_hbm_stall_quarantines_the_channel(self):
        srv = _serve_one_window()
        chan = _used_channel(srv.system.deployment)
        srv.inject(FaultSchedule(
            faults=(HBMStall(channel=chan, at_cycle=1000.0),)))
        srv.drain()
        assert chan in srv.dead_channels
        # the degraded window really avoids the dead channel
        assert chan not in srv.system.deployment.members[0].channels
        assert all(r.completed for r in srv.requests)

    def test_deadlock_surfaces_as_typed_events(self):
        """With detection explicitly disabled the drained event heap is
        the (slower) detector; the deadlock still becomes typed events
        and the loop still recovers — nothing escapes drain()."""
        srv = _serve_one_window()
        pid = _stage_pids(srv.system.deployment)[-1]
        srv.inject(FaultSchedule(faults=(PUHang(pid=pid, at_cycle=2000.0),)),
                   watchdog=None)
        srv.drain()
        assert any(e.kind == "fault" and "fault-deadlock" in e.detail
                   for e in srv.events)
        assert srv.quarantined
        assert all(r.completed or r.evicted for r in srv.requests)

    def test_shed_when_array_exhausted(self):
        srv = Server(verify=False)
        srv.join("hi", depth=1, max_slots=1, window=4, slo=SLO(priority=2))
        srv.join("lo", depth=1, max_slots=1, window=4)
        srv.submit(Request(tenant="hi", prompt_tokens=4, max_new_tokens=4))
        srv.submit(Request(tenant="lo", prompt_tokens=4, max_new_tokens=4))
        srv.quarantined = {p.pid for p in srv.system.pus}  # total loss
        report = srv.drain()
        assert all(r.evicted for r in srv.requests)
        shed = [e for e in srv.events if e.kind == "shed"]
        assert len(shed) == 2
        assert shed[0].tenant == "lo"  # lowest priority loses service first
        assert report.tenants


class TestDrainHardening:
    def test_drain_empty_server(self):
        rep = Server(verify=False).drain()
        assert rep.tenants == {}
        assert rep.wall_s == 0.0

    def test_drain_tenant_without_requests(self):
        srv = Server(verify=False)
        srv.join("t", depth=1, max_slots=1, window=4)
        rep = srv.drain()
        assert rep.tenants["t"].tokens == 0

    def test_drain_stuck_names_tenants(self):
        srv = Server(verify=False)
        srv.join("t", depth=1, max_slots=1, window=2)
        srv.submit(Request(tenant="t", prompt_tokens=4, max_new_tokens=64))
        with pytest.raises(DrainStuckError) as ei:
            srv.drain(max_windows=3)
        assert ei.value.stuck == ("t",)
        assert "t" in str(ei.value)
        assert ei.value.max_windows == 3


# ------------------------------------------------------- chaos determinism


CHAOS_SEED = 1001  # pu-hang on a placed pid: detect -> quarantine -> replay


def _chaos_run(seed):
    srv = Server(verify=False)
    srv.join("a", depth=1, max_slots=2, window=4)
    srv.join("b", depth=1, max_slots=1, window=4)
    for i in range(3):
        srv.submit(Request(tenant="a", prompt_tokens=4 + i,
                           max_new_tokens=8))
    srv.submit(Request(tenant="b", prompt_tokens=6, max_new_tokens=8))
    srv.inject(FaultSchedule.random(seed, n=1))
    report = srv.drain()
    return ([str(e) for e in srv.events], str(report),
            sorted(srv.quarantined), sorted(srv.dead_channels))


class TestChaosDeterminism:
    def test_same_seed_same_everything(self):
        """Satellite: same seed => byte-equal event log and RunReport
        across two independent serving runs."""
        a = _chaos_run(CHAOS_SEED)
        b = _chaos_run(CHAOS_SEED)
        assert a[0] == b[0]   # full event log, byte-equal
        assert a[1] == b[1]   # aggregate report
        assert a[2] == b[2] and a[3] == b[3]

    @pytest.mark.skipif(not HAVE_HYPOTHESIS,
                        reason="hypothesis not installed")
    def test_any_seed_detection_is_deterministic(self, cnn_dep):
        if not HAVE_HYPOTHESIS:  # pragma: no cover
            return

        @given(seed=st.integers(0, 2**16))
        @settings(max_examples=6, deadline=None)
        def prop(seed):
            sched = FaultSchedule.random(seed, n=2, pus=cnn_dep.pus)
            outs = []
            for _ in range(2):
                sys = System(cnn_dep.pus)
                sys.watchdog = Watchdog()
                sys.load(cnn_dep)
                sys.inject(sched)
                rep = sys.run()
                outs.append(([str(r) for r in rep.faults],
                             rep.aggregate_fps()))
            assert outs[0] == outs[1]

        prop()
