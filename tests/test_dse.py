"""DSE methodology tests (paper Sec. V-A, Figs. 5/6, Table III claims) and
multi-tenant co-exploration (joint placements of several models)."""
import pytest

from repro.compiler import zoo
from repro.dse import (
    constrained,
    explore,
    explore_multi,
)


@pytest.fixture(scope="module")
def dse():
    return explore(zoo.resnet50(256))


@pytest.fixture(scope="module")
def gopf():
    return 2 * zoo.resnet50(256).total_macs() / 1e9


class TestEnumeration:
    def test_35_single_batch_configs(self, dse):
        """(a,b) with a<=5, b<=5, a+b>=1 -> 6*6-1 = 35 configurations."""
        assert len(dse.single) == 35
        assert len({p.config for p in dse.single}) == 35

    def test_multi_batch_respects_resources(self, dse):
        for s in dse.multi:
            assert s.total_a <= 5 and s.total_b <= 5
            assert s.batch >= 1

    def test_multi_batch_unordered(self, dse):
        seen = set()
        for s in dse.multi:
            assert s.configs == tuple(sorted(s.configs))
            assert s.configs not in seen
            seen.add(s.configs)

    def test_throughput_aggregates(self, dse):
        by_cfg = {p.config: p for p in dse.single}
        for s in dse.multi[:200]:
            expect = sum(by_cfg[c].fps for c in s.configs)
            assert s.throughput == pytest.approx(expect)
            assert s.latency == pytest.approx(max(by_cfg[c].latency for c in s.configs))


class TestParetoAnalysis:
    def test_frontier_is_nondominated(self, dse):
        for f in dse.multi_frontier:
            dominated = any(
                o.throughput >= f.throughput and o.latency <= f.latency
                and (o.throughput > f.throughput or o.latency < f.latency)
                for o in dse.multi
            )
            assert not dominated

    def test_constraint_filtering(self, dse):
        lim = constrained(dse.multi, max_latency=0.020, min_throughput=100.0)
        assert lim
        assert all(s.latency <= 0.020 and s.throughput >= 100.0 for s in lim)

    def test_tolerance_admits_more_points(self):
        res0 = explore(zoo.resnet50(256), tolerance=0.0)
        res1 = explore(zoo.resnet50(256), tolerance=0.02)
        assert len(res1.multi_frontier) >= len(res0.multi_frontier)


class TestPaperClaims:
    """Quantitative reproduction of the paper's Sec. V-A findings."""

    def test_dp_a_uses_all_pus(self, dse):
        assert dse.dp_a.config == (5, 5)
        # paper: DP-A PBE 90.9% — our profile model lands in the same band
        assert 0.88 <= dse.dp_a.pbe <= 0.97

    def test_dp_b_hybrid_beats_pure_pipeline(self, dse):
        """Key insight: hybrid parallelism outperforms the all-PU pipeline
        (paper: 1.1x) at higher latency."""
        ratio = dse.dp_b.throughput / dse.dp_a.fps
        assert 1.02 <= ratio <= 1.2
        assert dse.dp_b.latency > dse.dp_a.latency

    def test_dp_b_high_system_pbe(self, dse):
        assert dse.dp_b.system_pbe >= 0.97  # paper: 99%

    def test_dp_c_matches_dp_b_throughput(self, dse):
        """DP-C (one PU per batch) reaches ~DP-B throughput with 2x batches."""
        assert dse.dp_c.throughput == pytest.approx(dse.dp_b.throughput, rel=0.02)
        assert dse.dp_c.batch == 10
        assert dse.dp_b.batch < dse.dp_c.batch

    def test_compute_efficiency_bands(self, dse, gopf):
        """CE 88.5%-98.0% across DP-A/B/C (Table III)."""
        ce_a = dse.dp_a.fps * gopf / 4608.0
        ce_c = dse.dp_c.throughput * gopf / 4608.0
        assert 0.85 <= ce_a <= 0.97
        assert 0.95 <= ce_c <= 1.0
        assert ce_c > ce_a

    def test_fps_per_tops_competitive(self, dse, gopf):
        """Paper: DP-B/C reach ~126.9 FPS/TOPS (224-eq frames, peak TOPS)."""
        fps224 = dse.dp_c.throughput * gopf / 7.72
        fps_per_tops = fps224 / 4.608
        assert 115.0 <= fps_per_tops <= 135.0

    def test_single_pu_configs_have_ideal_pbe(self, dse):
        for p in dse.single:
            if p.a + p.b == 1:
                assert p.pbe == pytest.approx(1.0)


class TestExploreMulti:
    """Co-exploration: joint placements of two tenant models (Sec. V-A
    generalized across the workload axis)."""

    @pytest.fixture(scope="class")
    def pair(self):
        return (zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
                zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1))

    @pytest.fixture(scope="class")
    def mres(self, pair):
        return explore_multi(list(pair), validate=1, validate_rounds=6)

    def test_joint_placements_respect_budget(self, mres):
        assert mres.points
        for p in mres.points:
            assert p.batch == 2
            assert p.total_a <= 5 and p.total_b <= 5

    def test_frontier_nondominated_in_tenant_rates(self, mres):
        assert mres.frontier
        for f in mres.frontier:
            assert not any(
                all(o.fps[i] >= f.fps[i] for i in range(2))
                and any(o.fps[i] > f.fps[i] for i in range(2))
                for o in mres.points
            )

    def test_balanced_point_is_max_min_fair(self, mres):
        solo = [mres.best_solo_fps(i) for i in range(2)]
        fair = min(mres.balanced.fps[i] / solo[i] for i in range(2))
        for p in mres.frontier:
            assert fair >= min(p.fps[i] / solo[i] for i in range(2)) - 1e-12

    def test_points_deploy_as_two_tenant_deployments(self, mres, pair):
        strat = mres.strategy(mres.balanced)
        assert strat.is_multi_tenant
        assert tuple(w.graph for w in strat.workloads) == tuple(pair)
        dep = mres.deploy(mres.balanced, rounds=2)
        dep.assert_disjoint()
        assert dep.batch == 2
        labels = [m.workload.label for m in dep.members]
        assert labels == [g.name for g in pair]

    def test_validation_cross_checks_each_tenant(self, mres):
        assert len(mres.validation) == 1
        rec = mres.validation[0]
        assert rec.configs == mres.balanced.configs
        assert len(rec.rel_errs) == 2
        assert rec.max_rel_err < 0.10

    def test_rejects_single_tenant(self):
        with pytest.raises(ValueError):
            explore_multi([zoo.tiny_cnn()])
