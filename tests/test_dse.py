"""DSE methodology tests (paper Sec. V-A, Figs. 5/6, Table III claims),
multi-tenant co-exploration (joint placements of several models), and the
fast-engine guarantees: cached/pruned/lazy exploration is byte-identical to
the brute-force reference engine, never generates instructions, and the
sort-based Pareto matches the O(n²) oracle."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.compiler import STATS, analyze, clear_analysis_cache, place, schedule_weights, zoo
from repro.dse import (
    constrained,
    explore,
    explore_multi,
    pareto_front,
    pareto_front_bruteforce,
)


@pytest.fixture(scope="module")
def dse():
    return explore(zoo.resnet50(256))


@pytest.fixture(scope="module")
def gopf():
    return 2 * zoo.resnet50(256).total_macs() / 1e9


class TestEnumeration:
    def test_35_single_batch_configs(self, dse):
        """(a,b) with a<=5, b<=5, a+b>=1 -> 6*6-1 = 35 configurations."""
        assert len(dse.single) == 35
        assert len({p.config for p in dse.single}) == 35

    def test_multi_batch_respects_resources(self, dse):
        for s in dse.multi:
            assert s.total_a <= 5 and s.total_b <= 5
            assert s.batch >= 1

    def test_multi_batch_unordered(self, dse):
        seen = set()
        for s in dse.multi:
            assert s.configs == tuple(sorted(s.configs))
            assert s.configs not in seen
            seen.add(s.configs)

    def test_throughput_aggregates(self, dse):
        by_cfg = {p.config: p for p in dse.single}
        for s in dse.multi[:200]:
            expect = sum(by_cfg[c].fps for c in s.configs)
            assert s.throughput == pytest.approx(expect)
            assert s.latency == pytest.approx(max(by_cfg[c].latency for c in s.configs))


class TestParetoAnalysis:
    def test_frontier_is_nondominated(self, dse):
        for f in dse.multi_frontier:
            dominated = any(
                o.throughput >= f.throughput and o.latency <= f.latency
                and (o.throughput > f.throughput or o.latency < f.latency)
                for o in dse.multi
            )
            assert not dominated

    def test_constraint_filtering(self, dse):
        lim = constrained(dse.multi, max_latency=0.020, min_throughput=100.0)
        assert lim
        assert all(s.latency <= 0.020 and s.throughput >= 100.0 for s in lim)

    def test_tolerance_admits_more_points(self):
        res0 = explore(zoo.resnet50(256), tolerance=0.0)
        res1 = explore(zoo.resnet50(256), tolerance=0.02)
        assert len(res1.multi_frontier) >= len(res0.multi_frontier)


class TestPaperClaims:
    """Quantitative reproduction of the paper's Sec. V-A findings."""

    def test_dp_a_uses_all_pus(self, dse):
        assert dse.dp_a.config == (5, 5)
        # paper: DP-A PBE 90.9% — our profile model lands in the same band
        assert 0.88 <= dse.dp_a.pbe <= 0.97

    def test_dp_b_hybrid_beats_pure_pipeline(self, dse):
        """Key insight: hybrid parallelism outperforms the all-PU pipeline
        (paper: 1.1x) at higher latency."""
        ratio = dse.dp_b.throughput / dse.dp_a.fps
        assert 1.02 <= ratio <= 1.2
        assert dse.dp_b.latency > dse.dp_a.latency

    def test_dp_b_high_system_pbe(self, dse):
        assert dse.dp_b.system_pbe >= 0.97  # paper: 99%

    def test_dp_c_matches_dp_b_throughput(self, dse):
        """DP-C (one PU per batch) reaches ~DP-B throughput with 2x batches."""
        assert dse.dp_c.throughput == pytest.approx(dse.dp_b.throughput, rel=0.02)
        assert dse.dp_c.batch == 10
        assert dse.dp_b.batch < dse.dp_c.batch

    def test_compute_efficiency_bands(self, dse, gopf):
        """CE 88.5%-98.0% across DP-A/B/C (Table III)."""
        ce_a = dse.dp_a.fps * gopf / 4608.0
        ce_c = dse.dp_c.throughput * gopf / 4608.0
        assert 0.85 <= ce_a <= 0.97
        assert 0.95 <= ce_c <= 1.0
        assert ce_c > ce_a

    def test_fps_per_tops_competitive(self, dse, gopf):
        """Paper: DP-B/C reach ~126.9 FPS/TOPS (224-eq frames, peak TOPS)."""
        fps224 = dse.dp_c.throughput * gopf / 7.72
        fps_per_tops = fps224 / 4.608
        assert 115.0 <= fps_per_tops <= 135.0

    def test_single_pu_configs_have_ideal_pbe(self, dse):
        for p in dse.single:
            if p.a + p.b == 1:
                assert p.pbe == pytest.approx(1.0)


class TestExploreMulti:
    """Co-exploration: joint placements of two tenant models (Sec. V-A
    generalized across the workload axis)."""

    @pytest.fixture(scope="class")
    def pair(self):
        return (zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
                zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1))

    @pytest.fixture(scope="class")
    def mres(self, pair):
        return explore_multi(list(pair), validate=1, validate_rounds=6)

    def test_joint_placements_respect_budget(self, mres):
        assert mres.points
        for p in mres.points:
            assert p.batch == 2
            assert p.total_a <= 5 and p.total_b <= 5

    def test_frontier_nondominated_in_tenant_rates(self, mres):
        assert mres.frontier
        for f in mres.frontier:
            assert not any(
                all(o.fps[i] >= f.fps[i] for i in range(2))
                and any(o.fps[i] > f.fps[i] for i in range(2))
                for o in mres.points
            )

    def test_balanced_point_is_max_min_fair(self, mres):
        solo = [mres.best_solo_fps(i) for i in range(2)]
        fair = min(mres.balanced.fps[i] / solo[i] for i in range(2))
        for p in mres.frontier:
            assert fair >= min(p.fps[i] / solo[i] for i in range(2)) - 1e-12

    def test_points_deploy_as_two_tenant_deployments(self, mres, pair):
        strat = mres.strategy(mres.balanced)
        assert strat.is_multi_tenant
        assert tuple(w.graph for w in strat.workloads) == tuple(pair)
        dep = mres.deploy(mres.balanced, rounds=2)
        dep.assert_disjoint()
        assert dep.batch == 2
        labels = [m.workload.label for m in dep.members]
        assert labels == [g.name for g in pair]

    def test_validation_cross_checks_each_tenant(self, mres):
        assert len(mres.validation) == 1
        rec = mres.validation[0]
        assert rec.configs == mres.balanced.configs
        assert len(rec.rel_errs) == 2
        assert rec.max_rel_err < 0.10

    def test_rejects_single_tenant(self):
        with pytest.raises(ValueError):
            explore_multi([zoo.tiny_cnn()])


# ---------------------------------------------------------------------------
# Fast-engine guarantees: equivalence, laziness, budget-derived DP-C, Pareto
# ---------------------------------------------------------------------------


def _graphs_under_test():
    return [
        zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
        zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1),
    ]


class TestFastEngineEquivalence:
    """The cached/pruned/lazy engine must return *byte-identical* frontiers
    and design points vs. the brute-force reference path (which recompiles
    everything per config, composes unpruned, and uses the O(n²) Pareto)."""

    @pytest.mark.parametrize("engine", ["batched", "scalar", "fast"])
    @pytest.mark.parametrize("gi", [0, 1], ids=["tiny_cnn", "qwen3_enc"])
    def test_explore_identical(self, gi, engine):
        g = _graphs_under_test()[gi]
        fast = explore(g, engine=engine)
        ref = explore(g, engine="reference")
        assert fast.single == ref.single
        assert fast.single_frontier == ref.single_frontier
        assert fast.multi_frontier == ref.multi_frontier
        assert fast.dp_a == ref.dp_a
        assert fast.dp_b == ref.dp_b
        assert fast.dp_c == ref.dp_c

    @pytest.mark.parametrize("tol", [0.02, 0.1])
    def test_explore_identical_with_tolerance(self, tol):
        """Margin-aware Step-2 pruning stays engaged at tolerance > 0 and
        preserves: the single-point sweep, the exact frontier, every DP
        point, and the tolerant-frontier membership of every kept schedule
        (the fast frontier is the reference one restricted to kept
        schedules)."""
        g = _graphs_under_test()[0]
        fast = explore(g, tolerance=tol)
        ref = explore(g, engine="reference", tolerance=tol)
        assert fast.single == ref.single
        assert fast.single_frontier == ref.single_frontier
        kept = {s.configs for s in fast.multi}
        assert kept <= {s.configs for s in ref.multi}
        exact = pareto_front_bruteforce(
            ref.multi, [lambda s: s.throughput, lambda s: -s.latency],
            tolerance=0.0)
        assert all(s.configs in kept for s in exact)
        assert fast.multi_frontier == [
            s for s in ref.multi_frontier if s.configs in kept]
        assert fast.dp_a == ref.dp_a
        assert fast.dp_b == ref.dp_b
        assert fast.dp_c == ref.dp_c

    @pytest.mark.parametrize("tol", [0.0, 0.05])
    def test_explore_multi_identical(self, tol):
        """The margin-aware incumbent bound is exactly frontier-preserving
        at any tolerance: an incumbent clearing the tolerance-scaled
        threshold of an optimistic completion excludes every actual
        completion from the tolerant frontier."""
        pair = _graphs_under_test()
        fast = explore_multi(pair, tolerance=tol)
        ref = explore_multi(pair, engine="reference", tolerance=tol)
        scalar = explore_multi(pair, engine="scalar", tolerance=tol)
        # scalar and batched share the pruned recursion; only Step-1
        # scoring differs, and it is byte-identical
        assert scalar.points == fast.points
        assert scalar.frontier == fast.frontier
        assert scalar.balanced == fast.balanced
        assert ({p.configs for p in fast.frontier}
                == {p.configs for p in ref.frontier})
        assert sorted(p.fps for p in fast.frontier) == sorted(
            p.fps for p in ref.frontier)
        assert fast.balanced == ref.balanced
        assert [s for s in fast.singles] == [s for s in ref.singles]
        # pruned points are a subset, in enumeration order
        ref_set = set(p.configs for p in ref.points)
        assert all(p.configs in ref_set for p in fast.points)

    def test_rejects_unknown_engine(self):
        with pytest.raises(ValueError):
            explore(zoo.tiny_cnn(), engine="warp")
        with pytest.raises(ValueError):
            explore_multi(_graphs_under_test(), engine="warp")

    def test_prune_keeps_fps_ties_masked_by_latency_max(self):
        """A config better only in *latency* must survive pruning: schedule
        latency is a max over members, so another member can mask the
        member-level improvement and leave two schedules exactly tied — and
        tied schedules are all frontier members in the brute-force path."""
        from repro.dse import SingleBatchPoint, enumerate_multi_batch

        pts = [
            # (1,0) and (1,1): identical fps, (1,1) worse latency & cost —
            # prunable only under a (broken) latency-strict rule
            SingleBatchPoint(a=1, b=0, fps=100.0, latency=0.010, tops=0.3, pbe=1.0),
            SingleBatchPoint(a=1, b=1, fps=100.0, latency=0.012, tops=0.9, pbe=0.5),
            # a slow third member whose latency masks the difference above
            SingleBatchPoint(a=0, b=1, fps=50.0, latency=0.020, tops=0.6, pbe=1.0),
        ]
        pruned = enumerate_multi_batch(pts, n_pu1x=2, n_pu2x=2, prune=True)
        brute = enumerate_multi_batch(pts, n_pu1x=2, n_pu2x=2, prune=False)
        assert pruned == brute  # nothing here is strictly fps-dominated
        objs = [lambda s: s.throughput, lambda s: -s.latency]
        assert pareto_front(pruned, objs) == pareto_front_bruteforce(brute, objs)
        # sanity: a strictly fps-dominated config *is* pruned
        pts.append(SingleBatchPoint(a=2, b=1, fps=90.0, latency=0.010,
                                    tops=1.2, pbe=0.4))
        pruned = enumerate_multi_batch(pts, n_pu1x=2, n_pu2x=2, prune=True)
        assert not any((2, 1) in s.configs for s in pruned)

    def test_tolerance_margin_prune(self):
        """At tolerance > 0 the dominance test demands an fps margin of
        tolerance * T_max: near-dominated configs (within the margin)
        survive, far-dominated ones are still pruned, and the pruned set's
        tolerant frontier is the brute-force frontier restricted to kept
        schedules while containing the entire exact frontier."""
        from repro.dse import SingleBatchPoint, enumerate_multi_batch
        from repro.dse.explorer import _max_schedule_throughput

        tol = 0.05
        pts = [
            SingleBatchPoint(a=1, b=0, fps=100.0, latency=0.010, tops=0.3, pbe=1.0),
            # dominated by (1,0) but within the margin -> must survive
            SingleBatchPoint(a=1, b=1, fps=96.0, latency=0.010, tops=0.9, pbe=0.5),
            # dominated by far more than the margin -> still pruned
            SingleBatchPoint(a=2, b=0, fps=40.0, latency=0.010, tops=0.6, pbe=0.4),
            SingleBatchPoint(a=0, b=1, fps=60.0, latency=0.015, tops=0.6, pbe=1.0),
        ]
        by_cfg = {p.config: p for p in pts}
        t_max = _max_schedule_throughput(by_cfg, 2, 2)
        assert t_max == pytest.approx(320.0)  # 2x(1,0) + 2x(0,1)
        margin = tol * t_max  # 16.0: (1,1) is 4.0 behind, (2,0) is 60.0
        assert 100.0 - 96.0 < margin < 100.0 - 40.0

        pruned = enumerate_multi_batch(pts, n_pu1x=2, n_pu2x=2,
                                       prune=True, tolerance=tol)
        brute = enumerate_multi_batch(pts, n_pu1x=2, n_pu2x=2, prune=False)
        assert any((1, 1) in s.configs for s in pruned)
        assert not any((2, 0) in s.configs for s in pruned)
        # at tolerance 0 the same config would be margin-0 pruned
        exact_pruned = enumerate_multi_batch(pts, n_pu1x=2, n_pu2x=2,
                                             prune=True, tolerance=0.0)
        assert not any((1, 1) in s.configs for s in exact_pruned)

        objs = [lambda s: s.throughput, lambda s: -s.latency]
        kept = {s.configs for s in pruned}
        assert kept <= {s.configs for s in brute}
        exact = pareto_front_bruteforce(brute, objs, tolerance=0.0)
        assert all(s.configs in kept for s in exact)
        ref_front = pareto_front_bruteforce(brute, objs, tolerance=tol)
        fast_front = pareto_front(pruned, objs, tolerance=tol)
        assert [s.configs for s in fast_front] == [
            s.configs for s in ref_front if s.configs in kept]


class TestLazyCompile:
    """Exploration never generates a single instruction; codegen happens at
    deploy time only (and the per-graph analysis runs exactly once)."""

    def test_explore_runs_zero_codegen(self):
        clear_analysis_cache()
        STATS.reset()
        res = explore(zoo.tiny_cnn(channels=(16, 32, 32), hw=16))
        snap = STATS.snapshot()
        assert snap["codegen_calls"] == 0
        assert snap["memory_plan_calls"] == 0
        assert snap["fuse_calls"] == 1
        assert snap["profile_calls"] == 1
        assert snap["analysis_misses"] == 1
        # deploying a point forces codegen for exactly its members
        dep = res.deploy(res.dp_a, rounds=2)
        assert STATS.snapshot()["codegen_calls"] == 1
        assert dep.members[0].compiled.programs

    def test_explore_multi_runs_zero_codegen_and_shares_same_graph(self):
        clear_analysis_cache()
        STATS.reset()
        g = zoo.tiny_cnn(channels=(16, 32, 32), hw=16)
        g2 = zoo.tiny_cnn(channels=(16, 32, 32), hw=16)  # same content
        explore_multi([g, g2])
        snap = STATS.snapshot()
        assert snap["codegen_calls"] == 0
        # identical content -> one shared Step-1 cache and one analysis
        assert snap["analysis_misses"] == 1
        assert snap["fuse_calls"] == 1

    def test_weight_schedule_shape_cache(self):
        """Shape-equal segments share one SMOF allocation — within a graph
        (repeated transformer blocks) and across depth-scaled variants."""
        from repro.core.pu import make_u50_system

        clear_analysis_cache()
        STATS.reset()
        pus = make_u50_system()
        a2 = analyze(zoo.transformer_encoder(depth=2, seq_len=128), pus)
        place(a2, 2, 2)
        hits_d2 = STATS.weight_schedule_shape_hits
        assert hits_d2 >= 1  # repeated blocks hit within one graph
        a4 = analyze(zoo.transformer_encoder(depth=4, seq_len=128), pus)
        place(a4, 2, 2)
        # the depth-4 variant reuses the depth-2 graph's segment shapes
        assert STATS.weight_schedule_shape_hits > hits_d2
        # a rebound schedule is identical to one computed from scratch
        for an in (a2, a4):
            for (nids, kind), ws in an._wscheds.items():
                fresh = schedule_weights(an.graph, list(nids),
                                         an.pu_kinds[kind])
                assert [(t.nid, t.tile_idx, t.n_chunks, t.static_chunks)
                        for t in ws.tiles] == \
                       [(t.nid, t.tile_idx, t.n_chunks, t.static_chunks)
                        for t in fresh.tiles]
                assert ws.total_stall() == pytest.approx(fresh.total_stall())

    def test_deployed_points_still_simulate(self):
        res = explore(zoo.tiny_cnn(channels=(16, 32, 32), hw=16))
        sim = res.simulate(res.dp_a, rounds=4)
        assert not sim.deadlocked
        assert sim.aggregate_fps(warmup=1) > 0


class TestBudgetDerivedDesignPoints:
    """DP-C derives its one-PU-per-batch target from the explored PU budget
    (a non-default array must not raise LookupError)."""

    def test_dp_c_non_default_budget(self):
        res = explore(zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
                      n_pu1x=3, n_pu2x=2)
        dp_c = res.dp_c
        assert dp_c.configs == tuple(sorted([(1, 0)] * 3 + [(0, 1)] * 2))
        assert dp_c.batch == 5
        assert res.n_pu1x == 3 and res.n_pu2x == 2

    def test_dp_c_default_budget_unchanged(self):
        res = explore(zoo.tiny_cnn(channels=(16, 32, 32), hw=16))
        assert res.dp_c.configs == tuple(sorted([(1, 0)] * 5 + [(0, 1)] * 5))
        assert res.dp_c.batch == 10


# --------------------------------------------------------- Pareto oracle --
def _check_matches_oracle(vals, tolerance):
    objectives = [lambda v: v[0], lambda v: v[1]]
    fast = pareto_front(vals, objectives, tolerance=tolerance)
    oracle = pareto_front_bruteforce(vals, objectives, tolerance=tolerance)
    assert fast == oracle


PARETO_EXAMPLES = [
    [],
    [(1.0, 1.0)],
    [(1.0, 2.0), (2.0, 1.0), (1.5, 1.5)],
    [(1.0, 1.0), (1.0, 1.0)],  # exact duplicates: all kept
    [(2.0, -1.0), (2.0, -1.0), (2.0, -2.0)],  # duplicate frontier + dominated
    [(0.0, 0.0), (0.0, 1.0), (1.0, 0.0)],  # zeros hit the thr==value edge
    [(-1.0, -2.0), (-2.0, -1.0), (-1.5, -1.5)],  # negative objectives
    [(3.0, 1.0), (2.0, 2.0), (1.0, 3.0), (2.5, 0.5), (0.5, 2.5)],
    [(1.0, 5.0), (1.0, 4.0), (2.0, 5.0)],  # equal-f1 group with dominated
    # duplicated frontier pairs: every copy of a kept point is kept
    [(1.0, 2.0), (1.0, 2.0), (2.0, 1.0), (2.0, 1.0)],
    [(1.0, 1.0), (1.0, 1.0), (1.0, 1.0)],  # all identical
    # duplicate dominator + partial ties along each axis
    [(2.0, 2.0), (2.0, 1.0), (1.0, 2.0), (2.0, 2.0)],
    [(0.0, -0.0), (-0.0, 0.0), (0.0, 0.0)],  # signed-zero ties
]


@pytest.mark.parametrize("tolerance", [0.0, 0.01, 0.25])
@pytest.mark.parametrize("vals", PARETO_EXAMPLES)
def test_pareto_sorted_matches_oracle_examples(vals, tolerance):
    _check_matches_oracle(list(vals), tolerance)


def test_pareto_three_objectives_uses_bruteforce():
    pts = [(1.0, 2.0, 3.0), (3.0, 2.0, 1.0), (2.0, 2.0, 2.0), (1.0, 1.0, 1.0)]
    objs = [lambda v: v[0], lambda v: v[1], lambda v: v[2]]
    assert pareto_front(pts, objs) == pareto_front_bruteforce(pts, objs)
    assert (1.0, 1.0, 1.0) not in pareto_front(pts, objs)


@pytest.mark.parametrize("tolerance", [0.0, 0.01, 0.25])
def test_pareto_multiobjective_vectorized_matches_oracle(tolerance):
    """Lists of >= 32 all-float rows take the numpy pairwise scan for >= 3
    objectives (the multi-tenant rate vectors) — same keep-set and order as
    the pure-Python oracle, ties and duplicates included."""
    base = [(float(i % 4) / 2.0, float((i * 7) % 5) / 2.0,
             float((i * 3) % 4) / 2.0) for i in range(12)]
    pts = [base[(i * 5) % len(base)] for i in range(64)]  # heavy duplication
    objs = [lambda v: v[0], lambda v: v[1], lambda v: v[2]]
    assert pareto_front(pts, objs, tolerance=tolerance) == \
        pareto_front_bruteforce(pts, objs, tolerance=tolerance)


if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=-1e9, max_value=1e9, allow_nan=False,
                       allow_infinity=False)
    # coarse grid values force plenty of exact ties (the tricky cases)
    gridded = st.integers(min_value=-4, max_value=4).map(lambda i: i / 2.0)
    point2 = st.tuples(st.one_of(finite, gridded), st.one_of(finite, gridded))

    @settings(max_examples=300, deadline=None)
    @given(vals=st.lists(point2, max_size=40),
           tolerance=st.one_of(st.just(0.0),
                               st.floats(min_value=0.0, max_value=0.5,
                                         allow_nan=False)))
    def test_pareto_sorted_matches_oracle_property(vals, tolerance):
        """The O(n log n) sweep and the O(n²) oracle agree on the exact
        keep-set (same points, same order) for any finite 2-objective input,
        tolerance included."""
        _check_matches_oracle(vals, tolerance)

    @settings(max_examples=200, deadline=None)
    @given(data=st.data())
    def test_pareto_tie_heavy_matches_oracle_property(data):
        """Duplicate-forcing regression: rows sampled from a small base
        pool guarantee exact duplicates and threshold-coinciding values —
        the historical worst case for sweep-based Pareto filters."""
        base = data.draw(st.lists(point2, min_size=1, max_size=6))
        n = data.draw(st.integers(min_value=1, max_value=40))
        vals = [data.draw(st.sampled_from(base)) for _ in range(n)]
        tolerance = data.draw(st.sampled_from([0.0, 1e-9, 0.05, 0.25]))
        _check_matches_oracle(vals, tolerance)

    point3 = st.tuples(st.one_of(finite, gridded), st.one_of(finite, gridded),
                       st.one_of(finite, gridded))

    @settings(max_examples=100, deadline=None)
    @given(vals=st.lists(point3, min_size=32, max_size=48),
           tolerance=st.sampled_from([0.0, 0.05]))
    def test_pareto_vectorized_3obj_matches_oracle_property(vals, tolerance):
        """>= 32 rows and 3 objectives route through the numpy pairwise
        scan; the keep-set must equal the pure-Python oracle exactly."""
        objectives = [lambda v: v[0], lambda v: v[1], lambda v: v[2]]
        fast = pareto_front(vals, objectives, tolerance=tolerance)
        oracle = pareto_front_bruteforce(vals, objectives,
                                         tolerance=tolerance)
        assert fast == oracle

    @settings(max_examples=100, deadline=None)
    @given(vals=st.lists(point2, min_size=1, max_size=25))
    def test_pareto_frontier_is_nondominated_property(vals):
        objectives = [lambda v: v[0], lambda v: v[1]]
        front = pareto_front(vals, objectives)
        assert front  # a finite nonempty set always has a maximum
        for f in front:
            assert not any(
                o[0] >= f[0] and o[1] >= f[1] and (o[0] > f[0] or o[1] > f[1])
                for o in vals
            )
        # every excluded point is dominated by some kept point
        kept = set(id(f) for f in front)
        for v in vals:
            if id(v) in kept:
                continue
            assert any(
                o[0] >= v[0] and o[1] >= v[1] and (o[0] > v[0] or o[1] > v[1])
                for o in front
            ) or v in front  # duplicates of kept points are kept too


def test_pareto_nonfinite_falls_back():
    vals = [(math.inf, 0.0), (1.0, 1.0), (0.0, math.nan)]
    objectives = [lambda v: v[0], lambda v: v[1]]
    # no crash, and agreement with the oracle by construction (same path)
    assert pareto_front(vals, objectives) == pareto_front_bruteforce(
        vals, objectives)
