"""Batched DSE engine tests (the vectorized scorer of ``repro.dse.batched``
and the incremental ``explore_multi(prev=...)`` path).

Locks the three guarantees the vectorized engine ships with:

* the numpy backend is **byte-identical** to the scalar ``place()`` path —
  per config and per metric, including the coupling decomposition
  (uncoupled max-stage time, credit-loop binding bound, round period);
* the ``AnalysisTables`` dense export reconstructs exactly the partition
  DP and stage overheads the scalar compiler computes;
* ``explore_multi(prev=...)`` reuses surviving tenants' Step-1 caches and
  seeds the incumbent set without changing the result: frontier and
  balanced point equal the from-scratch run, with exactly one fresh
  analysis for the changed tenant.

The JAX backend is tolerance-locked (XLA reassociates and FMA-fuses, so
byte equality is out of scope by design).
"""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.compiler import STATS, analyze, clear_analysis_cache, place, zoo
from repro.compiler.partition import partition
from repro.core.pu import make_u50_system
from repro.dse import explore_multi, score_details, score_single_batch
from repro.dse.explorer import _point_of, enumerate_single_batch


def _zoo_graphs():
    return [
        zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
        zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1),
        zoo.transformer_decoder("qwen3-0.6b", seq_len=64, decode_steps=8,
                                depth=2),
    ]


ZOO_IDS = ["tiny_cnn", "qwen3_enc", "qwen3_dec"]


class TestBatchedScoringEquivalence:
    """Numpy-backend scoring is byte-identical to the scalar place() path."""

    @pytest.mark.parametrize("gi", [0, 1, 2], ids=ZOO_IDS)
    def test_batched_equals_scalar_points(self, gi):
        g = _zoo_graphs()[gi]
        bat = enumerate_single_batch(g, engine="batched")
        scl = enumerate_single_batch(g, engine="scalar")
        assert bat == scl  # dataclass equality: every field, every config

    @pytest.mark.parametrize("budget", [(3, 2), (1, 4), (5, 5)])
    def test_batched_equals_scalar_nondefault_budgets(self, budget):
        a, b = budget
        g = _zoo_graphs()[0]
        bat = enumerate_single_batch(g, n_pu1x=a, n_pu2x=b, engine="batched")
        scl = enumerate_single_batch(g, n_pu1x=a, n_pu2x=b, engine="scalar")
        assert bat == scl
        assert len(bat) == (a + 1) * (b + 1) - 1

    @pytest.mark.parametrize("gi", [0, 1, 2], ids=ZOO_IDS)
    def test_score_details_matches_place_decomposition(self, gi):
        """Beyond the point metrics, the coupling decomposition (round
        period, uncoupled max-stage time, credit-loop binding bound) must
        match the scalar model float-for-float per config."""
        g = _zoo_graphs()[gi]
        pus = make_u50_system()
        an = analyze(g, pus)
        configs = [(a, b) for a in range(6) for b in range(6) if a + b > 0]
        sc = score_details(an, configs, pus=pus)
        assert sc.configs == configs
        for j, (a, b) in enumerate(configs):
            cm = place(an, a, b, pus=pus)
            assert sc.fps[j] == cm.predicted_fps
            assert sc.latency[j] == cm.predicted_latency
            assert sc.tops[j] == cm.used_tops
            assert sc.pbe[j] == cm.pbe()
            assert sc.round_seconds[j] == cm.coupling.round_seconds
            assert sc.uncoupled_seconds[j] == cm.coupling.uncoupled_seconds
            assert sc.binding_bound[j] == max(
                (bb.bound_seconds for bb in cm.coupling.bounds), default=0.0)

    def test_score_single_batch_wraps_details(self):
        g = _zoo_graphs()[0]
        pus = make_u50_system()
        an = analyze(g, pus)
        configs = [(1, 0), (2, 3), (0, 1)]
        pts = score_single_batch(an, configs, pus=pus)
        assert [p.config for p in pts] == configs
        for p in pts:
            assert p == _point_of(place(an, p.a, p.b, pus=pus), p.a, p.b)

    def test_budget_exceeding_pool_raises(self):
        """A config whose reconstructed stages outnumber the PU pool fails
        the same way the scalar path does (a graph with few segments can
        absorb an oversized budget in both engines — the partition caps the
        stage count)."""
        g = _zoo_graphs()[0]  # few segments: absorbs an oversized budget
        pus = make_u50_system()
        an = analyze(zoo.transformer_encoder("qwen3-0.6b", seq_len=64,
                                             depth=2), pus)
        with pytest.raises(ValueError, match="no free PU1x"):
            place(an, 6, 0, pus=pus)
        with pytest.raises(ValueError, match="no free PU1x"):
            score_details(an, [(6, 0)], pus=pus)
        # few segments: both engines absorb the oversized budget instead
        an_small = analyze(g, pus)
        assert (score_details(an_small, [(6, 0)], pus=pus).fps[0]
                == place(an_small, 6, 0, pus=pus).predicted_fps)

    def test_unknown_backend_and_engine_rejected(self):
        g = _zoo_graphs()[0]
        an = analyze(g, make_u50_system())
        with pytest.raises(ValueError):
            score_details(an, [(1, 0)], backend="warp")
        with pytest.raises(ValueError):
            enumerate_single_batch(g, engine="reference")

    if HAVE_HYPOTHESIS:

        @settings(max_examples=8, deadline=None)
        @given(
            a_budget=st.integers(min_value=1, max_value=5),
            b_budget=st.integers(min_value=0, max_value=5),
            channels=st.sampled_from([(4, 8, 8), (8, 16, 16), (16, 32, 32)]),
        )
        def test_random_zoo_and_budget_property(self, a_budget, b_budget,
                                                channels):
            g = zoo.tiny_cnn(channels=channels, hw=16)
            bat = enumerate_single_batch(g, n_pu1x=a_budget, n_pu2x=b_budget,
                                         engine="batched")
            scl = enumerate_single_batch(g, n_pu1x=a_budget, n_pu2x=b_budget,
                                         engine="scalar")
            assert bat == scl


class TestAnalysisTables:
    """The dense export reconstructs the scalar partition DP exactly."""

    @pytest.mark.parametrize("gi", [0, 1, 2], ids=ZOO_IDS)
    def test_reconstruct_matches_partition(self, gi):
        g = _zoo_graphs()[gi]
        an = analyze(g, make_u50_system())
        tab = an.tables()
        for a in range(4):
            for b in range(4):
                if a + b == 0:
                    continue
                stages = tab.reconstruct(a, b)
                ref = partition(an.graph, an.profiles, a, b,
                                memo=an._partition_memo)
                assert stages == ref.stages  # kind, nids and time per stage

    def test_tables_cached_on_analysis(self):
        an = analyze(_zoo_graphs()[0], make_u50_system())
        assert an.tables() is an.tables()  # built once, then reused


@pytest.mark.skipif("not __import__('importlib').util.find_spec('jax')")
class TestJaxBackend:
    """The jit/vmap backend tracks the exact numpy path within float
    tolerance (XLA may reassociate and FMA-fuse, so no byte equality)."""

    def test_jax_close_to_numpy(self):
        import numpy as np

        g = _zoo_graphs()[1]
        pus = make_u50_system()
        an = analyze(g, pus)
        configs = [(a, b) for a in range(4) for b in range(4) if a + b > 0]
        ref = score_details(an, configs, pus=pus, backend="numpy")
        jx = score_details(an, configs, pus=pus, backend="jax")
        for field in ("fps", "latency", "tops", "pbe", "round_seconds",
                      "uncoupled_seconds", "binding_bound"):
            np.testing.assert_allclose(getattr(jx, field),
                                       getattr(ref, field),
                                       rtol=1e-9, atol=1e-12)


class TestIncrementalExploreMulti:
    """``explore_multi(prev=...)`` equals from-scratch and re-scores only
    the changed tenant."""

    def _tenants(self):
        return [
            zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
            zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1),
            zoo.tiny_cnn(channels=(8, 16, 16), hw=16),
        ]

    def test_swap_one_tenant_matches_scratch(self):
        graphs = self._tenants()
        base = explore_multi(graphs)
        swapped = self._tenants()
        swapped[2] = zoo.tiny_cnn(channels=(4, 8, 8), hw=8)
        clear_analysis_cache()
        STATS.reset()
        inc = explore_multi(swapped, prev=base)
        # only the swapped-in tenant is analyzed; survivors ride prev's
        # Step-1 caches by identity
        assert STATS.snapshot()["analysis_misses"] == 1
        assert inc.singles[0] is base.singles[0]
        assert inc.singles[1] is base.singles[1]
        scratch = explore_multi(swapped)
        assert inc.frontier == scratch.frontier
        assert inc.balanced == scratch.balanced

    def test_add_and_drop_tenant(self):
        pair = self._tenants()[:2]
        base = explore_multi(pair)
        # add a tenant
        grown = pair + [zoo.tiny_cnn(channels=(4, 8, 8), hw=8)]
        inc = explore_multi(grown, prev=base)
        assert inc.frontier == explore_multi(grown).frontier
        # drop back to two tenants, reusing the 3-tenant result
        shrunk = explore_multi(pair, prev=inc)
        assert shrunk.frontier == base.frontier
        assert shrunk.balanced == base.balanced

    def test_budget_mismatch_ignores_prev(self):
        graphs = self._tenants()
        base = explore_multi(graphs)  # 5+5 budget
        clear_analysis_cache()
        STATS.reset()
        inc = explore_multi(graphs, n_pu1x=4, n_pu2x=4, prev=base)
        # prev unusable -> every tenant re-analyzed (3 distinct graphs)
        assert STATS.snapshot()["analysis_misses"] == 3
        assert inc.frontier == explore_multi(graphs, n_pu1x=4,
                                             n_pu2x=4).frontier

    def test_prev_with_tolerance_matches_scratch(self):
        graphs = self._tenants()
        base = explore_multi(graphs, tolerance=0.05)
        swapped = self._tenants()
        swapped[2] = zoo.tiny_cnn(channels=(4, 8, 8), hw=8)
        inc = explore_multi(swapped, tolerance=0.05, prev=base)
        scratch = explore_multi(swapped, tolerance=0.05)
        assert inc.frontier == scratch.frontier
        assert inc.balanced == scratch.balanced

    def test_result_records_budget_and_fingerprints(self):
        graphs = self._tenants()
        res = explore_multi(graphs, n_pu1x=3, n_pu2x=4)
        assert (res.n_pu1x, res.n_pu2x) == (3, 4)
        assert res.fingerprints == tuple(
            w.graph.fingerprint() for w in res.workloads)
