"""Online serving control plane tests: slot-packed decode conformance, the
per-slot K/V stream verifier check, the Session/RunReport API surface, the
deprecation shims, and the Server scheduling loop (admission order,
continuous-batching slot reuse, SLO eviction, and incremental mid-service
re-placement equal to a from-scratch exploration)."""
import copy

import pytest

from repro.compiler import zoo
from repro.core.isa import AddrCyc, DataMove
from repro.deploy import (
    SLO,
    RunReport,
    Session,
    Strategy,
    System,
    compile_deployment,
)
from repro.dse import explore_multi
from repro.dse.explorer import _normalize_engine
from repro.serve import Request, Server
from repro.verify import check_kv_streams, verify_programs


@pytest.fixture(scope="module")
def classic_dep():
    g = zoo.transformer_decoder(seq_len=64, decode_steps=8, depth=1)
    return compile_deployment(g, Strategy.single(2, 2))


@pytest.fixture(scope="module")
def classic_report(classic_dep):
    return System().load(classic_dep).run()


@pytest.fixture(scope="module")
def packed_dep():
    g = zoo.transformer_decoder(slots=(64, 32), decode_steps=8, depth=1)
    return compile_deployment(g, Strategy.single(2, 2))


@pytest.fixture(scope="module")
def packed_report(packed_dep):
    return System().load(packed_dep).run()


class TestPackedDecode:
    def test_one_slot_packed_is_bit_identical_to_classic(self, classic_report):
        g = zoo.transformer_decoder(slots=(64,), decode_steps=8, depth=1)
        dep = compile_deployment(g, Strategy.single(2, 2))
        rep = System().load(dep).run()
        assert rep.aggregate_fps() == pytest.approx(
            classic_report.aggregate_fps(), rel=1e-12)

    def test_two_slots_within_5pct_of_analytic(self, packed_dep,
                                               packed_report):
        sim_fps = packed_report.aggregate_fps()
        pred = packed_dep.predicted_throughput
        assert not packed_report.deadlocked
        assert abs(sim_fps - pred) / pred < 0.05

    def test_four_slots_within_5pct_of_analytic(self):
        g = zoo.transformer_decoder(slots=(128, 96, 64, 32), decode_steps=8,
                                    depth=1)
        dep = compile_deployment(g, Strategy.single(2, 2))
        rep = System().load(dep).run()
        pred = dep.predicted_throughput
        assert not rep.deadlocked
        assert abs(rep.aggregate_fps() - pred) / pred < 0.05

    def test_slot_token_accounting(self, packed_report):
        (m,) = packed_report.members
        assert m.n_slots == 2
        assert m.tokens == 2 * m.rounds
        assert packed_report.aggregate_token_rate() == pytest.approx(
            2 * packed_report.aggregate_fps(), rel=1e-9)

    def test_packed_deployment_is_verifier_clean(self, packed_dep):
        for m in packed_dep.members:
            rep = verify_programs(m.compiled.programs, mem=m.compiled.mem,
                                  member=m.workload.label)
            assert rep.ok, [str(d) for d in rep.errors]


def _kv_appends(programs, mem):
    """(dm, ac, plan) for every ST append into a K/V cache region."""
    plans = [p for p in mem.tensors.values() if p.kind == "kv"]
    out = []
    for pu in programs:
        insts = pu.st.instructions
        for idx, dm in enumerate(insts):
            if not isinstance(dm, DataMove) or idx + 1 >= len(insts):
                continue
            ac = insts[idx + 1]
            if not isinstance(ac, AddrCyc):
                continue
            for p in plans:
                if p.base_addr <= dm.cur_ba < p.base_addr + p.region_bytes:
                    out.append((dm, ac, p))
                    break
    return out


class TestKVStreamCheck:
    def test_clean_on_packed_deployment(self, packed_dep):
        for m in packed_dep.members:
            rep = check_kv_streams(m.compiled.programs, m.compiled.mem,
                                   member=m.workload.label)
            assert rep.ok and not rep.diagnostics

    def test_detects_cross_slot_append_mixup(self, packed_dep):
        (m,) = packed_dep.members
        programs = copy.deepcopy(m.compiled.programs)
        mem = m.compiled.mem
        appends = _kv_appends(programs, mem)
        # Retarget one slot's append cursor at a *different* slot's region —
        # every individual extent stays in bounds, so only the stream
        # cross-correlation can see it.
        victim = donor = None
        for dm, ac, p in appends:
            if donor is None:
                donor = (dm, ac, p)
            elif p.tid != donor[2].tid:
                victim = (dm, ac, p)
                break
        assert victim is not None, "need appends into two distinct slots"
        victim[0].cur_ba = donor[0].cur_ba
        victim[1].ba = donor[1].ba
        rep = check_kv_streams(programs, mem)
        msgs = " | ".join(d.message for d in rep.errors)
        assert not rep.ok
        assert "cross-slot append mixup" in msgs
        assert "no append stream" in msgs


class TestSessionAndRunReport:
    def test_load_returns_session_with_history(self, classic_dep):
        system = System()
        session = system.load(classic_dep)
        assert isinstance(session, Session)
        assert session.deployment is classic_dep
        assert [r.name for r in session.swaps] == [classic_dep.name]
        # switch returns the same live handle and records the swap
        assert system.switch(classic_dep) is session
        assert len(session.swaps) == 2
        assert session.swaps[-1].tenants == session.tenants

    def test_run_returns_forwarding_report(self, classic_report):
        rep = classic_report
        assert isinstance(rep, RunReport)
        assert rep.source == "run" and rep.sim is not None
        # unknown attributes forward to the backing SimResult
        assert rep.members is rep.sim.members
        assert rep.aggregate_fps() == rep.sim.aggregate_fps()
        assert set(rep.tenants) == set(rep.fps_by_workload())

    def test_percentiles_ordered(self, classic_report):
        rep = classic_report
        assert 0 < rep.latency_p50 <= rep.latency_p95 <= rep.latency_p99
        (t,) = rep.tenants.values()
        assert t.latency_p95 == rep.latency_p95
        assert rep.total_tokens == t.tokens > 0


class TestDeprecations:
    def test_bare_tuple_strategy_warns(self):
        with pytest.warns(DeprecationWarning, match="tuple-only"):
            s = Strategy.of((2, 2))
        assert s.configs == ((2, 2),)

    def test_tuple_list_strategy_warns(self):
        with pytest.warns(DeprecationWarning, match="tuple-only"):
            s = Strategy.of([(2, 2), (3, 3)])
        assert s.configs == ((2, 2), (3, 3))

    def test_named_constructors_do_not_warn(self, recwarn):
        Strategy.single(2, 2)
        Strategy.multi([(2, 2), (3, 3)])
        deps = [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]
        assert not deps

    def test_fast_engine_warns_and_normalizes(self):
        with pytest.warns(DeprecationWarning, match='engine="fast"'):
            assert _normalize_engine("fast") == "batched"
        assert _normalize_engine("batched") == "batched"
        with pytest.raises(ValueError):
            _normalize_engine("warp")


class TestServer:
    def test_request_validation(self):
        srv = Server()
        with pytest.raises(KeyError):
            srv.submit(Request("ghost", prompt_tokens=8, max_new_tokens=4))
        srv.join("a", depth=1, window=4)
        with pytest.raises(ValueError):
            srv.join("a")
        with pytest.raises(ValueError):
            srv.join("b", window=0)
        srv.submit(Request("a", prompt_tokens=8, max_new_tokens=4))
        with pytest.raises(ValueError):
            srv.leave("a")  # still has queued work
        srv.leave("a", force=True)
        assert srv.requests[0].evicted and not srv.requests[0].completed

    def test_admission_is_fifo_in_tenant_order(self):
        srv = Server()
        srv.join("a", depth=1, max_slots=1, window=4)
        srv.join("b", depth=1, max_slots=1, window=4)
        srv.submit(Request("b", prompt_tokens=32, max_new_tokens=8))   # b-1
        srv.submit(Request("a", prompt_tokens=32, max_new_tokens=4))   # a-2
        srv.submit(Request("a", prompt_tokens=16, max_new_tokens=4))   # a-3
        srv.drain()
        admits = [e.detail.split()[0] for e in srv.events
                  if e.kind == "admit"]
        # tenants admit in sorted name order, FIFO within a tenant; a-3
        # waits for a-2's slot and reuses it at the window boundary
        assert admits == ["a-2", "b-1", "a-3"]
        assert all(r.completed for r in srv.requests)

    def test_slot_reuse_matches_separate_runs(self):
        srv = Server()
        srv.join("t", depth=1, max_slots=2, window=4)
        reqs = [Request("t", prompt_tokens=48, max_new_tokens=8),
                Request("t", prompt_tokens=24, max_new_tokens=4),
                Request("t", prompt_tokens=32, max_new_tokens=4)]
        for r in reqs:
            srv.submit(r)
        rep = srv.drain()
        assert all(r.completed for r in reqs)
        assert all(r.generated == r.max_new_tokens for r in reqs)
        # window 1 packs r1+r2, r2 retires at the boundary, window 2 packs
        # r1 (deeper now) + r3 in the freed slot
        assert srv.windows == 2
        # token accounting equals N separate single-session decode runs
        a, b = srv.placement.config_for("t")
        separate = 0
        for r in reqs:
            g = zoo.transformer_decoder(seq_len=r.prompt_tokens,
                                        decode_steps=r.max_new_tokens,
                                        depth=1)
            dep = compile_deployment(g, Strategy.single(a, b))
            separate += System().load(dep).run().total_tokens
        assert rep.tenants["t"].tokens == separate == 16

    def test_slo_violation_replans_then_evicts(self):
        srv = Server(slo_patience=2)
        srv.join("lo", depth=1, max_slots=1, window=4,
                 slo=SLO(min_tokens_per_s=1e12))  # unattainable rate floor
        req = srv.submit(Request("lo", prompt_tokens=32, max_new_tokens=32))
        rep = srv.drain()
        kinds = [e.kind for e in srv.events]
        # two violating windows -> one remedial replan; two more -> shed
        assert any(e.kind == "replan" and e.detail == "slo remediation"
                   for e in srv.events)
        assert "evict" in kinds
        assert req.evicted and not req.completed
        assert 0 < req.generated < req.max_new_tokens
        assert rep.tenants["lo"].slo_attainment == 0.0

    def test_two_tenants_join_leave_mid_service(self):
        srv = Server()
        srv.join("alice", depth=1, max_slots=2, window=8)
        srv.join("bob", depth=1, max_slots=2, window=8)
        srv.submit(Request("alice", prompt_tokens=64, max_new_tokens=12))
        srv.submit(Request("alice", prompt_tokens=32, max_new_tokens=20))
        srv.submit(Request("bob", prompt_tokens=48, max_new_tokens=10))
        # arrives mid-service, admitted into bob's second slot on the fly
        srv.submit(Request("bob", prompt_tokens=40, max_new_tokens=8,
                           arrival_s=1e-4))
        rep = srv.drain()
        assert all(r.completed for r in srv.requests)
        assert rep.total_tokens == 50
        assert rep.tenants["alice"].latency_p95 > 0
        # bob leaves; alice keeps serving alone (single-tenant placement)
        srv.leave("bob")
        srv.submit(Request("alice", prompt_tokens=16, max_new_tokens=6,
                           arrival_s=srv.now))
        rep2 = srv.drain()
        assert "bob" not in rep2.tenants
        assert all(r.completed for r in srv.requests)

    def test_incremental_replacement_equals_from_scratch(self):
        srv = Server()
        srv.join("a", depth=1, max_slots=1, window=4)
        srv.join("b", depth=1, max_slots=1, window=4)
        srv.submit(Request("a", prompt_tokens=32, max_new_tokens=16))
        srv.submit(Request("b", prompt_tokens=32, max_new_tokens=16))
        assert srv.step()  # places {a, b}
        first = srv.placement
        assert srv._prev_multi is not None
        # c joins mid-service -> membership change -> incremental replan
        srv.join("c", depth=1, max_slots=1, window=4)
        srv.submit(Request("c", prompt_tokens=24, max_new_tokens=8,
                           arrival_s=srv.now))
        assert srv.step()
        second = srv.placement
        assert second is not first
        assert [e.kind for e in srv.events].count("replan") == 2
        # the online (prev=...) placement is byte-equal to exploring the
        # new tenant set from scratch
        ws = [srv._tenants[n].workload for n in ("a", "b", "c")]
        scratch = explore_multi(ws, n_pu1x=srv.n_pu1x,
                                n_pu2x=srv.n_pu2x).balanced
        assert second.point == scratch
        assert second.configs == scratch.configs
        srv.drain()
        assert all(r.completed for r in srv.requests)
