"""ISA unit tests: 64-bit encode/decode round-trips + Table I(b) dynamic
state-update algorithms (AddrCyc, Sync).

``hypothesis`` is an optional dev dependency: when present, the round-trip
and BID-cycling properties are checked on random inputs; without it they
degrade to the same checks over a fixed example grid."""
import pytest

try:
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.isa import (
    AddrCyc,
    Compute,
    Config,
    DataMove,
    Group,
    Instruction,
    Opcode,
    ProgCtrl,
    Sync,
    validate_group,
)
from repro.core.program import Program


# ---------------------------------------------------------------- encoding --
ALL_SAMPLES = [
    ProgCtrl(nr=0, icu_ba=3, prg_end=True),
    ProgCtrl(nr=1000, icu_ba=0),
    Config(op=Opcode.IM2COL_PRM, param0=7, param1=3, param2=1, param3=224),
    Config(op=Opcode.URAM_PRM, param0=0x1234),
    DataMove(op=Opcode.LINEAR_ADM, cur_ba=0xABCD00, length=65536, channel=17),
    DataMove(op=Opcode.WEIGHTS_ADM, cur_ba=64, length=64, channel=0),
    AddrCyc(ba=0x100000, aoffs=4096, nc=3, ic=3),
    Sync(op=Opcode.SEND_REQ, pid=9, bid=5, base_bid=0, nc=7, ic=7),
    Sync(op=Opcode.WAIT_ACK, pid=1, bid=0, base_bid=0, nc=1, ic=1, prg_end=True),
    Compute(m=2048, n=4096, k=4608, relu=True, add_enable=True, scale_shift=7,
            rounds=1, wchunks=36),
]


@pytest.mark.parametrize("inst", ALL_SAMPLES, ids=lambda i: type(i).__name__ + "_" + str(id(i) % 97))
def test_encode_decode_roundtrip(inst):
    word = inst.encode()
    assert 0 <= word < (1 << 64), "must be a 64-bit instruction"
    back = Instruction.decode(word)
    assert back == inst


def test_field_overflow_rejected():
    with pytest.raises(ValueError):
        Compute(m=1 << 13).encode()
    with pytest.raises(ValueError):
        DataMove(op=Opcode.LINEAR_ADM, length=64 << 22).encode()
    # HBM addresses must be 64-byte (AXI beat) aligned.
    with pytest.raises(ValueError):
        DataMove(op=Opcode.LINEAR_ADM, cur_ba=17).encode()


def test_datamove_length_rounds_up_to_beat():
    """Transfer lengths are encoded in 64 B beats (rounded up), as the ADM
    issues whole AXI beats."""
    inst = DataMove(op=Opcode.LINEAR_ADM, cur_ba=0, length=1000)
    back = Instruction.decode(inst.encode())
    assert back.length == 1024


def _check_addrcyc_roundtrip(ba, aoffs, nc):
    inst = AddrCyc(ba=ba * 64, aoffs=aoffs * 64, nc=nc, ic=nc)
    assert Instruction.decode(inst.encode()) == inst


ADDRCYC_EXAMPLES = [
    (0, 0, 0),
    (1, 1, 1),
    (12345, 77, 3),
    ((1 << 20) - 1, (1 << 14) - 1, 127),
]

if HAVE_HYPOTHESIS:

    @given(
        ba=st.integers(0, (1 << 20) - 1),
        aoffs=st.integers(0, (1 << 14) - 1),
        nc=st.integers(0, 127),
    )
    def test_addrcyc_roundtrip_hypothesis(ba, aoffs, nc):
        _check_addrcyc_roundtrip(ba, aoffs, nc)

else:

    @pytest.mark.parametrize("ba,aoffs,nc", ADDRCYC_EXAMPLES)
    def test_addrcyc_roundtrip_hypothesis(ba, aoffs, nc):
        _check_addrcyc_roundtrip(ba, aoffs, nc)


# --------------------------------------------------- Table I(b) algorithms --
def test_addrcyc_cycles_over_n_regions():
    """NC=n-1 cycles a DataMove base address over n regions."""
    n, size, base = 4, 4096, 0x1000
    adm = DataMove(op=Opcode.LINEAR_ADM, cur_ba=base, length=size)
    cyc = AddrCyc(ba=base, aoffs=size, nc=n - 1, ic=n - 1)
    seen = []
    for _ in range(3 * n):
        seen.append(adm.cur_ba)
        adm.cur_ba = cyc.step(adm.cur_ba)
    expect = [base + size * (i % n) for i in range(3 * n)]
    assert seen == expect


def test_addrcyc_pingpong_nc1():
    """NC=1 creates the two-region ping-pong of the B-buffers."""
    adm = DataMove(op=Opcode.LINEAR_ADM, cur_ba=0, length=64)
    cyc = AddrCyc(ba=0, aoffs=64, nc=1, ic=1)
    seq = []
    for _ in range(6):
        seq.append(adm.cur_ba)
        adm.cur_ba = cyc.step(adm.cur_ba)
    assert seq == [0, 64, 0, 64, 0, 64]


def test_sync_bid_bypass():
    s = Sync(op=Opcode.SEND_ACK, pid=0, bid=1, nc=0)
    for _ in range(5):
        s.step()
        assert s.bid == 1  # NC==0: bypass, BID unchanged


def test_sync_bid_pingpong():
    s = Sync(op=Opcode.SEND_REQ, pid=1, bid=0, base_bid=0, nc=1, ic=1)
    bids = []
    for _ in range(6):
        bids.append(s.bid)
        s.step()
    assert bids == [0, 1, 0, 1, 0, 1]


def test_sync_bid_depth4_rotation():
    """Deeper pipelines rotate BID over proportionally more buffers."""
    s = Sync(op=Opcode.SEND_REQ, pid=1, bid=2, base_bid=2, nc=3, ic=3)
    bids = [s.bid]
    for _ in range(8):
        s.step()
        bids.append(s.bid)
    assert bids[:8] == [2, 3, 4, 5, 2, 3, 4, 5]


def _check_sync_bid_cycle(nc, base, steps):
    s = Sync(op=Opcode.WAIT_REQ, pid=0, bid=base, base_bid=base, nc=nc, ic=nc)
    for i in range(steps):
        assert s.bid == base + (i % (nc + 1))
        s.step()


SYNC_CYCLE_EXAMPLES = [(1, 0, 6), (3, 2, 17), (7, 7, 60), (12, 0, 25)]

if HAVE_HYPOTHESIS:

    @given(nc=st.integers(1, 12), base=st.integers(0, 7), steps=st.integers(1, 60))
    def test_sync_bid_cycle_property(nc, base, steps):
        _check_sync_bid_cycle(nc, base, steps)

else:

    @pytest.mark.parametrize("nc,base,steps", SYNC_CYCLE_EXAMPLES)
    def test_sync_bid_cycle_property(nc, base, steps):
        _check_sync_bid_cycle(nc, base, steps)


# ------------------------------------------------------------ group checks --
def test_group_legality():
    validate_group(Sync(op=Opcode.WAIT_REQ, pid=0), Group.LD)
    validate_group(Sync(op=Opcode.SEND_REQ, pid=0), Group.ST)
    with pytest.raises(ValueError):
        validate_group(Sync(op=Opcode.SEND_REQ, pid=0), Group.LD)
    with pytest.raises(ValueError):
        validate_group(Compute(), Group.LD)
    with pytest.raises(ValueError):
        validate_group(DataMove(op=Opcode.WEIGHTS_ADM), Group.ST)


def test_program_validation():
    good = Program.assemble(
        Group.LD,
        [
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=0, length=64),
            AddrCyc(ba=0, aoffs=64, nc=1, ic=1),
        ],
        rounds=2,
    )
    good.validate()
    # AddrCyc without a predecessor DataMove is illegal.
    bad = Program(Group.LD, [AddrCyc(), ProgCtrl(nr=1, prg_end=True)])
    with pytest.raises(ValueError):
        bad.validate()
    # Missing PRG_END terminal.
    bad2 = Program(Group.LD, [DataMove(op=Opcode.LINEAR_ADM)])
    with pytest.raises(ValueError):
        bad2.validate()


def test_program_encode_decode_roundtrip():
    prog = Program.assemble(
        Group.ST,
        [
            Sync(op=Opcode.WAIT_ACK, pid=1, bid=0, base_bid=0, nc=1, ic=1),
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=0x40, length=128, channel=2),
            AddrCyc(ba=0x40, aoffs=128, nc=1, ic=1),
            Sync(op=Opcode.SEND_REQ, pid=1, bid=0, base_bid=0, nc=1, ic=1),
        ],
        rounds=10,
    )
    words = prog.encode()
    back = Program.decode(Group.ST, words)
    assert back.instructions == prog.instructions
