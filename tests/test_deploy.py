"""Deployment layer tests (paper Sec. V): Strategy normalization (including
per-member Workloads), disjoint resource partitioning with aggregate
diagnostics, DP-A/B/C compiled to executable deployments, multi-tenant
(mixed-model) deployments, System load/switch/run on one fixed machine, and
simulated-vs-analytic agreement."""
import pytest

from repro.compiler import zoo
from repro.core.pu import make_u50_system
from repro.deploy import (
    Strategy,
    System,
    Workload,
    compile_deployment,
    partition_resources,
)
from repro.dse import explore


@pytest.fixture(scope="module")
def graph():
    return zoo.resnet50(256)


@pytest.fixture(scope="module")
def dse(graph):
    return explore(graph)


@pytest.fixture(scope="module")
def system():
    return System()


@pytest.fixture(scope="module")
def dep_a(graph, dse):
    return dse.deploy(dse.dp_a, rounds=6)


@pytest.fixture(scope="module")
def dep_b(graph, dse):
    return dse.deploy(dse.dp_b, rounds=5)


@pytest.fixture(scope="module")
def dep_c(graph, dse):
    return dse.deploy(dse.dp_c, rounds=5)


@pytest.fixture(scope="module")
def sim_a(system, dep_a):
    return system.load(dep_a).run()


@pytest.fixture(scope="module")
def sim_b(system, sim_a, dep_b):
    return system.switch(dep_b).run()


@pytest.fixture(scope="module")
def sim_c(system, sim_b, dep_c):
    return system.switch(dep_c).run()


class TestStrategy:
    def test_of_accepts_all_schedule_forms(self, dse):
        assert Strategy.of((5, 5)).members == ((5, 5),)
        assert Strategy.of([(1, 0), (0, 1)]).members == ((1, 0), (0, 1))
        assert Strategy.of(dse.dp_a).members == ((5, 5),)
        assert Strategy.of(dse.dp_c).members == dse.dp_c.configs
        s = Strategy.single(2, 3)
        assert Strategy.of(s) is s

    def test_rejects_degenerate(self):
        with pytest.raises(ValueError):
            Strategy.multi([])
        with pytest.raises(ValueError):
            Strategy.multi([(0, 0)])

    def test_totals(self):
        s = Strategy.multi([(1, 0), (2, 3)])
        assert (s.total_a, s.total_b, s.batch) == (3, 3, 2)

    def test_legacy_tuple_forms_round_trip(self):
        """Old tuple-shaped strategies normalize and compare equal: members
        without a workload are interchangeable with their (a, b) tuples."""
        for form in ((5, 5), [(1, 0), (0, 1)], [(2, 3), (1, 1)]):
            s = Strategy.of(form)
            assert Strategy.of(s) is s
            assert Strategy.of(s.members) == s  # members re-normalize
            assert Strategy.of(s.configs) == s  # legacy view re-normalizes
            assert all(m.workload is None for m in s.members)
        m = Strategy.of((2, 3)).members[0]
        assert m == (2, 3) and (2, 3) == m
        assert hash(m) == hash((2, 3))
        a, b = m  # tuple unpacking still works
        assert (a, b) == (2, 3)

    def test_workload_members(self):
        cnn, enc = zoo.tiny_cnn(), zoo.transformer_encoder(
            "qwen3-0.6b", seq_len=64, depth=1)
        s = Strategy.tenants([(cnn, 2, 2), (enc, 3, 3)])
        assert s.is_multi_tenant
        assert [w.graph for w in s.workloads] == [cnn, enc]
        assert s.configs == ((2, 2), (3, 3))
        # a workload-bound member is NOT equal to its bare tuple
        assert s.members[0] != (2, 2)
        # (graph, a, b) triples normalize through Strategy.of too
        assert Strategy.of([(cnn, 2, 2), (enc, 3, 3)]) == s

    def test_broadcast_binds_only_unbound_members(self):
        cnn, enc = zoo.tiny_cnn(), zoo.linear_chain(3)
        s = Strategy.multi([(Workload(cnn), 1, 0), (0, 1)]).with_workload(enc)
        assert s.members[0].workload.graph is cnn
        assert s.members[1].workload.graph is enc

    def test_tenants_requires_workloads(self):
        with pytest.raises(ValueError):
            Strategy.tenants([(1, 0), (0, 1)])

    def test_of_preserves_member_workload(self):
        """A lone workload-bound Member normalizes without losing its
        workload (it must not be mistaken for a bare DSE point)."""
        cnn = zoo.tiny_cnn()
        m = Strategy.tenants([(cnn, 1, 1)]).members[0]
        assert Strategy.of(m).members[0].workload.graph is cnn
        assert Strategy.of([m]).members[0].workload.graph is cnn


class TestResourcePartitioning:
    def test_members_get_disjoint_channels(self):
        strat = Strategy.of([(1, 0)] * 5 + [(0, 1)] * 5)
        res = partition_resources(strat, make_u50_system())
        seen = set()
        for r in res:
            assert len(r.channel_pool) >= 3
            assert not (seen & set(r.channel_pool))
            seen |= set(r.channel_pool)
        assert len(seen) == 32  # the whole channel space is put to work

    def test_oversubscription_rejected(self):
        with pytest.raises(ValueError):
            partition_resources(Strategy.of([(5, 5), (1, 0)]), make_u50_system())

    def test_diagnostics_name_each_member(self):
        """An infeasible strategy reports every member's demand against the
        machine in one error, instead of failing deep inside compilation."""
        cnn, enc = zoo.tiny_cnn(), zoo.transformer_encoder(
            "qwen3-0.6b", seq_len=64, depth=1)
        strat = Strategy.tenants([(cnn, 5, 5), (enc, 1, 0)])
        with pytest.raises(ValueError) as ei:
            partition_resources(strat, make_u50_system())
        msg = str(ei.value)
        assert "member 0 [tiny_cnn]: 5x PU1x + 5x PU2x" in msg
        assert "member 1 [qwen3-0_6b_enc1_s64]: 1x PU1x + 0x PU2x" in msg
        assert "PU1x overcommitted: 6 requested, 5 available" in msg

    def test_channel_overcommit_diagnosed(self):
        with pytest.raises(ValueError) as ei:
            partition_resources(Strategy.of([(1, 0)] * 3), make_u50_system(),
                                n_channels=2)
        msg = str(ei.value)
        assert "HBM channels overcommitted" in msg
        assert "member 2" in msg

    def test_traffic_weighted_channel_shares(self):
        """In a mixed-model deployment the streaming-heavier tenant gets the
        wider channel slice (slice sizing follows each member's own memory
        footprint, not just its PU count)."""
        cnn = zoo.tiny_cnn(channels=(16, 32, 32), hw=16)
        enc = zoo.transformer_encoder("qwen3-0.6b", seq_len=256, depth=2)
        res = partition_resources(
            Strategy.tenants([(cnn, 2, 2), (enc, 2, 2)]), make_u50_system())
        assert len(res[1].channel_pool) > len(res[0].channel_pool)
        # same workload on both members -> back to the PU-count split
        res_eq = partition_resources(
            Strategy.tenants([(cnn, 2, 2), (cnn, 2, 2)]), make_u50_system())
        assert len(res_eq[0].channel_pool) == len(res_eq[1].channel_pool)


class TestCompiledDeployments:
    def test_dp_c_disjoint_pus_and_channels(self, dep_c):
        dep_c.assert_disjoint()
        all_pids = sorted(pid for m in dep_c.members for pid in m.pids)
        assert all_pids == list(range(10))  # one PU per member, all ten used
        assert dep_c.batch == 10

    def test_dp_b_disjoint(self, dep_b, dse):
        dep_b.assert_disjoint()
        assert dep_b.batch == dse.dp_b.batch

    def test_analytic_model_matches_dse_cache(self, dep_b, dep_c, dse):
        """The deployment aggregates reproduce the Step-2 schedule metrics."""
        assert dep_b.predicted_throughput == pytest.approx(dse.dp_b.throughput)
        assert dep_b.predicted_latency == pytest.approx(dse.dp_b.latency)
        assert dep_c.predicted_throughput == pytest.approx(dse.dp_c.throughput)

    def test_rounds_override_patches_programs(self, dep_a):
        progs = dep_a.programs(rounds=3)
        assert all(p.ld.progctrl.nr == 3 for p in progs)
        # the compiled originals are untouched
        assert all(p.ld.progctrl.nr == dep_a.rounds
                   for m in dep_a.members for p in m.compiled.programs)


class TestSystemExecution:
    def test_dp_a_throughput_within_10pct_of_analytic(self, dep_a, sim_a):
        assert not sim_a.deadlocked
        meas = sim_a.aggregate_fps(warmup=2)
        assert meas == pytest.approx(dep_a.predicted_throughput, rel=0.10)

    def test_dp_b_throughput_within_10pct_of_analytic(self, dep_b, sim_b):
        assert not sim_b.deadlocked
        meas = sim_b.aggregate_fps(warmup=2)
        assert meas == pytest.approx(dep_b.predicted_throughput, rel=0.10)

    def test_dp_c_throughput_within_10pct_of_analytic(self, dep_c, sim_c):
        assert not sim_c.deadlocked
        meas = sim_c.aggregate_fps(warmup=2)
        assert meas == pytest.approx(dep_c.predicted_throughput, rel=0.10)

    def test_per_member_latency_accounting(self, dep_c, sim_c):
        assert len(sim_c.members) == 10
        for m, dm in zip(sim_c.members, dep_c.members):
            assert m.rounds == 5
            assert m.member.first_pid == dm.first_pid
            assert m.latency_seconds() > 0
            # a one-PU member's latency tracks its own analytic prediction
            assert m.latency_seconds() == pytest.approx(dm.predicted_latency, rel=0.35)
        # system latency = slowest member
        assert sim_c.member_latency_seconds() == pytest.approx(
            max(m.latency_seconds() for m in sim_c.members))

    def test_switch_matches_fresh_load(self, dep_c, sim_c):
        """A switch-then-run is bit-identical to a fresh session's load-run:
        switching leaves no residue on the fixed machine."""
        fresh = System().load(dep_c).run()
        assert fresh.aggregate_fps(warmup=2) == pytest.approx(
            sim_c.aggregate_fps(warmup=2), rel=1e-9)
        assert fresh.round_end_cycles == sim_c.round_end_cycles

    def test_switch_requires_loaded_deployment(self, dep_a):
        with pytest.raises(RuntimeError):
            System().switch(dep_a)

    def test_incompatible_hardware_rejected(self, graph):
        pus = [p for p in make_u50_system() if p.pid not in (4, 9)]  # 4+4 PUs
        dep = compile_deployment(graph, (2, 2), pus=pus, rounds=2)
        with pytest.raises(ValueError):
            System().load(dep)

    def test_session_history_records_switches(self, system, sim_a, sim_b, sim_c):
        names = [n for n, _ in system.history]
        assert len(names) >= 3


class TestMultiTenant:
    """Mixed-model deployments (acceptance criterion): a ResNet-50 member
    and a qwen3-encoder member on disjoint PU/HBM slices compile, simulate
    deadlock-free, each member within 10% of its own analytic model, and a
    single-tenant -> two-tenant switch is bit-identical to a fresh load."""

    @pytest.fixture(scope="class")
    def qwen_graph(self):
        return zoo.transformer_encoder("qwen3-0.6b", seq_len=256, depth=2)

    @pytest.fixture(scope="class")
    def mixed_dep(self, graph, qwen_graph):
        strat = Strategy.tenants([(graph, 2, 2), (qwen_graph, 3, 3)],
                                 name="resnet+qwen")
        return compile_deployment(None, strat, rounds=5)

    @pytest.fixture(scope="class")
    def mixed_sim(self, mixed_dep):
        return System().load(mixed_dep).run()

    def test_disjoint_slices_and_labels(self, mixed_dep, graph, qwen_graph):
        mixed_dep.assert_disjoint()
        assert mixed_dep.is_multi_tenant
        assert mixed_dep.graph is None  # no single-model view of a mixed set
        assert [m.workload.graph for m in mixed_dep.members] == [graph, qwen_graph]

    def test_each_member_within_10pct_of_its_analytic(self, mixed_dep, mixed_sim):
        assert not mixed_sim.deadlocked
        for sm, dm in zip(mixed_sim.members, mixed_dep.members):
            assert sm.workload == dm.workload.label
            assert sm.throughput_fps(warmup=2) == pytest.approx(
                dm.predicted_fps, rel=0.10)

    def test_per_tenant_rates_attributable(self, mixed_dep, mixed_sim):
        rates = mixed_sim.fps_by_workload(warmup=2)
        assert set(rates) == {w.label for w in mixed_dep.workloads}
        assert sum(rates.values()) == pytest.approx(
            mixed_sim.aggregate_fps(warmup=2))
        pred = mixed_dep.predicted_throughput_by_workload()
        assert set(pred) == set(rates)

    def test_single_to_two_tenant_switch_bit_identical(self, dep_a, mixed_dep):
        """Acceptance: System.switch from a single-tenant deployment to the
        two-tenant split reproduces fresh-load results bit-identically."""
        system = System()
        system.load(dep_a).run()
        assert system.tenants == (dep_a.members[0].workload.label,)
        switched = system.switch(mixed_dep).run()
        fresh = System().load(mixed_dep).run()
        assert switched.round_end_cycles == fresh.round_end_cycles
        assert switched.round_latencies_cycles == fresh.round_latencies_cycles
        assert switched.aggregate_fps(warmup=2) == pytest.approx(
            fresh.aggregate_fps(warmup=2), rel=1e-12)

    def test_workload_rounds_override(self):
        g = zoo.tiny_cnn()
        w = Workload(g, rounds=3)
        dep = compile_deployment(None, Strategy.single(1, 1, workload=w),
                                 rounds=7)
        assert all(p.ld.progctrl.nr == 3 for p in dep.programs())
        # an explicit programs(rounds=...) still repatches every member
        assert all(p.ld.progctrl.nr == 2 for p in dep.programs(rounds=2))

    def test_unbound_members_need_graph(self):
        with pytest.raises(ValueError):
            compile_deployment(None, (2, 2))


class TestConformance:
    """Analytic-vs-simulated conformance guard (locks in the validation PR 1
    measured on ResNet-50: 7.2% / 3.2% / 3.3% for DP-A/B/C) on a small CNN
    and the transformer frontend. Tolerances are *fixed* so a regression in
    profiler / partitioner / codegen / simulator timing shows up as a drift
    between the analytic cache and the discrete-event execution."""

    # (design point, rounds, fixed relative tolerance) — dp_c directly after
    # dp_a so the session performs the acceptance criterion's DP-A -> DP-C
    # switch (single-member to 10-member on the unchanged machine).
    # Tolerances tightened with the instruction-granular analytic model
    # (per-instruction decode, per-transfer ADM floors, node-granular weight
    # stalls): observed errors are 6.8%/1.8%/3.2% (tiny_cnn) and
    # 4.5%/0.6%/0.8% (qwen encoder) for DP-A/C/B.
    PLAN = [("dp_a", 6, 0.08), ("dp_c", 5, 0.03), ("dp_b", 5, 0.045)]

    @pytest.fixture(scope="class")
    def cnn_runs(self):
        return self._run_all(zoo.tiny_cnn(channels=(16, 32, 32), hw=16))

    @pytest.fixture(scope="class")
    def tf_runs(self):
        return self._run_all(
            zoo.transformer_encoder("qwen3-0.6b", seq_len=256, depth=2))

    def _run_all(self, graph):
        res = explore(graph)
        system = System()
        out = {}
        for dp_name, rounds, tol in self.PLAN:
            dep = res.deploy(getattr(res, dp_name), rounds=rounds)
            for p in dep.programs():
                p.validate()
            sys_call = system.load if system.deployment is None else system.switch
            sim = sys_call(dep).run()
            out[dp_name] = (dep, sim, tol)
        return out

    @pytest.mark.parametrize("dp_name", ["dp_a", "dp_b", "dp_c"])
    def test_small_cnn_within_tolerance(self, cnn_runs, dp_name):
        dep, sim, tol = cnn_runs[dp_name]
        assert not sim.deadlocked
        assert sim.aggregate_fps(warmup=2) == pytest.approx(
            dep.predicted_throughput, rel=tol)

    @pytest.mark.parametrize("dp_name", ["dp_a", "dp_b", "dp_c"])
    def test_transformer_within_tolerance(self, tf_runs, dp_name):
        dep, sim, tol = tf_runs[dp_name]
        assert not sim.deadlocked
        assert sim.aggregate_fps(warmup=2) == pytest.approx(
            dep.predicted_throughput, rel=tol)

    def test_transformer_switch_a_to_c(self, tf_runs):
        """Acceptance: a direct DP-A -> DP-C System.switch on the transformer
        graph reports aggregate fps within the conformance tolerance (PLAN
        orders dp_c right after dp_a, so the _run_all session executed
        exactly that switch on one fixed machine)."""
        assert list(tf_runs)[:2] == ["dp_a", "dp_c"]
        (_, sim_a, _), (dep_c, sim_c, tol_c) = tf_runs["dp_a"], tf_runs["dp_c"]
        assert sim_a.rounds and sim_c.rounds
        assert sim_c.aggregate_fps(warmup=2) == pytest.approx(
            dep_c.predicted_throughput, rel=tol_c)


class TestDeepPipelineConformance:
    """Deep (all-ten-PU) pipelines of tiny stages used to run 15-20% hot in
    the analytic model: per-stage compute no longer hides the cross-PU
    REQ/ACK round-trip and the HBM channel port contention, so
    max(stage_times) undershot the simulated period. With the coupling model
    these configurations hold the standard conformance tolerance."""

    def test_ten_stage_tiny_chain_within_3pct(self):
        g = zoo.linear_chain(10, ch=8, hw=8)
        dep = compile_deployment(g, (5, 5), rounds=10)
        sim = System().load(dep).run()
        assert not sim.deadlocked
        assert sim.aggregate_fps(warmup=2) == pytest.approx(
            dep.predicted_throughput, rel=0.03)

    def test_prediction_is_coupling_aware(self):
        """The deployed prediction must come from the coupled steady-state
        rate, never the bare stage-time maximum, whenever a boundary bound
        binds."""
        g = zoo.linear_chain(10, ch=8, hw=8)
        dep = compile_deployment(g, (5, 5), rounds=10)
        cpl = dep.members[0].compiled.coupling
        assert cpl is not None
        assert cpl.round_seconds >= cpl.uncoupled_seconds


class TestDecodeServing:
    """Decode-phase workloads through the unchanged DSE/deploy stack
    (acceptance): explore produces DP-A/B/C deployments that simulate within
    5% of the analytic model, and a running System hot-swaps a prefill
    deployment to a decode deployment with no reconfiguration. One decode
    round = one token; deployments default to one full decode window."""

    SEQ, STEPS, DEPTH = 64, 8, 4

    @pytest.fixture(scope="class")
    def dec_graph(self):
        return zoo.transformer_decoder("qwen3-0.6b", seq_len=self.SEQ,
                                       decode_steps=self.STEPS,
                                       depth=self.DEPTH)

    @pytest.fixture(scope="class")
    def dec_dse(self, dec_graph):
        return explore(dec_graph)

    def test_deployment_rounds_default_to_decode_window(self, dec_graph):
        """Precedence: explicit Workload.rounds > explicit rounds= > decode
        window > DEFAULT_ROUNDS — a graph-derived default never overrides an
        explicit argument."""
        from repro.deploy.deployment import DEFAULT_ROUNDS

        dep = compile_deployment(dec_graph, (1, 1))
        assert all(p.ld.progctrl.nr == self.STEPS for p in dep.programs())
        dep = compile_deployment(dec_graph, (1, 1), rounds=3)  # explicit wins
        assert all(p.ld.progctrl.nr == 3 for p in dep.programs())
        w = Workload(dec_graph, rounds=2)  # workload rounds beat everything
        dep = compile_deployment(w, (1, 1), rounds=3)
        assert all(p.ld.progctrl.nr == 2 for p in dep.programs())
        dep = compile_deployment(zoo.tiny_cnn(), (1, 1))  # non-decode default
        assert all(p.ld.progctrl.nr == DEFAULT_ROUNDS for p in dep.programs())

    @pytest.mark.parametrize("dp_name", ["dp_a", "dp_b"])
    def test_design_points_within_5pct(self, dec_dse, dp_name):
        dep = dec_dse.deploy(getattr(dec_dse, dp_name))
        sim = System().load(dep).run()
        assert not sim.deadlocked
        assert all(m.rounds == self.STEPS for m in sim.members)
        assert sim.aggregate_fps(warmup=2) == pytest.approx(
            dep.predicted_throughput, rel=0.05)

    def test_dp_c_within_5pct(self):
        """DP-C (one PU per member) on the reduced config — the tiny weights
        keep the 10-member simulation fast. With the pipeline coupling model
        (residual serialization, per-channel HBM contention, credit-loop
        bound) the decode predictions hold at 5%."""
        from repro.configs import get_config

        g = zoo.transformer_decoder(get_config("qwen3-0.6b").reduced(),
                                    seq_len=self.SEQ, decode_steps=self.STEPS,
                                    depth=self.DEPTH)
        dep = compile_deployment(g, [(1, 0)] * 5 + [(0, 1)] * 5)
        dep.assert_disjoint()
        sim = System().load(dep).run()
        assert not sim.deadlocked
        assert len(sim.members) == 10
        assert sim.aggregate_fps(warmup=2) == pytest.approx(
            dep.predicted_throughput, rel=0.05)

    def test_prefill_to_decode_hot_swap(self, dec_dse):
        """Acceptance: prefill tenant -> decode tenant on one fixed machine,
        new instruction programs only, bit-identical to a fresh load."""
        prefill = zoo.transformer_encoder("qwen3-0.6b", seq_len=self.SEQ,
                                          depth=self.DEPTH)
        dep_pre = compile_deployment(prefill, (2, 2), rounds=4)
        dep_dec = dec_dse.deploy(dec_dse.dp_a)

        system = System()
        sim_pre = system.load(dep_pre).run()
        assert not sim_pre.deadlocked
        swapped = system.switch(dep_dec).run()
        fresh = System().load(dep_dec).run()
        assert not swapped.deadlocked
        assert swapped.round_end_cycles == fresh.round_end_cycles
        assert swapped.aggregate_fps(warmup=2) == pytest.approx(
            fresh.aggregate_fps(warmup=2), rel=1e-12)
        assert len(system.history) == 2


class TestDSEIntegration:
    def test_every_frontier_point_is_deployable(self, dse):
        """Any Step-2 schedule is one call away from an executable form."""
        s = min(dse.multi_frontier, key=lambda s: s.batch)
        dep = dse.deploy(s, rounds=2)
        assert dep.batch == s.batch
        assert dep.predicted_throughput == pytest.approx(s.throughput)

    def test_explore_validate_cross_checks_cache(self, graph):
        res = explore(graph, validate=1, validate_rounds=4)
        assert len(res.validation) == 1
        rec = res.validation[0]
        assert rec.configs == (res.dp_a.config,)
        assert rec.rel_err < 0.10
