"""Compilation framework tests (paper Sec. IV): fusion, DP partitioning,
SMOF weight scheduling, stage-distance buffers, liveness channel assignment,
instruction generation, and end-to-end compile->simulate consistency."""

import pytest

from repro.compiler import (
    CHUNK_BYTES,
    buffer_requirements,
    compile_model,
    fuse,
    partition,
    profile_graph,
    schedule_weights,
    zoo,
)
from repro.compiler.graph import OpType
from repro.core import simulate
from repro.core.pu import make_u50_system

PUS = make_u50_system()
PU1X = PUS[0]
PU2X = PUS[5]
KINDS = {"PU1x": PU1X, "PU2x": PU2X}


# ------------------------------------------------------------------ fusion --
class TestFusion:
    def test_resnet_bottleneck_fusion_counts(self):
        g = zoo.resnet50(256)
        f = fuse(g)
        # 16 bottlenecks -> 16 FusedConvAdd nodes, no standalone Add/ReLU.
        fused = [n for n in f.nodes if n.op is OpType.FUSED_CONV_ADD]
        assert len(fused) == 16
        assert not [n for n in f.nodes if n.op in (OpType.ADD, OpType.RELU)]
        # conv1 + 16*3 bottleneck convs + 4 downsamples + pools(2) + fc
        assert len(f.nodes) == 1 + 16 * 3 + 4 + 2 + 1

    def test_fusion_preserves_macs_and_weights(self):
        g = zoo.resnet50(256)
        f = fuse(g)
        assert f.total_macs() == g.total_macs()
        assert f.total_weight_bytes() == g.total_weight_bytes()

    def test_fused_nodes_have_relu_and_residual(self):
        f = fuse(zoo.resnet50(256))
        for nd in f.nodes:
            if nd.op is OpType.FUSED_CONV_ADD:
                assert nd.relu  # bottleneck ends with ReLU(add)
                assert nd.residual_input is not None

    def test_fusion_topological_validity(self):
        for g in (zoo.resnet50(224), zoo.tiny_cnn(), zoo.linear_chain()):
            fuse(g).validate_topological()

    def test_act_before_add_never_fuses_into_post_add_act(self):
        """GEMM -> act -> Add must NOT collapse into a fused GEMM+Add with
        the activation enable set: the post-processing block applies the
        activation after the shortcut add, which would reorder act and add
        (act(x+r) instead of act(x)+r). The act folds, the Add stays."""
        from repro.compiler.graph import Graph
        from repro.compiler.zoo import _add, _conv, _relu

        g = Graph(name="preact")
        x = g.add_tensor("input", (8, 8, 8))
        g.input_tensors = [x.tid]
        a = _conv(g, x, 8, 3, 1, 1, "c0")
        b = _relu(g, _conv(g, a, 8, 3, 1, 1, "c1"), "r1")
        s = _add(g, b, a, "add")
        g.output_tensors = [s.tid]
        g.validate_topological()

        f = fuse(g)
        assert sum(1 for n in f.nodes if n.op is OpType.ADD) == 1
        assert not [n for n in f.nodes if n.op is OpType.FUSED_CONV_ADD]
        (c1,) = [n for n in f.nodes if n.name.startswith("c1")]
        assert c1.op is OpType.CONV and c1.relu

    def test_geglu_archs_get_gated_ffn(self):
        """geglu configs (gemma3) build the gate/mul FFN like swiglu (full
        gemma3 dims exceed the 12-bit M field, so use the reduced config)."""
        from repro.configs import get_config

        cfg = get_config("gemma3-4b").reduced()
        g = zoo.transformer_encoder(cfg, seq_len=64, depth=1)
        gates = [n for n in g.nodes if n.name.endswith("ffn.gate")]
        assert gates and all(n.m == cfg.d_ff for n in gates)
        assert [n for n in g.nodes if n.op is OpType.MUL]

    def test_oversized_shapes_rejected_at_graph_build(self):
        """ISA field limits surface as clear errors at graph construction,
        not as encode failures deep inside codegen."""
        with pytest.raises(AssertionError):
            zoo.transformer_encoder("dbrx-132b", seq_len=2048, depth=1)
        with pytest.raises(AssertionError):
            zoo.vit(1024)

    def test_resnet_gmacs_canonical(self):
        # canonical ResNet-50 ~3.9 GMACs at 224 (conv+fc; pools add a little)
        g = zoo.resnet50(224)
        gmacs = g.total_macs() / 1e9
        assert 3.7 <= gmacs <= 4.3
        # paper's input: 256x256
        g256 = zoo.resnet50(256)
        assert g256.total_macs() > g.total_macs() * 1.25


# ------------------------------------------------------------ transformer --
class TestTransformerFrontend:
    """The transformer lowering flows through the same stack as the CNNs."""

    def test_vit_shapes_and_macs(self):
        """ViT-Base/16 at 224 is ~17.5 GMACs / ~86 M weight bytes."""
        g = zoo.vit(224)
        assert 16.5e9 <= g.total_macs() <= 18.5e9
        assert 80e6 <= g.total_weight_bytes() <= 92e6

    def test_encoder_parameterized_from_configs(self):
        """zoo.transformer_encoder picks shapes up from repro.configs."""
        from repro.configs import get_config

        cfg = get_config("qwen3-0.6b")
        g = zoo.transformer_encoder("qwen3-0.6b", seq_len=128, depth=2)
        score = [n for n in g.nodes if n.op is OpType.ATTN_SCORE]
        assert len(score) == 2
        assert all(n.k == cfg.resolved_head_dim for n in score)
        assert all(n.n == cfg.num_heads * 128 for n in score)
        # GQA: k/v projections sized by num_kv_heads, q by num_heads
        kproj = [n for n in g.nodes if n.name.endswith("wk")]
        assert all(n.m == cfg.num_kv_heads * cfg.resolved_head_dim for n in kproj)

    def test_fusion_folds_activations_and_residuals(self):
        """proj->act folds into the GEMM; GEMM->residual-add chains fuse."""
        f = fuse(zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=2))
        fused = [n for n in f.nodes if n.op is OpType.FUSED_PROJ_ADD]
        # wo+add1 and ffn.down+add2 per block
        assert len(fused) == 4
        assert all(n.residual_input is not None for n in fused)
        assert not [n for n in f.nodes if n.op in (OpType.ADD, OpType.GELU)]
        # SwiGLU gate proj absorbed its SiLU (vector-activation enable)
        gates = [n for n in f.nodes if n.name.endswith("ffn.gate")]
        assert gates and all(n.relu and n.attrs.get("act") == "silu" for n in gates)

    def test_fusion_preserves_transformer_macs(self):
        g = zoo.vit(96, depth=2, d_model=192, heads=3, d_ff=768)
        f = fuse(g)
        assert f.total_macs() == g.total_macs()
        assert f.total_weight_bytes() == g.total_weight_bytes()

    def test_attention_gemms_are_weightless(self):
        g = zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1)
        for nd in g.nodes:
            if nd.op in (OpType.ATTN_SCORE, OpType.ATTN_CONTEXT):
                assert nd.weight_bytes == 0
                assert nd.macs == nd.m * nd.n * nd.k
                assert len(nd.inputs) == 2

    def test_attention_operand_streams_through_weight_port(self):
        """Score/context GEMMs emit a WEIGHTS_ADM for their second operand
        and carry the URAM interlock in Compute.wchunks."""
        from repro.core.isa import Compute, DataMove, Opcode

        cm = compile_model(zoo.transformer_encoder("qwen3-0.6b", seq_len=64,
                                                   depth=1), 1, 0, rounds=2)
        (prog,) = cm.programs
        wadms = [i for i in prog.cp if isinstance(i, DataMove)
                 and i.op is Opcode.WEIGHTS_ADM and i.cur_ba != 0]
        assert len(wadms) == 2  # one per attention GEMM (K and V streams)
        n_attn = sum(1 for nd in cm.graph.nodes
                     if nd.op in (OpType.ATTN_SCORE, OpType.ATTN_CONTEXT))
        assert n_attn == 2
        computes = [i for i in prog.cp if isinstance(i, Compute)]
        assert sum(c.wchunks for c in computes) >= 2

    def test_ffn_weights_exceed_uram_and_stream(self):
        """qwen3 FFN matrices (~3 MB each) exceed the 2.25 MB URAM: the SMOF
        scheduler must go dynamic and stay feasible."""
        f = fuse(zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=2))
        ws = schedule_weights(f, [nd.nid for nd in f.nodes], PU2X)
        assert not ws.fully_static()
        assert ws.feasible()

    def test_encoder_compile_simulate_consistency(self):
        g = zoo.transformer_encoder("qwen3-0.6b", seq_len=256, depth=2)
        cm = compile_model(g, 2, 2, rounds=4)
        for prog in cm.programs:
            prog.validate()
        last = max(s.index for s in cm.part.stages if s.nids)
        res = simulate(cm.programs, first_pid=cm.pid_map[0],
                       last_pid=cm.pid_map[last])
        assert not res.deadlocked
        assert res.rounds == 4
        assert res.throughput_fps(warmup=2) == pytest.approx(cm.predicted_fps, rel=0.12)

    def test_vit_partitions_balance_heads_and_blocks(self):
        """The DP cut lands mid-block when that balances the pipeline; the
        REQ/ACK handshakes across the cut keep the simulation live."""
        g = zoo.vit(96, depth=4, d_model=192, heads=3, d_ff=768)
        cm = compile_model(g, 2, 2, rounds=3)
        used = [s for s in cm.part.stages if s.nids]
        assert len(used) == 4
        res = simulate(cm.programs, first_pid=cm.pid_map[used[0].index],
                       last_pid=cm.pid_map[used[-1].index])
        assert not res.deadlocked
        assert cm.pbe() > 0.7


# ----------------------------------------------------------------- decode --
class TestDecodeFrontend:
    """Autoregressive decode: K/V caches as append-only regions, attention
    GEMMs streaming a per-round *growing* operand (AddrLen/CYCLE_LEN)."""

    SEQ, STEPS, DEPTH = 64, 8, 2

    def _graph(self):
        return zoo.transformer_decoder("qwen3-0.6b", seq_len=self.SEQ,
                                       decode_steps=self.STEPS,
                                       depth=self.DEPTH)

    def test_decoder_shapes_parameterized_from_configs(self):
        from repro.configs import get_config

        cfg = get_config("qwen3-0.6b")
        g = self._graph()
        assert g.decode_steps == self.STEPS
        score = [n for n in g.nodes if n.op is OpType.ATTN_SCORE]
        assert len(score) == self.DEPTH
        n_avg = round(self.SEQ + (self.STEPS + 1) / 2)
        assert all(n.m == 1 and n.k == cfg.resolved_head_dim for n in score)
        assert all(n.n == cfg.num_heads * n_avg for n in score)
        ctxg = [n for n in g.nodes if n.op is OpType.ATTN_CONTEXT]
        assert all(n.m == cfg.resolved_head_dim and n.k == n_avg for n in ctxg)
        # K/V caches: GQA-sized rows, prefill prefix + decode window rows
        kv_dim = cfg.num_kv_heads * cfg.resolved_head_dim
        caches = [t for t in g.tensors.values() if t.is_kv_cache]
        assert len(caches) == 2 * self.DEPTH
        for t in caches:
            assert t.shape == (self.SEQ + self.STEPS, kv_dim)
            assert t.kv_base_rows == self.SEQ
            assert t.kv_steps == self.STEPS

    def test_kv_cache_plans_are_single_appendonly_regions(self):
        f = fuse(self._graph())
        prof = profile_graph(f, KINDS)
        p = partition(f, prof, 2, 2)
        plans = buffer_requirements(f, p, n_io=4)
        kv = [pl for pl in plans.values() if pl.kind == "kv"]
        assert len(kv) == 2 * self.DEPTH
        for pl in kv:
            tinfo = f.tensors[pl.tid]
            assert pl.n_regions == 1  # append-only: one region, beta credits
            assert pl.region_bytes == tinfo.kv_region_bytes
            assert pl.beta >= 1

    def test_codegen_emits_advancing_length_streams(self):
        from repro.core.isa import AddrCyc, AddrLen, DataMove, Opcode

        cm = compile_model(self._graph(), 1, 0, rounds=3)
        (prog,) = cm.programs
        # attention operands: WEIGHTS_ADM + AddrLen, lengths over the window
        addrlens = [(prog.cp.instructions[i - 1], inst)
                    for i, inst in enumerate(prog.cp.instructions)
                    if isinstance(inst, AddrLen)]
        assert len(addrlens) == 2 * self.DEPTH
        row = 1024  # kv_heads * head_dim bytes, beat-aligned
        for adm, al in addrlens:
            assert isinstance(adm, DataMove) and adm.op is Opcode.WEIGHTS_ADM
            assert adm.length == al.len_base == (self.SEQ + 1) * row
            assert al.loffs == row
            assert al.nc == al.ic == self.STEPS - 1
        # cache appends: one row per round, address advancing past the prefix
        appends = [(prog.st.instructions[i - 1], inst)
                   for i, inst in enumerate(prog.st.instructions)
                   if isinstance(inst, AddrCyc) and inst.aoffs == row]
        assert len(appends) == 2 * self.DEPTH
        for adm, ac in appends:
            assert adm.length == row
            assert ac.nc == self.STEPS - 1
            assert adm.cur_ba == ac.ba  # starts at base + prefix rows

    def test_simulator_executes_advancing_lengths(self):
        """After r rounds the patched WEIGHTS_ADM length is the round-r cache
        prefix; after a full window it wraps back to the base length."""
        from repro.core.isa import AddrLen, DataMove, Opcode
        from repro.core.simulator import MultiPUSimulator

        cm = compile_model(self._graph(), 0, 1, rounds=self.STEPS - 2)
        sim = MultiPUSimulator()
        res = sim.run(cm.programs)
        assert not res.deadlocked
        icu = sim.icus[cm.programs[0].pid]
        insts = icu.program.cp.instructions
        row = 1024
        checked = 0
        for i, inst in enumerate(insts):
            if isinstance(inst, AddrLen):
                adm = insts[i - 1]
                assert isinstance(adm, DataMove) and adm.op is Opcode.WEIGHTS_ADM
                # stepped (STEPS-2) times from ic=NC: length sits at round
                # index STEPS-2 of the window
                assert adm.length == inst.len_base + (self.STEPS - 2) * row
                checked += 1
        assert checked == 2 * self.DEPTH

    def test_decode_compile_simulate_consistency(self):
        g = self._graph()
        cm = compile_model(g, 2, 2, rounds=self.STEPS)
        for prog in cm.programs:
            prog.validate()
        last = max(s.index for s in cm.part.stages if s.nids)
        res = simulate(cm.programs, first_pid=cm.pid_map[0],
                       last_pid=cm.pid_map[last])
        assert not res.deadlocked
        assert res.rounds == self.STEPS
        assert res.throughput_fps(warmup=2) == pytest.approx(
            cm.predicted_fps, rel=0.10)

    def test_decode_attention_macs_track_average_cache(self):
        """Per-round attention MACs equal H*hd*avg_len for score and context
        (the step-dependent work averaged over the decode window)."""
        from repro.configs import get_config

        cfg = get_config("qwen3-0.6b")
        g = self._graph()
        n_avg = round(self.SEQ + (self.STEPS + 1) / 2)
        expect = cfg.num_heads * cfg.resolved_head_dim * n_avg
        for nd in g.nodes:
            if nd.op in (OpType.ATTN_SCORE, OpType.ATTN_CONTEXT):
                assert nd.macs == expect
                assert nd.weight_bytes == 0

    def test_kv_cache_cannot_be_graph_io(self):
        """A K/V cache uses single-region append-only addressing; host
        A/C-region cycling (graph inputs/outputs) is incompatible and must
        be rejected at planning time, not silently misallocated."""
        from repro.compiler.graph import Graph
        from repro.compiler.partition import Partition, Stage

        g = Graph(name="bad_kv_io")
        x = g.add_tensor("input", (1, 64))
        g.input_tensors = [x.tid]
        cache = g.add_tensor("cache", (72, 64), kv_base_rows=64)
        nd = g.add_node(name="wk", op=OpType.PROJ, inputs=[x.tid],
                        outputs=[cache.tid], m=64, n=1, k=64)
        g.output_tensors = [cache.tid]
        p = Partition(stages=[Stage(0, "PU1x", (nd.nid,), 1.0)],
                      node_order=[nd.nid])
        with pytest.raises(ValueError, match="graph input/output"):
            buffer_requirements(g, p)

    def test_decode_window_limits_enforced(self):
        with pytest.raises(AssertionError):
            zoo.transformer_decoder("qwen3-0.6b", seq_len=64,
                                    decode_steps=129, depth=1)
        with pytest.raises(AssertionError):
            zoo.transformer_decoder("qwen3-0.6b", seq_len=16300,
                                    decode_steps=128, depth=1)


# --------------------------------------------------------------- partition --
class TestPartition:
    def test_single_pu_takes_all(self):
        f = fuse(zoo.linear_chain(6))
        prof = profile_graph(f, KINDS)
        p = partition(f, prof, 1, 0)
        assert len(p.stages) == 1
        assert len(p.stages[0].nids) == len(f.nodes)
        assert p.pbe({"PU1x": 1.0, "PU2x": 2.0}) == pytest.approx(1.0)

    def test_dp_matches_bruteforce_two_stage(self):
        """2-PU split of a chain: DP must find the optimal cut point."""
        f = fuse(zoo.linear_chain(8))
        prof = profile_graph(f, KINDS)
        p = partition(f, prof, 2, 0)
        times = [prof["PU1x"][nd.nid].t_node for nd in f.nodes]
        best = min(
            max(sum(times[:i]), sum(times[i:])) for i in range(len(times) + 1)
        )
        assert p.max_stage_time == pytest.approx(best, rel=1e-9)

    def test_more_pus_never_worse(self):
        f = fuse(zoo.resnet50(224))
        prof = profile_graph(f, KINDS)
        prev = float("inf")
        for a, b in [(1, 0), (1, 1), (2, 2), (5, 5)]:
            t = partition(f, prof, a, b).max_stage_time
            assert t <= prev + 1e-12
            prev = t

    def test_heterogeneity_exploited(self):
        """With one PU1x + one PU2x, the 2x unit should receive more work."""
        f = fuse(zoo.resnet50(224))
        prof = profile_graph(f, KINDS)
        p = partition(f, prof, 1, 1)
        used = [s for s in p.stages if s.nids]
        assert len(used) == 2
        work = {
            s.pu_kind: sum(f.node_by_id(n).macs for n in s.nids) for s in used
        }
        assert work["PU2x"] > work["PU1x"]

    def test_stages_contiguous_and_complete(self):
        f = fuse(zoo.resnet50(256))
        prof = profile_graph(f, KINDS)
        p = partition(f, prof, 3, 4)
        covered = [n for s in p.stages for n in s.nids]
        assert covered == [nd.nid for nd in f.nodes]  # contiguous, in order


# ----------------------------------------------------------------- weights --
class TestWeightScheduling:
    def test_small_segment_fully_static(self):
        f = fuse(zoo.tiny_cnn())
        ws = schedule_weights(f, [nd.nid for nd in f.nodes], PU1X)
        assert ws.fully_static()
        assert ws.total_stall() == 0.0

    def test_resnet_whole_model_needs_streaming(self):
        f = fuse(zoo.resnet50(256))
        ws = schedule_weights(f, [nd.nid for nd in f.nodes], PU2X)
        assert not ws.fully_static()  # 25.6 MB weights >> 2.25 MB URAM
        assert ws.feasible()

    def test_capacity_constraint_holds(self):
        f = fuse(zoo.resnet50(256))
        ws = schedule_weights(f, [nd.nid for nd in f.nodes], PU2X)
        assert ws.static_bytes() + ws.worst_adjacent_dynamic() <= PU2X.uram_capacity_bytes

    def test_deficit_allocation_hides_most_loads(self):
        """The greedy allocation should hide nearly all weight-transfer time
        behind execution (residual stall small vs total load time)."""
        f = fuse(zoo.resnet50(256))
        ws = schedule_weights(f, [nd.nid for nd in f.nodes], PU2X)
        dyn_chunks = sum(t.dynamic_chunks for t in ws.tiles)
        total_load = dyn_chunks * ws.t_chunk_load
        assert ws.total_stall() < 0.25 * total_load

    def test_static_allocation_reduces_stall_vs_none(self):
        f = fuse(zoo.resnet50(256))
        ws = schedule_weights(f, [nd.nid for nd in f.nodes], PU2X)
        # compare against an all-dynamic schedule
        from repro.compiler.weights import WeightSchedule, build_tiles

        nids = [nd.nid for nd in f.nodes]
        raw = WeightSchedule(
            tiles=build_tiles(f, nids, PU2X),
            pu_kind="PU2x",
            capacity_bytes=PU2X.uram_capacity_bytes,
            t_chunk_load=PU2X.adm_seconds(CHUNK_BYTES),
        )
        assert ws.total_stall() < raw.total_stall()


# ------------------------------------------------------------------ memory --
class TestMemoryOptimization:
    def _partition(self, g, a, b):
        f = fuse(g)
        prof = profile_graph(f, KINDS)
        return f, prof, partition(f, prof, a, b)

    def test_stage_distance_beta(self):
        """beta(T) = max producer->consumer stage distance + 1."""
        f, prof, p = self._partition(zoo.linear_chain(8), 2, 0)
        plans = buffer_requirements(f, p, n_io=4)
        stage_of = p.stage_of_node()
        for tid, plan in plans.items():
            if plan.kind != "intermediate":
                assert plan.beta == 4
                continue
            prod = stage_of[f.producer_of(tid).nid]
            dist = max(stage_of[c.nid] - prod for c in f.consumers_of(tid))
            assert plan.beta == dist + 1

    def test_cross_stage_tensor_gets_pingpong(self):
        f, prof, p = self._partition(zoo.linear_chain(8), 2, 0)
        plans = buffer_requirements(f, p, n_io=4)
        boundary = [
            plan
            for tid, plan in plans.items()
            if plan.kind == "intermediate"
            and plan.producer_stage == 0
            and 1 in plan.consumer_stages
        ]
        assert boundary and all(b.beta == 2 for b in boundary)

    def test_residual_spanning_stages_needs_more_buffers(self):
        """A residual edge crossing k stages needs k+1 buffers (handcrafted
        partition that splits a residual block across three stages)."""
        from repro.compiler.partition import Partition, Stage

        f = fuse(zoo.tiny_cnn())
        # fused nodes: c0(relu), c1(relu), c2+add(resid from c0.out), fc
        nids = [nd.nid for nd in f.nodes]
        assert len(nids) == 4
        p = Partition(
            stages=[
                Stage(0, "PU1x", (nids[0],), 1.0),
                Stage(1, "PU1x", (nids[1],), 1.0),
                Stage(2, "PU2x", (nids[2],), 1.0),
                Stage(3, "PU1x", (nids[3],), 1.0),
            ],
            node_order=nids,
        )
        plans = buffer_requirements(f, p, n_io=4)
        resid_tid = f.nodes[2].residual_input
        assert resid_tid is not None
        # produced at stage 0, consumed at stages 1 (primary) and 2 (residual)
        assert plans[resid_tid].beta == 3

    def test_fork_inputs_on_distinct_channels(self):
        """Cross-PU forks (primary + residual into one consumer) must use
        different HBM channels (Sec. IV-C)."""
        from repro.compiler.memory import assign_channels

        f, prof, p = self._partition(zoo.resnet50(256), 5, 5)
        plans = buffer_requirements(f, p, n_io=4)
        mem = assign_channels(f, p, plans, prof)
        checked = 0
        for nd in f.nodes:
            if nd.residual_input is None:
                continue
            prim, res = nd.inputs[0], nd.residual_input
            if prim in mem.tensors and res in mem.tensors:
                assert (
                    mem.tensors[prim].read_channel != mem.tensors[res].read_channel
                )
                checked += 1
        assert checked >= 16

    def test_channel_budget_respected(self):
        from repro.compiler.memory import assign_channels
        from repro.core.pu import N_HBM_CHANNELS

        f, prof, p = self._partition(zoo.resnet50(256), 5, 5)
        plans = buffer_requirements(f, p, n_io=4)
        mem = assign_channels(f, p, plans, prof)
        chans = {pl.read_channel for pl in plans.values()} | {
            pl.write_channel for pl in plans.values()
        } | set(mem.weight_channel.values())
        assert all(0 <= c < N_HBM_CHANNELS for c in chans)


# ----------------------------------------------------- end-to-end compile --
class TestCompileEndToEnd:
    @pytest.mark.parametrize("a,b", [(0, 1), (1, 1), (2, 3), (5, 5)])
    def test_compile_simulate_consistency(self, a, b):
        """Simulated throughput within ~12% of the analytic prediction."""
        g = zoo.resnet50(256)
        cm = compile_model(g, a, b, rounds=6)
        for prog in cm.programs:
            prog.validate()
        last_stage = max(s.index for s in cm.part.stages if s.nids)
        res = simulate(cm.programs, first_pid=cm.pid_map[0], last_pid=cm.pid_map[last_stage])
        assert not res.deadlocked
        assert res.rounds == 6
        fps = res.throughput_fps(warmup=2)
        assert fps == pytest.approx(cm.predicted_fps, rel=0.13)

    def test_dp_c_single_pu_high_ce(self):
        """DP-C style: one PU runs the whole model at ~95% CE (paper: 98%)."""
        cm = compile_model(zoo.resnet50(256), 0, 1, rounds=6)
        res = simulate(cm.programs)
        fps = res.throughput_fps(warmup=2)
        gops = 2 * cm.graph.total_macs() * fps / 1e9
        ce = gops / (cm.used_tops * 1e3)
        assert ce > 0.92

    def test_dp_a_full_pipeline_ce(self):
        """DP-A style: all 10 PUs pipelined; CE in the high-80s (paper 88.5%)."""
        cm = compile_model(zoo.resnet50(256), 5, 5, rounds=8)
        last_stage = max(s.index for s in cm.part.stages if s.nids)
        res = simulate(cm.programs, first_pid=cm.pid_map[0], last_pid=cm.pid_map[last_stage])
        fps = res.throughput_fps(warmup=3)
        gops = 2 * cm.graph.total_macs() * fps / 1e9
        ce = gops / 4608.0
        assert 0.80 <= ce <= 0.98
        assert cm.pbe() > 0.85

    def test_tiny_cnn_two_pu(self):
        cm = compile_model(zoo.tiny_cnn(), 1, 1, rounds=5)
        res = simulate(cm.programs)
        assert not res.deadlocked
        assert res.rounds == 5

    def test_programs_use_uniform_coordination(self):
        """Sync instructions appear in LD/ST only; CP carries compute+weights."""
        from repro.core.isa import Sync, Compute

        cm = compile_model(zoo.resnet50(256), 2, 2, rounds=4)
        for prog in cm.programs:
            assert not [i for i in prog.cp if isinstance(i, Sync)]
            assert [i for i in prog.cp if isinstance(i, Compute)]

    def test_rounds_parameter_respected(self):
        cm = compile_model(zoo.tiny_cnn(), 1, 0, rounds=9)
        res = simulate(cm.programs)
        assert res.rounds == 9
