"""End-to-end training driver with fault tolerance: train an LM on the
synthetic stream, checkpoint periodically, auto-resume after interruption.

CPU demo: a reduced config for a few hundred steps (use --full on a pod).

    PYTHONPATH=src python examples/train_lm.py --steps 200 --ckpt-every 50
    # kill it mid-run, then re-run the same command: it resumes.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import DataConfig, DataState, TokenStream
from repro.runtime.optimizer import AdamWConfig, adamw_init
from repro.runtime.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, None, opt_cfg, remat=False))

    params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw_init(opt_cfg, params)
    stream = TokenStream(dcfg)
    start = 0

    # fault tolerance: auto-resume from the latest checkpoint
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        restored, start, extra = ckpt.restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt}
        )
        params, opt = restored["params"], restored["opt"]
        stream = TokenStream(dcfg, DataState.from_dict(extra["data"]))
        print(f"resumed from step {start}")

    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"training {args.arch} ({n/1e6:.1f}M params) for {args.steps} steps")

    t0, first_loss = time.time(), None
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, stream.next())
        params, opt, m = step_fn(params, opt, batch)
        if first_loss is None:
            first_loss = float(m["nll"])
        if (step + 1) % 10 == 0:
            print(
                f"step {step+1:4d}  nll {float(m['nll']):.4f}  "
                f"lr {float(m['lr']):.2e}  |g| {float(m['grad_norm']):.2f}"
            )
        if (step + 1) % args.ckpt_every == 0:
            path = ckpt.save_checkpoint(
                args.ckpt_dir, step + 1, {"params": params, "opt": opt},
                extra={"data": stream.state.as_dict()},
            )
            print(f"  checkpoint -> {path}")

    print(
        f"\ndone in {time.time()-t0:.1f}s; "
        f"loss {first_loss:.3f} -> {float(m['nll']):.3f}"
    )


if __name__ == "__main__":
    main()
