"""Transformer DSE: the compilation framework beyond CNNs.

The same three-step exploration (Sec. V-A) over the transformer frontend:
ViT-Base/16 (the vision analogue of ResNet-50) or a qwen3-0.6b encoder
stack parameterized from ``repro.configs``. Attention score/context GEMMs
stream their second operand through the SA weight port, FFN matrices SMOF-
stream out of HBM, layernorm/softmax run in the PU vector units — and every
design point deploys and hot-swaps on the fixed U50 machine exactly like
ResNet-50 does:

    PYTHONPATH=src python examples/transformer_dse.py                 # ViT-Base/224
    PYTHONPATH=src python examples/transformer_dse.py --model qwen3 --depth 4
"""
import argparse

from repro.compiler import zoo
from repro.deploy import System
from repro.dse import explore

PEAK_TOPS = 4.608


def build_graph(args):
    if args.model == "vit":
        return zoo.vit(args.input_hw)
    return zoo.transformer_encoder("qwen3-0.6b", seq_len=args.seq_len,
                                   depth=args.depth)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=("vit", "qwen3"), default="vit")
    ap.add_argument("--input-hw", type=int, default=224, help="ViT input size")
    ap.add_argument("--seq-len", type=int, default=256, help="qwen3 sequence")
    ap.add_argument("--depth", type=int, default=4,
                    help="qwen3 block count (28 = the full config)")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip the deploy/run/switch simulation demo")
    args = ap.parse_args()

    g = build_graph(args)
    gopf = 2 * g.total_macs() / 1e9  # GOPs per frame/sequence
    print(g.summary())
    res = explore(g, tolerance=0.01)

    print(f"step 1: {len(res.single)} single-batch configurations")
    print(f"step 2: {len(res.multi)} multi-batch schedules")
    print(f"step 3: Pareto frontier keeps {len(res.multi_frontier)}\n")

    for name, dp in (("DP-A", res.dp_a), ("DP-B", res.dp_b), ("DP-C", res.dp_c)):
        gops = dp.throughput * gopf
        print(
            f"{name}: batch={dp.batch:2d}  "
            f"fps={dp.throughput:8.1f}  latency={dp.latency*1e3:6.2f} ms  "
            f"CE={gops/(PEAK_TOPS*1e3):.3f}  "
            f"configs={'+'.join(f'({a},{b})' for a, b in dp.configs)}"
        )

    if args.no_sim:
        return

    print("\nruntime strategy switching on one fixed machine:")
    system = System()
    dep_a = res.deploy(res.dp_a, rounds=5)
    sim_a = system.load(dep_a).run()
    dep_c = res.deploy(res.dp_c, rounds=4)
    sim_c = system.switch(dep_c).run()  # same PU array, new programs
    for name, dep, sim in (("DP-A", dep_a, sim_a), ("DP-C", dep_c, sim_c)):
        meas, pred = sim.aggregate_fps(warmup=2), dep.predicted_throughput
        print(
            f"  {name}: measured {meas:8.1f} fps vs predicted {pred:8.1f} "
            f"({abs(meas - pred) / pred * 100:4.1f}% off, "
            f"{dep.batch} member pipeline(s), deadlock={sim.deadlocked})"
        )


if __name__ == "__main__":
    main()
