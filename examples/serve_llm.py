"""End-to-end serving driver: load an assigned architecture (reduced config
on CPU; full config on a real pod), run batched requests through the
continuous-batching engine, report throughput/latency.

    PYTHONPATH=src python examples/serve_llm.py --arch qwen3-0.6b --requests 8
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf
from repro.runtime.serve import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (needs a real accelerator)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if cfg.frontend == "frame_embed":
        raise SystemExit("use an LM/VLM arch for the serving example")

    print(f"initializing {args.arch} ({cfg.num_layers}L d={cfg.d_model}) ...")
    params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"  {n_params/1e6:.1f}M params")

    eng = ServingEngine(cfg, params, batch_slots=args.slots, max_len=256)
    t0 = time.time()
    for i in range(args.requests):
        prompt = [(7 * i + j) % (cfg.vocab_size - 1) + 1 for j in range(5)]
        eng.submit(prompt, max_new_tokens=args.new_tokens)
    done = eng.run_until_drained()
    dt = time.time() - t0

    total_tokens = sum(len(r.generated) for r in done)
    lats = [r.finished_at - r.submitted_at for r in done]
    print(f"\nserved {len(done)} requests, {total_tokens} tokens in {dt:.1f}s")
    print(f"  throughput: {total_tokens/dt:.1f} tok/s")
    print(f"  request latency: mean {sum(lats)/len(lats):.2f}s  max {max(lats):.2f}s")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt {r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
