"""Quickstart: compile ResNet-50 into multi-PU instruction programs and
execute them on the discrete-event simulator — the paper's core loop
(Sec. IV compilation -> Sec. III coordination -> Sec. V performance).

    PYTHONPATH=src python examples/quickstart.py [--pu1x 2 --pu2x 3]
"""
import argparse

from repro.compiler import compile_model, zoo
from repro.core import simulate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pu1x", type=int, default=5)
    ap.add_argument("--pu2x", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    g = zoo.resnet50(256)
    print(g.summary())

    cm = compile_model(g, args.pu1x, args.pu2x, rounds=args.rounds)
    print(f"\ncompiled to {len(cm.programs)} pipeline stages:")
    for s in cm.part.stages:
        if not s.nids:
            continue
        pid = cm.pid_map[s.index]
        print(
            f"  stage {s.index} -> PU{pid} ({s.pu_kind}): {len(s.nids)} nodes, "
            f"{cm.stage_times[s.index]*1e3:.2f} ms/round "
            f"({cm.programs[s.index].total_instructions()} instructions)"
        )
    print(f"\npredicted: {cm.predicted_fps:.1f} fps, PBE {cm.pbe():.3f}")

    last = max(s.index for s in cm.part.stages if s.nids)
    res = simulate(cm.programs, first_pid=cm.pid_map[0], last_pid=cm.pid_map[last])
    fps = res.throughput_fps(warmup=2)
    gops = 2 * cm.graph.total_macs() * fps / 1e9
    print(
        f"simulated: {fps:.1f} fps | {gops:.0f} GOPS | "
        f"CE {gops / (cm.used_tops * 1e3):.3f} vs used PUs | "
        f"latency {res.latency_seconds()*1e3:.2f} ms | "
        f"{res.tokens_sent} REQ/ACK tokens | deadlock={res.deadlocked}"
    )

    # peek at one instruction program
    print("\nfirst stage LD program:")
    print(cm.programs[0].ld.disassemble())


if __name__ == "__main__":
    main()
