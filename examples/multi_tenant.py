"""Multi-tenant deployments: different models for different tenants on one
fixed machine (FPGA-virtualization style, cf. arXiv:2003.12101) — the
paper's Sec. V deployment machinery generalized so every member pipeline
carries its own :class:`repro.deploy.Workload`.

The co-exploration (``explore_multi``) searches joint placements of the
tenants on the shared PU array and Pareto-filters by the vector of
per-tenant rates; any point compiles to an executable two-tenant deployment
on disjoint PU/HBM slices, and a running single-tenant session hot-swaps to
it mid-session — new instruction programs only, no reconfiguration.

    PYTHONPATH=src python examples/multi_tenant.py                  # ResNet-50 + ViT
    PYTHONPATH=src python examples/multi_tenant.py --small          # tiny pair (CI)
"""
import argparse

from repro.compiler import zoo
from repro.deploy import Strategy, System, compile_deployment
from repro.dse import explore_multi


def tenant_graphs(small: bool):
    if small:
        return (zoo.tiny_cnn(channels=(16, 32, 32), hw=16),
                zoo.transformer_encoder("qwen3-0.6b", seq_len=64, depth=1))
    return zoo.resnet50(256), zoo.vit(224)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="tiny tenant pair (fast; used by the CI smoke job)")
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()

    g_a, g_b = tenant_graphs(args.small)
    print(f"tenant A: {g_a.name}   tenant B: {g_b.name}\n")

    # --- co-exploration: joint placements of both tenants -------------------
    res = explore_multi([g_a, g_b])
    print(f"joint placements: {len(res.points)}, "
          f"Pareto frontier (fps_A, fps_B): {len(res.frontier)}")
    solo = [res.best_solo_fps(i) for i in range(2)]
    print(f"best solo rates (whole machine to itself): "
          f"A {solo[0]:.1f} fps, B {solo[1]:.1f} fps\n")
    for p in sorted(res.frontier, key=lambda p: -p.fps[0])[:10]:
        (a0, b0), (a1, b1) = p.configs
        print(f"  A({a0},{b0}) {p.fps[0]:9.1f} fps ({p.fps[0]/solo[0]*100:5.1f}% of solo)"
              f"   B({a1},{b1}) {p.fps[1]:9.1f} fps ({p.fps[1]/solo[1]*100:5.1f}% of solo)")

    pick = res.balanced
    print(f"\nmax-min-fair point: {pick}")

    # --- a running single-tenant session hot-swaps to the two-tenant split --
    best_a = max(res.singles[0], key=lambda p: p.fps)
    dep_solo = compile_deployment(g_a, Strategy.single(*best_a.config),
                                  rounds=args.rounds + 1)
    dep_two = res.deploy(pick, rounds=args.rounds)

    system = System()
    sim_solo = system.load(dep_solo).run()
    print(f"\nsingle-tenant DP-A ({g_a.name} on {best_a.config}): "
          f"{sim_solo.aggregate_fps(warmup=2):.1f} fps, "
          f"deadlock={sim_solo.deadlocked}")

    sim_two = system.switch(dep_two).run()  # same PU array, new programs
    print(f"switched to two-tenant split (no reconfiguration, "
          f"loads={len(system.history)}):")
    rates = sim_two.fps_by_workload(warmup=2)
    for (label, meas), pred in zip(rates.items(), pick.fps):
        print(f"  {label:24s} measured {meas:9.1f} fps   "
              f"analytic {pred:9.1f} fps   ({abs(meas - pred)/pred*100:4.1f}% off)")
    print(f"  deadlock={sim_two.deadlocked}, "
          f"members={[m.label for m in sim_two.members]}")


if __name__ == "__main__":
    main()
