"""The paper's coordination technique on TPU: pipeline an LM across mesh
stages with compiler-emitted instruction programs, verify the schedule on
the discrete-event simulator, execute via shard_map + ppermute, and show
runtime strategy switching (pipeline vs hybrid) without reconfiguration.

Run with forced host devices to see real multi-stage execution on CPU:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/pipeline_parallel.py --stages 4
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import MultiPUSimulator, PipelineMember
from repro.core.pu import PUSpec
from repro.models import transformer as tf
from repro.runtime.pipeline import (
    layer_cost_seconds,
    make_pipeline_forward,
    make_pipeline_mesh,
    plan_pipeline,
    stack_stage_params,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    B, S = 4, 32
    mb = B // args.microbatches

    # --- step 1: the compiler plans the pipeline + emits ISA programs ------
    plan = plan_pipeline(cfg, n_stages=args.stages, microbatches=args.microbatches,
                        seq_len=S, microbatch_size=mb)
    print(f"plan: {plan.n_stages} stages x {plan.layers_per_stage} layers, "
          f"boundaries {plan.boundaries}")
    print(f"analytic: {plan.predicted_throughput:.1f} microbatches/s, "
          f"latency {plan.predicted_latency*1e3:.2f} ms")
    print("\nstage 1 instruction programs (coordination expressed in the ISA):")
    print(plan.programs[1].ld.disassemble())

    # --- step 2: verify the schedule on the discrete-event simulator -------
    pus = [PUSpec(pid=i, kind="PU2x", sa_rows=64, sa_cols=8, slr=i // 2)
           for i in range(args.stages)]
    sim = MultiPUSimulator(pus)
    member = PipelineMember(first_pid=0, last_pid=args.stages - 1, label="lm")
    res = sim.run(plan.programs, members=[member])
    mres = res.members[0]
    print(f"\nsimulator: {mres.rounds} microbatches drained, "
          f"{mres.throughput_fps(warmup=1):.1f} microbatches/s, "
          f"deadlock={res.deadlocked}, {res.tokens_sent} REQ/ACK tokens")

    # --- step 3: execute on the mesh (shard_map + ppermute) ----------------
    n_dev = len(jax.devices())
    if n_dev >= args.stages:
        mesh = make_pipeline_mesh(args.stages, 1, 1)
        params = tf.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        sparams = stack_stage_params(cfg, params, plan)
        fn = jax.jit(make_pipeline_forward(cfg, plan, mesh))
        toks = jax.random.randint(jax.random.PRNGKey(1), (args.microbatches, mb, S),
                                  0, cfg.vocab_size)
        out = fn(sparams, toks)
        ref, _ = tf.forward(cfg, params, {"tokens": toks.reshape(B, S)})
        err = float(jnp.max(jnp.abs(out.reshape(B, S, -1) - ref)))
        print(f"\nmesh execution: logits {out.shape}, max |delta| vs plain "
              f"forward = {err:.2e}")
    else:
        print(f"\n({n_dev} device(s): set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={args.stages} "
              f"to run the mesh execution step)")

    # --- step 4: strategy switching without reconfiguration ----------------
    # 4a. On the simulator: the PU array is fixed; sim.reset() clears only
    # the transient ICU/ISU state and a re-planned instruction schedule with
    # fewer stages runs on the same machine (repro.deploy.System wraps this
    # load/switch/run cycle for compiled DNN deployments).
    print("\nruntime switching on the fixed simulated machine:")
    for n_stages in sorted({args.stages, max(1, args.stages // 2)}, reverse=True):
        alt = plan_pipeline(cfg, n_stages=n_stages, microbatches=args.microbatches,
                            seq_len=S, microbatch_size=mb)
        sim.reset()
        r = sim.run(alt.programs,
                    members=[PipelineMember(0, n_stages - 1, f"{n_stages}stg")])
        print(f"  stages={n_stages}: {r.members[0].throughput_fps(warmup=1):8.1f} "
              f"microbatches/s measured (deadlock={r.deadlocked})")

    # 4b. At TPU scale: the same trade-off, analytically.
    print("\nanalytic deployment sweep (same mesh, new instruction programs):")
    chips = 256
    for n_stages in (1, 2, 4, 8):
        dp = chips // n_stages
        t = layer_cost_seconds(get_config(args.arch), 4096, 4, 1)
        full = get_config(args.arch)
        per_stage = -(-full.num_layers // n_stages) * t
        thr = dp / per_stage  # dp replicas x pipeline rate
        lat = (n_stages + args.microbatches - 1) * per_stage
        print(f"  stages={n_stages:2d} dp={dp:3d}: throughput {thr:9.1f} mb/s, "
              f"latency {lat*1e3:6.2f} ms")


if __name__ == "__main__":
    main()
