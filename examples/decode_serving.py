"""Autoregressive decode serving: KV-cache scheduling through the ISA,
compiler and deploy stack.

``zoo.transformer_decoder`` models the decode half of a serving workload:
one program round = one new token, attention score/context GEMMs stream a
per-block K/V cache region whose valid prefix *grows* every round (the
AddrLen/CYCLE_LEN length-advance instructions, cf. the paper's AddrCyc
cyclic addressing). The graph flows through the unchanged DSE and deploy
stack, and a running :class:`repro.deploy.System` hot-swaps between the
prefill tenant and the decode tenant with no reconfiguration — the paper's
runtime strategy switching applied to the two phases of LLM serving.

    PYTHONPATH=src python examples/decode_serving.py                 # full
    PYTHONPATH=src python examples/decode_serving.py --small         # CI
    PYTHONPATH=src python examples/decode_serving.py --no-sim        # analytic
"""
import argparse

from repro.compiler import zoo
from repro.deploy import Strategy, System, compile_deployment
from repro.dse import explore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--seq-len", type=int, default=256,
                    help="prefill prefix length (K/V cache base rows)")
    ap.add_argument("--steps", type=int, default=64,
                    help="decode window (one program round per token)")
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--small", action="store_true",
                    help="tiny sizes + few simulated steps (CI smoke mode)")
    ap.add_argument("--no-sim", action="store_true",
                    help="analytic DSE only, skip the simulated hot swap")
    args = ap.parse_args()
    if args.small:
        args.seq_len, args.steps, args.depth = 64, 8, 4

    prefill = zoo.transformer_encoder(args.arch, seq_len=args.seq_len,
                                      depth=args.depth)
    decode = zoo.transformer_decoder(args.arch, seq_len=args.seq_len,
                                     decode_steps=args.steps, depth=args.depth)
    print(f"prefill: {prefill.summary()}")
    print(f"decode:  {decode.summary()}")
    print(f"decode round = 1 token; cache grows {args.seq_len}+1 .. "
          f"{args.seq_len + args.steps} rows over the window\n")

    # --- the decode workload through the unchanged 3-step DSE ---------------
    res = explore(decode)
    print("decode design points (analytic; fps = tokens/s per sequence):")
    for name, dp in (("DP-A", res.dp_a), ("DP-B", res.dp_b), ("DP-C", res.dp_c)):
        print(f"  {name}: batch={dp.batch} tok/s={dp.throughput:9.1f} "
              f"latency_ms={dp.latency * 1e3:7.3f} "
              f"configs={'+'.join(f'{a}x1_{b}x2' for a, b in dp.configs)}")
    if args.no_sim:
        return

    # --- prefill tenant -> decode tenant on one fixed machine ---------------
    dep_pre = compile_deployment(prefill, Strategy.single(2, 2), rounds=4)
    dep_dec = res.deploy(res.dp_a)  # rounds default to the decode window

    system = System()
    sim_pre = system.load(dep_pre).run()
    print(f"\nprefill deployment (2,2): {sim_pre.aggregate_fps(warmup=2):.1f} "
          f"seq/s, deadlock={sim_pre.deadlocked}")

    sim_dec = system.switch(dep_dec).run()  # same PU array, new programs
    meas = sim_dec.aggregate_fps(warmup=2)
    pred = dep_dec.predicted_throughput
    print(f"switched to decode DP-A (no reconfiguration, "
          f"loads={len(system.history)}):")
    print(f"  {meas:.1f} tok/s measured over {sim_dec.members[0].rounds} "
          f"decode steps   analytic {pred:.1f} tok/s   "
          f"({abs(meas - pred) / pred * 100:.1f}% off), "
          f"deadlock={sim_dec.deadlocked}")

    back = system.switch(dep_pre).run()  # and back to prefill
    print(f"switched back to prefill: {back.aggregate_fps(warmup=2):.1f} "
          f"seq/s (loads={len(system.history)}, reconfigured=0)")


if __name__ == "__main__":
    main()
