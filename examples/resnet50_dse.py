"""Design-space exploration on ResNet-50 (paper Sec. V-A, Figs. 5/6):
enumerate 35 single-batch configs, compose hybrid multi-batch schedules,
Pareto-filter, print the DP-A/B/C design points with Table III metrics —
then make them *executable*: every DSE point deploys with one call
(``res.deploy(...)``) and a :class:`repro.deploy.System` session runs DP-A
and hot-switches to DP-C on the same fixed machine, reporting measured vs
predicted throughput for both.

    PYTHONPATH=src python examples/resnet50_dse.py [--max-latency-ms 20]
"""
import argparse

from repro.compiler import zoo
from repro.deploy import System
from repro.dse import constrained, explore

GOPS_224EQ = 7.72
PEAK_TOPS = 4.608


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-latency-ms", type=float, default=None)
    ap.add_argument("--min-fps", type=float, default=None)
    ap.add_argument("--no-sim", action="store_true",
                    help="skip the deploy/run/switch simulation demo")
    args = ap.parse_args()

    g = zoo.resnet50(256)
    gopf = 2 * g.total_macs() / 1e9
    res = explore(g, tolerance=0.01)

    print(f"step 1: {len(res.single)} single-batch configurations")
    print(f"step 2: {len(res.multi)} multi-batch schedules")
    print(f"step 3: Pareto frontier keeps {len(res.multi_frontier)}\n")

    for name, dp in (("DP-A", res.dp_a), ("DP-B", res.dp_b), ("DP-C", res.dp_c)):
        gops = dp.throughput * gopf
        print(
            f"{name}: batch={dp.batch:2d}  "
            f"fps(224eq)={gops/GOPS_224EQ:6.1f}  latency={dp.latency*1e3:5.2f} ms  "
            f"CE={gops/(PEAK_TOPS*1e3):.3f}  "
            f"configs={'+'.join(f'({a},{b})' for a, b in dp.configs)}"
        )

    if args.max_latency_ms or args.min_fps:
        lim = constrained(
            res.multi,
            max_latency=(args.max_latency_ms or 1e9) / 1e3,
            min_throughput=(args.min_fps or 0.0) / (gopf / GOPS_224EQ),
        )
        best = max(lim, key=lambda s: s.throughput) if lim else None
        print(f"\nconstrained pick ({len(lim)} feasible):", best and best.configs)

    print("\nthroughput-latency frontier (multi-batch):")
    for s in sorted(res.multi_frontier, key=lambda s: s.latency)[:12]:
        gops = s.throughput * gopf
        print(
            f"  batch={s.batch:2d} fps={gops/GOPS_224EQ:6.1f} "
            f"lat={s.latency*1e3:5.2f} ms tops={s.tops:.2f} pbe={s.system_pbe:.3f}"
        )

    if args.no_sim:
        return

    # ---- deploy / run / switch: the DSE points as executable programs ------
    print("\nruntime strategy switching on one fixed machine:")
    system = System()
    dep_a = res.deploy(res.dp_a, rounds=6)
    sim_a = system.load(dep_a).run()
    dep_c = res.deploy(res.dp_c, rounds=5)
    sim_c = system.switch(dep_c).run()  # same PU array, new programs
    for name, dep, sim in (("DP-A", dep_a, sim_a), ("DP-C", dep_c, sim_c)):
        meas, pred = sim.aggregate_fps(warmup=2), dep.predicted_throughput
        print(
            f"  {name}: measured {meas * gopf / GOPS_224EQ:6.1f} fps(224eq) "
            f"vs predicted {pred * gopf / GOPS_224EQ:6.1f} "
            f"({abs(meas - pred) / pred * 100:4.1f}% off, "
            f"{dep.batch} member pipeline(s), deadlock={sim.deadlocked})"
        )


if __name__ == "__main__":
    main()
