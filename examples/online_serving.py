"""Online serving: continuous batching, elastic tenancy and SLO-driven
re-placement on one fixed machine.

The :class:`repro.serve.Server` takes the paper's runtime strategy
switching online. Tenants join and leave while the system serves; their
admitted requests become decode sessions *continuously batched* into
slot-packed members — several sessions at different K/V cache depths share
one member, each with its own AddrLen length stream — and every membership
change (or sustained SLO violation) triggers an incremental re-placement
(``explore_multi(prev=...)``) whose result hot-swaps onto the running
:class:`repro.deploy.System` with no reconfiguration. Virtual time comes
from the simulator, so the whole run is deterministic.

    PYTHONPATH=src python examples/online_serving.py          # full
    PYTHONPATH=src python examples/online_serving.py --small  # CI smoke
"""
import argparse

from repro.serve import SLO, Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--small", action="store_true",
                    help="tiny depths + short requests (CI smoke mode)")
    args = ap.parse_args()
    depth, window = (1, 4) if args.small else (2, 8)
    scale = 1 if args.small else 2

    srv = Server()

    # --- two tenants with different service classes -------------------------
    srv.join("chat", args.arch, depth=depth, max_slots=2, window=window,
             slo=SLO(min_tokens_per_s=50.0, priority=1))
    srv.join("batch", args.arch, depth=depth, max_slots=2, window=window)
    for prompt, new in ((64, 6 * scale), (32, 10 * scale), (48, 4 * scale)):
        srv.submit(Request("chat", prompt_tokens=prompt, max_new_tokens=new))
    srv.submit(Request("batch", prompt_tokens=128, max_new_tokens=8 * scale))

    srv.step()  # one serving window: chat packs 2 sessions, batch runs 1
    placed = next(e for e in srv.events if e.kind == "replan")
    print(f"after window 1: t={srv.now * 1e3:.3f} ms, placement {placed.detail}")

    # --- a third tenant joins mid-service -> incremental re-placement -------
    srv.join("burst", args.arch, depth=depth, max_slots=1, window=window)
    srv.submit(Request("burst", prompt_tokens=16, max_new_tokens=4 * scale,
                       arrival_s=srv.now))

    report = srv.drain()

    print(f"\n{report}\n")
    print("event log:")
    for e in srv.events:
        print(f"  {e}")

    completed = sum(r.completed for r in srv.requests)
    replans = sum(e.kind == "replan" for e in srv.events)
    print(f"\n{completed}/{len(srv.requests)} requests completed over "
          f"{srv.windows} windows ({replans} placements, "
          f"{sum(e.kind == 'swap' for e in srv.events)} program swaps, "
          f"0 reconfigurations)")
    if completed != len(srv.requests):
        raise SystemExit("not all requests completed")


if __name__ == "__main__":
    main()
