"""Fault-tolerant serving: inject -> detect -> quarantine -> replan -> replay.

A seeded hardware fault (here: a PU that silently stops decoding
mid-round) is injected into the simulated array while the
:class:`repro.serve.Server` is serving two tenants. The watchdog
(:class:`repro.faults.Watchdog`) converts the silent hang into structured
:class:`~repro.faults.FaultReport` diagnostics naming the exact PU,
instruction and starved sync channel; the server then quarantines the
suspect PU, re-places the surviving tenants over the masked array
(``plan_placement(available=...)`` — byte-equal to a from-scratch
exploration of the degraded budget), hot-swaps the degraded deployment
onto the unchanged machine, and replays every interrupted decode session
from its last completed window's K/V append cursor. The run is fully
deterministic: same schedule, same event log.

    PYTHONPATH=src python examples/fault_tolerant_serving.py          # full
    PYTHONPATH=src python examples/fault_tolerant_serving.py --small  # CI smoke
"""
import argparse

from repro.faults import FaultSchedule, PUHang
from repro.serve import SLO, Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--small", action="store_true",
                    help="tiny depths + short requests (CI smoke mode)")
    args = ap.parse_args()
    depth, window = (1, 4) if args.small else (2, 8)
    scale = 1 if args.small else 2

    srv = Server()
    srv.join("chat", args.arch, depth=depth, max_slots=2, window=window,
             slo=SLO(priority=1))
    srv.join("batch", args.arch, depth=depth, max_slots=1, window=window)
    for prompt, new in ((8, 6 * scale), (4, 10 * scale)):
        srv.submit(Request("chat", prompt_tokens=prompt, max_new_tokens=new))
    srv.submit(Request("batch", prompt_tokens=6, max_new_tokens=8 * scale))

    # One clean window to learn the placement, then hang a PU it uses.
    srv.step()
    target = srv.system.deployment.members[0].pids[-1]
    print(f"window 1 clean; injecting a hang at pu{target} "
          f"(mid-round, cycle 2000)")
    srv.inject(FaultSchedule(faults=(PUHang(pid=target, at_cycle=2000.0),)))

    report = srv.drain()

    print(f"\n{report}\n")
    print("fault diagnostics:")
    for r in srv.faults:
        print(f"  {r}")
    print("\nevent log (fault-tolerance path):")
    for e in srv.events:
        if e.kind in ("inject", "fault", "quarantine", "replay", "shed",
                      "replan"):
            print(f"  {e}")

    completed = sum(r.completed for r in srv.requests)
    survivors = sum(1 for r in srv.requests if not r.evicted)
    print(f"\n{completed}/{len(srv.requests)} requests completed over "
          f"{srv.windows} windows; quarantined PUs: "
          f"{sorted(srv.quarantined) or 'none'}; "
          f"{len(srv.faults)} fault reports")
    if not srv.faults:
        raise SystemExit("fault was not detected")
    if target not in srv.quarantined:
        raise SystemExit(f"pu{target} was not quarantined")
    if completed != survivors:
        raise SystemExit(
            "not all surviving requests completed on the degraded array")


if __name__ == "__main__":
    main()
