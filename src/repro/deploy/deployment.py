"""Compile a :class:`Strategy` into an executable :class:`Deployment`.

``compile_deployment(graph, strategy)`` runs the full compilation framework
(Fig. 4) once per member pipeline on a disjoint PU/HBM-channel slice of the
machine and bundles the results: merged instruction programs ready for the
discrete-event simulator (or the hardware), per-member placement, and the
analytic aggregate performance model (throughput = sum of members, system
latency = slowest member, CE over the assigned PUs) that the DSE caches.

Every member carries its *own* :class:`~repro.deploy.Workload` and is
compiled against its own graph — so one deployment can mix models
(FPGA-virtualization-style multi-tenancy: a ResNet member and a ViT member
on disjoint slices). The ``graph`` argument is the backward-compatible
broadcast: it binds every workload-less member, and may be ``None`` when the
strategy already assigns a workload to each member (e.g. built by
``Strategy.tenants`` or ``explore_multi``).

This is the uniform executable form of every DSE design point: DP-A is a
one-member deployment, DP-B/DP-C are multi-member ones, a multi-tenant
split is a per-member-workload one — all produced by the same call and all
loadable into :class:`repro.deploy.System`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

from ..compiler.compile import CompiledModel, compile_model
from ..compiler.graph import Graph
from ..core.program import PUProgram
from ..core.pu import N_HBM_CHANNELS, PUSpec, make_u50_system
from ..core.simulator import PipelineMember
from .resources import MemberResources, partition_resources
from .strategy import Strategy, Workload


@dataclass
class DeployedMember:
    """One member pipeline of a deployment, placed on its machine slice."""

    index: int
    config: tuple[int, int]
    workload: Workload
    compiled: CompiledModel
    resources: MemberResources

    @property
    def graph(self) -> Graph:
        return self.workload.graph

    @property
    def pids(self) -> tuple[int, ...]:
        return tuple(sorted(self.compiled.pid_map.values()))

    @property
    def channels(self) -> tuple[int, ...]:
        return self.resources.channel_pool

    @property
    def first_pid(self) -> int:
        stages = [s.index for s in self.compiled.part.stages if s.nids]
        return self.compiled.pid_map[min(stages)]

    @property
    def last_pid(self) -> int:
        stages = [s.index for s in self.compiled.part.stages if s.nids]
        return self.compiled.pid_map[max(stages)]

    @property
    def predicted_fps(self) -> float:
        return self.compiled.predicted_fps

    @property
    def predicted_latency(self) -> float:
        return self.compiled.predicted_latency

    def sim_member(self) -> PipelineMember:
        a, b = self.config
        return PipelineMember(
            first_pid=self.first_pid,
            last_pid=self.last_pid,
            label=f"m{self.index}({a},{b})",
            workload=self.workload.label,
            slots=self.workload.slots,
            pids=self.pids,
        )


@dataclass
class Deployment:
    """An executable deployment: programs + placement + analytic model.

    ``rounds`` is the explicit deployment-wide loop-count request, ``None``
    when per-member defaults applied (Workload.rounds / decode window /
    ``DEFAULT_ROUNDS``) — the actually-compiled count of each member is its
    programs' ProgCtrl NR field."""

    strategy: Strategy
    members: list[DeployedMember]
    pus: list[PUSpec]
    rounds: Optional[int]

    @property
    def name(self) -> str:
        return self.strategy.name or str(self.strategy)

    @property
    def batch(self) -> int:
        return len(self.members)

    @property
    def workloads(self) -> tuple[Workload, ...]:
        """Distinct workloads, in first-appearance member order."""
        return self.strategy.workloads

    @property
    def is_multi_tenant(self) -> bool:
        return len(self.workloads) > 1

    @property
    def graph(self) -> Optional[Graph]:
        """The single model of a single-tenant deployment (legacy view);
        ``None`` when members run different workloads."""
        w = self.workloads
        return w[0].graph if len(w) == 1 else None

    # -- executable form -----------------------------------------------------
    def programs(self, rounds: Optional[int] = None) -> list[PUProgram]:
        """The merged per-PU instruction programs of all members.

        ``rounds`` overrides the per-round loop count compiled into the
        programs by patching the terminal ProgCtrl NR field of each group —
        the same in-BRAM field the host would rewrite on hardware. Workload
        ``rounds`` overrides (per-member round semantics) are already
        compiled in; an explicit ``rounds`` here repatches every member."""
        progs = [p for m in self.members for p in m.compiled.programs]
        if rounds is None:
            return progs
        patched = []
        for p in progs:
            q = p.clone()
            for grp in (q.ld, q.cp, q.st):
                grp.progctrl.nr = rounds
            patched.append(q)
        return patched

    def sim_members(self) -> list[PipelineMember]:
        return [m.sim_member() for m in self.members]

    # -- analytic model (the DSE cache, aggregated) --------------------------
    @property
    def predicted_throughput(self) -> float:
        """Sum of member rates. For a multi-tenant deployment the members'
        frames are of different models; see ``predicted_throughput_by_workload``
        for the per-tenant split."""
        return sum(m.predicted_fps for m in self.members)

    def predicted_throughput_by_workload(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for m in self.members:
            out[m.workload.label] = out.get(m.workload.label, 0.0) + m.predicted_fps
        return out

    @property
    def predicted_latency(self) -> float:
        return max(m.predicted_latency for m in self.members)

    @property
    def used_tops(self) -> float:
        return sum(m.compiled.used_tops for m in self.members)

    def predicted_ce(self, peak_tops: Optional[float] = None) -> float:
        """CE = achieved GOPS / peak GOPS (defaults to the assigned PUs).

        Achieved GOPS sums each member's own model work x its own rate, so
        the metric is well-defined for mixed-model deployments too."""
        peak = peak_tops if peak_tops is not None else self.used_tops
        gops = sum(
            2.0 * m.graph.total_macs() * m.predicted_fps / 1e9
            for m in self.members
        )
        return gops / (peak * 1e3) if peak else 0.0

    def assert_disjoint(self) -> None:
        """Invariant: member pipelines never share a PU or an HBM channel."""
        pids: set[int] = set()
        chans: set[int] = set()
        for m in self.members:
            if pids & set(m.pids) or chans & set(m.channels):
                raise AssertionError(f"member {m.index} overlaps earlier members")
            pids |= set(m.pids)
            chans |= set(m.channels)


DEFAULT_ROUNDS = 16


def compile_deployment(
    g: "Optional[Graph | Workload]",
    strategy,
    *,
    pus: Optional[list[PUSpec]] = None,
    rounds: Optional[int] = None,
    n_io: int = 4,
    n_channels: int = N_HBM_CHANNELS,
    verify: bool = True,
    available: Optional[Iterable[int]] = None,
    channels: Optional[Iterable[int]] = None,
) -> Deployment:
    """Compile any schedule-like ``strategy`` (see :meth:`Strategy.of`) into
    an executable deployment.

    ``g`` (a Graph or a :class:`Workload`) is broadcast onto every member
    that does not already carry its own :class:`Workload`; pass ``g=None``
    for a fully multi-tenant strategy (every member workload-bound). Each member pipeline is compiled by the
    single-pipeline framework — against its own graph — on a disjoint PU
    subset and HBM channel pool; the partitioning that previously had to be
    hand-wired through ``compile_model(pid_offset=..., channel_pool=...)``
    happens here.

    Per-member loop count, in precedence order: the member's explicit
    ``Workload.rounds``; an explicit ``rounds`` argument here; one full
    decode window for decode-phase graphs (``graph.decode_steps`` — one
    program round is one token, so a decode tenant runs a complete
    advancing-length pass per measurement); ``DEFAULT_ROUNDS``.

    ``verify=True`` (the default) runs the static program verifier
    (:mod:`repro.verify`) over every member's compiled programs — ISA lint,
    sync-token deadlock-freedom, memory hazards, cross-member isolation —
    and raises :class:`~repro.verify.VerificationError` (carrying the
    structured :class:`~repro.verify.VerifyReport`) on any error-severity
    diagnostic. Pass ``verify=False`` to skip (e.g. when intentionally
    compiling a defective program for the mutation harness).

    ``available``/``channels`` are the degraded-array masks (fault
    tolerance): pids / HBM channel ids still healthy. Members are placed
    and compiled against the healthy subset only, but the deployment still
    records the *full* ``pus`` array — the machine (the bitstream) is
    unchanged, quarantined units merely receive no programs — so it stays
    loadable into the same :class:`~repro.deploy.System`."""
    strategy = Strategy.of(strategy).with_workload(g)
    unbound = [i for i, m in enumerate(strategy.members) if m.workload is None]
    if unbound:
        raise ValueError(
            f"strategy {strategy} has no workload for member(s) {unbound} "
            "and no graph was given to broadcast"
        )
    pus = pus if pus is not None else make_u50_system()
    pool_pus = pus
    if available is not None:
        avail = set(available)
        pool_pus = [p for p in pus if p.pid in avail]
        if not pool_pus:
            raise ValueError("no available PUs: every pid is masked out")
        for kind in ("PU1x", "PU2x"):
            need = strategy.total_a if kind == "PU1x" else strategy.total_b
            if need > 0 and not any(p.kind == kind for p in pool_pus):
                raise ValueError(
                    f"strategy {strategy} needs {kind} units but every "
                    f"{kind} pid is quarantined")
    chan_list = sorted(channels) if channels is not None else None
    placement = partition_resources(strategy, pool_pus, n_channels=n_channels,
                                    channels=chan_list)

    members: list[DeployedMember] = []
    for member, res in zip(strategy.members, placement):
        workload = member.workload
        if workload.rounds is not None:
            member_rounds = workload.rounds
        elif rounds is not None:
            member_rounds = rounds
        else:
            member_rounds = workload.graph.decode_steps or DEFAULT_ROUNDS
        masked = strategy.batch > 1 or available is not None or channels is not None
        cm = compile_model(
            workload.graph,
            member.a,
            member.b,
            pus=pool_pus,
            rounds=member_rounds,
            n_io=n_io,
            pid_offset=res.pid_offset if masked else None,
            channel_pool=list(res.channel_pool) if masked else None,
        )
        # Force instruction generation here: compilation is lazy (the DSE
        # evaluates thousands of configs without ever emitting instructions),
        # and the deploy boundary is where a design point becomes executable.
        cm.ensure_programs()
        members.append(DeployedMember(index=res.index, config=res.config,
                                      workload=workload, compiled=cm,
                                      resources=res))

    dep = Deployment(strategy=strategy, members=members, pus=pus,
                     rounds=rounds)
    dep.assert_disjoint()
    if verify:
        from ..verify import verify_deployment

        verify_deployment(dep).raise_if_failed()
    return dep
