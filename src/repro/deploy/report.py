"""Unified run reporting: one result schema for offline runs and serving.

Historically a ``System.run`` handed back the raw
:class:`~repro.core.simulator.SimResult` and every caller aggregated it
differently (``aggregate_fps`` here, ``fps_by_workload`` there, ad-hoc
dictionaries in the benchmarks). :class:`RunReport` is the single schema
all of them share now: per-tenant throughput, token accounting, latency
percentiles and SLO attainment, produced by ``System.run`` (wrapping the
``SimResult``, to which it transparently forwards, so existing call sites
keep working), by ``Server.drain`` (aggregated over serving windows, no
single backing sim) and consumed by ``benchmarks/paper_repro.py``.

:class:`SLO` lives here rather than in :mod:`repro.serve` because reports
carry attainment against it and ``deploy`` must not import the serving
layer that builds on it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from ..core.simulator import SimResult


@dataclass(frozen=True)
class SLO:
    """Per-tenant service-level objective for the serving control plane.

    ``min_tokens_per_s`` is a floor on the tenant's aggregate decode rate
    (measured per serving window); ``deadline_s`` bounds a request's
    completion latency; ``priority`` orders tenants under contention
    (higher wins — lower-priority tenants shed load first).
    """

    min_tokens_per_s: Optional[float] = None
    deadline_s: Optional[float] = None
    priority: int = 0


def _percentile(xs, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a sequence."""
    if not xs:
        return 0.0
    s = sorted(xs)
    rank = max(1, math.ceil(q / 100.0 * len(s)))
    return s[min(rank, len(s)) - 1]


@dataclass(frozen=True)
class TenantReport:
    """One tenant's share of a run: throughput, tokens, latency, SLO."""

    tenant: str
    fps: float                # steady-state member rounds/s
    token_rate: float         # fps scaled by packed slot counts
    rounds: int
    tokens: int
    # Latency samples in seconds: per-round pipeline latencies for offline
    # runs, completed-request latencies for serving runs.
    latencies_s: tuple[float, ...] = ()
    slo: Optional[SLO] = None
    # Fraction of measurement windows (serving) meeting the SLO; None when
    # no SLO applies.
    slo_attainment: Optional[float] = None

    def latency_percentile(self, q: float) -> float:
        return _percentile(self.latencies_s, q)

    @property
    def latency_p50(self) -> float:
        return self.latency_percentile(50)

    @property
    def latency_p95(self) -> float:
        return self.latency_percentile(95)

    @property
    def latency_p99(self) -> float:
        return self.latency_percentile(99)


@dataclass
class RunReport:
    """The one result schema of a run — offline or serving.

    ``tenants`` maps workload label to :class:`TenantReport`; ``wall_s`` is
    the simulated seconds covered; ``source`` is ``"run"`` for a single
    ``System.run`` and ``"serve"`` for an aggregated ``Server.drain``.
    When a single :class:`~repro.core.simulator.SimResult` backs the report
    it is kept in ``sim`` and every unknown attribute forwards to it, so
    all historical ``SimResult`` call sites (``members``,
    ``round_end_cycles``, ``deadlocked``, ...) work on a report unchanged.
    """

    tenants: dict[str, TenantReport] = field(default_factory=dict)
    wall_s: float = 0.0
    source: str = "run"
    sim: Optional[SimResult] = None

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_sim(sim: SimResult, warmup: int = 1) -> "RunReport":
        """Wrap one simulation result, splitting accounting per tenant."""
        by_label: dict[str, list] = {}
        for m in sim.members:
            by_label.setdefault(m.workload, []).append(m)
        tenants = {}
        for label, ms in by_label.items():
            lats = tuple(c / sim.sys_clk_hz for m in ms
                         for c in m.round_latencies_cycles)
            tenants[label] = TenantReport(
                tenant=label,
                fps=sum(m.throughput_fps(warmup) for m in ms),
                token_rate=sum(m.token_rate(warmup) for m in ms),
                rounds=sum(m.rounds for m in ms),
                tokens=sum(m.tokens for m in ms),
                latencies_s=lats,
            )
        return RunReport(tenants=tenants, wall_s=sim.end_seconds,
                         source="run", sim=sim)

    # -- unified aggregate accessors ----------------------------------------
    def aggregate_fps(self, warmup: int = 1) -> float:
        """System throughput: sum of per-member steady-state rates."""
        if self.sim is not None:
            return self.sim.aggregate_fps(warmup)
        return sum(t.fps for t in self.tenants.values())

    def fps_by_workload(self, warmup: int = 1) -> dict[str, float]:
        """Per-tenant throughput split (the multi-tenant metric)."""
        if self.sim is not None:
            return self.sim.fps_by_workload(warmup)
        return {name: t.fps for name, t in self.tenants.items()}

    def aggregate_token_rate(self, warmup: int = 1) -> float:
        """System tokens/s (slot-aware; equals fps when nothing packed)."""
        if self.sim is not None:
            return self.sim.aggregate_token_rate(warmup)
        return sum(t.token_rate for t in self.tenants.values())

    @property
    def total_tokens(self) -> int:
        return sum(t.tokens for t in self.tenants.values())

    def latency_percentile(self, q: float) -> float:
        """Percentile over every tenant's merged latency samples."""
        merged = [x for t in self.tenants.values() for x in t.latencies_s]
        return _percentile(merged, q)

    @property
    def latency_p50(self) -> float:
        return self.latency_percentile(50)

    @property
    def latency_p95(self) -> float:
        return self.latency_percentile(95)

    @property
    def latency_p99(self) -> float:
        return self.latency_percentile(99)

    def slo_attainment(self) -> dict[str, float]:
        """Per-tenant SLO attainment (tenants with an SLO only)."""
        return {name: t.slo_attainment for name, t in self.tenants.items()
                if t.slo_attainment is not None}

    # -- SimResult forwarding (historical call sites) ------------------------
    def __getattr__(self, name: str):
        if name.startswith("_") or self.__dict__.get("sim") is None:
            raise AttributeError(
                f"{type(self).__name__!s} has no attribute {name!r}")
        return getattr(self.__dict__["sim"], name)

    def __str__(self) -> str:
        parts = [f"RunReport[{self.source}] wall={self.wall_s:.4g}s"]
        for name, t in sorted(self.tenants.items()):
            slo = (f" slo={t.slo_attainment:.0%}"
                   if t.slo_attainment is not None else "")
            parts.append(f"  {name or '<default>'}: {t.token_rate:.1f} tok/s "
                         f"({t.tokens} tokens, p95 {t.latency_p95 * 1e3:.2f} ms"
                         f"{slo})")
        return "\n".join(parts)
