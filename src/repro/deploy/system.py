"""Runtime sessions: one fixed PU array, hot-swappable deployments.

The paper's headline capability (Sec. V): the FPGA is configured once; a host
switches among deployment strategies — pipeline parallelism, batch-level
parallelism, hybrids — purely by loading new instruction programs into the
ICU BRAMs. :class:`System` is that story as an API:

    system = System()                        # fixed make_u50_system() machine
    session = system.load(deployment_a)      # -> Session handle
    session.run(rounds=6)                    # measure strategy A -> RunReport
    session.switch(deployment_c).run()       # swap programs, same hardware

``load``/``switch`` return a :class:`~repro.deploy.session.Session` — the
handle carrying the active tenants, the current strategy and the swap
history — and ``run`` returns a :class:`~repro.deploy.report.RunReport`
(the unified result schema). Both are thin over the legacy objects: the
session forwards unknown attributes to the system and the report to its
``SimResult``, so historical chained forms (``system.load(dep).run()``)
and result consumers keep working unchanged.

``switch`` is exactly ``load`` with a hardware-compatibility check against
the *current* machine — it never rebuilds the PU array, only resets the
transient kernel/ICU/ISU state (BRAM program images, LUTRAMs, buffers), so a
switch-then-run is bit-identical to a fresh load-then-run.

Deployments whose member sets differ in *model*, not just shape, swap the
same way: going from a single-tenant DP-A to a two-tenant ResNet+ViT split
(per-member :class:`~repro.deploy.Workload`) is still just new instruction
programs on the unchanged PU array — no reconfiguration, and the per-tenant
rates come back through ``RunReport.fps_by_workload``.
"""
from __future__ import annotations

from typing import Optional

from ..core.pu import PUSpec, make_u50_system
from ..core.simulator import MultiPUSimulator
from .deployment import Deployment
from .report import RunReport
from .session import Session


class System:
    """One fixed simulated machine, executing hot-swappable deployments."""

    def __init__(self, pus: Optional[list[PUSpec]] = None, trace: bool = False) -> None:
        self.pus = list(pus) if pus is not None else make_u50_system()
        self.sim = MultiPUSimulator(self.pus, trace=trace)
        self.deployment: Optional[Deployment] = None
        self.session: Optional[Session] = None
        self.history: list[tuple[str, RunReport]] = []
        # Optional repro.faults.Watchdog: every run spawns the fault monitor
        # so silent hangs come back as structured RunReport.faults.
        self.watchdog = None

    # -- fault injection (repro.faults) --------------------------------------
    def inject(self, schedule) -> None:
        """Attach a :class:`repro.faults.FaultSchedule` to the simulated
        hardware; it re-arms on every run until :meth:`clear_faults`."""
        self.sim.inject(schedule)

    def clear_faults(self) -> None:
        self.sim.clear_faults()

    # -- deployment lifecycle ------------------------------------------------
    def _check_compatible(self, deployment: Deployment) -> None:
        if list(deployment.pus) != self.pus:
            raise ValueError(
                f"deployment {deployment.name!r} was compiled for different "
                "hardware than this system (PU array is fixed at session start)"
            )

    @property
    def tenants(self) -> tuple[str, ...]:
        """Workload labels of the active deployment (empty before load)."""
        if self.deployment is None:
            return ()
        return tuple(w.label for w in self.deployment.workloads)

    def load(self, deployment: Deployment) -> Session:
        """Stage ``deployment`` as the active strategy; returns the
        :class:`Session` handle (one per system lifetime, created on first
        load; later loads/switches record onto the same handle).

        The deployment may serve any mix of workloads — a multi-tenant
        member set loads exactly like a single-model one, since only the
        instruction programs differ."""
        self._check_compatible(deployment)
        self.deployment = deployment
        if self.session is None:
            self.session = Session(self)
        self.session._record(deployment)
        return self.session

    def switch(self, deployment: Deployment) -> Session:
        """Swap to another strategy on the *unchanged* hardware — including
        one whose members run different models (single-tenant -> multi-tenant
        and back).

        Equivalent to :meth:`load`; requires that a deployment is already
        active, which is what makes it a switch."""
        if self.deployment is None:
            raise RuntimeError("nothing loaded yet — use System.load first")
        return self.load(deployment)

    def run(self, rounds: Optional[int] = None, *,
            until_cycles: float = float("inf")) -> RunReport:
        """Execute the active deployment for ``rounds`` program rounds
        (default: the round count it was compiled with). Returns the
        unified :class:`RunReport` (forwards to its backing ``SimResult``)."""
        if self.deployment is None:
            raise RuntimeError("no deployment loaded — use System.load first")
        self.sim.reset()  # clear transient state; the PU array persists
        res = self.sim.run(
            self.deployment.programs(rounds),
            members=self.deployment.sim_members(),
            until_cycles=until_cycles,
            watchdog=self.watchdog,
        )
        report = RunReport.from_sim(res)
        self.history.append((self.deployment.name, report))
        return report
