"""Runtime sessions: one fixed PU array, hot-swappable deployments.

The paper's headline capability (Sec. V): the FPGA is configured once; a host
switches among deployment strategies — pipeline parallelism, batch-level
parallelism, hybrids — purely by loading new instruction programs into the
ICU BRAMs. :class:`System` is that story as an API:

    system = System()                       # fixed make_u50_system() machine
    system.load(deployment_a).run(rounds=6) # measure strategy A
    system.switch(deployment_c).run()       # swap programs, same hardware

``switch`` is exactly ``load`` with a hardware-compatibility check against
the *current* machine — it never rebuilds the PU array, only resets the
transient kernel/ICU/ISU state (BRAM program images, LUTRAMs, buffers), so a
switch-then-run is bit-identical to a fresh load-then-run.

Deployments whose member sets differ in *model*, not just shape, swap the
same way: going from a single-tenant DP-A to a two-tenant ResNet+ViT split
(per-member :class:`~repro.deploy.Workload`) is still just new instruction
programs on the unchanged PU array — no reconfiguration, and the per-tenant
rates come back through ``SimResult.fps_by_workload``.
"""
from __future__ import annotations

from typing import Optional

from ..core.pu import PUSpec, make_u50_system
from ..core.simulator import MultiPUSimulator, SimResult
from .deployment import Deployment


class System:
    """A session over one fixed simulated machine, executing deployments."""

    def __init__(self, pus: Optional[list[PUSpec]] = None, trace: bool = False) -> None:
        self.pus = list(pus) if pus is not None else make_u50_system()
        self.sim = MultiPUSimulator(self.pus, trace=trace)
        self.deployment: Optional[Deployment] = None
        self.history: list[tuple[str, SimResult]] = []

    # -- deployment lifecycle ------------------------------------------------
    def _check_compatible(self, deployment: Deployment) -> None:
        if list(deployment.pus) != self.pus:
            raise ValueError(
                f"deployment {deployment.name!r} was compiled for different "
                "hardware than this system (PU array is fixed at session start)"
            )

    @property
    def tenants(self) -> tuple[str, ...]:
        """Workload labels of the active deployment (empty before load)."""
        if self.deployment is None:
            return ()
        return tuple(w.label for w in self.deployment.workloads)

    def load(self, deployment: Deployment) -> "System":
        """Stage ``deployment`` as the active strategy (chainable).

        The deployment may serve any mix of workloads — a multi-tenant
        member set loads exactly like a single-model one, since only the
        instruction programs differ."""
        self._check_compatible(deployment)
        self.deployment = deployment
        return self

    def switch(self, deployment: Deployment) -> "System":
        """Swap to another strategy on the *unchanged* hardware — including
        one whose members run different models (single-tenant -> multi-tenant
        and back).

        Equivalent to :meth:`load`; requires that a deployment is already
        active, which is what makes it a switch."""
        if self.deployment is None:
            raise RuntimeError("nothing loaded yet — use System.load first")
        return self.load(deployment)

    def run(self, rounds: Optional[int] = None, *,
            until_cycles: float = float("inf")) -> SimResult:
        """Execute the active deployment for ``rounds`` program rounds
        (default: the round count it was compiled with)."""
        if self.deployment is None:
            raise RuntimeError("no deployment loaded — use System.load first")
        self.sim.reset()  # clear transient state; the PU array persists
        res = self.sim.run(
            self.deployment.programs(rounds),
            members=self.deployment.sim_members(),
            until_cycles=until_cycles,
        )
        self.history.append((self.deployment.name, res))
        return res
