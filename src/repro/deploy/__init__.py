# First-class deployments (paper Sec. V): Workload (one tenant's model),
# Strategy (what to run — members are (workload, a, b) pipelines),
# compile_deployment (how it lands on disjoint PU/channel slices, one graph
# per member), Deployment (executable programs + analytic model), System
# (one fixed machine, runtime strategy switching without reconfiguration —
# including single-tenant <-> multi-tenant swaps).
from .deployment import DeployedMember, Deployment, compile_deployment
from .resources import MemberResources, check_fits, partition_resources
from .strategy import Member, Strategy, Workload
from .system import System

__all__ = [
    "DeployedMember",
    "Deployment",
    "Member",
    "MemberResources",
    "Strategy",
    "System",
    "Workload",
    "check_fits",
    "compile_deployment",
    "partition_resources",
]
