# First-class deployments (paper Sec. V): Strategy (what to run),
# compile_deployment (how it lands on disjoint PU/channel slices),
# Deployment (executable programs + analytic model), System (one fixed
# machine, runtime strategy switching without reconfiguration).
from .deployment import DeployedMember, Deployment, compile_deployment
from .resources import MemberResources, partition_resources
from .strategy import Strategy
from .system import System

__all__ = [
    "DeployedMember",
    "Deployment",
    "MemberResources",
    "Strategy",
    "System",
    "compile_deployment",
    "partition_resources",
]
