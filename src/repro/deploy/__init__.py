# First-class deployments (paper Sec. V): Workload (one tenant's model),
# Strategy (what to run — members are (workload, a, b) pipelines),
# compile_deployment (how it lands on disjoint PU/channel slices, one graph
# per member), Deployment (executable programs + analytic model), System
# (one fixed machine, runtime strategy switching without reconfiguration —
# including single-tenant <-> multi-tenant swaps), Session (the handle
# load/switch return: tenants, current strategy, swap history), RunReport
# (the unified result schema of run() and Server.drain()).
from .deployment import DeployedMember, Deployment, compile_deployment
from .report import SLO, RunReport, TenantReport
from .resources import MemberResources, check_fits, partition_resources
from .session import Session, SwapRecord
from .strategy import Member, Strategy, Workload
from .system import System

__all__ = [
    "DeployedMember",
    "Deployment",
    "Member",
    "MemberResources",
    "RunReport",
    "SLO",
    "Session",
    "Strategy",
    "SwapRecord",
    "System",
    "TenantReport",
    "Workload",
    "check_fits",
    "compile_deployment",
    "partition_resources",
]
