"""Session handles over a :class:`~repro.deploy.System`.

``System.load``/``switch`` return a :class:`Session`: the stable handle on
one machine's deployment lifecycle — which tenants are being served, what
strategy is active, and the full swap history — where the old API returned
the mutated ``System`` itself. The handle is a thin shim over its system
(every unknown attribute forwards), so legacy chained call forms
(``system.load(dep).run()``) and code that treated the return value as the
``System`` keep working unchanged; new code reads ``session.tenants``,
``session.strategy`` and ``session.swaps`` and drives swaps through
``session.switch(...)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional

from .report import RunReport
from .strategy import Strategy

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from .deployment import Deployment
    from .system import System


@dataclass(frozen=True)
class SwapRecord:
    """One program swap: which deployment went live, serving whom."""

    name: str
    strategy: Strategy
    tenants: tuple[str, ...]


class Session:
    """Handle on one system's deployment lifecycle (created by ``load``)."""

    def __init__(self, system: "System") -> None:
        self.system = system
        self.swaps: list[SwapRecord] = []

    # -- state views ---------------------------------------------------------
    @property
    def deployment(self) -> "Optional[Deployment]":
        return self.system.deployment

    @property
    def strategy(self) -> Optional[Strategy]:
        dep = self.system.deployment
        return dep.strategy if dep is not None else None

    @property
    def tenants(self) -> tuple[str, ...]:
        return self.system.tenants

    @property
    def history(self) -> list[tuple[str, RunReport]]:
        return self.system.history

    # -- lifecycle (delegates to the system, returns this handle) ------------
    def load(self, deployment: "Deployment") -> "Session":
        return self.system.load(deployment)

    def switch(self, deployment: "Deployment") -> "Session":
        return self.system.switch(deployment)

    def run(self, rounds: Optional[int] = None, *,
            until_cycles: float = float("inf")) -> RunReport:
        return self.system.run(rounds, until_cycles=until_cycles)

    def _record(self, deployment: "Deployment") -> None:
        self.swaps.append(SwapRecord(name=deployment.name,
                                     strategy=deployment.strategy,
                                     tenants=self.system.tenants))

    # -- thin shim: anything else behaves like the system itself -------------
    def __getattr__(self, name: str):
        if name.startswith("_") or name in ("system", "swaps"):
            raise AttributeError(
                f"{type(self).__name__!s} has no attribute {name!r}")
        return getattr(self.system, name)

    def __repr__(self) -> str:
        strat = self.strategy
        return (f"Session(tenants={list(self.tenants)!r}, "
                f"strategy={str(strat) if strat else None!r}, "
                f"swaps={len(self.swaps)})")
