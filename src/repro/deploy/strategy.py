"""Deployment strategies (paper Sec. V): a uniform value type for *what to
run on the PU array*, independent of how it was found.

A :class:`Strategy` is a tuple of member pipeline configurations ``(a, b)`` —
``a`` PU1x + ``b`` PU2x units pipelining one batch. One member is classic
pipeline parallelism (DP-A); several members on disjoint PU subsets are
batch-level / hybrid parallelism (DP-B, DP-C). DSE points
(``SingleBatchPoint`` / ``MultiBatchSchedule``), raw ``(a, b)`` tuples and
tuples thereof all normalize through :meth:`Strategy.of`, so any Step-1/2
schedule is directly compilable by :func:`repro.deploy.compile_deployment`.
"""
from __future__ import annotations

import numbers
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class Strategy:
    """A deployment strategy: one (a, b) pipeline config per concurrent batch."""

    members: tuple[tuple[int, int], ...]
    name: str = field(default="", compare=False)

    def __post_init__(self) -> None:
        if not self.members:
            raise ValueError("strategy needs at least one member pipeline")
        norm = []
        for m in self.members:
            t = tuple(m)
            if len(t) != 2:
                raise ValueError(f"malformed member config {m!r}")
            try:
                a, b = int(t[0]), int(t[1])
            except (TypeError, ValueError) as e:
                raise ValueError(f"malformed member config {m!r}") from e
            # integral floats / numpy ints normalize to plain ints
            if a != t[0] or b != t[1] or a < 0 or b < 0:
                raise ValueError(f"malformed member config {m!r}")
            if a + b == 0:
                raise ValueError("member config (0, 0) uses no PU")
            norm.append((a, b))
        object.__setattr__(self, "members", tuple(norm))

    # -- constructors --------------------------------------------------------
    @staticmethod
    def single(a: int, b: int, name: str = "") -> "Strategy":
        """A single-batch pipeline across ``a`` PU1x + ``b`` PU2x."""
        s = Strategy(members=((a, b),), name=name)  # normalizes a/b to ints
        if not name:
            na, nb = s.members[0]
            s = Strategy(members=s.members, name=f"pipeline({na},{nb})")
        return s

    @staticmethod
    def multi(configs, name: str = "") -> "Strategy":
        """A multi-batch schedule: one member pipeline per concurrent batch."""
        try:
            members = tuple(tuple(c) for c in configs)
        except TypeError as e:
            raise ValueError(f"malformed member configs {configs!r}") from e
        s = Strategy(members=members, name=name)
        if not name:
            s = Strategy(members=s.members, name="+".join(
                f"({a},{b})" for a, b in s.members))
        return s

    @staticmethod
    def of(obj: Any, name: str = "") -> "Strategy":
        """Normalize any schedule-like object into a Strategy.

        Accepts a Strategy, a DSE ``MultiBatchSchedule`` (has ``.configs``),
        a DSE ``SingleBatchPoint`` (has ``.config``), an ``(a, b)`` pair, or
        an iterable of ``(a, b)`` pairs."""
        if isinstance(obj, Strategy):
            return obj
        # single points first: SingleBatchPoint also exposes a uniform
        # .configs view, but keeps its pipeline(a,b) naming through .config
        cfg = getattr(obj, "config", None)
        if cfg is not None:
            return Strategy.single(*cfg, name=name)
        cfgs = getattr(obj, "configs", None)
        if cfgs is not None:
            return Strategy.multi(cfgs, name=name)
        seq = tuple(obj)
        if len(seq) == 2 and all(isinstance(x, numbers.Number) for x in seq):
            return Strategy.single(*seq, name=name)
        return Strategy.multi(seq, name=name)

    # -- properties ----------------------------------------------------------
    @property
    def batch(self) -> int:
        """Concurrent batches = number of member pipelines."""
        return len(self.members)

    @property
    def is_single(self) -> bool:
        return len(self.members) == 1

    @property
    def total_a(self) -> int:
        return sum(m[0] for m in self.members)

    @property
    def total_b(self) -> int:
        return sum(m[1] for m in self.members)

    @property
    def total_pus(self) -> int:
        return self.total_a + self.total_b

    def __str__(self) -> str:
        body = "+".join(f"({a},{b})" for a, b in self.members)
        return f"{self.name or 'strategy'}[{body}]"
