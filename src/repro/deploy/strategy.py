"""Deployment strategies (paper Sec. V): a uniform value type for *what to
run on the PU array*, independent of how it was found.

A :class:`Strategy` is a tuple of :class:`Member` pipeline configurations.
Each member is ``(workload, a, b)`` — ``a`` PU1x + ``b`` PU2x units
pipelining one batch of one :class:`Workload` (a DNN graph plus its
round/batch semantics). One member is classic pipeline parallelism (DP-A);
several members on disjoint PU subsets are batch-level / hybrid parallelism
(DP-B, DP-C); members carrying *different* workloads are multi-tenant
deployments (FPGA-virtualization style: different models serving different
tenants on one fixed machine).

The workload axis is optional everywhere: DSE points (``SingleBatchPoint`` /
``MultiBatchSchedule``), raw ``(a, b)`` tuples and tuples thereof all
normalize through :meth:`Strategy.of` exactly as before — a workload-less
member compares equal to its legacy ``(a, b)`` tuple, and
:func:`repro.deploy.compile_deployment` broadcasts its single graph over all
workload-less members. ``(workload, a, b)`` triples (or ``(graph, a, b)``)
opt individual members into their own model.
"""
from __future__ import annotations

import numbers
from dataclasses import dataclass, field
from typing import Any, Optional

from .._deprecation import warn_deprecated
from ..compiler.graph import Graph

# Normalization layers the deprecation warning walks past, so the warning
# is attributed to whoever actually wrote the legacy tuple form.
_STRATEGY_SHIMS = ("repro.deploy.strategy", "repro.deploy.deployment")


def _warn_tuple_strategy() -> None:
    warn_deprecated(
        "tuple-only Strategy member forms are deprecated: build strategies "
        "with Strategy.single(a, b), Strategy.multi([Member(a, b), ...]) or "
        "Strategy.tenants([(workload, a, b), ...])",
        skip=_STRATEGY_SHIMS)


@dataclass(frozen=True)
class Workload:
    """One tenant's work: a DNN graph plus its round semantics and a label.

    ``rounds`` optionally overrides the deployment-wide per-round loop count
    for members running this workload (e.g. a latency-critical tenant running
    fewer rounds per measurement window than a batch tenant); it always wins
    over the ``rounds`` given to ``compile_deployment``. When neither is
    set, a decode-phase graph (``graph.decode_steps``) defaults to one full
    decode window — see :func:`repro.deploy.compile_deployment`. ``label``
    keys per-member accounting in
    :class:`repro.core.simulator.MemberSimResult`; it defaults to the graph
    name.

    ``slots`` names the decode sessions packed into this workload's member
    (slot-packed decode graphs, ``transformer_decoder(slots=...)``): one
    name per concurrent session, in slot order. It flows into
    :class:`repro.core.simulator.PipelineMember` so round accounting scales
    to per-session token accounting. Empty for unpacked workloads.
    """

    graph: Graph
    label: str = ""
    rounds: Optional[int] = None
    slots: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        if not isinstance(self.graph, Graph):
            raise TypeError(f"Workload.graph must be a Graph, got {self.graph!r}")
        if not self.label:
            object.__setattr__(self, "label", self.graph.name)
        if self.rounds is not None and self.rounds <= 0:
            raise ValueError(f"Workload.rounds must be positive, got {self.rounds}")
        slots = tuple(str(s) for s in self.slots)
        if not slots:
            # slot-packed graphs carry their packing in attrs; default the
            # slot ids so token accounting works without a serving layer
            packed = self.graph.attrs.get("slot_prefix_rows") or ()
            slots = tuple(f"slot{i}" for i in range(len(packed)))
        object.__setattr__(self, "slots", slots)

    @staticmethod
    def of(obj: "Workload | Graph | None", label: str = "") -> "Optional[Workload]":
        if obj is None or isinstance(obj, Workload):
            return obj
        if isinstance(obj, Graph):
            return Workload(graph=obj, label=label)
        raise TypeError(f"cannot interpret {obj!r} as a Workload")

    # Graphs are mutable node DAGs compared by identity; a workload is the
    # *specific* graph object the deployment will compile.
    def __eq__(self, other: Any) -> bool:
        if not isinstance(other, Workload):
            return NotImplemented
        return (self.graph is other.graph and self.label == other.label
                and self.rounds == other.rounds and self.slots == other.slots)

    def __hash__(self) -> int:
        return hash((id(self.graph), self.label, self.rounds, self.slots))

    def __str__(self) -> str:
        return self.label

    def __repr__(self) -> str:
        extra = f", rounds={self.rounds}" if self.rounds is not None else ""
        if self.slots:
            extra += f", slots={self.slots!r}"
        return f"Workload({self.label!r}{extra})"


@dataclass(frozen=True)
class Member:
    """One member pipeline: ``a`` PU1x + ``b`` PU2x running ``workload``.

    ``workload`` is ``None`` for legacy single-model strategies (the graph is
    supplied to ``compile_deployment`` and broadcast); such members compare
    equal to — and hash like — their historical ``(a, b)`` tuple form, so old
    tuple-shaped strategies round-trip unchanged.
    """

    a: int
    b: int
    workload: Optional[Workload] = None

    def __post_init__(self) -> None:
        if self.a < 0 or self.b < 0:
            raise ValueError(f"malformed member config ({self.a}, {self.b})")
        if self.a + self.b == 0:
            raise ValueError("member config (0, 0) uses no PU")

    @property
    def config(self) -> tuple[int, int]:
        return (self.a, self.b)

    @property
    def n_pus(self) -> int:
        return self.a + self.b

    def with_workload(self, workload: "Workload | Graph | None") -> "Member":
        """This member bound to ``workload`` (kept as-is if already bound)."""
        if self.workload is not None or workload is None:
            return self
        return Member(a=self.a, b=self.b, workload=Workload.of(workload))

    # -- legacy (a, b) tuple interchangeability ------------------------------
    def __iter__(self):
        """Unpack as the legacy pair: ``a, b = member``."""
        yield self.a
        yield self.b

    def __eq__(self, other: Any) -> bool:
        if isinstance(other, Member):
            return (self.a, self.b, self.workload) == (other.a, other.b, other.workload)
        if isinstance(other, tuple):
            return (self.workload is None and len(other) == 2
                    and tuple(other) == (self.a, self.b))
        return NotImplemented

    def __hash__(self) -> int:
        if self.workload is None:
            return hash((self.a, self.b))
        return hash((self.a, self.b, self.workload))

    def __str__(self) -> str:
        if self.workload is None:
            return f"({self.a},{self.b})"
        return f"({self.workload}:{self.a},{self.b})"


def _as_member(m: Any) -> Member:
    """Normalize ``(a, b)`` / ``(workload|graph, a, b)`` / Member."""
    if isinstance(m, Member):
        return m
    t = tuple(m)
    if len(t) == 3 and isinstance(t[0], (Workload, Graph)):
        w, a, b = t
        t = (a, b)
        workload = Workload.of(w)
    elif len(t) == 2:
        workload = None
    else:
        raise ValueError(f"malformed member config {m!r}")
    try:
        a, b = int(t[0]), int(t[1])
    except (TypeError, ValueError) as e:
        raise ValueError(f"malformed member config {m!r}") from e
    # integral floats / numpy ints normalize to plain ints
    if a != t[0] or b != t[1]:
        raise ValueError(f"malformed member config {m!r}")
    return Member(a=a, b=b, workload=workload)


@dataclass(frozen=True)
class Strategy:
    """A deployment strategy: one member pipeline per concurrent batch."""

    members: tuple[Member, ...]
    name: str = field(default="", compare=False)

    def __post_init__(self) -> None:
        if not self.members:
            raise ValueError("strategy needs at least one member pipeline")
        object.__setattr__(
            self, "members", tuple(_as_member(m) for m in self.members))

    # -- constructors --------------------------------------------------------
    @staticmethod
    def single(a: int, b: int, name: str = "",
               workload: "Workload | Graph | None" = None) -> "Strategy":
        """A single-batch pipeline across ``a`` PU1x + ``b`` PU2x."""
        member = _as_member((a, b)).with_workload(workload)
        s = Strategy(members=(member,), name=name)
        if not name:
            s = Strategy(members=s.members,
                         name=f"pipeline({s.members[0].a},{s.members[0].b})")
        return s

    @staticmethod
    def multi(configs, name: str = "") -> "Strategy":
        """A multi-batch schedule: one member pipeline per concurrent batch.

        Each config is ``(a, b)``, ``(workload, a, b)``, ``(graph, a, b)``
        or a :class:`Member`."""
        try:
            members = tuple(_as_member(c) for c in configs)
        except TypeError as e:
            raise ValueError(f"malformed member configs {configs!r}") from e
        s = Strategy(members=members, name=name)
        if not name:
            s = Strategy(members=s.members,
                         name="+".join(str(m) for m in s.members))
        return s

    @staticmethod
    def tenants(assignments, name: str = "") -> "Strategy":
        """Multi-tenant constructor: ``[(workload_or_graph, a, b), ...]``."""
        s = Strategy.multi(assignments, name=name)
        for m in s.members:
            if m.workload is None:
                raise ValueError(
                    f"Strategy.tenants requires a workload per member; {m} has none")
        return s

    @staticmethod
    def of(obj: Any, name: str = "") -> "Strategy":
        """Normalize any schedule-like object into a Strategy.

        Accepts a Strategy, a DSE ``MultiBatchSchedule`` (has ``.configs``),
        a DSE ``SingleBatchPoint`` (has ``.config``), an ``(a, b)`` pair, a
        ``(workload, a, b)`` triple, or an iterable of pairs / triples /
        Members."""
        if isinstance(obj, Strategy):
            return obj
        # a lone Member keeps its workload (it also has a .config view, so
        # it must not fall into the DSE-point branches below)
        if isinstance(obj, Member):
            return Strategy.multi([obj], name=name)
        # single points first: SingleBatchPoint also exposes a uniform
        # .configs view, but keeps its pipeline(a,b) naming through .config
        cfg = getattr(obj, "config", None)
        if cfg is not None:
            return Strategy.single(*cfg, name=name)
        cfgs = getattr(obj, "configs", None)
        if cfgs is not None:
            return Strategy.multi(cfgs, name=name)
        seq = tuple(obj)
        if len(seq) == 2 and all(isinstance(x, numbers.Number) for x in seq):
            _warn_tuple_strategy()
            return Strategy.single(*seq, name=name)
        if len(seq) == 3 and isinstance(seq[0], (Workload, Graph)):
            return Strategy.multi([seq], name=name)
        if any(isinstance(m, (tuple, list)) and len(m) == 2 for m in seq):
            _warn_tuple_strategy()
        return Strategy.multi(seq, name=name)

    def with_workload(self, workload: "Workload | Graph | None") -> "Strategy":
        """Broadcast ``workload`` onto every workload-less member (the
        backward-compatible single-model path of ``compile_deployment``)."""
        if workload is None:
            return self
        w = Workload.of(workload)
        return Strategy(members=tuple(m.with_workload(w) for m in self.members),
                        name=self.name)

    # -- properties ----------------------------------------------------------
    @property
    def batch(self) -> int:
        """Concurrent batches = number of member pipelines."""
        return len(self.members)

    @property
    def is_single(self) -> bool:
        return len(self.members) == 1

    @property
    def configs(self) -> tuple[tuple[int, int], ...]:
        """The legacy workload-less view: one (a, b) per member."""
        return tuple(m.config for m in self.members)

    @property
    def workloads(self) -> tuple[Workload, ...]:
        """Distinct workloads, in first-appearance member order."""
        seen: list[Workload] = []
        for m in self.members:
            if m.workload is not None and m.workload not in seen:
                seen.append(m.workload)
        return tuple(seen)

    @property
    def is_multi_tenant(self) -> bool:
        return len(self.workloads) > 1

    @property
    def total_a(self) -> int:
        return sum(m.a for m in self.members)

    @property
    def total_b(self) -> int:
        return sum(m.b for m in self.members)

    @property
    def total_pus(self) -> int:
        return self.total_a + self.total_b

    def __str__(self) -> str:
        body = "+".join(str(m) for m in self.members)
        return f"{self.name or 'strategy'}[{body}]"
