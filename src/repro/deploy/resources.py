"""Disjoint resource partitioning for multi-member deployments.

Concurrent member pipelines must never contend: each member gets a disjoint
PU subset (by kind, in pid order) and a disjoint HBM channel pool (Sec. V-A —
"each batch is processed by a disjoint PU subset"; [33] motivates channel
isolation). This logic used to leak into callers of ``compile_model`` through
the ``pid_offset``/``channel_pool`` kwargs; it is now owned by the deploy
layer and callers only ever see a :class:`~repro.deploy.Strategy`.

Channel policy: all available channels are split proportionally to each
member's *demand* (largest-remainder rounding, minimum 3 channels per member
when the budget allows — weights + LD + ST streams), as consecutive disjoint
ranges. Demand is the member's PU count scaled by its workload's per-round
HBM traffic (the activation bytes its memory plan will cycle through HBM),
so in a multi-tenant deployment a streaming-heavy tenant gets a wider slice.
When members run the same workload — or carry none — the traffic factors
cancel and the split reduces to the historical PU-count-proportional one; a
single-member strategy keeps the whole channel space.

Infeasible strategies fail fast in :func:`check_fits` with one aggregate
error that names every member's requested vs. available PUs and channels,
instead of erroring deep inside per-member compilation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from ..core.pu import N_HBM_CHANNELS, PUSpec
from .strategy import Member, Strategy

MIN_CHANNELS_PER_MEMBER = 1  # a member needs at least one HBM channel


@dataclass(frozen=True)
class MemberResources:
    """Placement of one member pipeline on the shared machine."""

    index: int
    config: tuple[int, int]
    pid_offset: dict[str, int]  # PUs of each kind consumed by earlier members
    channel_pool: tuple[int, ...]


def _member_traffic(member: Member) -> float:
    """Per-round HBM activation traffic estimate of a member's workload.

    Every graph tensor crosses HBM at least once per round (produced by one
    PU's ST stream, consumed by another's LD stream), so the padded tensor
    footprint is the slice-sizing signal the member's memory plan will turn
    into DataMove streams. Workload-less members return 0 (resolved to the
    mean by the caller, so a broadcast graph splits by PU count alone)."""
    if member.workload is None:
        return 0.0
    g = member.workload.graph
    return float(sum(t.nbytes_padded for t in g.tensors.values()))


def _member_weights(strategy: Strategy) -> list[float]:
    """Channel-share weight per member: PU count x relative HBM traffic."""
    traffic = [_member_traffic(m) for m in strategy.members]
    known = [t for t in traffic if t > 0]
    mean = sum(known) / len(known) if known else 1.0
    return [
        m.n_pus * ((t / mean) if t > 0 else 1.0)
        for m, t in zip(strategy.members, traffic)
    ]


def check_fits(strategy: Strategy, pus: list[PUSpec],
               n_channels: int = N_HBM_CHANNELS) -> None:
    """Validate that all member slices fit the machine.

    ``pus`` is the *available* PU list — a degraded array simply passes its
    healthy subset — and ``n_channels`` the available channel count.
    Raises a single ValueError enumerating each member's requested PUs and
    minimum channels against what the machine offers, so an overcommitted
    multi-tenant strategy reports every tenant's demand at once."""
    n1 = sum(1 for p in pus if p.kind == "PU1x")
    n2 = sum(1 for p in pus if p.kind == "PU2x")
    need_chan = MIN_CHANNELS_PER_MEMBER * strategy.batch
    problems = []
    if strategy.total_a > n1:
        problems.append(f"PU1x overcommitted: {strategy.total_a} requested, {n1} available")
    if strategy.total_b > n2:
        problems.append(f"PU2x overcommitted: {strategy.total_b} requested, {n2} available")
    if need_chan > n_channels:
        problems.append(
            f"HBM channels overcommitted: {strategy.batch} members x "
            f">={MIN_CHANNELS_PER_MEMBER} = {need_chan} requested, {n_channels} available"
        )
    if not problems:
        return
    lines = [
        f"strategy {strategy} does not fit the machine "
        f"({n1}x PU1x + {n2}x PU2x, {n_channels} HBM channels):"
    ]
    for i, m in enumerate(strategy.members):
        tenant = f" [{m.workload}]" if m.workload is not None else ""
        lines.append(
            f"  member {i}{tenant}: {m.a}x PU1x + {m.b}x PU2x, "
            f">={MIN_CHANNELS_PER_MEMBER} channel(s)"
        )
    lines.extend(f"  {p}" for p in problems)
    raise ValueError("\n".join(lines))


def _channel_shares(weights: list[float], n_channels: int) -> list[int]:
    """Integer split of ``n_channels``: every member first gets a floor of
    min(3, n_channels // len(weights)) channels (never less than 1), then
    the remainder is distributed proportionally to ``weights`` by largest
    remainder. Always sums to exactly ``n_channels``."""
    n = len(weights)
    if n_channels < n:
        raise ValueError(f"{n} member pipelines but only {n_channels} HBM channels")
    floor_share = min(3, n_channels // n)
    rem = n_channels - floor_share * n
    total_w = sum(weights)
    exact = [rem * w / total_w for w in weights]
    extra = [int(e) for e in exact]
    order = sorted(range(n), key=lambda j: exact[j] - extra[j], reverse=True)
    for k in range(rem - sum(extra)):
        extra[order[k]] += 1
    return [floor_share + extra[i] for i in range(n)]


def partition_resources(
    strategy: Strategy,
    pus: list[PUSpec],
    n_channels: int = N_HBM_CHANNELS,
    channels: "Optional[Sequence[int]]" = None,
) -> list[MemberResources]:
    """Assign each member pipeline disjoint PUs (as kind offsets into the
    given — possibly degraded — PU list) and a disjoint HBM channel range.

    ``channels`` restricts the split to an explicit list of available
    channel ids (the serving loop passes the healthy channels of a
    quarantined array); members then get consecutive disjoint slices of
    that list instead of of ``range(n_channels)``."""
    chan_list = list(channels) if channels is not None else list(range(n_channels))
    check_fits(strategy, pus, n_channels=len(chan_list))
    shares = _channel_shares(_member_weights(strategy), len(chan_list))
    out: list[MemberResources] = []
    offsets = {"PU1x": 0, "PU2x": 0}
    chan_next = 0
    for i, m in enumerate(strategy.members):
        pool = tuple(chan_list[chan_next:chan_next + shares[i]])
        chan_next += shares[i]
        out.append(
            MemberResources(
                index=i,
                config=m.config,
                pid_offset=dict(offsets),
                channel_pool=pool,
            )
        )
        offsets["PU1x"] += m.a
        offsets["PU2x"] += m.b
    return out
