"""Disjoint resource partitioning for multi-member deployments.

Concurrent member pipelines must never contend: each member gets a disjoint
PU subset (by kind, in pid order) and a disjoint HBM channel pool (Sec. V-A —
"each batch is processed by a disjoint PU subset"; [33] motivates channel
isolation). This logic used to leak into callers of ``compile_model`` through
the ``pid_offset``/``channel_pool`` kwargs; it is now owned by the deploy
layer and callers only ever see a :class:`~repro.deploy.Strategy`.

Channel policy: all available channels are split proportionally to each
member's PU count (largest-remainder rounding, minimum 3 channels per member
when the budget allows — weights + LD + ST streams), as consecutive disjoint
ranges. A single-member strategy therefore keeps the whole channel space,
matching the historical single-pipeline behavior.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.pu import N_HBM_CHANNELS, PUSpec
from .strategy import Strategy


@dataclass(frozen=True)
class MemberResources:
    """Placement of one member pipeline on the shared machine."""

    index: int
    config: tuple[int, int]
    pid_offset: dict[str, int]  # PUs of each kind consumed by earlier members
    channel_pool: tuple[int, ...]


def check_fits(strategy: Strategy, pus: list[PUSpec]) -> None:
    n1 = sum(1 for p in pus if p.kind == "PU1x")
    n2 = sum(1 for p in pus if p.kind == "PU2x")
    if strategy.total_a > n1 or strategy.total_b > n2:
        raise ValueError(
            f"strategy {strategy} needs {strategy.total_a}x PU1x + "
            f"{strategy.total_b}x PU2x but the system has {n1} + {n2}"
        )


def _channel_shares(weights: list[int], n_channels: int) -> list[int]:
    """Integer split of ``n_channels``: every member first gets a floor of
    min(3, n_channels // len(weights)) channels (never less than 1), then
    the remainder is distributed proportionally to ``weights`` by largest
    remainder. Always sums to exactly ``n_channels``."""
    n = len(weights)
    if n_channels < n:
        raise ValueError(f"{n} member pipelines but only {n_channels} HBM channels")
    floor_share = min(3, n_channels // n)
    rem = n_channels - floor_share * n
    total_w = sum(weights)
    exact = [rem * w / total_w for w in weights]
    extra = [int(e) for e in exact]
    order = sorted(range(n), key=lambda j: exact[j] - extra[j], reverse=True)
    for k in range(rem - sum(extra)):
        extra[order[k]] += 1
    return [floor_share + extra[i] for i in range(n)]


def partition_resources(
    strategy: Strategy,
    pus: list[PUSpec],
    n_channels: int = N_HBM_CHANNELS,
) -> list[MemberResources]:
    """Assign each member pipeline disjoint PUs (as kind offsets) and a
    disjoint HBM channel range."""
    check_fits(strategy, pus)
    shares = _channel_shares([a + b for a, b in strategy.members], n_channels)
    out: list[MemberResources] = []
    offsets = {"PU1x": 0, "PU2x": 0}
    chan_next = 0
    for i, (a, b) in enumerate(strategy.members):
        pool = tuple(range(chan_next, chan_next + shares[i]))
        chan_next += shares[i]
        out.append(
            MemberResources(
                index=i,
                config=(a, b),
                pid_offset=dict(offsets),
                channel_pool=pool,
            )
        )
        offsets["PU1x"] += a
        offsets["PU2x"] += b
    return out
