"""rwkv6-7b [ssm] "Finch": attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,  # wkv heads: d_model / ssm_head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    attn="none",
    ssm_head_dim=64,
    mlp="dense",  # rwkv channel-mix (squared relu)
    act="sqrelu",
    citation="arXiv:2404.05892",
))
