"""Architecture configuration system.

Every assigned architecture is a frozen :class:`ArchConfig` registered under
its pool id (``--arch <id>``). Shapes follow the assignment: every LM arch
carries the four canonical input shapes; ``long_500k`` only applies to
sub-quadratic architectures (``supports_long``).

``reduced()`` returns a tiny same-family config for CPU smoke tests; the full
configs are exercised exclusively through the dry-run (ShapeDtypeStruct, no
allocation).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES: tuple[ShapeCfg, ...] = (
    ShapeCfg("train_4k", 4_096, 256, "train"),
    ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    ShapeCfg("decode_32k", 32_768, 128, "decode"),
    ShapeCfg("long_500k", 524_288, 1, "decode"),
)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // num_heads

    # attention flavor
    attn: str = "full"  # full | swa | local_global | none
    window: int = 4_096  # SWA / local window
    global_every: int = 0  # local_global: every Nth layer is global (gemma3: 6)
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # MLP
    mlp: str = "swiglu"  # swiglu | geglu | dense
    act: str = "silu"

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 1024  # dispatch group size (tokens)

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    attn_every: int = 0  # hybrid (zamba2): shared attn block after every N
    n_shared_attn: int = 2  # alternating shared blocks

    # io frontend (vlm/audio: stubbed embeddings per the assignment)
    frontend: str = "tokens"  # tokens | patch_embed | frame_embed
    n_prefix_embeds: int = 256  # vlm: image tokens folded into the sequence

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    citation: str = ""

    # ------------------------------------------------------------------ api --
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def supports_long(self) -> bool:
        """long_500k runs only for sub-quadratic attention state (SSM /
        hybrid / windowed); pure full-attention archs skip it (DESIGN.md)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn in ("swa", "local_global")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def shapes(self) -> list[ShapeCfg]:
        out = []
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.supports_long:
                continue
            out.append(s)
        return out

    def all_shapes_with_skips(self) -> list[tuple[ShapeCfg, bool]]:
        return [
            (s, s.name == "long_500k" and not self.supports_long)
            for s in LM_SHAPES
        ]

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        gate = 2 if self.mlp in ("swiglu", "geglu") else 1
        per_mlp = d * f * (gate + 1)
        if self.family == "moe":
            per_mlp = per_mlp * self.n_experts + d * self.n_experts
        if self.family == "ssm":  # rwkv6: time-mix ~ 4*d^2 + channel-mix
            per_layer = 4 * d * d + d * f * 2
            return emb + self.num_layers * per_layer
        if self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            per_mamba = d * (2 * di + 2 * N * 1 + self.ssm_heads) + di * d + di * (self.ssm_conv)
            shared = self.n_shared_attn * (per_attn + per_mlp)
            return emb + self.num_layers * per_mamba + shared
        return emb + self.num_layers * (per_attn + per_mlp)

    def active_param_count(self) -> int:
        if self.family != "moe":
            return self.param_count()
        dense_like = replace(self, n_experts=0, top_k=0, family="dense")
        d, f = self.d_model, self.d_ff
        gate = 2 if self.mlp in ("swiglu", "geglu") else 1
        per_mlp = d * f * (gate + 1)
        return dense_like.param_count() - self.num_layers * per_mlp + self.num_layers * self.top_k * per_mlp

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return replace(
            self,
            num_layers=max(2, min(4, self.num_layers)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4 // self.num_heads or 1)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            window=64,
            global_every=self.global_every and 2,
            attn_every=self.attn_every and 2,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            moe_group=64,
            n_prefix_embeds=8,
        )


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from . import _ensure_loaded

    _ensure_loaded()
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    from . import _ensure_loaded

    _ensure_loaded()
    return dict(_REGISTRY)
