# Assigned architectures (public pool) + the paper's own ResNet-50.
# One module per architecture; all register into base._REGISTRY.
import importlib

from .base import ArchConfig, ShapeCfg, LM_SHAPES, all_configs, get_config

ARCH_MODULES = [
    "zamba2_7b",
    "h2o_danube3_4b",
    "starcoder2_15b",
    "qwen3_0_6b",
    "gemma3_4b",
    "grok1_314b",
    "dbrx_132b",
    "internvl2_76b",
    "musicgen_large",
    "rwkv6_7b",
]

_loaded = False


def _ensure_loaded() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


__all__ = ["ArchConfig", "ShapeCfg", "LM_SHAPES", "all_configs", "get_config", "ARCH_MODULES"]
