"""dbrx-132b [moe]: 16 fine-grained experts top-4, GQA kv=8
[hf:databricks/dbrx-base; unverified]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    attn="full",
    mlp="swiglu",
    n_experts=16,
    top_k=4,
    citation="hf:databricks/dbrx-base",
))
