"""qwen3-0.6b [dense]: qk_norm, GQA kv=8, tied embeddings
[hf:Qwen/Qwen3-8B; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,  # qwen3 uses explicit 128 (> d_model/heads)
    attn="full",
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp="swiglu",
    tie_embeddings=True,
    citation="hf:Qwen/Qwen3-8B",
))
