"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens
(frontend STUB: input_specs supplies precomputed frame embeddings)
[arXiv:2306.05284; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # MHA
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    attn="full",
    mlp="dense",
    act="gelu",
    frontend="frame_embed",
    citation="arXiv:2306.05284",
))
