"""internvl2-76b [vlm]: InternViT frontend (STUB: input_specs supplies
precomputed patch embeddings) + LLaMA-70B-class decoder backbone
[arXiv:2404.16821; unverified]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    attn="full",
    mlp="swiglu",
    frontend="patch_embed",
    n_prefix_embeds=256,
    citation="arXiv:2404.16821",
))
