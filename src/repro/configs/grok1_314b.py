"""grok-1-314b [moe]: 8 experts top-2, GQA kv=8 [hf:xai-org/grok-1;
unverified]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    attn="full",
    mlp="geglu",
    act="gelu",
    n_experts=8,
    top_k=2,
    citation="hf:xai-org/grok-1",
))
