"""zamba2-7b [hybrid]: Mamba2 backbone + 2 alternating shared attention
blocks applied every 6 layers [arXiv:2411.15242; unverified]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,  # MHA in the shared blocks
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,  # 3584 / 32
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    attn_every=6,
    n_shared_attn=2,
    mlp="swiglu",
    citation="arXiv:2411.15242",
))
