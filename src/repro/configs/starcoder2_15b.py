"""starcoder2-15b [dense]: GQA kv=4, RoPE, non-gated GELU MLP
[arXiv:2402.19173; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    attn="full",
    mlp="dense",
    act="gelu",
    citation="arXiv:2402.19173",
))
