"""gemma3-4b [dense]: 5:1 local:global attention, 128k context, GeGLU,
huge vocab [hf:google/gemma-3-1b-pt; unverified]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    head_dim=256,
    attn="local_global",
    window=1024,
    global_every=6,  # 5 local : 1 global
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp="geglu",
    act="gelu",
    tie_embeddings=True,
    citation="hf:google/gemma-3-1b-pt",
))
