"""Memory-hazard analyzer: static RAW/WAR and isolation checks.

Works on the compiled instruction streams *and* the memory plan that
produced them, so every DataMove can be mapped back to the
:class:`~repro.compiler.memory.TensorPlan` whose region it touches:

* **Region bounds** — unrolling a DataMove's successor ``AddrCyc`` (address
  cycling) or ``AddrLen`` (append-only length growth) gives the exact HBM
  byte extent touched across a full window; it must stay inside the plan's
  allocated extent (``align(region_bytes) * n_regions``). This is what
  proves a K/V cache never overruns its ``kv_base_rows + decode window``
  allocation.
* **Ping-pong safety** — a multi-region tensor (``beta > 1``) must cycle
  over exactly ``beta`` regions with a stride covering the transfer length,
  otherwise producer-round N and consumer-round N-1 alias the same bytes
  (the RAW/WAR hazard the B-buffer scheme exists to prevent).
* **Handshake guards** — every ST write to a consumed tensor must be
  preceded by a ``WAIT_ACK`` over the plan's exact BID range and publish a
  matching ``SEND_REQ``; every LD read of a produced tensor must sit inside
  a ``WAIT_REQ`` / ``SEND_ACK`` pair. CP-side reads (weight streaming, the
  residual port, attention's second operand) are exempt by design: their
  ordering comes from the LD-held sync pair plus the URAM interlock.
* **Member isolation** — across deployment members, HBM *channels* are the
  isolation boundary (members share one address space by construction, each
  compiled with the same bump allocator base): any channel used by two
  members is an error, and an address overlap on such a channel with a
  write on either side is flagged as a concrete corruption witness.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..core.isa import AddrCyc, AddrLen, DataMove, Group, Opcode, Sync, effective_opcode
from ..core.program import Program, PUProgram
from ..compiler.memory import MemoryPlan, TensorPlan
from .report import Code, Severity, VerifyReport


def _align(x: int, a: int = 4096) -> int:
    return (x + a - 1) // a * a


def _plan_extent(plan: TensorPlan) -> tuple[int, int]:
    """Allocated HBM byte range [lo, hi) — mirrors the bump allocator."""
    return plan.base_addr, plan.base_addr + _align(plan.region_bytes) * plan.n_regions


@dataclass
class _Access:
    """One DataMove's full byte extent over all its rounds."""

    mode: str  # "r" | "w"
    channel: int
    lo: int
    hi: int
    pid: int
    group: str
    index: int
    plan: Optional[TensorPlan]


def _succ_cycle(prog: Program, idx: int):
    nxt = prog.instructions[idx + 1] if idx + 1 < len(prog.instructions) else None
    return nxt if isinstance(nxt, (AddrCyc, AddrLen)) else None


def _extent(dm: DataMove, cyc) -> tuple[int, int]:
    """[lo, hi) bytes touched across the cycle (Table I(b) unrolled)."""
    if isinstance(cyc, AddrCyc):
        # addresses {ba + j*aoffs} for j in 0..nc, plus the latched start
        starts = [dm.cur_ba, cyc.ba, cyc.ba + cyc.nc * cyc.aoffs]
        return min(starts), max(starts) + dm.length
    if isinstance(cyc, AddrLen):
        # fixed address, growing length: max at len_base + nc*loffs
        max_len = max(dm.length, cyc.len_base + cyc.nc * cyc.loffs)
        return dm.cur_ba, dm.cur_ba + max_len
    return dm.cur_ba, dm.cur_ba + dm.length


def _find_plan(mem: MemoryPlan, addr: int) -> Optional[TensorPlan]:
    for plan in mem.tensors.values():
        lo, hi = _plan_extent(plan)
        if lo <= addr < hi:
            return plan
    return None


def _collect_accesses(programs: list[PUProgram],
                      mem: Optional[MemoryPlan]) -> list[_Access]:
    out = []
    for pu in programs:
        for group, prog in ((Group.LD, pu.ld), (Group.CP, pu.cp),
                            (Group.ST, pu.st)):
            for idx, inst in enumerate(prog.instructions):
                if not isinstance(inst, DataMove):
                    continue
                mode = "w" if group is Group.ST else "r"
                lo, hi = _extent(inst, _succ_cycle(prog, idx))
                plan = _find_plan(mem, lo) if mem is not None else None
                out.append(_Access(mode, inst.channel, lo, hi, pu.pid,
                                   group.value, idx, plan))
    return out


_SCRATCH_LIMIT = 0x0100_0000  # below the bump allocator: weight/host scratch


def check_region_bounds(programs: list[PUProgram], mem: MemoryPlan, *,
                        member: str = "",
                        report: Optional[VerifyReport] = None) -> VerifyReport:
    """AddrCyc/AddrLen unrolled extents stay inside their plan; cyclic
    multi-region access really cycles ``beta`` disjoint regions."""
    rep = report if report is not None else VerifyReport(label=member)

    # Plans must tile disjoint HBM ranges (bump-allocator invariant).
    spans = sorted((_plan_extent(p) + (p.tid,)) for p in mem.tensors.values())
    for (lo1, hi1, t1), (lo2, hi2, t2) in zip(spans, spans[1:]):
        if lo2 < hi1:
            rep.add(Code.HAZ_REGION_OVERRUN,
                    f"tensor plans {t1} and {t2} overlap in HBM "
                    f"([0x{lo1:x},0x{hi1:x}) vs [0x{lo2:x},0x{hi2:x}))",
                    member=member)

    for acc in _collect_accesses(programs, mem):
        if acc.plan is None:
            if acc.lo < _SCRATCH_LIMIT:
                continue  # weight-chunk streaming from low scratch space
            rep.add(Code.HAZ_REGION_OVERRUN,
                    f"transfer [0x{acc.lo:x},0x{acc.hi:x}) targets no "
                    "planned region",
                    severity=Severity.WARNING, member=member, pid=acc.pid,
                    group=acc.group, index=acc.index)
            continue
        lo, hi = _plan_extent(acc.plan)
        if acc.lo < lo or acc.hi > hi:
            rep.add(Code.HAZ_REGION_OVERRUN,
                    f"transfer extent [0x{acc.lo:x},0x{acc.hi:x}) overruns "
                    f"tensor {acc.plan.tid} plan [0x{lo:x},0x{hi:x}) "
                    f"(kind={acc.plan.kind}, beta={acc.plan.beta})",
                    member=member, pid=acc.pid, group=acc.group,
                    index=acc.index)

    # Ping-pong discipline on multi-region plans.
    for pu in programs:
        for group, prog in ((Group.LD, pu.ld), (Group.CP, pu.cp),
                            (Group.ST, pu.st)):
            for idx, inst in enumerate(prog.instructions):
                if not isinstance(inst, DataMove):
                    continue
                cyc = _succ_cycle(prog, idx)
                if not isinstance(cyc, AddrCyc):
                    continue
                plan = _find_plan(mem, cyc.ba)
                if plan is None or plan.n_regions <= 1:
                    continue
                if cyc.nc + 1 != plan.beta:
                    rep.add(Code.HAZ_PINGPONG,
                            f"tensor {plan.tid}: AddrCyc cycles {cyc.nc + 1} "
                            f"region(s) but the plan ping-pongs over "
                            f"beta={plan.beta}",
                            member=member, pid=pu.pid, group=group.value,
                            index=idx)
                if cyc.nc > 0 and cyc.aoffs < inst.length:
                    rep.add(Code.HAZ_PINGPONG,
                            f"tensor {plan.tid}: region stride AOFFS="
                            f"{cyc.aoffs} is smaller than the "
                            f"{inst.length}-byte transfer — adjacent "
                            "ping-pong regions alias (RAW/WAR hazard)",
                            member=member, pid=pu.pid, group=group.value,
                            index=idx)
    return rep


def _bid_range(plan: TensorPlan) -> set[int]:
    return set(range(plan.bid_base, plan.bid_base + plan.beta))


def _sync_bid_set(inst: Sync) -> set[int]:
    if inst.nc == 0:
        return {inst.bid}
    return set(range(inst.base_bid, inst.base_bid + inst.nc + 1))


def _segments(prog: Program):
    """Yield (idx, DataMove, pre_syncs, post_syncs): the Sync instructions
    between the previous DataMove and this one, and between this one and the
    next (guard instructions travel with the transfer they protect)."""
    dms = [i for i, inst in enumerate(prog.instructions)
           if isinstance(inst, DataMove)]
    for k, idx in enumerate(dms):
        lo = dms[k - 1] + 1 if k else 0
        hi = dms[k + 1] if k + 1 < len(dms) else len(prog.instructions)
        pre = [s for s in prog.instructions[lo:idx] if isinstance(s, Sync)]
        post = [s for s in prog.instructions[idx + 1:hi]
                if isinstance(s, Sync)]
        yield idx, prog.instructions[idx], pre, post


def check_handshake_guards(programs: list[PUProgram], mem: MemoryPlan, *,
                           member: str = "",
                           report: Optional[VerifyReport] = None
                           ) -> VerifyReport:
    """Every consumed-tensor write sits behind its ACK, every produced-
    tensor read inside its REQ/ACK pair, with BID ranges matching the plan."""
    rep = report if report is not None else VerifyReport(label=member)

    def guard(syncs: list[Sync], op: Opcode, plan: TensorPlan):
        """(present, matching) for guards of ``op`` against ``plan``."""
        cands = [s for s in syncs if effective_opcode(s) is op]
        match = any(_sync_bid_set(s) == _bid_range(plan) for s in cands)
        return bool(cands), match

    for pu in programs:
        # -- ST: writes to consumed tensors -------------------------------
        for idx, dm, pre, post in _segments(pu.st):
            plan = _find_plan(mem, dm.cur_ba)
            if (plan is None or plan.kind == "output"
                    or not plan.consumer_stages):
                continue
            present, match = guard(pre, Opcode.WAIT_ACK, plan)
            if not present:
                rep.add(Code.HAZ_UNGUARDED_WRITE,
                        f"write to consumed tensor {plan.tid} is not guarded "
                        "by a WAIT_ACK — peer may still be reading",
                        member=member, pid=pu.pid, group="ST", index=idx)
            elif not match:
                rep.add(Code.HAZ_BID_MISMATCH,
                        f"WAIT_ACK guard(s) before write to tensor "
                        f"{plan.tid} cover the wrong BID range (plan BIDs "
                        f"{sorted(_bid_range(plan))})",
                        member=member, pid=pu.pid, group="ST", index=idx)
            present, match = guard(pre + post, Opcode.SEND_REQ, plan)
            if not present:
                rep.add(Code.HAZ_UNGUARDED_WRITE,
                        f"write to consumed tensor {plan.tid} never "
                        "publishes a SEND_REQ — consumers starve",
                        member=member, pid=pu.pid, group="ST", index=idx)
            elif not match:
                rep.add(Code.HAZ_BID_MISMATCH,
                        f"SEND_REQ(s) around write to tensor {plan.tid} "
                        f"cover the wrong BID range (plan BIDs "
                        f"{sorted(_bid_range(plan))})",
                        member=member, pid=pu.pid, group="ST", index=idx)

        # -- LD: reads of produced tensors (skip the one-shot prologue) ---
        try:
            icu_ba = pu.ld.progctrl.icu_ba
        except ValueError:
            icu_ba = 0
        for idx, dm, pre, post in _segments(pu.ld):
            if idx < icu_ba:
                continue
            plan = _find_plan(mem, dm.cur_ba)
            if plan is None or plan.kind != "intermediate":
                continue
            present, match = guard(pre, Opcode.WAIT_REQ, plan)
            if not present:
                rep.add(Code.HAZ_UNGUARDED_READ,
                        f"read of produced tensor {plan.tid} is not guarded "
                        "by a WAIT_REQ — data may not have landed",
                        member=member, pid=pu.pid, group="LD", index=idx)
            elif not match:
                rep.add(Code.HAZ_BID_MISMATCH,
                        f"WAIT_REQ guard(s) before read of tensor "
                        f"{plan.tid} cover the wrong BID range (plan BIDs "
                        f"{sorted(_bid_range(plan))})",
                        member=member, pid=pu.pid, group="LD", index=idx)
            present, match = guard(post, Opcode.SEND_ACK, plan)
            if not present:
                rep.add(Code.HAZ_UNGUARDED_READ,
                        f"read of tensor {plan.tid} is never acknowledged "
                        "(missing SEND_ACK) — producer credits leak away",
                        member=member, pid=pu.pid, group="LD", index=idx)
            elif not match:
                rep.add(Code.HAZ_BID_MISMATCH,
                        f"SEND_ACK(s) after read of tensor {plan.tid} "
                        f"cover the wrong BID range (plan BIDs "
                        f"{sorted(_bid_range(plan))})",
                        member=member, pid=pu.pid, group="LD", index=idx)
    return rep


def check_kv_streams(programs: list[PUProgram], mem: MemoryPlan, *,
                     member: str = "",
                     report: Optional[VerifyReport] = None) -> VerifyReport:
    """Per-slot K/V stream consistency: every cache region's length-advancing
    reader and append cursor describe the *same* slot geometry.

    With slot-packed decode (several sessions at different cache depths in
    one member) each cache region carries its own AddrLen read stream and
    its own AddrCyc append stream. The bounds/ping-pong checks see each
    stream in isolation; this check cross-correlates the two per region, so
    a cross-slot mixup — one slot's append cursor pointed at another slot's
    region, or a read prefix compiled for a different slot's depth — is
    caught even when every individual extent stays in bounds:

    * the read stream must start at the region base, advance in whole rows
      (``len_base`` a multiple of ``loffs``), and imply a non-negative
      prefix that stays inside the region across all ``nc`` rounds;
    * exactly one append stream must target the region, writing one row
      (``length == aoffs == loffs``) starting right after the read prefix
      (``ba == base + base_rows*row``) over the same round count.
    """
    rep = report if report is not None else VerifyReport(label=member)

    kv_plans = {p.tid: p for p in mem.tensors.values() if p.kind == "kv"}
    if not kv_plans:
        return rep
    reads: dict[int, list] = {}
    appends: dict[int, list] = {}
    for pu in programs:
        for group, prog in ((Group.LD, pu.ld), (Group.CP, pu.cp),
                            (Group.ST, pu.st)):
            for idx, inst in enumerate(prog.instructions):
                if not isinstance(inst, DataMove):
                    continue
                cyc = _succ_cycle(prog, idx)
                plan = _find_plan(mem, inst.cur_ba)
                if plan is None or plan.kind != "kv":
                    continue
                loc = (pu.pid, group.value, idx, inst, cyc)
                if isinstance(cyc, AddrLen):
                    reads.setdefault(plan.tid, []).append(loc)
                elif group is Group.ST:
                    appends.setdefault(plan.tid, []).append(loc)

    for tid, plan in sorted(kv_plans.items()):
        rs = reads.get(tid, [])
        ws = appends.get(tid, [])
        if not rs and not ws:
            continue  # untouched region (dead tensor) — nothing to correlate
        if not ws:
            rep.add(Code.HAZ_KV_STREAM,
                    f"kv tensor {tid}: length-advancing read stream has no "
                    "append stream — the prefix never grows past round 0",
                    member=member)
            continue
        if not rs:
            rep.add(Code.HAZ_KV_STREAM,
                    f"kv tensor {tid}: append stream has no length-advancing "
                    "reader — appended rows are never consumed",
                    severity=Severity.WARNING, member=member)
        if len(ws) > 1:
            locs = ", ".join(f"pu{p}.{g}[{i}]" for p, g, i, _, _ in ws)
            rep.add(Code.HAZ_KV_STREAM,
                    f"kv tensor {tid}: {len(ws)} append streams target one "
                    f"slot region ({locs}) — cross-slot append mixup",
                    member=member)

        geom = None  # (row, base_rows, nc) implied by the read side
        for pid, grp, idx, dm, al in rs:
            row, len0, nc = al.loffs, al.len_base, al.nc
            if row <= 0 or len0 % row or len0 < row:
                rep.add(Code.HAZ_KV_STREAM,
                        f"kv tensor {tid}: read stream advances by LOFFS="
                        f"{row} from LEN_BASE={len0} — not a whole-row "
                        "prefix", member=member, pid=pid, group=grp,
                        index=idx)
                continue
            base_rows = len0 // row - 1
            if dm.cur_ba != plan.base_addr:
                rep.add(Code.HAZ_KV_STREAM,
                        f"kv tensor {tid}: prefix read starts at "
                        f"0x{dm.cur_ba:x}, not the region base "
                        f"0x{plan.base_addr:x}", member=member, pid=pid,
                        group=grp, index=idx)
            if len0 + nc * row > plan.region_bytes:
                rep.add(Code.HAZ_KV_STREAM,
                        f"kv tensor {tid}: read prefix grows to "
                        f"{len0 + nc * row} bytes, past the "
                        f"{plan.region_bytes}-byte region — depth belongs "
                        "to a deeper slot", member=member, pid=pid,
                        group=grp, index=idx)
            if geom is None:
                geom = (row, base_rows, nc)
            elif geom != (row, base_rows, nc):
                rep.add(Code.HAZ_KV_STREAM,
                        f"kv tensor {tid}: read streams disagree on slot "
                        f"geometry ({geom} vs {(row, base_rows, nc)})",
                        member=member, pid=pid, group=grp, index=idx)

        for pid, grp, idx, dm, ac in ws:
            if not isinstance(ac, AddrCyc):
                rep.add(Code.HAZ_KV_STREAM,
                        f"kv tensor {tid}: append write carries no AddrCyc "
                        "cursor — every round overwrites one row",
                        member=member, pid=pid, group=grp, index=idx)
                continue
            if geom is None:
                continue  # read side already diagnosed (or absent)
            row, base_rows, nc = geom
            want_ba = plan.base_addr + base_rows * row
            if dm.length != row or ac.aoffs != row:
                rep.add(Code.HAZ_KV_STREAM,
                        f"kv tensor {tid}: append writes {dm.length} bytes "
                        f"with stride {ac.aoffs}, but the read side's row "
                        f"is {row} bytes", member=member, pid=pid,
                        group=grp, index=idx)
            if ac.ba != want_ba or dm.cur_ba != want_ba:
                rep.add(Code.HAZ_KV_STREAM,
                        f"kv tensor {tid}: append cursor starts at "
                        f"0x{ac.ba:x}, but the read prefix ends at "
                        f"0x{want_ba:x} ({base_rows} base rows) — append "
                        "and read disagree on the slot's depth",
                        member=member, pid=pid, group=grp, index=idx)
            if ac.nc != nc:
                rep.add(Code.HAZ_KV_STREAM,
                        f"kv tensor {tid}: append cursor covers "
                        f"{ac.nc + 1} round(s) but the read stream "
                        f"advances over {nc + 1}", member=member, pid=pid,
                        group=grp, index=idx)
    return rep


def check_isolation(members: list[tuple[str, list[PUProgram],
                                        Optional[MemoryPlan]]], *,
                    report: Optional[VerifyReport] = None) -> VerifyReport:
    """Cross-member isolation: no HBM channel serves two members; address
    overlaps on a shared channel with a writer are concrete corruption."""
    rep = report if report is not None else VerifyReport(label="deployment")
    accesses = [(label, _collect_accesses(progs, mem))
                for label, progs, mem in members]
    for i in range(len(accesses)):
        for j in range(i + 1, len(accesses)):
            li, ai = accesses[i]
            lj, aj = accesses[j]
            chans_i = {a.channel for a in ai}
            chans_j = {a.channel for a in aj}
            for ch in sorted(chans_i & chans_j):
                rep.add(Code.HAZ_CHANNEL_SHARED,
                        f"HBM channel {ch} is used by both member "
                        f"{li!r} and member {lj!r} — members must own "
                        "disjoint channel pools",
                        member=li)
                hits = [
                    (x, y)
                    for x in ai if x.channel == ch
                    for y in aj if y.channel == ch
                    if x.lo < y.hi and y.lo < x.hi
                    and ("w" in (x.mode, y.mode))
                ]
                for x, y in hits[:4]:  # cap the witness list
                    rep.add(Code.HAZ_MEMBER_OVERLAP,
                            f"member {li!r} pu{x.pid}.{x.group}[{x.index}] "
                            f"({x.mode} [0x{x.lo:x},0x{x.hi:x})) overlaps "
                            f"member {lj!r} pu{y.pid}.{y.group}[{y.index}] "
                            f"({y.mode} [0x{y.lo:x},0x{y.hi:x})) on channel "
                            f"{ch}",
                            member=li, pid=x.pid, group=x.group,
                            index=x.index)
    return rep
