"""Defect-injection harness cross-validating the verifier vs the simulator.

Each mutator clones a compiled program bundle and plants one realistic
compiler bug — the classes the static analyzer claims to catch:

* :func:`drop_send_ack`  — a consumer stops acknowledging one tensor's
  reads; the producer's ACK credits run dry and the pipeline deadlocks.
* :func:`swap_bids`      — two WAIT instructions trade channels (the
  classic BID-allocation off-by-one); nobody sends on the waited channels.
* :func:`shrink_region`  — a ping-pong tensor's AddrCyc strides collapse
  to 0; producer round N overwrites the bytes consumer round N-1 reads.
* :func:`overflow_field` — a GEMM's M dimension exceeds its 12-bit field;
  hardware would silently truncate and execute a different GEMM.
* :func:`hijack_channel` — one member's store is redirected onto another
  member's HBM channel and address range (multi-tenant isolation breach).

The ``confirm_*`` helpers demonstrate the same defect *dynamically* with
verification bypassed: deadlock via the discrete-event simulator, data
corruption via the runtime transfer-overlap detector over the simulator's
trace (:func:`runtime_hazards`), and field truncation via the timing
divergence between the intended and the truncated instruction image.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Optional

from ..compiler.memory import MemoryPlan
from ..core.isa import AddrCyc, Compute, DataMove, Opcode, Sync
from ..core.program import PUProgram
from ..core.pu import PUSpec
from ..core.simulator import MultiPUSimulator, SimResult
from .report import VerifyReport
from . import verify_programs


@dataclass
class Mutation:
    """A mutated program bundle plus where the defect was planted."""

    name: str
    programs: list[PUProgram]
    detail: str


def _clone(programs: list[PUProgram]) -> list[PUProgram]:
    return [p.clone() for p in programs]


# ---------------------------------------------------------------- mutators --
def drop_send_ack(programs: list[PUProgram]) -> Mutation:
    """Remove the first loop-body SEND_ACK of some LD program."""
    muts = _clone(programs)
    for pu in muts:
        icu_ba = pu.ld.progctrl.icu_ba
        for idx in range(icu_ba, len(pu.ld.instructions)):
            inst = pu.ld.instructions[idx]
            if isinstance(inst, Sync) and inst.op is Opcode.SEND_ACK:
                del pu.ld.instructions[idx]
                return Mutation(
                    "drop_send_ack", muts,
                    f"removed SEND_ACK(dst=pu{inst.pid}, bid={inst.bid}) "
                    f"at pu{pu.pid}.LD[{idx}]")
    raise ValueError("no loop-body SEND_ACK found to drop")


def swap_bids(programs: list[PUProgram]) -> Mutation:
    """Swap the channel state of the first two distinct WAIT instructions."""
    muts = _clone(programs)
    waits: list[tuple[int, str, Sync]] = []
    for pu in muts:
        for gname, prog in (("LD", pu.ld), ("CP", pu.cp), ("ST", pu.st)):
            for inst in prog.instructions:
                if isinstance(inst, Sync) and not inst.is_send:
                    waits.append((pu.pid, gname, inst))
    for i in range(len(waits)):
        for j in range(i + 1, len(waits)):
            a, b = waits[i][2], waits[j][2]
            # The BID state itself must differ — swapping two waits that
            # happen to cover the same range (a multi-consumer fork) is a
            # no-op, not a defect.
            if (a.base_bid, a.bid, a.nc) != (b.base_bid, b.bid, b.nc):
                fields = ("bid", "base_bid", "nc", "ic")
                for f in fields:
                    va, vb = getattr(a, f), getattr(b, f)
                    setattr(a, f, vb)
                    setattr(b, f, va)
                return Mutation(
                    "swap_bids", muts,
                    f"swapped channels of {a.op.name}@pu{waits[i][0]}."
                    f"{waits[i][1]} and {b.op.name}@pu{waits[j][0]}."
                    f"{waits[j][1]}")
    raise ValueError("no two distinct WAIT instructions found to swap")


def shrink_region(programs: list[PUProgram], mem: MemoryPlan,
                  tid: Optional[int] = None) -> Mutation:
    """Collapse the region stride of a multi-region intermediate tensor on
    both its write and read sides (AOFFS := 0): all rounds then alias
    region 0, defeating the ping-pong. ``tid`` picks the tensor (default:
    first eligible). Whether the aliasing *manifests* at runtime depends on
    whether the producer ever runs a round ahead — iterate eligible tids to
    find one whose corruption the trace exhibits."""
    muts = _clone(programs)
    for plan in sorted(mem.tensors.values(), key=lambda p: p.tid):
        if plan.kind != "intermediate" or plan.beta <= 1:
            continue
        if tid is not None and plan.tid != tid:
            continue
        hit = 0
        for pu in muts:
            for prog in (pu.ld, pu.cp, pu.st):
                for inst in prog.instructions:
                    if isinstance(inst, AddrCyc) and inst.ba == plan.base_addr:
                        inst.aoffs = 0
                        hit += 1
        if hit:
            return Mutation(
                "shrink_region", muts,
                f"zeroed AOFFS of {hit} AddrCyc(s) over tensor {plan.tid} "
                f"(beta={plan.beta})")
    raise ValueError("no multi-region intermediate tensor found")


def overflow_field(programs: list[PUProgram]) -> tuple[Mutation, list[PUProgram]]:
    """Overflow the first GEMM's 12-bit M field. Returns the *intended*
    mutated bundle plus the *truncated* bundle — what hardware actually
    executes after the field wraps — so the two can be compared in
    simulation (they compute different GEMMs)."""
    muts = _clone(programs)
    for pu in muts:
        for inst in pu.cp.instructions:
            if isinstance(inst, Compute):
                inst.m += 1 << 12
                truncated = _clone(muts)
                for tpu in truncated:
                    for tinst in tpu.cp.instructions:
                        if isinstance(tinst, Compute):
                            tinst.m &= (1 << 12) - 1
                return (
                    Mutation("overflow_field", muts,
                             f"GEMM M={inst.m} exceeds 12 bits at "
                             f"pu{pu.pid}.CP"),
                    truncated,
                )
    raise ValueError("no Compute instruction found")


def hijack_channel(member_programs: list[list[PUProgram]]
                   ) -> tuple[list[list[PUProgram]], str]:
    """Redirect the second member's first store onto the first member's
    store channel *and* address range — the isolation breach a buggy
    resource partitioner would produce. Returns the mutated per-member
    bundles (member 0 untouched)."""
    if len(member_programs) < 2:
        raise ValueError("need at least two members")
    target: Optional[DataMove] = None
    for pu in member_programs[0]:
        for inst in pu.st.instructions:
            if isinstance(inst, DataMove):
                target = inst
                break
        if target:
            break
    if target is None:
        raise ValueError("member 0 has no store DataMove")
    muts = [member_programs[0]] + [_clone(ps) for ps in member_programs[1:]]
    for pu in muts[1]:
        for idx, inst in enumerate(pu.st.instructions):
            if isinstance(inst, DataMove):
                inst.channel = target.channel
                inst.cur_ba = target.cur_ba
                nxt = (pu.st.instructions[idx + 1]
                       if idx + 1 < len(pu.st.instructions) else None)
                if isinstance(nxt, AddrCyc):
                    nxt.ba = target.cur_ba
                return muts, (
                    f"member 1 pu{pu.pid}.ST[{idx}] redirected onto member "
                    f"0's channel {target.channel} @0x{target.cur_ba:x}")
    raise ValueError("member 1 has no store DataMove")


# ------------------------------------------------- dynamic confirmation ----
def verify_mutation(mut: Mutation, *, mem: Optional[MemoryPlan] = None,
                    pu_specs: Optional[dict[int, PUSpec]] = None
                    ) -> VerifyReport:
    """Run the full static verifier over a mutated bundle."""
    return verify_programs(mut.programs, mem=mem, pu_specs=pu_specs,
                           member=mut.name)


def simulate_raw(programs: list[PUProgram],
                 pus: Optional[list[PUSpec]] = None, *,
                 trace: bool = False,
                 until_cycles: float = float("inf"),
                 ) -> tuple[SimResult, list]:
    """Simulate with verification bypassed; returns (result, kernel trace).

    A deadlocked bundle simply drains the event heap — every ICU decoder
    parks on a WAIT with no wake-up pending — so ``SimResult.deadlocked``
    is exact, no event-count horizon needed."""
    sim = MultiPUSimulator(pus, trace=trace)
    res = sim.run(programs, until_cycles=until_cycles)
    return res, list(sim.kernel.trace)


def _trace_xfers(trace: list):
    xfers = []
    for t0, who, what in trace:
        if not (isinstance(what, tuple) and what and what[0] == "xfer"):
            continue
        _, mode, channel, addr, nbytes, t_end = what
        pid = int(who.split(".")[0][2:])
        xfers.append((t0, t_end, mode, channel, addr, addr + nbytes, pid, who))
    return xfers


def runtime_hazards(trace: list, *,
                    member_of: Optional[dict[int, int]] = None) -> list[str]:
    """Concurrent-overlap detector over the simulator's transfer trace.

    Same-member hazards need *temporal* + byte overlap with a writer on one
    side and a different PU on the other (same-PU pairs are excluded: the
    intra-PU write->read stream is tile-interlocked by design, with the
    same-PU SEND_REQ intentionally emitted before the store). Cross-member
    pairs (``member_of``: pid -> member index) are corruption on byte +
    channel overlap *alone* — one tenant's bytes in another's region is a
    breach regardless of timing (and the per-channel port serializes
    transfers, so requiring temporal overlap there would mask it)."""
    xfers = _trace_xfers(trace)
    hazards = []
    for i in range(len(xfers)):
        s0, e0, m0, c0, lo0, hi0, p0, w0 = xfers[i]
        for j in range(i + 1, len(xfers)):
            s1, e1, m1, c1, lo1, hi1, p1, w1 = xfers[j]
            if p0 == p1 or "w" not in (m0, m1):
                continue
            if not (lo0 < hi1 and lo1 < hi0):
                continue
            cross = (member_of is not None
                     and member_of.get(p0) != member_of.get(p1))
            if cross:
                if c0 != c1:
                    continue  # isolated channels: no physical conflict
            elif not (s0 < e1 and s1 < e0):
                continue  # same member: needs true temporal overlap
            hazards.append(
                f"{w0} {m0} [0x{lo0:x},0x{hi0:x})@[{s0:.0f},{e0:.0f}) vs "
                f"{w1} {m1} [0x{lo1:x},0x{hi1:x})@[{s1:.0f},{e1:.0f})")
    return hazards


def stale_reads(trace: list) -> list[str]:
    """Handshake-order corruption detector over the transfer trace.

    For every (byte range, writer PU, reader PU) stream pair, the k-th read
    of a range must complete before the (k+1)-th write rewrites it — the
    ping-pong ACK discipline guarantees exactly this. A violation means the
    reader observed round k+1 bytes (or a torn mix) where round k data was
    expected: the data corruption a shrunken/aliased region produces, even
    when the per-channel port serializes the transfers themselves."""
    xfers = _trace_xfers(trace)
    groups: dict[tuple, dict[str, list]] = {}
    for s, e, mode, _ch, lo, hi, pid, _who in xfers:
        groups.setdefault((lo, hi), {}).setdefault(mode, []).append((s, e, pid))
    out = []
    for (lo, hi), by_mode in groups.items():
        writes = sorted(by_mode.get("w", []))
        reads = sorted(by_mode.get("r", []))
        if not writes or not reads:
            continue
        if {p for _, _, p in writes} & {p for _, _, p in reads}:
            continue  # same-PU streaming pairs are interlocked by design
        for k, (rs, re, rpid) in enumerate(reads):
            if k + 1 < len(writes):
                ws, we, wpid = writes[k + 1]
                if ws < re:
                    out.append(
                        f"range [0x{lo:x},0x{hi:x}): write #{k + 1} by "
                        f"pu{wpid} starts at {ws:.0f} before read #{k} by "
                        f"pu{rpid} ends at {re:.0f} (stale/torn read)")
    return out
