"""ISA lint tier: per-instruction encodability and structural checks.

Everything here is local to one instruction or one :class:`Program` — no
cross-PU reasoning. Catches the defects that would silently truncate on
hardware (field-width overflow like ``Compute.M``'s 12-bit limit,
misaligned beat addresses), violate the assembler conventions (missing
``PRG_END``, opcode illegal in its ICU group, Config without a successor
DataMove), corrupt round semantics (reserved-field violations: ``IC > NC``
counters that the decoder would never reset), or fail the encode/decode
round-trip.
"""
from __future__ import annotations

from ..core.isa import (
    BEAT,
    AddrCyc,
    AddrLen,
    DataMove,
    Group,
    Instruction,
    Sync,
    validate_group,
)
from ..core.program import Program, PUProgram
from .report import Code, Severity, VerifyReport


def _classify_encode_error(msg: str) -> Code:
    if "aligned" in msg:
        return Code.LINT_MISALIGNED
    return Code.LINT_FIELD_OVERFLOW


def _roundtrip_ok(inst: Instruction, word: int) -> bool:
    """decode(encode(inst)) must re-encode to the same 64-bit word and
    decode to the same instruction type (LEN round-up is part of the
    encoding contract, so word-level comparison is the right equality)."""
    decoded = Instruction.decode(word)
    if type(decoded) is not type(inst):
        return False
    return decoded.encode() == word


def _check_reserved(rep: VerifyReport, inst: Instruction, *, member: str,
                    pid: int, group: str, index: int) -> None:
    """Counter invariants the decoder relies on: IC initialises to NC
    (Table I(b)), so IC > NC — or a nonzero IC under the NC==0 bypass —
    means the cycling state machine starts outside its own cycle."""
    if isinstance(inst, Sync):
        if inst.nc == 0 and inst.ic != 0:
            rep.add(Code.LINT_RESERVED,
                    f"{inst.op.name} has IC={inst.ic} under the NC=0 bypass",
                    member=member, pid=pid, group=group, index=index)
        elif inst.ic > inst.nc:
            rep.add(Code.LINT_RESERVED,
                    f"{inst.op.name} IC={inst.ic} exceeds NC={inst.nc}",
                    member=member, pid=pid, group=group, index=index)
    elif isinstance(inst, (AddrCyc, AddrLen)):
        if inst.ic > inst.nc:
            rep.add(Code.LINT_RESERVED,
                    f"{type(inst).__name__} IC={inst.ic} exceeds NC={inst.nc}",
                    member=member, pid=pid, group=group, index=index)


def lint_program(prog: Program, *, pid: int, member: str = "",
                 report: VerifyReport | None = None) -> VerifyReport:
    rep = report if report is not None else VerifyReport(label=prog.name)
    group = prog.group.value

    if not prog.instructions:
        rep.add(Code.LINT_STRUCTURE, "empty program",
                member=member, pid=pid, group=group)
        return rep
    if not prog.instructions[-1].prg_end:
        rep.add(Code.LINT_MISSING_PRG_END,
                "last instruction does not set PRG_END",
                member=member, pid=pid, group=group,
                index=len(prog.instructions) - 1)

    try:
        prog.validate()
    except ValueError as e:
        # validate() also rejects a missing PRG_END; don't double-report.
        if "PRG_END" not in str(e):
            rep.add(Code.LINT_STRUCTURE, str(e),
                    member=member, pid=pid, group=group)

    for idx, inst in enumerate(prog.instructions):
        try:
            validate_group(inst, prog.group)
        except ValueError as e:
            rep.add(Code.LINT_GROUP, str(e),
                    member=member, pid=pid, group=group, index=idx)
        try:
            word = inst.encode()
        except ValueError as e:
            rep.add(_classify_encode_error(str(e)),
                    f"{type(inst).__name__}: {e}",
                    member=member, pid=pid, group=group, index=idx)
        else:
            if not _roundtrip_ok(inst, word):
                rep.add(Code.LINT_ROUNDTRIP,
                        f"{type(inst).__name__} does not survive "
                        f"encode/decode (word=0x{word:016x})",
                        member=member, pid=pid, group=group, index=idx)
        if isinstance(inst, DataMove) and inst.length % BEAT:
            # LEN encodes with round-up, so a ragged byte length silently
            # over-reads on hardware; flag it even though encode() accepts.
            rep.add(Code.LINT_MISALIGNED,
                    f"{inst.op.name} LEN={inst.length} is not a multiple of "
                    f"the {BEAT}-byte beat (encoder rounds up)",
                    severity=Severity.WARNING,
                    member=member, pid=pid, group=group, index=idx)
        _check_reserved(rep, inst, member=member, pid=pid, group=group,
                        index=idx)
    return rep


def lint_pu_program(pu_prog: PUProgram, *, member: str = "",
                    report: VerifyReport | None = None) -> VerifyReport:
    rep = report if report is not None else VerifyReport(
        label=pu_prog.label or f"pu{pu_prog.pid}")
    groups = [(Group.LD, pu_prog.ld), (Group.CP, pu_prog.cp),
              (Group.ST, pu_prog.st)]
    for _, prog in groups:
        lint_program(prog, pid=pu_prog.pid, member=member, report=rep)
    # Round counts must agree across the three groups, else the PU's
    # streams drift apart and the last round deadlocks on its peers.
    nrs = {}
    for grp, prog in groups:
        try:
            nrs[grp.value] = prog.progctrl.nr
        except ValueError:
            pass  # structure diagnostics already cover the missing ProgCtrl
    if len(set(nrs.values())) > 1:
        rep.add(Code.SYNC_ROUNDS,
                f"group round counts disagree: {nrs}",
                member=member, pid=pu_prog.pid)
    return rep
