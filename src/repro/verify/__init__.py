"""Static program verification (see ROADMAP "Program verification").

Three tiers over compiled :class:`~repro.core.program.PUProgram` bundles,
none of which executes a simulated cycle:

* :mod:`repro.verify.lint` — per-instruction encodability / structure;
* :mod:`repro.verify.sync` — sync-token flow: abstract (untimed) execution
  with deadlock-cycle extraction plus exact per-round token-rate balance;
* :mod:`repro.verify.hazard` — memory hazards: region bounds, ping-pong
  aliasing, handshake guards, cross-member isolation.

``verify_deployment`` is what ``compile_deployment(..., verify=True)``
(default) runs; ``python -m repro.verify`` exposes the same checks over
any zoo model from the command line, and :mod:`repro.verify.mutate` is the
defect-injection harness that cross-validates the analyzer against the
simulator.
"""
from __future__ import annotations

from typing import Optional

from ..core.program import PUProgram
from ..core.pu import PUSpec
from .hazard import (check_handshake_guards, check_isolation,
                     check_kv_streams, check_region_bounds)
from .lint import lint_program, lint_pu_program
from .report import Code, Diagnostic, Severity, VerificationError, VerifyReport
from .sync import check_token_balance, check_token_flow, check_wchunk_interlock

__all__ = [
    "Code",
    "Diagnostic",
    "Severity",
    "VerificationError",
    "VerifyReport",
    "check_handshake_guards",
    "check_isolation",
    "check_kv_streams",
    "check_region_bounds",
    "check_token_balance",
    "check_token_flow",
    "check_wchunk_interlock",
    "lint_program",
    "lint_pu_program",
    "verify_deployment",
    "verify_programs",
]


def verify_programs(
    programs: list[PUProgram],
    *,
    mem=None,
    pu_specs: Optional[dict[int, PUSpec]] = None,
    member: str = "",
    lint: bool = True,
    sync: bool = True,
    hazards: bool = True,
) -> VerifyReport:
    """Run every applicable static check over one program bundle.

    ``mem`` (a :class:`~repro.compiler.memory.MemoryPlan`) enables the
    hazard tier; ``pu_specs`` gives the sync tier exact buffer-slot counts
    (defaults to the 2-slot ping-pong when omitted)."""
    rep = VerifyReport(label=member or "programs")
    if lint:
        for pu in programs:
            lint_pu_program(pu, member=member, report=rep)
    if sync:
        check_token_balance(programs, member=member, report=rep)
        check_wchunk_interlock(programs, member=member, report=rep)
        check_token_flow(programs, pu_specs=pu_specs, member=member,
                         report=rep)
    if hazards and mem is not None:
        check_region_bounds(programs, mem, member=member, report=rep)
        check_handshake_guards(programs, mem, member=member, report=rep)
        check_kv_streams(programs, mem, member=member, report=rep)
    return rep


def verify_deployment(dep) -> VerifyReport:
    """Verify every member of a :class:`~repro.deploy.Deployment` plus the
    cross-member isolation invariant. Returns the merged report; call
    ``.raise_if_failed()`` to turn errors into :class:`VerificationError`."""
    rep = VerifyReport(label=dep.name)
    member_data = []
    for m in dep.members:
        label = f"m{m.index}:{m.workload.label}" if len(dep.members) > 1 else ""
        programs = m.compiled.programs
        mem = m.compiled.mem
        specs = m.compiled.pu_specs
        sub = verify_programs(programs, mem=mem, pu_specs=specs,
                              member=label)
        rep.extend(sub)
        member_data.append((label or dep.name, programs, mem))
    if len(member_data) > 1:
        check_isolation(member_data, report=rep)
    return rep
