"""Sync-token flow checker: static deadlock-freedom for program bundles.

Abstracts every PU's LD/CP/ST instruction streams to their *sync skeleton*
— SEND/WAIT REQ/ACK per ``(pid, bid)`` channel (with BID cycling and the
ACK-bypass prologue), the intra-PU buffer interlocks (activation ping-pong
slots between LD and CP, output slots between CP and ST), and the URAM
weight-chunk interlock — then proves the bundle runs to completion without
simulating a single timed cycle:

1. **Abstract execution.** Token production/consumption is a Petri net in
   which every place (LUTRAM entry, buffer slot) has exactly one consumer
   stream (the ISA's group-legality table guarantees this: WAIT_REQ only in
   LD, WAIT_ACK only in ST, GEMM only in CP), so greedy maximal firing is
   confluent — if the greedy run finishes all rounds, *every* hardware
   interleaving does; if it stalls, every interleaving stalls at the same
   marking. Timing cannot change reachability, only ordering.
2. **Stall triage.** On a stall the checker builds the cross-PU wait-for
   graph (blocked stream -> streams able to produce what it awaits), finds
   cycles (deadlock: reported with the exact instruction index of every
   participant) and dead waits (starvation: no live producer remains).
3. **Per-round token balance.** Independently of execution, the per-round
   send and wait *rates* of every ``(dst, kind, src, bid)`` channel are
   compared as exact fractions (a BID-cycling sync instruction touches each
   bid in its range once per ``NC+1`` rounds; prologue sends are one-shot
   credits, not rates) — mismatches mean tokens leak (accumulate without
   bound) or starve (the one-shot credits run out mid-window).
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Iterable, Optional

from ..core.isa import Compute, DataMove, Group, Opcode, Sync, effective_opcode
from ..core.program import Program, PUProgram
from ..core.pu import PUSpec
from .report import Code, Severity, VerifyReport

#: Abstract-execution round cap: bounds work on huge decode windows while
#: staying far above every compiled loop count we emit (NR is 24 bits, but
#: real deployments run 16..decode_steps rounds).
ROUND_CAP = 1024

_WEIGHT_OPS = frozenset({Opcode.WEIGHTS_ADM})


def _sync_bids(inst: Sync) -> range:
    """The BID set a cycling sync instruction touches across rounds."""
    if inst.nc == 0:
        return range(inst.bid, inst.bid + 1)
    return range(inst.base_bid, inst.base_bid + inst.nc + 1)


@dataclass
class _Blocked:
    """Why a stream cannot advance: a token channel, a buffer slot, or the
    URAM weight interlock."""

    what: str  # "token" | "buf" | "wchunk"
    kind: str = ""  # token: "req"/"ack"; buf: semaphore name
    src_pid: int = -1
    bid: int = -1

    def describe(self, pid: int) -> str:
        if self.what == "token":
            return (f"WAIT_{self.kind.upper()} on channel "
                    f"(src_pid={self.src_pid}, bid={self.bid})")
        if self.what == "buf":
            return f"buffer slot {self.kind!r} of pu{pid}"
        return "URAM weight-chunk interlock"


class _PUState:
    """Abstract per-PU coordination state (counters, no data)."""

    def __init__(self, act_slots: int, out_slots: int) -> None:
        self.act_free = act_slots
        self.act_full = 0
        self.out_free = out_slots
        self.out_full = 0
        # (kind, src_pid, bid) -> outstanding token count
        self.lutram: dict[tuple[str, int, int], int] = {}
        self.weights_issued = 0

    def tokens(self, kind: str, src_pid: int, bid: int) -> int:
        return self.lutram.get((kind, src_pid, bid), 0)

    def take(self, kind: str, src_pid: int, bid: int) -> None:
        self.lutram[(kind, src_pid, bid)] -= 1

    def put(self, kind: str, src_pid: int, bid: int) -> None:
        key = (kind, src_pid, bid)
        self.lutram[key] = self.lutram.get(key, 0) + 1


class _Stream:
    """One ICU group's program, abstractly executed over its rounds."""

    def __init__(self, pid: int, group: Group, prog: Program) -> None:
        self.pid = pid
        self.group = group
        self.insts = prog.instructions
        ctrl = prog.progctrl
        self.nr = ctrl.nr
        self.icu_ba = ctrl.icu_ba
        self.pc = 0
        self.rounds_done = 0
        self.done = not self.insts
        self.capped = False
        self.blocked: Optional[_Blocked] = None
        self.gemm_wtarget = 0
        self.st_holding = False  # out slot held across a broadcast store

    @property
    def name(self) -> str:
        return f"pu{self.pid}.{self.group.value}"

    def round_limit(self) -> int:
        return min(self.nr, ROUND_CAP) if self.nr else ROUND_CAP

    def try_step(self, me: _PUState, world: dict[int, _PUState]) -> bool:
        """Fire one instruction if its abstract preconditions hold."""
        inst = self.insts[self.pc]

        if isinstance(inst, Sync):
            if inst.is_send:
                dst = world.get(inst.pid)
                if dst is not None:
                    dst.put(inst.kind, self.pid, inst.bid)
                inst.step()
            else:
                if me.tokens(inst.kind, inst.pid, inst.bid) <= 0:
                    self.blocked = _Blocked("token", inst.kind, inst.pid,
                                            inst.bid)
                    return False
                me.take(inst.kind, inst.pid, inst.bid)
                inst.step()

        elif isinstance(inst, DataMove):
            if self.group is Group.LD:
                if me.act_free <= 0:
                    self.blocked = _Blocked("buf", "act_free")
                    return False
                me.act_free -= 1
                me.act_full += 1
            elif self.group is Group.ST:
                # Broadcast stores (DataMove.hold): the node's first
                # transfer drains the slot, held transfers re-read it, and
                # only the final transfer (hold=0) frees it.
                if not self.st_holding:
                    if me.out_full <= 0:
                        self.blocked = _Blocked("buf", "out_full")
                        return False
                    me.out_full -= 1
                self.st_holding = inst.hold
                if not self.st_holding:
                    me.out_free += 1
            else:  # CP: async engines; issue completes in program order
                if effective_opcode(inst) in _WEIGHT_OPS:
                    me.weights_issued += 1

        elif isinstance(inst, Compute):
            self.gemm_wtarget += inst.wchunks
            if me.weights_issued < self.gemm_wtarget:
                self.gemm_wtarget -= inst.wchunks  # retry re-adds
                self.blocked = _Blocked("wchunk")
                return False
            if me.act_full <= 0:
                self.gemm_wtarget -= inst.wchunks
                self.blocked = _Blocked("buf", "act_full")
                return False
            if me.out_free <= 0:
                self.gemm_wtarget -= inst.wchunks
                self.blocked = _Blocked("buf", "out_free")
                return False
            me.act_full -= 1
            me.act_free += 1
            me.out_free -= 1
            me.out_full += 1

        # ProgCtrl / Config / AddrCyc / AddrLen: no coordination effect.

        self.blocked = None
        if inst.prg_end:
            self.rounds_done += 1
            if self.rounds_done >= self.round_limit():
                self.done = True
                self.capped = self.nr == 0 or self.rounds_done < self.nr
            else:
                self.pc = self.icu_ba
        else:
            self.pc += 1
        return True


def _build_streams(programs: Iterable[PUProgram]) -> list[_Stream]:
    streams = []
    for pu in programs:
        clone = pu.clone()  # abstract execution mutates Sync BID state
        for group, prog in ((Group.LD, clone.ld), (Group.CP, clone.cp),
                            (Group.ST, clone.st)):
            streams.append(_Stream(pu.pid, group, prog))
    return streams


def _providers(stream: _Stream, streams: list[_Stream]) -> list[_Stream]:
    """Streams whose remaining execution could unblock ``stream``."""
    b = stream.blocked
    assert b is not None
    out = []
    if b.what == "token":
        send_op = Opcode.SEND_REQ if b.kind == "req" else Opcode.SEND_ACK
        for t in streams:
            if t.pid != b.src_pid or t.done:
                continue
            for idx, inst in enumerate(t.insts):
                # A one-shot prologue send (index < ICU_BA) only counts if
                # it has not fired yet; body sends re-run every round.
                reachable = (idx >= t.icu_ba
                             or (t.rounds_done == 0 and t.pc <= idx))
                if (reachable and isinstance(inst, Sync)
                        and inst.op is send_op
                        and inst.pid == stream.pid
                        and b.bid in _sync_bids(inst)):
                    out.append(t)
                    break
    elif b.what == "buf":
        group = {"act_free": Group.CP, "act_full": Group.LD,
                 "out_free": Group.ST, "out_full": Group.CP}[b.kind]
        for t in streams:
            if t.pid == stream.pid and t.group is group and not t.done:
                out.append(t)
    else:  # wchunk: only this PU's own CP stream issues WEIGHTS_ADM — and
        # that is the blocked stream itself, so the interlock is dead.
        pass
    return out


def _find_cycles(blocked: list[_Stream],
                 edges: dict[int, list[int]]) -> list[list[int]]:
    """Cycles in the wait-for graph (one representative per node set)."""
    cycles: list[list[int]] = []
    seen_sets: set[frozenset[int]] = set()
    state: dict[int, int] = {}  # 0 unvisited / 1 on stack / 2 done

    def dfs(v: int, stack: list[int]) -> None:
        state[v] = 1
        stack.append(v)
        for w in edges.get(v, ()):
            if state.get(w, 0) == 0:
                dfs(w, stack)
            elif state.get(w) == 1:
                cyc = stack[stack.index(w):]
                key = frozenset(cyc)
                if key not in seen_sets:
                    seen_sets.add(key)
                    cycles.append(list(cyc))
        stack.pop()
        state[v] = 2

    for s in blocked:
        if state.get(id(s), 0) == 0:
            dfs(id(s), [])
    return cycles


def check_token_flow(programs: list[PUProgram], *,
                     pu_specs: Optional[dict[int, PUSpec]] = None,
                     member: str = "",
                     report: Optional[VerifyReport] = None) -> VerifyReport:
    """Abstract execution + stall triage over one program bundle."""
    rep = report if report is not None else VerifyReport(label=member)
    streams = _build_streams(programs)
    world: dict[int, _PUState] = {}
    for pu in programs:
        spec = (pu_specs or {}).get(pu.pid)
        world[pu.pid] = _PUState(spec.act_buf_slots if spec else 2,
                                 spec.out_buf_slots if spec else 2)

    # Greedy maximal firing: keep sweeping until no stream can advance.
    fuel = 4_000_000
    progress = True
    while progress and fuel > 0:
        progress = False
        for s in streams:
            me = world[s.pid]
            while not s.done and fuel > 0 and s.try_step(me, world):
                progress = True
                fuel -= 1
    if fuel <= 0:  # pragma: no cover - ROUND_CAP bounds total work
        rep.add(Code.SYNC_STALL, "abstract execution exceeded its fuel budget",
                severity=Severity.WARNING, member=member)
        return rep

    if any(s.capped for s in streams):
        rep.add(Code.SYNC_STALL,
                f"round count capped at {ROUND_CAP} for abstract execution",
                severity=Severity.INFO, member=member)

    blocked = [s for s in streams if not s.done]
    if not blocked:
        return rep

    by_id = {id(s): s for s in streams}
    edges = {id(s): [id(t) for t in _providers(s, streams)] for s in blocked}

    cycles = _find_cycles(blocked, edges)
    in_cycle: set[int] = set()
    for cyc in cycles:
        in_cycle.update(cyc)
        parts = []
        for sid in cyc:
            s = by_id[sid]
            parts.append(f"{s.name}[{s.pc}] awaits "
                         f"{s.blocked.describe(s.pid)}")
        rep.add(Code.SYNC_DEADLOCK,
                "wait-for cycle: " + " -> ".join(parts),
                member=member, pid=by_id[cyc[0]].pid,
                group=by_id[cyc[0]].group.value, index=by_id[cyc[0]].pc)

    for s in blocked:
        if id(s) in in_cycle:
            continue
        live = [by_id[w] for w in edges[id(s)] if not by_id[w].done]
        code = Code.SYNC_WCHUNK if s.blocked.what == "wchunk" else Code.SYNC_STALL
        if not live:
            rep.add(code,
                    f"{s.name}[{s.pc}] starved: awaits "
                    f"{s.blocked.describe(s.pid)} with no live producer "
                    f"(round {s.rounds_done + 1}/{s.round_limit()})",
                    member=member, pid=s.pid, group=s.group.value, index=s.pc)
        else:
            rep.add(Code.SYNC_STALL,
                    f"{s.name}[{s.pc}] blocked on "
                    f"{s.blocked.describe(s.pid)} behind "
                    + ", ".join(t.name for t in live),
                    severity=(Severity.INFO if cycles else Severity.ERROR),
                    member=member, pid=s.pid, group=s.group.value, index=s.pc)
    return rep


def check_token_balance(programs: list[PUProgram], *, member: str = "",
                        report: Optional[VerifyReport] = None) -> VerifyReport:
    """Exact per-round send/wait rate comparison per token channel."""
    rep = report if report is not None else VerifyReport(label=member)
    pids = {pu.pid for pu in programs}
    send_rate: dict[tuple, Fraction] = {}
    wait_rate: dict[tuple, Fraction] = {}
    credits: dict[tuple, int] = {}
    where: dict[tuple, tuple] = {}  # channel -> (pid, group, index) sample

    for pu in programs:
        for group, prog in ((Group.LD, pu.ld), (Group.CP, pu.cp),
                            (Group.ST, pu.st)):
            try:
                icu_ba = prog.progctrl.icu_ba
            except ValueError:
                continue
            for idx, inst in enumerate(prog.instructions):
                if not isinstance(inst, Sync):
                    continue
                per_bid = Fraction(1, 1 if inst.nc == 0 else inst.nc + 1)
                for b in _sync_bids(inst):
                    if inst.is_send:
                        key = (inst.pid, inst.kind, pu.pid, b)
                        if idx < icu_ba:  # one-shot prologue credit
                            credits[key] = credits.get(key, 0) + 1
                        else:
                            send_rate[key] = send_rate.get(key, 0) + per_bid
                    else:
                        key = (pu.pid, inst.kind, inst.pid, b)
                        wait_rate[key] = wait_rate.get(key, 0) + per_bid
                    where.setdefault(key, (pu.pid, group.value, idx))

    for key in sorted(set(send_rate) | set(wait_rate)):
        dst, kind, src, bid = key
        sends = send_rate.get(key, Fraction(0))
        waits = wait_rate.get(key, Fraction(0))
        if src not in pids or dst not in pids:
            # Half of the channel lives outside this bundle (partial
            # verification of a member slice) — rate comparison is moot.
            continue
        pid, group, idx = where[key]
        chan = f"(dst=pu{dst}, {kind}, src=pu{src}, bid={bid})"
        if waits and sends < waits:
            rep.add(Code.SYNC_TOKEN_STARVE,
                    f"channel {chan}: per-round sends {sends} < waits {waits}"
                    + (f" ({credits[key]} one-shot prologue credit(s) delay"
                       " the stall, they cannot prevent it)"
                       if key in credits else ""),
                    member=member, pid=pid, group=group, index=idx)
        elif waits and sends > waits:
            rep.add(Code.SYNC_TOKEN_LEAK,
                    f"channel {chan}: per-round sends {sends} > waits "
                    f"{waits} — tokens accumulate without bound",
                    member=member, pid=pid, group=group, index=idx)
        elif not waits and sends:
            # In this codegen every recurring token stream throttles a
            # peer; one nobody waits on means that throttle was removed
            # (e.g. a dropped WAIT_ACK in a multi-consumer fork, where the
            # store still looks guarded but one consumer no longer gates
            # the producer) — an error, not an oddity.
            rep.add(Code.SYNC_TOKEN_LEAK,
                    f"channel {chan}: sent at rate {sends} but never waited "
                    "on — the peer this stream throttled is no longer gated",
                    member=member, pid=pid, group=group, index=idx)
    return rep


def check_wchunk_interlock(programs: list[PUProgram], *, member: str = "",
                           report: Optional[VerifyReport] = None
                           ) -> VerifyReport:
    """The URAM read interlock must be satisfiable from *earlier* issues:
    at every GEMM the cumulative ``wchunks`` demand cannot exceed the
    WEIGHTS_ADM transfers already issued in program order (the CP stream is
    sequential, so later issues can never rescue an earlier blocked GEMM)."""
    rep = report if report is not None else VerifyReport(label=member)
    for pu in programs:
        issued = 0
        target = 0
        for idx, inst in enumerate(pu.cp.instructions):
            if isinstance(inst, DataMove) and effective_opcode(inst) in _WEIGHT_OPS:
                issued += 1
            elif isinstance(inst, Compute):
                target += inst.wchunks
                if target > issued:
                    rep.add(Code.SYNC_WCHUNK,
                            f"GEMM requires {target} cumulative weight "
                            f"chunk(s) but only {issued} WEIGHTS_ADM issued "
                            "before it",
                            member=member, pid=pu.pid, group="CP", index=idx)
    return rep
