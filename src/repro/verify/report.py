"""Structured diagnostics for the static program verifier.

Every check in :mod:`repro.verify` reports through a :class:`Diagnostic`
carrying a typed :class:`Code`, a severity, and the precise location
(member label, PU id, ICU group, instruction index) so a failing compile
points at the exact instruction. A :class:`VerifyReport` aggregates the
diagnostics of one deployment (or one bare program list) and is what
``compile_deployment(..., verify=True)`` raises from on errors.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class Severity(enum.Enum):
    ERROR = "error"
    WARNING = "warning"
    INFO = "info"


class Code(enum.Enum):
    """Typed diagnostic codes, grouped by analysis tier.

    SYNC_* come from the sync-token flow checker, HAZ_* from the
    memory-hazard analyzer, LINT_* from the ISA lint tier (see the
    ROADMAP "Program verification" section for the static/dynamic split).
    """

    # -- sync-token flow checker -------------------------------------------
    SYNC_DEADLOCK = "sync-deadlock"          # cross-PU wait-for cycle
    SYNC_STALL = "sync-stall"                # blocked wait, no live provider
    SYNC_TOKEN_STARVE = "sync-token-starve"  # per-round waits exceed sends
    SYNC_TOKEN_LEAK = "sync-token-leak"      # per-round sends exceed waits
    SYNC_WCHUNK = "sync-wchunk"              # GEMM interlock never satisfiable
    SYNC_ROUNDS = "sync-rounds"              # LD/CP/ST round counts disagree
    # -- memory-hazard analyzer --------------------------------------------
    HAZ_MEMBER_OVERLAP = "haz-member-overlap"    # cross-member region overlap
    HAZ_CHANNEL_SHARED = "haz-channel-shared"    # cross-member channel share
    HAZ_REGION_OVERRUN = "haz-region-overrun"    # AddrCyc/AddrLen out of extent
    HAZ_PINGPONG = "haz-pingpong"                # cyclic regions collide
    HAZ_BID_MISMATCH = "haz-bid-mismatch"        # guard BID range != plan BIDs
    HAZ_UNGUARDED_WRITE = "haz-unguarded-write"  # store without WAIT_ACK guard
    HAZ_UNGUARDED_READ = "haz-unguarded-read"    # load without WAIT_REQ guard
    HAZ_KV_STREAM = "haz-kv-stream"              # per-slot K/V stream mismatch
    # -- ISA lint ----------------------------------------------------------
    LINT_FIELD_OVERFLOW = "lint-field-overflow"  # value exceeds field width
    LINT_MISALIGNED = "lint-misaligned"          # address not beat-aligned
    LINT_ROUNDTRIP = "lint-roundtrip"            # encode/decode mismatch
    LINT_MISSING_PRG_END = "lint-missing-prg-end"
    LINT_GROUP = "lint-group"                    # opcode illegal in ICU group
    LINT_RESERVED = "lint-reserved"              # reserved-field violation
    LINT_STRUCTURE = "lint-structure"            # Program.validate() failure


@dataclass
class Diagnostic:
    code: Code
    message: str
    severity: Severity = Severity.ERROR
    member: str = ""                 # deployment member label ("" = global)
    pid: Optional[int] = None        # PU id
    group: Optional[str] = None      # "LD" | "CP" | "ST"
    index: Optional[int] = None      # instruction index within the group

    @property
    def location(self) -> str:
        parts = []
        if self.member:
            parts.append(self.member)
        if self.pid is not None:
            loc = f"pu{self.pid}"
            if self.group:
                loc += f".{self.group}"
            if self.index is not None:
                loc += f"[{self.index}]"
            parts.append(loc)
        return ":".join(parts)

    def __str__(self) -> str:
        loc = self.location
        where = f" at {loc}" if loc else ""
        return f"[{self.severity.value}] {self.code.value}{where}: {self.message}"


class VerificationError(RuntimeError):
    """Raised by :meth:`VerifyReport.raise_if_failed` on ERROR diagnostics."""

    def __init__(self, report: "VerifyReport") -> None:
        super().__init__(report.summary())
        self.report = report


@dataclass
class VerifyReport:
    """All diagnostics of one verification run, queryable by severity/code."""

    label: str = ""
    diagnostics: list[Diagnostic] = field(default_factory=list)

    def add(self, code: Code, message: str, *, severity: Severity = Severity.ERROR,
            member: str = "", pid: Optional[int] = None,
            group: Optional[str] = None, index: Optional[int] = None) -> Diagnostic:
        d = Diagnostic(code=code, message=message, severity=severity,
                       member=member, pid=pid, group=group, index=index)
        self.diagnostics.append(d)
        return d

    def extend(self, other: "VerifyReport") -> None:
        self.diagnostics.extend(other.diagnostics)

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity is Severity.ERROR]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity is Severity.WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_code(self, code: Code) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.code is code]

    def has(self, code: Code) -> bool:
        return any(d.code is code for d in self.diagnostics)

    def summary(self) -> str:
        name = self.label or "programs"
        if self.ok and not self.warnings:
            return f"verify {name}: clean ({len(self.diagnostics)} notes)"
        head = (f"verify {name}: {len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")
        lines = [head] + [f"  {d}" for d in self.diagnostics
                          if d.severity is not Severity.INFO]
        return "\n".join(lines)

    def raise_if_failed(self) -> "VerifyReport":
        if not self.ok:
            raise VerificationError(self)
        return self
