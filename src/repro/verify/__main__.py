"""Command-line static verifier: ``python -m repro.verify [model ...]``.

Compiles zoo models (or a curated sweep covering every model class when no
model is named) and runs the full static analyzer over the resulting
deployments, printing one summary line per target and every non-INFO
diagnostic. Exit status 1 if any target has error-severity diagnostics —
suitable as a blocking CI step (``--quick`` shrinks the models so the sweep
stays fast).

Examples::

    python -m repro.verify                          # full zoo sweep
    python -m repro.verify --quick                  # CI-sized sweep
    python -m repro.verify resnet50 --config 3 3
    python -m repro.verify decoder --depth 2 --decode-steps 8 -v
    python -m repro.verify multi                    # multi-tenant pair
"""
from __future__ import annotations

import argparse
import sys
import time

from ..compiler import zoo
from ..deploy import Strategy, Workload, compile_deployment
from . import verify_deployment
from .report import Severity

MODELS = ("tiny_cnn", "resnet50", "vit", "encoder", "decoder", "packed",
          "multi")


def _target(name: str, args: argparse.Namespace):
    """Build ``(graph, strategy, rounds, label)`` for one verify target."""
    q = args.quick
    depth = args.depth if args.depth is not None else (2 if q else None)
    seq = args.seq_len if args.seq_len is not None else (64 if q else 256)
    if name == "tiny_cnn":
        g = zoo.tiny_cnn()
        cfg, rounds = (2, 1), 12
    elif name == "resnet50":
        hw = args.input_hw if args.input_hw is not None else (64 if q else 256)
        g = zoo.resnet50(input_hw=hw)
        cfg, rounds = (3, 3), 8
    elif name == "vit":
        hw = args.input_hw if args.input_hw is not None else (64 if q else 224)
        g = zoo.vit(input_hw=hw, depth=depth if depth is not None else 12)
        cfg, rounds = (2, 2), 8
    elif name == "encoder":
        g = zoo.transformer_encoder(seq_len=seq, depth=depth)
        cfg, rounds = (2, 2), 8
    elif name == "decoder":
        steps = args.decode_steps if args.decode_steps is not None else 8
        g = zoo.transformer_decoder(seq_len=seq, depth=depth,
                                    decode_steps=steps)
        cfg, rounds = (2, 2), None  # decode window defaults per member
    elif name == "packed":
        # slot-packed decode: three sessions at different cache depths in
        # one member — exercises the per-slot AddrLen streams and the
        # check_kv_streams hazard tier
        steps = args.decode_steps if args.decode_steps is not None else 8
        g = zoo.transformer_decoder(slots=(2 * seq, seq, seq // 2),
                                    depth=depth, decode_steps=steps)
        cfg, rounds = (2, 2), None
    elif name == "multi":
        strat = Strategy.tenants([
            (Workload(zoo.tiny_cnn(), "cnn"), 1, 1),
            (Workload(zoo.transformer_encoder(seq_len=seq, depth=depth or 2),
                      "enc"), 1, 1),
        ])
        return None, strat, 4, "multi[cnn+enc]"
    else:  # pragma: no cover - argparse choices guard this
        raise ValueError(f"unknown model {name!r}")
    if args.config:
        cfg = tuple(args.config)
    if args.rounds is not None:
        rounds = args.rounds
    label = f"{name}({cfg[0]},{cfg[1]})"
    return g, Strategy.single(*cfg), rounds, label


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="Static program verification over compiled zoo models.")
    ap.add_argument("models", nargs="*", choices=[[], *MODELS],
                    help=f"targets to verify (default: all of {', '.join(MODELS)})")
    ap.add_argument("--config", nargs=2, type=int, metavar=("A", "B"),
                    help="member config: A PU1x + B PU2x")
    ap.add_argument("--rounds", type=int, help="per-round loop count override")
    ap.add_argument("--input-hw", type=int, help="CNN/ViT input resolution")
    ap.add_argument("--seq-len", type=int, help="transformer sequence length")
    ap.add_argument("--depth", type=int, help="transformer/ViT block count")
    ap.add_argument("--decode-steps", type=int, help="decoder window length")
    ap.add_argument("--quick", action="store_true",
                    help="shrink models to CI-friendly sizes")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print INFO diagnostics too")
    args = ap.parse_args(argv)

    names = args.models or list(MODELS)
    failures = 0
    for name in names:
        g, strat, rounds, label = _target(name, args)
        t0 = time.perf_counter()
        dep = compile_deployment(g, strat, rounds=rounds, verify=False)
        t1 = time.perf_counter()
        rep = verify_deployment(dep)
        t2 = time.perf_counter()
        n_inst = sum(len(grp.instructions) for m in dep.members
                     for p in m.compiled.programs
                     for grp in (p.ld, p.cp, p.st))
        status = "clean" if rep.ok else f"{len(rep.errors)} error(s)"
        print(f"{label:24s} {status:12s} {n_inst:6d} inst  "
              f"compile {t1 - t0:6.2f}s  verify {t2 - t1:6.2f}s")
        shown = (rep.diagnostics if args.verbose else
                 [d for d in rep.diagnostics
                  if d.severity is not Severity.INFO])
        for d in shown:
            print(f"    {d}")
        if not rep.ok:
            failures += 1
    if failures:
        print(f"FAILED: {failures}/{len(names)} target(s) with errors")
        return 1
    print(f"OK: {len(names)} target(s) verified clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
