"""Value types of the serving control plane: requests, sessions, events.

A :class:`Request` is one tenant job: a prompt of ``prompt_tokens`` already
prefilled (the session's initial cache depth) plus ``max_new_tokens`` to
decode, one token per program round. Admission turns a request into a
:class:`DecodeSession` — a slot in the tenant's slot-packed decode member
whose cache depth grows every round. Everything the scheduler does (admit /
retire / replan / swap / evict / join / leave) is recorded as a
:class:`ServeEvent`, which is what the deterministic scheduler tests
assert on.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Request:
    """One serving job: decode ``max_new_tokens`` on top of a prefilled
    prompt of ``prompt_tokens`` for ``tenant``. ``arrival_s`` is virtual
    arrival time; the server fills the lifecycle fields."""

    tenant: str
    prompt_tokens: int
    max_new_tokens: int
    arrival_s: float = 0.0
    rid: str = ""

    # -- lifecycle (server-owned) -------------------------------------------
    admitted_s: Optional[float] = None
    finished_s: Optional[float] = None
    generated: int = 0
    evicted: bool = False

    def __post_init__(self) -> None:
        if self.prompt_tokens < 1:
            raise ValueError("prompt_tokens must be >= 1 (prefilled prefix)")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def done(self) -> bool:
        return self.finished_s is not None

    @property
    def completed(self) -> bool:
        return self.done and not self.evicted

    @property
    def latency_s(self) -> Optional[float]:
        """Arrival -> completion latency in virtual seconds."""
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s


@dataclass
class DecodeSession:
    """One admitted request occupying one packed slot: current cache depth
    (grows one row per round) and tokens still to decode."""

    request: Request
    depth: int       # current K/V cache rows (prompt + generated so far)
    remaining: int   # tokens left to decode

    @property
    def rid(self) -> str:
        return self.request.rid

    def advance(self, rounds: int) -> None:
        self.request.generated += rounds
        self.depth += rounds
        self.remaining -= rounds


@dataclass(frozen=True)
class ServeEvent:
    """One scheduler decision, timestamped in virtual seconds."""

    t: float
    kind: str     # join|leave|admit|retire|swap|replan|evict|slo-violation
                  # |inject|fault|quarantine|replay|shed (fault tolerance)
    tenant: str
    detail: str = ""

    def __str__(self) -> str:
        d = f" {self.detail}" if self.detail else ""
        return f"[{self.t:10.6f}s] {self.kind:<14s} {self.tenant}{d}"


@dataclass
class WindowSample:
    """Per-tenant measurement of one serving window (SLO accounting)."""

    t: float
    tokens: int
    dt: float
    met: Optional[bool] = None  # None when the tenant carries no rate SLO

    @property
    def rate(self) -> float:
        return self.tokens / self.dt if self.dt > 0 else 0.0


@dataclass
class TenantState:
    """Server-internal per-tenant record (spec + live scheduling state)."""

    name: str
    workload: object             # stable placement Workload (DSE identity)
    arch: object                 # ArchConfig of the tenant's model
    depth: int                   # decoder blocks
    max_slots: int
    window: int                  # decode steps per serving window (cap)
    slo: Optional[object] = None
    queue: list = field(default_factory=list)      # pending Requests (FIFO)
    active: list = field(default_factory=list)     # DecodeSessions, slot order
    tokens: int = 0
    rounds: int = 0
    samples: list = field(default_factory=list)    # WindowSamples
    violations: int = 0          # consecutive violating windows
    replans: int = 0             # SLO-triggered replans already spent

    @property
    def free_slots(self) -> int:
        return self.max_slots - len(self.active)

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active)
