# Online serving control plane (paper Sec. V, taken online): a queue-driven
# Server packs concurrent decode sessions at different cache depths into
# shared members (per-slot AddrLen length streams), re-places tenants on
# join/leave or sustained SLO violation via incremental explore_multi, and
# hot-swaps the running System mid-service.
from ..deploy import SLO, RunReport, TenantReport
from .request import (DecodeSession, Request, ServeEvent, TenantState,
                      WindowSample)
from .server import MAX_WINDOW, DrainStuckError, Server

__all__ = [
    "DecodeSession",
    "DrainStuckError",
    "MAX_WINDOW",
    "Request",
    "RunReport",
    "Server",
    "ServeEvent",
    "SLO",
    "TenantReport",
    "TenantState",
    "WindowSample",
]
