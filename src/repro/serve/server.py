"""Queue-driven serving control plane over :class:`repro.deploy.System`.

The paper's runtime strategy switching (new instruction programs, no
reconfiguration) turned into an online serving loop:

* **Elastic tenancy** — tenants :meth:`Server.join`/:meth:`Server.leave`
  at runtime; whenever the *active* tenant set changes, the server re-places
  everyone through :func:`repro.dse.plan_placement` (incremental
  ``explore_multi(prev=...)`` for two or more tenants) and hot-swaps the
  running system to the new joint placement mid-service.
* **Continuous batching** — each tenant's admitted requests become decode
  sessions packed into *one* shared member at their own cache depths
  (``transformer_decoder(slots=...)``: independent per-slot AddrLen length
  streams). Serving advances in windows sized so the shortest packed
  session retires exactly at a window boundary, freeing its slot for the
  head of the queue — the slot is reused without disturbing its neighbors.
* **SLO enforcement** — per-window token rates are measured against each
  tenant's :class:`repro.deploy.SLO`. A sustained violation first spends
  one re-placement; if violations persist, the lowest-priority tenant's
  youngest session is evicted (load shedding).
* **Fault tolerance** — with a :class:`repro.faults.Watchdog` armed, a
  window that hangs (a stuck PU, a lost sync token, a dead HBM channel)
  comes back as structured :class:`~repro.faults.FaultReport` diagnostics
  instead of an unbounded simulation. The server quarantines the suspect
  PU / HBM channel, re-places the surviving tenants over the *masked*
  array (``plan_placement(available=...)`` — the changed budget forces
  the safe from-scratch exploration), hot-swaps the degraded deployment,
  and replays every interrupted decode session from its last completed
  window's K/V append cursor (the faulted window's partial progress is
  discarded, so no session observes a half-written cache row). When the
  shrunken array cannot host every tenant, the lowest-priority tenant's
  work is shed. Faults and deadlocks surface as typed ``srv.events``
  entries, never as exceptions escaping :meth:`Server.drain`.

Time is virtual: each window's duration is the simulated wall time of its
deployment run, so the whole loop is deterministic — admission order, swap
points and evictions are pure functions of the submitted requests (and of
the injected fault schedule, which is itself a frozen seeded value).
"""
from __future__ import annotations

import bisect
from typing import Optional

from ..compiler.zoo import transformer_decoder
from ..configs import get_config
from ..core.events import DeadlockError
from ..core.pu import N_HBM_CHANNELS
from ..deploy import (RunReport, SLO, Strategy, System, TenantReport,
                      Workload, compile_deployment)
from ..dse.replan import Placement, plan_placement
from ..faults import FaultCode, FaultReport, Watchdog, reports_from_blocked
from .request import (DecodeSession, Request, ServeEvent, TenantState,
                      WindowSample)

MAX_WINDOW = 128  # 7-bit AddrCyc NC bound on the cache append side


class DrainStuckError(RuntimeError):
    """:meth:`Server.drain` exhausted its window budget with work left.

    ``stuck`` names every tenant still holding queued or active requests,
    so a wedged serving loop reports *who* is stuck instead of silently
    truncating."""

    def __init__(self, max_windows: int, stuck) -> None:
        self.max_windows = max_windows
        self.stuck = tuple(stuck)
        names = ", ".join(self.stuck) or "<none>"
        super().__init__(
            f"drain did not converge in {max_windows} windows; "
            f"tenants still holding work: {names}")


class Server:
    """Admission, packing, placement and eviction over one fixed machine."""

    def __init__(self, pus=None, *, n_pu1x: int = 5, n_pu2x: int = 5,
                 slo_patience: int = 2, verify: bool = True,
                 engine: str = "batched",
                 watchdog: Optional[Watchdog] = None) -> None:
        self.system = System(pus)
        self.n_pu1x = n_pu1x
        self.n_pu2x = n_pu2x
        self.slo_patience = slo_patience
        self.verify = verify
        self.engine = engine
        self.watchdog = watchdog
        self.system.watchdog = watchdog
        self.now = 0.0
        self.events: list[ServeEvent] = []
        self.requests: list[Request] = []
        self.placement: Optional[Placement] = None
        self.windows = 0
        self.faults: list[FaultReport] = []   # every detected fault, in order
        self.quarantined: set[int] = set()    # pids removed from service
        self.dead_channels: set[int] = set()  # HBM channels removed
        self._tenants: dict[str, TenantState] = {}
        self._placed = None  # (names, quarantined, dead_channels) at replan
        self._prev_multi = None  # last MultiDSEResult, threaded as prev=
        self._seq = 0

    # -- fault injection -----------------------------------------------------
    def inject(self, schedule, *, watchdog="auto") -> None:
        """Attach a :class:`repro.faults.FaultSchedule` to the simulated
        hardware (re-armed every window until recovery routes around it).

        Unless a watchdog is already configured — or one is explicitly
        given (pass ``watchdog=None`` to exercise the slower drained-heap
        deadlock detection instead) — a default
        :class:`repro.faults.Watchdog` is armed alongside, so injected
        faults are detected rather than deadlocking the loop."""
        self.system.inject(schedule)
        if watchdog == "auto":
            watchdog = self.watchdog or Watchdog()
        self.watchdog = watchdog
        self.system.watchdog = watchdog
        self._event("inject", "", schedule.describe())

    # -- tenancy -------------------------------------------------------------
    def join(self, name: str, arch="qwen3-0.6b", *, depth: int = 1,
             max_slots: int = 2, window: int = 8,
             placement_prefix: int = 64,
             slo: Optional[SLO] = None) -> TenantState:
        """Register a tenant: its model (``arch`` config name or ArchConfig,
        ``depth`` decoder blocks), slot capacity and SLO.

        ``placement_prefix`` fixes the representative cache depth of the
        tenant's *placement graph* — the stable graph the DSE places (its
        fingerprint must not change between replans, or the incremental
        ``prev=`` reuse would never hit). The actually-served windows
        compile their own slot-packed graphs at the live depths."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already joined")
        if not 1 <= window <= MAX_WINDOW:
            raise ValueError(f"window must be in [1, {MAX_WINDOW}]")
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        cfg = get_config(arch) if isinstance(arch, str) else arch
        g = transformer_decoder(cfg, slots=(placement_prefix,) * max_slots,
                                decode_steps=window, depth=depth)
        t = TenantState(name=name, workload=Workload(g, label=name),
                        arch=cfg, depth=depth, max_slots=max_slots,
                        window=window, slo=slo)
        self._tenants[name] = t
        self._event("join", name,
                    f"{cfg.name} x{depth} slots={max_slots} window={window}")
        return t

    def leave(self, name: str, *, force: bool = False) -> None:
        """Deregister ``name``. Refuses while the tenant still has queued or
        active requests unless ``force``, which evicts them."""
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(f"no tenant {name!r}")
        if t.has_work and not force:
            raise ValueError(
                f"tenant {name!r} still has work; drain first or force=True")
        for sess in t.active:
            self._finish(sess.request, evicted=True)
        for req in t.queue:
            self._finish(req, evicted=True)
        del self._tenants[name]
        self._event("leave", name)

    def submit(self, req: Request) -> Request:
        """Queue a request; it becomes eligible at ``req.arrival_s``."""
        t = self._tenants.get(req.tenant)
        if t is None:
            raise KeyError(f"no tenant {req.tenant!r} — join first")
        self._seq += 1
        if not req.rid:
            req.rid = f"{req.tenant}-{self._seq}"
        self.requests.append(req)
        bisect.insort(t.queue, req, key=lambda r: (r.arrival_s, r.rid))
        return req

    # -- the serving loop ----------------------------------------------------
    def step(self) -> bool:
        """Serve one window. Returns False when there is nothing to do.

        A faulted window (watchdog detection or deadlock) does not advance
        any session: its partial progress is discarded, the suspect PU /
        channel is quarantined, and the next step re-places the survivors
        on the masked array and replays the interrupted sessions from
        their last completed window's K/V append cursor."""
        self._admit()
        if not self._active_tenants():
            arrivals = [r.arrival_s for t in self._tenants.values()
                        for r in t.queue]
            if not arrivals:
                return False
            self.now = max(self.now, min(arrivals))  # idle-skip virtual time
            self._admit()
            if not self._active_tenants():
                return False
        if not self._ensure_placement():
            # everything placeable was shed; anything left retries later
            return any(t.has_work for t in self._tenants.values())
        dep = self._compile_window()
        if self.system.deployment is None:
            self.system.load(dep)
        else:
            self.system.switch(dep)
        self._event("swap", "", dep.name)
        try:
            report = self.system.run()
        except DeadlockError as e:
            # max_events livelock guard: surface as typed events + recover.
            self.windows += 1
            self._handle_faults(reports_from_blocked(e.blocked))
            return True
        self.windows += 1
        faults = list(report.faults)
        if not faults and report.deadlocked:
            # No watchdog armed: the drained heap is the detection.
            faults = reports_from_blocked(report.blocked)
        if faults:
            self.now += report.wall_s  # the wedged window still took time
            self._handle_faults(faults)
            return True
        dt = report.wall_s
        self.now += dt
        self._account(report, dt)
        return True

    def drain(self, *, max_windows: int = 10_000) -> RunReport:
        """Serve until every queue and slot is empty; return the aggregate
        :class:`RunReport` (per-tenant token rates, request latency
        percentiles, SLO attainment). With zero tenants (or only empty
        queues) this is a no-op returning an empty report. Raises
        :class:`DrainStuckError` naming the stuck tenants if the loop does
        not converge within ``max_windows``."""
        for _ in range(max_windows):
            if not self.step():
                break
        else:
            stuck = sorted(n for n, t in self._tenants.items() if t.has_work)
            raise DrainStuckError(max_windows, stuck)
        return self.report()

    def report(self) -> RunReport:
        """Aggregate serving report over everything served so far."""
        tenants = {}
        for name, t in sorted(self._tenants.items()):
            tenants[name] = self._tenant_report(t)
        return RunReport(tenants=tenants, wall_s=self.now, source="serve")

    # -- internals -----------------------------------------------------------
    def _event(self, kind: str, tenant: str, detail: str = "") -> None:
        self.events.append(ServeEvent(t=self.now, kind=kind, tenant=tenant,
                                      detail=detail))

    def _active_tenants(self) -> list[TenantState]:
        return [t for _, t in sorted(self._tenants.items()) if t.active]

    def _admit(self) -> None:
        for _, t in sorted(self._tenants.items()):
            while t.free_slots > 0 and t.queue \
                    and t.queue[0].arrival_s <= self.now:
                req = t.queue.pop(0)
                req.admitted_s = self.now
                t.active.append(DecodeSession(request=req,
                                              depth=req.prompt_tokens,
                                              remaining=req.max_new_tokens))
                self._event("admit", t.name,
                            f"{req.rid} depth={req.prompt_tokens} "
                            f"new={req.max_new_tokens}")

    def _healthy_pids(self) -> list[int]:
        return [p.pid for p in self.system.pus
                if p.pid not in self.quarantined]

    def _healthy_channels(self) -> list[int]:
        return [c for c in range(N_HBM_CHANNELS)
                if c not in self.dead_channels]

    def _ensure_placement(self) -> bool:
        """Re-place the active tenants if the tenant set *or* the healthy
        array changed since the last plan. When the shrunken array cannot
        host everyone, sheds the lowest-priority tenant's work and retries
        until a feasible placement exists (or no tenant remains — returns
        False; True means ``self.placement`` covers every active tenant)."""
        while True:
            active = self._active_tenants()
            if not active:
                return False
            names = frozenset(t.name for t in active)
            key = (names, frozenset(self.quarantined),
                   frozenset(self.dead_channels))
            if self.placement is not None and key == self._placed:
                return True
            try:
                self.placement = plan_placement(
                    [t.workload for t in active], pus=self.system.pus,
                    n_pu1x=self.n_pu1x, n_pu2x=self.n_pu2x,
                    prev=self._prev_multi, engine=self.engine,
                    available=self._healthy_pids() if self.quarantined
                    else None)
            except ValueError as e:
                # Degraded array cannot host this tenant set: shed the
                # lowest-priority tenant's work and try the smaller set.
                if not self._shed_tenant(reason=str(e)):
                    return False
                continue
            if self.placement.result is not None:
                self._prev_multi = self.placement.result
            self._placed = key
            cfgs = ", ".join(f"{t.name}({a},{b})" for t, (a, b)
                             in zip(active, self.placement.configs))
            self._event("replan", "", cfgs)
            return True

    def _shed_tenant(self, reason: str = "") -> bool:
        """Shed *all* work (active sessions + queue) of the lowest-priority
        tenant holding any — the degraded array cannot meet everyone's
        demand, so the least important tenant loses service entirely.
        Returns False when no tenant had work to shed."""
        candidates = [t for _, t in sorted(self._tenants.items())
                      if t.has_work]
        if not candidates:
            return False
        def prio(t: TenantState) -> tuple:
            return ((t.slo.priority if t.slo else 0), t.name)
        victim = min(candidates, key=prio)
        n = len(victim.active) + len(victim.queue)
        for sess in victim.active:
            self._finish(sess.request, evicted=True)
        for req in victim.queue:
            self._finish(req, evicted=True)
        victim.active.clear()
        victim.queue.clear()
        self._event("shed", victim.name,
                    f"{n} request(s) dropped: degraded array cannot host "
                    f"all tenants" + (f" ({reason.splitlines()[0]})"
                                      if reason else ""))
        return True

    def _handle_faults(self, faults: list) -> None:
        """Turn a wedged window into quarantine + replay.

        The report list mixes root causes with secondary victims (a PU
        parked on a WAIT whose partner hung is itself reported as blocked),
        so suspects are ranked: an injected/instrumented PU hang first,
        then a dead HBM channel, then the *source* of the earliest-starved
        sync channel (the waiter closest to a lost token parks first, and
        a starvation cycle's later channels point at secondary victims),
        then heartbeat-flagged members, and only then generic stalls."""
        self.faults.extend(faults)
        for r in faults:
            self._event("fault", r.member, str(r))
        suspects: set[int] = set()
        dead_chans: set[int] = set()
        for r in faults:  # rung 1: the PU that stopped issuing
            if r.code == FaultCode.PU_HANG and r.pid is not None:
                suspects.add(r.pid)
        for r in faults:  # rung 2: a stalled HBM channel
            if r.code == FaultCode.HBM_TIMEOUT and r.hbm_channel is not None:
                dead_chans.add(r.hbm_channel)
        if not suspects and not dead_chans:
            # rung 3: the silent source of the *first* channel to starve
            for r in sorted((r for r in faults
                             if r.code in (FaultCode.SYNC_TIMEOUT,
                                           FaultCode.DEADLOCK)
                             and r.channel is not None),
                            key=lambda r: (r.cycle, str(r))):
                src = r.channel[0]
                if src not in self.quarantined:
                    suspects.add(src)
                    break
        if not suspects and not dead_chans:
            for r in faults:  # rung 4: a member making no round progress
                if r.code == FaultCode.HEARTBEAT and r.pid is not None:
                    suspects.add(r.pid)
        if not suspects and not dead_chans:
            for r in faults:  # rung 5: fall back to any blocked pid
                if r.pid is not None and r.pid not in self.quarantined:
                    suspects.add(r.pid)
                    break
        for pid in sorted(suspects):
            self.quarantined.add(pid)
            self._event("quarantine", "", f"pu{pid} removed from service "
                        f"({len(self._healthy_pids())} PUs remain)")
        for c in sorted(dead_chans):
            self.dead_channels.add(c)
            self._event("quarantine", "", f"hbm channel {c} removed from "
                        f"service ({len(self._healthy_channels())} remain)")
        # The faulted window's partial progress is discarded (sessions were
        # never advanced), so every interrupted session replays from its
        # last completed window's K/V append cursor.
        for t in self._active_tenants():
            for sess in t.active:
                self._event("replay", t.name,
                            f"{sess.rid} from depth={sess.depth} "
                            f"remaining={sess.remaining}")
        self.placement = None
        self._placed = None

    def _compile_window(self):
        assignments = []
        for t in self._active_tenants():
            w = min(t.window, min(s.remaining for s in t.active))
            g = transformer_decoder(t.arch,
                                    slots=tuple(s.depth for s in t.active),
                                    decode_steps=w, depth=t.depth)
            wl = Workload(g, label=t.name, rounds=w,
                          slots=tuple(s.rid for s in t.active))
            a, b = self.placement.config_for(t.name)
            assignments.append((wl, a, b))
        strat = Strategy.tenants(assignments)
        kw = {}
        if self.quarantined:
            kw["available"] = self._healthy_pids()
        if self.dead_channels:
            kw["channels"] = self._healthy_channels()
        return compile_deployment(None, strat, pus=self.system.pus,
                                  verify=self.verify, **kw)

    def _finish(self, req: Request, *, evicted: bool = False) -> None:
        req.finished_s = self.now
        req.evicted = evicted

    def _account(self, report: RunReport, dt: float) -> None:
        for t in self._active_tenants():
            tr = report.tenants.get(t.name)
            if tr is None:  # pragma: no cover - every active tenant ran
                continue
            rounds = tr.rounds
            t.rounds += rounds
            t.tokens += tr.tokens
            for sess in list(t.active):
                sess.advance(rounds)
                if sess.remaining <= 0:
                    t.active.remove(sess)
                    self._finish(sess.request)
                    self._event("retire", t.name,
                                f"{sess.rid} tokens={sess.request.generated} "
                                f"lat={self.now - sess.request.arrival_s:.6f}s")
            self._check_slo(t, tr.tokens, dt)

    def _check_slo(self, t: TenantState, tokens: int, dt: float) -> None:
        if t.slo is None or t.slo.min_tokens_per_s is None:
            t.samples.append(WindowSample(t=self.now, tokens=tokens, dt=dt))
            return
        met = (tokens / dt if dt > 0 else 0.0) >= t.slo.min_tokens_per_s
        t.samples.append(WindowSample(t=self.now, tokens=tokens, dt=dt,
                                      met=met))
        if met:
            t.violations = 0
            return
        t.violations += 1
        self._event("slo-violation", t.name,
                    f"{tokens / dt if dt > 0 else 0.0:.1f} < "
                    f"{t.slo.min_tokens_per_s:.1f} tok/s "
                    f"({t.violations}/{self.slo_patience})")
        if t.violations < self.slo_patience:
            return
        t.violations = 0
        if t.replans == 0:
            # First remedy: one fresh joint placement for the current mix.
            t.replans += 1
            self.placement = None
            self._placed = None
            self._event("replan", t.name, "slo remediation")
        else:
            self._shed()

    def _shed(self) -> None:
        """Evict the lowest-priority tenant's youngest session."""
        candidates = [t for t in self._active_tenants()]
        if not candidates:
            return
        def prio(t: TenantState) -> tuple:
            return ((t.slo.priority if t.slo else 0), t.name)
        victim = min(candidates, key=prio)
        sess = victim.active.pop()  # youngest admitted session
        self._finish(sess.request, evicted=True)
        self._event("evict", victim.name,
                    f"{sess.rid} after {sess.request.generated} tokens")

    def _tenant_report(self, t: TenantState) -> TenantReport:
        lats = tuple(r.latency_s for r in self.requests
                     if r.tenant == t.name and r.completed)
        attain = None
        if t.slo is not None:
            parts = []
            if t.slo.min_tokens_per_s is not None:
                rated = [s for s in t.samples if s.met is not None]
                if rated:
                    parts.append(sum(s.met for s in rated) / len(rated))
            if t.slo.deadline_s is not None:
                done = [r for r in self.requests
                        if r.tenant == t.name and r.completed]
                if done:
                    parts.append(sum(r.latency_s <= t.slo.deadline_s
                                     for r in done) / len(done))
            if parts:
                attain = min(parts)
        wall = self.now if self.now > 0 else 1.0
        return TenantReport(tenant=t.name, fps=t.rounds / wall,
                            token_rate=t.tokens / wall, rounds=t.rounds,
                            tokens=t.tokens, latencies_s=lats, slo=t.slo,
                            slo_attainment=attain)
