"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across pods (DCN-connected).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests on 1-8 CPU devices)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
