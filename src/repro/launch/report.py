"""Render EXPERIMENTS.md tables from the dry-run JSON artifact.

    PYTHONPATH=src python -m repro.launch.report dryrun_baseline.json
"""
from __future__ import annotations

import json
import sys
from collections import Counter


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b/2**30:.1f}G"
    if b >= 2**20:
        return f"{b/2**20:.1f}M"
    return f"{b:.0f}"


def render(cells: list[dict], mesh: str) -> str:
    rows = [c for c in cells if c["mesh"] == mesh]
    out = [
        f"### Mesh {mesh}",
        "",
        "| arch | shape | status | compile_s | args/chip | temp/chip | "
        "t_comp (ms) | t_mem (ms) | t_coll (ms) | bound | useful |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        if c["status"] != "ok":
            out.append(
                f"| {c['arch']} | {c['shape']} | {c['status']}: "
                f"{c['reason'][:48]} | | | | | | | | |"
            )
            continue
        out.append(
            f"| {c['arch']} | {c['shape']} | ok | {c['compile_s']:.0f} | "
            f"{fmt_bytes(c['arg_bytes'])} | {fmt_bytes(c['temp_bytes'])} | "
            f"{c['t_compute']*1e3:.1f} | {c['t_memory']*1e3:.1f} | "
            f"{c['t_collective']*1e3:.1f} | {c['bottleneck'][:4]} | "
            f"{c['useful_ratio']:.2f} |"
        )
    ok = [c for c in rows if c["status"] == "ok"]
    bn = Counter(c["bottleneck"] for c in ok)
    out += ["", f"{len(ok)} cells ok; bottleneck split: {dict(bn)}", ""]
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_baseline.json"
    with open(path) as f:
        cells = json.load(f)
    for mesh in sorted({c["mesh"] for c in cells}):
        print(render(cells, mesh))


if __name__ == "__main__":
    main()
