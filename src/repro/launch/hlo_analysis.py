"""Optimized-HLO text analyzer for the roofline terms.

``compiled.cost_analysis()`` counts every computation ONCE (while bodies are
not multiplied by trip count) and reports post-SPMD per-shard numbers. For
scan-over-layers models that under-counts by ~num_layers, so we parse
``compiled.as_text()`` ourselves:

  * build the computation call graph (while/call/conditional/fusion),
  * multiply op costs by the product of enclosing ``known_trip_count``s
    (XLA annotates statically-known while trip counts after optimization),
  * FLOPs: dot ops = 2 * prod(output) * prod(contracting dims)
           (+ convolution support for the CNN path),
  * HBM bytes: per top-level op, operands + outputs (fusion internals stay
    in registers/VMEM, so fusion boundaries approximate HBM traffic),
  * collective bytes: per op type, wire-byte factors on the shard bytes
    (ring model: AG/RS (n-1)/n, AR 2(n-1)/n, A2A (n-1)/n, permute 1).

All quantities are PER CHIP (the HLO is the per-shard program).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "iota",
}

# type may be a tuple containing layouts and /*index=N*/ comments; lazily
# consume everything up to the first " opcode(" token (tuple types never
# contain a word directly followed by an open paren).
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>.*?)\s+"
    r"(?P<opcode>[\w\-]+)\((?P<rest>.*)$"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(type_str: str) -> int:
    return sum(
        DTYPE_BYTES[dt] * int(math.prod(shape)) for dt, shape in _parse_shapes(type_str)
    )


@dataclass
class HloOp:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes tail


@dataclass
class Computation:
    name: str
    ops: list[HloOp] = field(default_factory=list)


@dataclass
class RooflineCounts:
    """Per-chip counts.

    ``hbm_bytes`` uses producer-side accounting: every op's *output* bytes,
    trip-count scaled (each tensor is written once and read >=1 times; we
    count the write — a lower bound on traffic that avoids double counting.
    Add the compiled argument bytes once for parameter reads). CPU HLO is
    less fused than TPU HLO, so this is still an upper bound on a real TPU's
    traffic wherever Pallas kernels (flash attention, SSD) keep
    intermediates in VMEM."""

    flops: float = 0.0
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_bytes_by_type: dict = field(default_factory=lambda: defaultdict(float))
    collective_ops: int = 0
    dots: int = 0
    unknown_trip_loops: int = 0
    top_collectives: list = field(default_factory=list)  # (wire_bytes, descr)
    top_hbm_ops: list = field(default_factory=list)  # (bytes, descr)


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    entry = ""
    for line in text.splitlines():
        if line.startswith("ENTRY") or (line and not line[0].isspace() and "->" in line and line.rstrip().endswith("{")):
            m2 = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line.strip())
            if m2:
                current = Computation(m2.group(1))
                comps[current.name] = current
                if line.startswith("ENTRY"):
                    entry = current.name
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        om = _OP_RE.match(line)
        if om:
            current.ops.append(
                HloOp(om.group("name"), om.group("type"), om.group("opcode"), om.group("rest"))
            )
    if not entry and comps:
        # fall back: computation containing the most ops
        entry = max(comps, key=lambda c: len(comps[c].ops))
    return comps, entry


def _dot_flops(op: HloOp, shapes: dict[str, str]) -> float:
    out_elems = sum(int(math.prod(s)) for _, s in _parse_shapes(op.type_str))
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    operands = re.findall(r"%([\w.\-]+)", op.rest)
    if not m or not operands:
        return 2.0 * out_elems  # degenerate
    lhs_shape = None
    lhs_type = shapes.get(operands[0])
    if lhs_type:
        parsed = _parse_shapes(lhs_type)
        if parsed:
            lhs_shape = parsed[0][1]
    if lhs_shape is None:
        return 2.0 * out_elems
    cdims = [int(d) for d in m.group(1).split(",") if d]
    k = int(math.prod(lhs_shape[d] for d in cdims)) if cdims else 1
    return 2.0 * out_elems * k


def _conv_flops(op: HloOp, shapes: dict[str, str]) -> float:
    out_elems = sum(int(math.prod(s)) for _, s in _parse_shapes(op.type_str))
    operands = re.findall(r"%([\w.\-]+)", op.rest)
    if len(operands) < 2:
        return 2.0 * out_elems
    rhs_type = shapes.get(operands[1], "")
    parsed = _parse_shapes(rhs_type)
    if not parsed:
        return 2.0 * out_elems
    kernel_elems = int(math.prod(parsed[0][1]))
    out_ch = parsed[0][1][-1] if parsed[0][1] else 1
    per_out = kernel_elems / max(out_ch, 1)
    return 2.0 * out_elems * per_out


def _dus_update_bytes(comp: "Computation | None", shapes: dict[str, str]) -> float | None:
    """If the fusion's root is a dynamic-update-slice (possibly wrapped in
    convert/copy — XLA:CPU upcasts bf16 around dots), return the update
    operand's byte count: the real write traffic of the aliased buffer."""
    if comp is None or not comp.ops:
        return None
    by_name = {op.name: op for op in comp.ops}
    root = comp.ops[-1]
    hops = 0
    while root.opcode in ("convert", "copy", "bitcast") and hops < 4:
        operands = re.findall(r"%([\w.\-]+)", root.rest.split(")")[0])
        if not operands or operands[0] not in by_name:
            return None
        root = by_name[operands[0]]
        hops += 1
    if root.opcode != "dynamic-update-slice":
        return None
    operands = re.findall(r"%([\w.\-]+)", root.rest.split(")")[0])
    if len(operands) < 2:
        return None
    upd = shapes.get(operands[1])
    return _nbytes(upd) if upd else None


def _group_size(op: HloOp, default: int) -> int:
    m = _GROUPS_RE.search(op.rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(op.rest)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        n = len([x for x in first.split(",") if x.strip() != ""])
        return max(n, 1)
    return default


_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def analyze(text: str, *, default_group: int = 16) -> RooflineCounts:
    comps, entry = parse_module(text)
    shapes: dict[str, str] = {}
    for comp in comps.values():
        for op in comp.ops:
            shapes[op.name] = op.type_str

    counts = RooflineCounts()
    visited_stack: list[str] = []

    def visit(comp_name: str, mult: float, count_bytes: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in visited_stack:
            return
        visited_stack.append(comp_name)
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                tm = _TRIP_RE.search(op.rest)
                trip = int(tm.group(1)) if tm else 1
                if not tm:
                    counts.unknown_trip_loops += 1
                called = _CALLED_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                if called:
                    visit(called.group(1), mult * trip, count_bytes)
                if cond:
                    visit(cond.group(1), mult * trip, count_bytes)
                continue
            if oc == "conditional":
                bm = _BRANCHES_RE.search(op.rest)
                if bm:
                    branches = re.findall(r"%?([\w.\-]+)", bm.group(1))
                    for b in branches[:1]:  # count one branch (max would be fairer; they're usually similar)
                        visit(b, mult, count_bytes)
                continue
            if oc in ("call", "async-start", "async-done"):
                called = _CALLED_RE.search(op.rest)
                if called:
                    visit(called.group(1), mult, count_bytes)
                continue
            if oc == "fusion":
                called = _CALLED_RE.search(op.rest)
                if called:
                    visit(called.group(1), mult, count_bytes=False)  # flops only
                if count_bytes:
                    out_b = _nbytes(op.type_str)
                    # in-place dynamic-update-slice fusions alias their
                    # operand buffer: actual HBM writes = the update slice,
                    # not the whole (e.g. KV-cache) array.
                    if called:
                        dus = _dus_update_bytes(comps.get(called.group(1)), shapes)
                        if dus is not None:
                            out_b = dus
                    b = mult * out_b
                    counts.hbm_bytes += b
                    if b > (1 << 28):
                        counts.top_hbm_ops.append((b, f"fusion x{mult:g} {op.type_str[:72]}"))
                continue
            if oc == "dynamic-update-slice":
                if count_bytes:
                    ops_names = re.findall(r"%([\w.\-]+)", op.rest.split(")")[0])
                    upd = _nbytes(shapes.get(ops_names[1], "")) if len(ops_names) > 1 else 0
                    counts.hbm_bytes += mult * (upd or _nbytes(op.type_str))
                continue
            base = oc.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVES:
                if not oc.endswith("-done"):
                    data = _nbytes(op.type_str)
                    n = _group_size(op, default_group)
                    wire = _WIRE_FACTOR[base](max(n, 2)) * data
                    counts.collective_wire_bytes += mult * wire
                    counts.collective_bytes_by_type[base] += mult * data
                    counts.collective_ops += 1
                    counts.top_collectives.append(
                        (mult * wire, f"{base} x{mult:g} {op.type_str[:72]}")
                    )
                if count_bytes:
                    counts.hbm_bytes += mult * _nbytes(op.type_str)
                continue
            if oc == "dot":
                f = _dot_flops(op, shapes)
                counts.flops += mult * f
                counts.dot_flops += mult * f
                counts.dots += 1
                if count_bytes:
                    counts.hbm_bytes += mult * _nbytes(op.type_str)
                continue
            if oc == "convolution":
                f = _conv_flops(op, shapes)
                counts.flops += mult * f
                counts.conv_flops += mult * f
                if count_bytes:
                    counts.hbm_bytes += mult * _nbytes(op.type_str)
                continue
            if count_bytes and oc not in _SKIP_BYTES:
                b = mult * _nbytes(op.type_str)
                counts.hbm_bytes += b
                if b > (1 << 28):
                    counts.top_hbm_ops.append((b, f"{oc} x{mult:g} {op.type_str[:72]}"))
        visited_stack.pop()

    def _op_io_bytes(op: HloOp, shapes: dict[str, str]) -> float:
        out = _nbytes(op.type_str)
        inp = 0
        for operand in re.findall(r"%([\w.\-]+)", op.rest.split(")")[0]):
            t = shapes.get(operand)
            if t:
                inp += _nbytes(t)
        return float(out + inp)

    visit(entry, 1.0, True)
    counts.top_collectives = sorted(counts.top_collectives, reverse=True)[:8]
    counts.top_hbm_ops = sorted(counts.top_hbm_ops, reverse=True)[:8]
    return counts
