"""ShapeDtypeStruct stand-ins for every model input / state — weak-type
correct, shardable, zero device allocation. The dry-run lowers against these.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCfg
from ..models import transformer as tf


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ArchConfig, shape: ShapeCfg, *, act_dtype=jnp.bfloat16) -> dict:
    """Inputs for train_step (train_*) or prefill (prefill_*)."""
    B, S = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {}
    if cfg.frontend == "frame_embed":
        batch["frame_embeds"] = _sds((B, S, cfg.d_model), act_dtype)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
        if cfg.frontend == "patch_embed":
            batch["patch_embeds"] = _sds((B, cfg.n_prefix_embeds, cfg.d_model), act_dtype)
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def decode_input_specs(cfg: ArchConfig, shape: ShapeCfg, *, act_dtype=jnp.bfloat16) -> dict:
    """One-new-token inputs for serve_step at a KV/state cache of seq_len."""
    B = shape.global_batch
    if cfg.frontend == "frame_embed":
        return {"frame_embeds": _sds((B, 1, cfg.d_model), act_dtype)}
    return {"tokens": _sds((B, 1), jnp.int32)}


def params_specs(cfg: ArchConfig, *, dtype=jnp.bfloat16) -> Any:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0), dtype))


def cache_specs(cfg: ArchConfig, batch: int, max_len: int, *, dtype=jnp.bfloat16) -> Any:
    return jax.eval_shape(lambda: tf.init_cache(cfg, batch, max_len, dtype))


def opt_state_specs(cfg: ArchConfig, opt_cfg, *, dtype=jnp.bfloat16) -> Any:
    from ..runtime.optimizer import adamw_init

    p = params_specs(cfg, dtype=dtype)
    return jax.eval_shape(lambda pp: adamw_init(opt_cfg, pp), p)
