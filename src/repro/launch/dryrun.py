import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production mesh (16x16 single-pod / 2x16x16 multi-pod), print
# memory_analysis + cost_analysis, and derive the roofline terms from the
# optimized HLO (launch.hlo_analysis).
#
# The XLA_FLAGS line above MUST stay the first statement: jax locks the
# device count at first initialization.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --both-meshes --out results.json

import argparse
import json
import time
import traceback
from dataclasses import asdict, dataclass, field

import jax
import jax.numpy as jnp

from ..configs import LM_SHAPES, all_configs, get_config
from ..configs.base import ArchConfig, ShapeCfg
from ..runtime.optimizer import AdamWConfig
from ..runtime.serve import make_serve_step
from ..runtime.sharding import make_policy
from ..runtime.train import make_train_step
from . import hlo_analysis, specs
from .mesh import make_production_mesh

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

SHAPES = {s.name: s for s in LM_SHAPES}


@dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str  # ok | skipped | error
    reason: str = ""
    compile_s: float = 0.0
    # memory analysis (per chip, bytes)
    arg_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    # cost analysis (XLA, body-once per-shard)
    xla_flops: float = 0.0
    xla_bytes: float = 0.0
    # hlo_analysis (per chip, trip-count scaled)
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_by_type: dict = field(default_factory=dict)
    n_collectives: int = 0
    unknown_trip_loops: int = 0
    # roofline terms (seconds, per chip)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops_per_chip: float = 0.0
    useful_ratio: float = 0.0


def model_flops_per_chip(cfg: ArchConfig, shape: ShapeCfg, n_chips: int) -> float:
    """6*N_active*D for training, 2*N_active*D for inference forward; decode
    counts one token per sequence."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = shape.global_batch  # one new token per sequence
    # decode also re-reads the KV cache: attention flops ~ 2*2*L*kv*hd*S per tok
    attn = 4.0 * cfg.num_layers * cfg.num_kv_heads * cfg.resolved_head_dim * shape.seq_len
    return (2.0 * n_active + attn) * tokens / n_chips


def _build_lowerable(cfg: ArchConfig, shape: ShapeCfg, mesh, policy):
    """Returns (fn, args) ready for jax.jit(...).lower(*args)."""
    p_specs = specs.params_specs(cfg)
    p_shard = policy.params_sharding(p_specs)

    if shape.kind == "train":
        opt_big = cfg.param_count() * 2 / 256 > (2 << 30)
        opt_cfg = AdamWConfig(moment_dtype=jnp.bfloat16 if opt_big else jnp.float32)
        o_specs = specs.opt_state_specs(cfg, opt_cfg)
        o_shard = jax.tree.map(
            lambda s: s, policy.params_sharding(o_specs["m"])
        )
        opt_shard = {
            "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            "m": o_shard,
            "v": policy.params_sharding(o_specs["v"]),
        }
        batch = dict(specs.input_specs(cfg, shape))
        b_shard = policy.inputs_sharding(batch)
        step = make_train_step(cfg, policy, opt_cfg, remat=True, microbatch=1)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, None),
            donate_argnums=(0, 1),
        )
        return fn, (p_specs, o_specs, batch)

    if shape.kind == "prefill":
        from ..runtime.serve import make_prefill

        batch = specs.input_specs(cfg, shape)
        b_shard = policy.inputs_sharding(batch)
        fn = jax.jit(
            make_prefill(cfg, policy),
            in_shardings=(p_shard, b_shard),
            out_shardings=None,
        )
        return fn, (p_specs, batch)

    # decode
    c_specs = specs.cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_shard = policy.cache_sharding(c_specs)
    batch = specs.decode_input_specs(cfg, shape)
    b_shard = policy.inputs_sharding(batch)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    fn = jax.jit(
        make_serve_step(cfg, policy),
        in_shardings=(p_shard, c_shard, b_shard, jax.NamedSharding(mesh, jax.sharding.PartitionSpec())),
        out_shardings=None,
        donate_argnums=(1,),
    )
    return fn, (p_specs, c_specs, batch, pos)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, keep_text: bool = False) -> CellResult:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name, status="ok")

    if shape.name == "long_500k" and not cfg.supports_long:
        res.status = "skipped"
        res.reason = "pure full attention: 500k decode KV is unbounded (DESIGN.md)"
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    policy = make_policy(cfg, mesh)

    t0 = time.time()
    try:
        with mesh:
            fn, args = _build_lowerable(cfg, shape, mesh, policy)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
    except Exception as e:
        res.status = "error"
        res.reason = f"{type(e).__name__}: {e}"[:500]
        if verbose:
            traceback.print_exc()
        return res
    res.compile_s = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        res.arg_bytes = int(mem.argument_size_in_bytes)
        res.output_bytes = int(mem.output_size_in_bytes)
        res.temp_bytes = int(mem.temp_size_in_bytes)
    except Exception:
        pass
    try:
        ca = compiled.cost_analysis() or {}
        res.xla_flops = float(ca.get("flops", 0.0))
        res.xla_bytes = float(ca.get("bytes accessed", 0.0))
    except Exception:
        pass

    text = compiled.as_text()
    counts = hlo_analysis.analyze(text, default_group=mesh.shape["model"])
    res.flops = counts.flops
    res.hbm_bytes = counts.hbm_bytes
    res.collective_wire_bytes = counts.collective_wire_bytes
    res.collective_by_type = dict(counts.collective_bytes_by_type)
    res.n_collectives = counts.collective_ops
    res.unknown_trip_loops = counts.unknown_trip_loops

    res.t_compute = counts.flops / PEAK_FLOPS
    res.t_memory = counts.hbm_bytes / HBM_BW
    res.t_collective = counts.collective_wire_bytes / ICI_BW
    terms = {
        "compute": res.t_compute,
        "memory": res.t_memory,
        "collective": res.t_collective,
    }
    res.bottleneck = max(terms, key=terms.get)
    res.model_flops_per_chip = model_flops_per_chip(cfg, shape, n_chips)
    res.useful_ratio = (
        res.model_flops_per_chip / res.flops if res.flops else 0.0
    )

    if verbose:
        print(
            f"[{mesh_name}] {arch} x {shape_name}: compile {res.compile_s:.1f}s | "
            f"args {res.arg_bytes/2**30:.2f} GiB temp {res.temp_bytes/2**30:.2f} GiB | "
            f"flops/chip {res.flops:.3e} | hbm {res.hbm_bytes:.3e} B | "
            f"wire {res.collective_wire_bytes:.3e} B | "
            f"terms c/m/x = {res.t_compute*1e3:.2f}/{res.t_memory*1e3:.2f}/"
            f"{res.t_collective*1e3:.2f} ms -> {res.bottleneck} | "
            f"useful {res.useful_ratio:.2f}"
        )
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    archs = sorted(all_configs()) if args.arch == "all" else [args.arch]
    shape_names = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for sn in shape_names:
                results.append(run_cell(arch, sn, multi_pod=mp))

    ok = sum(1 for r in results if r.status == "ok")
    sk = sum(1 for r in results if r.status == "skipped")
    er = sum(1 for r in results if r.status == "error")
    print(f"\n== dry-run summary: {ok} ok, {sk} skipped, {er} errors ==")
    for r in results:
        if r.status == "error":
            print(f"  ERROR {r.mesh} {r.arch} x {r.shape}: {r.reason}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump([asdict(r) for r in results], f, indent=1)
        print(f"wrote {args.out}")

    if er:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
