"""Pipeline coupling model: credit-loop bounds on the steady-state rate.

The analytic model of ``place()`` historically treated the pipeline round
time as ``max(stage_times)`` — stages as independent servers. The compiled
programs are tighter than that: every cross-stage tensor is forwarded
through a WAIT_ACK/SEND_REQ (producer ST) <-> WAIT_REQ/SEND_ACK (consumer
LD) handshake over a finite ring of ping-pong buffer regions
(``TensorPlan.n_regions``), so a fast producer can run at most ``beta``
rounds ahead of the consumer that returns its credits.

In timed-event-graph terms the steady pipeline is a marked graph. Its
period is bounded below by every cycle's delay divided by the tokens on
it. Two cycle families matter:

* each instruction group's serial round work — the classic per-stage
  bound, already captured by ``stage_times``;
* each cross-stage credit loop. The ACK-bypass prologue places
  ``beta(T)`` credit tokens on tensor T's loop, and one traversal costs

      t_write(T) + L_req + t_read(T) + L_ack + 4 * DECODE_CYCLES

  — the producer's store ADM, the REQ token's ISU flight to the consumer,
  the consumer's load ADM (zero for side/second-operand inputs, whose LD
  handshake ACKs immediately while the CP streams the data), the ACK
  token's flight back, and one decode slot for each of the four handshake
  instructions. Token flight times come from
  :func:`repro.core.isu.token_latency_cycles` and the decode cost from
  :data:`repro.core.icu.DECODE_CYCLES` — calibration constants of the
  simulated hardware, not fit parameters.

The coupled round time is the max over both families — closed form, no
simulation, O(edges) per config — so ``place()`` stays cheap and the
fast-DSE ``analyze``/``place`` split and STATS call-count gates are
untouched (buffer depths come from :func:`buffer_requirements` directly,
which never runs the liveness/channel planning counted by
``STATS.memory_plan_calls``).

Token latencies are evaluated on the *canonical* PU assignment (pipeline
order onto the default PU pool, ignoring any multi-batch ``pid_offset``):
ISU latency depends only on hop distance and SLR crossing, which are
identical for every contiguous same-kind placement, and the canonical form
keeps DSE-cache predictions and offset-placed deployment predictions
byte-identical.

Graph input/output tensors are host-coordinated (``n_io`` A/C regions over
PCIe) and are not part of the PU-to-PU credit system; they carry no bound
here.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.icu import DECODE_CYCLES
from ..core.isu import token_latency_cycles
from ..core.pu import PUSpec
from .graph import Graph
from .memory import TensorPlan
from .partition import Partition

# WAIT_ACK + SEND_REQ (producer ST) + WAIT_REQ + SEND_ACK (consumer LD)
_HANDSHAKE_DECODES = 4


@dataclass(frozen=True)
class BoundaryBound:
    """One cross-stage tensor's credit-loop period bound."""

    tid: int
    producer_stage: int
    consumer_stage: int
    depth: int  # credit tokens on the loop (ping-pong regions / kv credits)
    cycle_seconds: float  # one traversal of the credit loop
    req_latency_seconds: float  # one-way store->load forwarding latency

    @property
    def bound_seconds(self) -> float:
        """Minimum steady-state round period this loop allows."""
        return self.cycle_seconds / self.depth


@dataclass(frozen=True)
class CouplingModel:
    """Coupled steady-state rate of one placed pipeline."""

    uncoupled_seconds: float  # max(stage_times) — the independent-server view
    bounds: tuple[BoundaryBound, ...]

    @property
    def round_seconds(self) -> float:
        return max(
            self.uncoupled_seconds,
            max((b.bound_seconds for b in self.bounds), default=0.0),
        )

    @property
    def binding(self) -> "BoundaryBound | None":
        """The boundary whose credit loop limits the rate, if any does."""
        worst = max(self.bounds, key=lambda b: b.bound_seconds, default=None)
        if worst is not None and worst.bound_seconds > self.uncoupled_seconds:
            return worst
        return None

    @property
    def forward_latency_seconds(self) -> float:
        """Per-item latency added by handshake forwarding: each distinct
        producer->consumer stage hop pays its one-way REQ flight once.
        Hops are summed in canonical (producer, consumer) order so every
        engine — scalar, reference, and the vectorized scorer of
        ``repro.dse.batched`` — accumulates the identical float sequence."""
        hops: dict[tuple[int, int], float] = {}
        for b in self.bounds:
            key = (b.producer_stage, b.consumer_stage)
            cur = hops.get(key)
            if cur is None or b.req_latency_seconds < cur:
                hops[key] = b.req_latency_seconds
        return sum(hops[k] for k in sorted(hops))


def _credit_depth(plan: TensorPlan) -> int:
    """Tokens the ACK-bypass prologue puts on this tensor's loop. For
    ordinary tensors that is the physical ping-pong region count; a K/V
    cache is a single append-only region but keeps the stage-distance
    credit depth (writes append rows disjoint from the prefix reads)."""
    return plan.beta if plan.kind == "kv" else plan.n_regions


def coupling_bounds(
    g: Graph,
    part: Partition,
    plans: dict[int, TensorPlan],
    pid_map: dict[int, int],
    pu_specs: dict[int, PUSpec],
) -> tuple[BoundaryBound, ...]:
    """Credit-loop bounds for every cross-stage tensor edge.

    ``pid_map`` must be the canonical stage->pid assignment (see module
    docstring); ``plans`` the :func:`buffer_requirements` output for the
    same partition.
    """
    stage_of = part.stage_of_node()
    bounds: list[BoundaryBound] = []
    for tid, plan in plans.items():
        if plan.kind in ("input", "output") or plan.producer_stage is None:
            continue
        pstage = plan.producer_stage
        ppid = pid_map.get(pstage)
        if ppid is None:
            continue
        pspec = pu_specs[ppid]
        tinfo = g.tensors[tid]
        t_write = pspec.adm_seconds(tinfo.write_bytes)
        # the slowest consumer stage's ACK paces the producer
        for c in g.consumers_of(tid):
            cstage = stage_of.get(c.nid)
            if cstage is None or cstage == pstage:
                continue  # intra-stage edges stream write->read (no loop)
            cpid = pid_map.get(cstage)
            if cpid is None:
                continue
            cspec = pu_specs[cpid]
            # primary inputs are read by the consumer LD before it ACKs;
            # side/second operands ACK immediately (CP streams the data).
            t_read = (
                cspec.adm_seconds(tinfo.nbytes_padded)
                if c.inputs and c.inputs[0] == tid
                else 0.0
            )
            l_req = token_latency_cycles(pspec, cspec) / pspec.sys_clk_hz
            l_ack = token_latency_cycles(cspec, pspec) / cspec.sys_clk_hz
            t_dec = _HANDSHAKE_DECODES * DECODE_CYCLES / pspec.sys_clk_hz
            bounds.append(
                BoundaryBound(
                    tid=tid,
                    producer_stage=pstage,
                    consumer_stage=cstage,
                    depth=max(1, _credit_depth(plan)),
                    cycle_seconds=t_write + l_req + t_read + l_ack + t_dec,
                    req_latency_seconds=l_req + 2 * DECODE_CYCLES / pspec.sys_clk_hz,
                )
            )
    return tuple(bounds)


def couple(
    g: Graph,
    part: Partition,
    plans: dict[int, TensorPlan],
    stage_times: dict[int, float],
    pid_map: dict[int, int],
    pu_specs: dict[int, PUSpec],
) -> CouplingModel:
    """Build the coupling model for one placed configuration."""
    return CouplingModel(
        uncoupled_seconds=max(stage_times.values()) if stage_times else 0.0,
        bounds=coupling_bounds(g, part, plans, pid_map, pu_specs),
    )
