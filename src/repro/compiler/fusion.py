"""Hardware-aware node fusion (paper Sec. IV-A, Fig. 4(b1)).

Adapts the DNN graph to the PU dataflow capabilities while preserving
computational correctness:

  * A GEMM (Conv or Proj) followed by an element-wise Add fuses into
    FusedConvAdd / FusedProjAdd — the PU post-processing block supports
    residual shortcut additions in dataflow (the *other* producer feeding the
    Add remains unchanged and its output becomes the fused node's
    ``residual_input``). This covers both CNN shortcuts (Fig. 4(b1)) and the
    transformer residual stream (attention-out + x, FFN-down + h).
  * Activation functions (ReLU, and the vector-unit GELU/SiLU of transformer
    FFNs) integrate into the preceding compute node: the Compute
    instruction's vector-activation enable is set and the standalone node
    disappears.

The pass returns a new topologically-ordered Graph whose compute nodes map
1:1 onto PU GEMM executions.

Fusion is config-independent: it runs once per graph content inside
``repro.compiler.analyze`` (memoized by ``Graph.fingerprint``) and the fused
graph is shared — read-only — by every (a, b) configuration a DSE sweep
evaluates.
"""
from __future__ import annotations

from .graph import Graph, Node, OpType

# GEMMs that can absorb a successor Add into their post-processing block.
_FUSABLE_GEMMS = {
    OpType.CONV: OpType.FUSED_CONV_ADD,
    OpType.PROJ: OpType.FUSED_PROJ_ADD,
}
# Standalone activation nodes foldable into a preceding compute node.
_ACT_OPS = (OpType.RELU, OpType.GELU)


def fuse(g: Graph) -> Graph:
    """Apply activation-integration and GEMM+Add(+act) fusion."""
    nodes = list(g.nodes)
    consumed: set[int] = set()  # node ids folded into a fused node
    # position of a tensor's production in the topological order
    pos_of = {tid: i for i, nd in enumerate(nodes) for tid in nd.outputs}
    for tid in g.input_tensors:
        pos_of.setdefault(tid, -1)

    def sole_consumer(tid: int) -> Node | None:
        cons = [nd for nd in nodes if tid in nd.inputs and nd.nid not in consumed]
        return cons[0] if len(cons) == 1 else None

    out = Graph(name=g.name + ".fused")
    out.tensors = dict(g.tensors)
    out._next_tid = g._next_tid
    out.input_tensors = list(g.input_tensors)
    out.output_tensors = list(g.output_tensors)
    out.attrs = dict(g.attrs)  # decode-phase metadata survives fusion

    # tensor rewiring: fused chains alias their intermediate tensors to the
    # final output tensor of the chain.
    alias: dict[int, int] = {}

    def resolve(tid: int) -> int:
        while tid in alias:
            tid = alias[tid]
        return tid

    for nd in nodes:
        if nd.nid in consumed:
            continue
        if nd.op in (OpType.CONV, OpType.FC, OpType.PROJ):
            op = nd.op
            relu = nd.relu
            residual = nd.residual_input
            attrs = dict(nd.attrs)
            out_tid = nd.outputs[0]

            # activation folding *before* the Add (proj -> act -> ... chains:
            # FFN gate/up activations precede the residual join).
            act_folded = False
            nxt = sole_consumer(out_tid)
            if nxt is not None and nxt.op in _ACT_OPS:
                relu = True
                act_folded = True
                attrs.setdefault("act", nxt.attrs.get("act", "relu"))
                consumed.add(nxt.nid)
                out_tid = nxt.outputs[0]

            # GEMM -> Add fusion (residual shortcut executed in dataflow).
            # Not after a folded activation: the post-processing block applies
            # act *after* the shortcut add, so fusing a GEMM->act->Add chain
            # would reorder them (act(x+r) instead of act(x)+r) — the Add
            # stays a standalone vector op there.
            if op in _FUSABLE_GEMMS and residual is None and not act_folded:
                nxt = sole_consumer(out_tid)
                if nxt is not None and nxt.op is OpType.ADD:
                    other = [t for t in nxt.inputs if t != out_tid]
                    # The fused node must be the *latest* producer feeding the
                    # Add: its residual input must already exist at this
                    # topological position ("the other Conv layer remains
                    # unchanged", Fig. 4(b1)).
                    if len(other) == 1 and pos_of.get(other[0], 1 << 30) < pos_of[nd.outputs[0]]:
                        residual = other[0]
                        consumed.add(nxt.nid)
                        out_tid = nxt.outputs[0]
                        op = _FUSABLE_GEMMS[op]

            # (Fused)GEMM -> activation integration after the Add.
            nxt = sole_consumer(out_tid)
            if nxt is not None and nxt.op in _ACT_OPS:
                relu = True
                attrs.setdefault("act", nxt.attrs.get("act", "relu"))
                consumed.add(nxt.nid)
                out_tid = nxt.outputs[0]

            if out_tid != nd.outputs[0]:
                alias[nd.outputs[0]] = out_tid
            out.add_node(
                name=nd.name if op is nd.op else nd.name + "+add",
                op=op,
                inputs=[resolve(t) for t in nd.inputs],
                # Add/act fusion rewrites the primary output only; any extra
                # outputs (multi-consumer forks) survive untouched.
                outputs=[out_tid, *nd.outputs[1:]],
                m=nd.m, n=nd.n, k=nd.k,
                kernel=nd.kernel, stride=nd.stride, padding=nd.padding,
                relu=relu,
                residual_input=resolve(residual) if residual is not None else None,
                scale_shift=nd.scale_shift,
                attrs=attrs,
            )
        elif nd.op in _ACT_OPS:
            # Standalone activation after a non-fusable producer (e.g. Add
            # that could not fuse): keep as vector op.
            out.add_node(
                name=nd.name, op=nd.op,
                inputs=[resolve(t) for t in nd.inputs],
                outputs=list(nd.outputs),
                m=nd.m, n=nd.n, k=nd.k,
                scale_shift=nd.scale_shift,
                attrs=dict(nd.attrs),
            )
        elif nd.op in (OpType.ADD, OpType.MUL):
            # Unfused Add/Mul (both producers already consumed etc.) — vector
            # op with a second operand through the residual stream.
            out.add_node(
                name=nd.name, op=nd.op,
                inputs=[resolve(t) for t in nd.inputs],
                outputs=list(nd.outputs),
                m=nd.m, n=nd.n, k=nd.k,
                scale_shift=nd.scale_shift,
                attrs=dict(nd.attrs),
            )
        else:  # pools, layernorm, softmax, attention GEMMs, ...
            out.add_node(
                name=nd.name, op=nd.op,
                inputs=[resolve(t) for t in nd.inputs],
                outputs=list(nd.outputs),
                m=nd.m, n=nd.n, k=nd.k,
                kernel=nd.kernel, stride=nd.stride, padding=nd.padding,
                scale_shift=nd.scale_shift,
                attrs=dict(nd.attrs),
            )

    # Fix up graph outputs that were aliased into fused nodes.
    out.output_tensors = [resolve(t) for t in out.output_tensors]
    out.validate_topological()
    return out
