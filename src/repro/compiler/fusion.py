"""Hardware-aware node fusion (paper Sec. IV-A, Fig. 4(b1)).

Adapts the DNN graph to the PU dataflow capabilities while preserving
computational correctness:

  * Conv followed by element-wise Add fuses into FusedConvAdd(ReLU) — the PU
    post-processing block supports residual shortcut additions in dataflow
    (the *other* conv feeding the Add remains unchanged and its output
    becomes the fused node's ``residual_input``).
  * Activation functions (ReLU) integrate into the preceding compute node.

The pass returns a new topologically-ordered Graph whose compute nodes map
1:1 onto PU GEMM executions.
"""
from __future__ import annotations

from .graph import Graph, Node, OpType


def fuse(g: Graph) -> Graph:
    """Apply ReLU-integration and Conv+Add(+ReLU) fusion."""
    nodes = list(g.nodes)
    consumed: set[int] = set()  # node ids folded into a fused node
    # tensor id -> producing node (pre-fusion view)
    producer = {tid: nd for nd in nodes for tid in nd.outputs}
    # position of a tensor's production in the topological order
    pos_of = {tid: i for i, nd in enumerate(nodes) for tid in nd.outputs}
    for tid in g.input_tensors:
        pos_of.setdefault(tid, -1)

    def sole_consumer(tid: int) -> Node | None:
        cons = [nd for nd in nodes if tid in nd.inputs and nd.nid not in consumed]
        return cons[0] if len(cons) == 1 else None

    out = Graph(name=g.name + ".fused")
    out.tensors = dict(g.tensors)
    out._next_tid = g._next_tid
    out.input_tensors = list(g.input_tensors)
    out.output_tensors = list(g.output_tensors)

    # tensor rewiring: fused chains alias their intermediate tensors to the
    # final output tensor of the chain.
    alias: dict[int, int] = {}

    def resolve(tid: int) -> int:
        while tid in alias:
            tid = alias[tid]
        return tid

    for nd in nodes:
        if nd.nid in consumed:
            continue
        if nd.op in (OpType.CONV, OpType.FC):
            op = nd.op
            relu = nd.relu
            residual = nd.residual_input
            out_tid = nd.outputs[0]

            # Conv -> Add fusion (residual shortcut executed in dataflow).
            if op is OpType.CONV and residual is None:
                nxt = sole_consumer(out_tid)
                if nxt is not None and nxt.op is OpType.ADD:
                    other = [t for t in nxt.inputs if t != out_tid]
                    # The fused node must be the *latest* producer feeding the
                    # Add: its residual input must already exist at this
                    # topological position ("the other Conv layer remains
                    # unchanged", Fig. 4(b1)).
                    if len(other) == 1 and pos_of.get(other[0], 1 << 30) < pos_of[nd.outputs[0]]:
                        residual = other[0]
                        consumed.add(nxt.nid)
                        out_tid = nxt.outputs[0]
                        op = OpType.FUSED_CONV_ADD

            # (Fused)Conv -> ReLU integration.
            nxt = sole_consumer(out_tid)
            if nxt is not None and nxt.op is OpType.RELU:
                relu = True
                consumed.add(nxt.nid)
                out_tid = nxt.outputs[0]

            if out_tid != nd.outputs[0]:
                alias[nd.outputs[0]] = out_tid
            new = out.add_node(
                name=nd.name if op is nd.op else nd.name + "+add",
                op=op,
                inputs=[resolve(t) for t in nd.inputs],
                outputs=[out_tid],
                m=nd.m, n=nd.n, k=nd.k,
                kernel=nd.kernel, stride=nd.stride, padding=nd.padding,
                relu=relu,
                residual_input=resolve(residual) if residual is not None else None,
                scale_shift=nd.scale_shift,
            )
        elif nd.op is OpType.RELU:
            # Standalone ReLU after a non-fusable producer (e.g. Add that
            # could not fuse): keep as vector op.
            new = out.add_node(
                name=nd.name, op=nd.op,
                inputs=[resolve(t) for t in nd.inputs],
                outputs=list(nd.outputs),
                m=nd.m, n=nd.n, k=nd.k,
            )
        elif nd.op is OpType.ADD:
            # Unfused Add (both producers already consumed etc.) — vector op.
            new = out.add_node(
                name=nd.name, op=nd.op,
                inputs=[resolve(t) for t in nd.inputs],
                outputs=list(nd.outputs),
                m=nd.m, n=nd.n, k=nd.k,
            )
        else:  # pools etc.
            new = out.add_node(
                name=nd.name, op=nd.op,
                inputs=[resolve(t) for t in nd.inputs],
                outputs=list(nd.outputs),
                m=nd.m, n=nd.n, k=nd.k,
                kernel=nd.kernel, stride=nd.stride, padding=nd.padding,
            )

    # Fix up graph outputs that were aliased into fused nodes.
    out.output_tensors = [resolve(t) for t in out.output_tensors]
    out.validate_topological()
    return out
