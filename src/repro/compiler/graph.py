"""DNN graph IR for the compilation framework (paper Sec. IV, Fig. 4).

The framework consumes quantized (INT8, power-of-two scales) DNN models. We
use an ONNX-like node/tensor representation built directly in Python (the
container has no onnx package; the IR mirrors the fields the paper's parser
extracts: weights/bias dims, quantization scales, dependency structure,
tensor identifiers).

Operators cover the GEMM-based PU capabilities: Conv (lowered to GEMM via
IM2COL), FC/GEMM, elementwise Add (residual), ReLU, pooling (executed in the
PU vector units), plus structural ops handled at graph level.
"""
from __future__ import annotations

import enum
import hashlib
import math
from dataclasses import dataclass, field
from typing import Optional


class OpType(enum.Enum):
    CONV = "Conv"
    FC = "Gemm"
    ADD = "Add"
    RELU = "Relu"
    MAXPOOL = "MaxPool"
    AVGPOOL = "GlobalAveragePool"
    FUSED_CONV_ADD = "FusedConvAdd"  # Conv + residual Add (+ ReLU) in dataflow
    INPUT = "Input"
    OUTPUT = "Output"
    # -- transformer frontend (GEMM-shaped primitives of the encoder block) --
    PROJ = "Proj"  # weighted projection GEMM: Q/K/V/output, FFN up/gate/down
    FUSED_PROJ_ADD = "FusedProjAdd"  # Proj + residual Add (+ act) in dataflow
    ATTN_SCORE = "AttnScore"  # Q @ K^T per head: activation x activation GEMM
    ATTN_CONTEXT = "AttnContext"  # softmax(S) @ V per head: act x act GEMM
    SOFTMAX = "Softmax"  # vector-unit row softmax over attention scores
    LAYERNORM = "LayerNorm"  # vector-unit normalization (LN / RMSNorm)
    GELU = "Gelu"  # vector-unit activation (folded into PROJ by fusion)
    MUL = "Mul"  # elementwise gate multiply (SwiGLU), vector unit
    CONCAT = "Concat"  # row-wise gather of per-slot tensors, vector unit


# GEMM-shaped ops that carry weights streamed/preloaded into URAM.
WEIGHTED_OPS = frozenset(
    {OpType.CONV, OpType.FC, OpType.PROJ, OpType.FUSED_CONV_ADD, OpType.FUSED_PROJ_ADD}
)
# GEMMs whose second operand is an *activation* streamed through the weight
# port of the systolic array (no resident weights).
ATTN_GEMM_OPS = frozenset({OpType.ATTN_SCORE, OpType.ATTN_CONTEXT})


@dataclass(frozen=True)
class TensorInfo:
    """A tensor edge in the DAG (activation tensor, NCHW).

    ``kv_base_rows >= 0`` marks an *append-only K/V cache region* for
    autoregressive decode: ``shape[0]`` is the maximum row count (prefill
    prefix + decode window), the prefill phase populated the first
    ``kv_base_rows`` rows, and each program round appends exactly one row
    while reads cover the full valid prefix (which therefore *grows* one row
    per round — the AddrLen/CYCLE_LEN semantics)."""

    tid: int
    name: str
    shape: tuple[int, ...]  # (C, H, W) activation or (N,) flat
    dtype_bytes: int = 1  # INT8
    kv_base_rows: int = -1  # >= 0: append-only K/V cache (see above)

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.shape)) * self.dtype_bytes

    @property
    def nbytes_padded(self) -> int:
        return (self.nbytes + 63) // 64 * 64  # 64B AXI-beat alignment

    # -- K/V cache geometry (decode-phase scheduling) ------------------------
    @property
    def is_kv_cache(self) -> bool:
        return self.kv_base_rows >= 0

    @property
    def kv_steps(self) -> int:
        """Decode rounds covered by the region (appended rows)."""
        return self.shape[0] - self.kv_base_rows

    @property
    def kv_row_stride(self) -> int:
        """Beat-aligned bytes of one appended row (one token's K or V)."""
        row = int(math.prod(self.shape[1:])) * self.dtype_bytes
        return (row + 63) // 64 * 64

    @property
    def kv_avg_rows(self) -> float:
        """Mean valid length over the decode window: round r reads
        base + r + 1 rows, so the average is base + (steps + 1) / 2."""
        return self.kv_base_rows + (self.kv_steps + 1) / 2

    @property
    def kv_region_bytes(self) -> int:
        """Full single-region allocation (max rows, row-stride padded)."""
        return self.shape[0] * self.kv_row_stride

    # -- per-round traffic views (used by the analytic model) ----------------
    @property
    def stream_bytes(self) -> int:
        """Per-round bytes when streamed through the SA weight port: the
        average valid prefix for caches, the whole tensor otherwise."""
        if self.is_kv_cache:
            return int(self.kv_avg_rows * self.kv_row_stride)
        return self.nbytes_padded

    @property
    def write_bytes(self) -> int:
        """Per-round bytes stored by the producer: one appended row for
        caches, the whole tensor otherwise."""
        return self.kv_row_stride if self.is_kv_cache else self.nbytes_padded


@dataclass
class Node:
    """One DAG node. After fusion, a node maps to exactly one PU GEMM (or a
    vector-unit op) — 'the nodes are partitioned into computational tiles
    matching the first SA dimension of each mapped PU'."""

    nid: int
    name: str
    op: OpType
    inputs: list[int]  # tensor ids
    outputs: list[int]
    # GEMM view (for CONV/FC/FUSED_*): out = W[KxM]^T @ im2col(x)[KxN]
    m: int = 0  # output channels
    n: int = 0  # spatial positions (H_out * W_out) or batch rows
    k: int = 0  # in_ch * kh * kw
    # conv params
    kernel: tuple[int, int] = (1, 1)
    stride: tuple[int, int] = (1, 1)
    padding: tuple[int, int] = (0, 0)
    relu: bool = False
    residual_input: Optional[int] = None  # tensor id of fused shortcut
    scale_shift: int = 0  # po2 requant shift
    attrs: dict = field(default_factory=dict)

    @property
    def macs(self) -> int:
        if self.op in WEIGHTED_OPS or self.op in ATTN_GEMM_OPS:
            return self.m * self.n * self.k
        return 0

    @property
    def weight_bytes(self) -> int:
        """INT8 weights + INT32 bias footprint in URAM."""
        if self.op in WEIGHTED_OPS:
            return self.m * self.k + 4 * self.m
        return 0

    @property
    def is_compute(self) -> bool:
        return (self.op in WEIGHTED_OPS or self.op in ATTN_GEMM_OPS
                or self.op in (OpType.MAXPOOL, OpType.AVGPOOL, OpType.SOFTMAX,
                               OpType.LAYERNORM, OpType.MUL, OpType.CONCAT))


@dataclass
class Graph:
    """Node DAG + tensor table. Nodes are stored in topological order."""

    name: str
    nodes: list[Node] = field(default_factory=list)
    tensors: dict[int, TensorInfo] = field(default_factory=dict)
    input_tensors: list[int] = field(default_factory=list)
    output_tensors: list[int] = field(default_factory=list)
    # graph-level metadata (e.g. decode phase: {"phase": "decode",
    # "prefill_len": S, "decode_steps": T} — one program round = one token)
    attrs: dict = field(default_factory=dict)
    _next_tid: int = 0
    _next_nid: int = 0

    # -- construction --------------------------------------------------------
    def add_tensor(self, name: str, shape: tuple[int, ...], dtype_bytes: int = 1,
                   kv_base_rows: int = -1) -> TensorInfo:
        t = TensorInfo(self._next_tid, name, tuple(shape), dtype_bytes,
                       kv_base_rows=kv_base_rows)
        self.tensors[t.tid] = t
        self._next_tid += 1
        return t

    def add_node(self, **kw) -> Node:
        node = Node(nid=self._next_nid, **kw)
        self._next_nid += 1
        self.nodes.append(node)
        return node

    # -- queries --------------------------------------------------------------
    @property
    def decode_steps(self) -> Optional[int]:
        """Decode-window length of a decode-phase graph (``None`` for
        prefill/CNN graphs). One program round advances one decode step."""
        steps = self.attrs.get("decode_steps")
        return int(steps) if steps else None

    def fingerprint(self) -> str:
        """Stable content hash over nodes, tensors, IO lists and attrs.

        The memoization key of the config-independent compile analysis
        (:func:`repro.compiler.analyze`): two Graph objects with identical
        content share one fused/profiled/weight-scheduled artifact, so a DSE
        sweep — or several tenants of ``explore_multi`` referencing the same
        model — pays for fusion and profiling exactly once. The full content
        is hashed on every call (~1 ms even for deep graphs, trivial next to
        one compile), so in-place mutations of node fields, tensors or attrs
        are always observed and can never serve a stale cached analysis.
        """
        h = hashlib.sha256()
        h.update(repr((self.name, sorted(self.attrs.items()),
                       self.input_tensors, self.output_tensors)).encode())
        for t in sorted(self.tensors.values(), key=lambda t: t.tid):
            h.update(repr((t.tid, t.name, t.shape, t.dtype_bytes,
                           t.kv_base_rows)).encode())
        for nd in self.nodes:
            h.update(repr((nd.nid, nd.name, nd.op.value, nd.inputs, nd.outputs,
                           nd.m, nd.n, nd.k, nd.kernel, nd.stride, nd.padding,
                           nd.relu, nd.residual_input, nd.scale_shift,
                           sorted(nd.attrs.items()))).encode())
        return h.hexdigest()

    def producer_of(self, tid: int) -> Optional[Node]:
        for nd in self.nodes:
            if tid in nd.outputs:
                return nd
        return None

    def consumers_of(self, tid: int) -> list[Node]:
        out = [nd for nd in self.nodes if tid in nd.inputs]
        out += [nd for nd in self.nodes if nd.residual_input == tid]
        return out

    def node_by_id(self, nid: int) -> Node:
        for nd in self.nodes:
            if nd.nid == nid:
                return nd
        raise KeyError(nid)

    def compute_nodes(self) -> list[Node]:
        return [nd for nd in self.nodes if nd.is_compute]

    def total_macs(self) -> int:
        return sum(nd.macs for nd in self.nodes)

    def total_weight_bytes(self) -> int:
        return sum(nd.weight_bytes for nd in self.nodes)

    def validate_topological(self) -> None:
        """Nodes must be topologically ordered over tensor dependencies."""
        produced: set[int] = set(self.input_tensors)
        for nd in self.nodes:
            needs = list(nd.inputs) + ([nd.residual_input] if nd.residual_input is not None else [])
            for tid in needs:
                if tid not in produced:
                    raise ValueError(
                        f"node {nd.name} consumes tensor {tid} before production"
                    )
            produced.update(nd.outputs)

    def summary(self) -> str:
        gmacs = self.total_macs() / 1e9
        wmb = self.total_weight_bytes() / 1e6
        return (
            f"Graph {self.name}: {len(self.nodes)} nodes, "
            f"{gmacs:.2f} GMACs ({2*gmacs:.2f} GOPs), {wmb:.1f} MB weights"
        )
