"""DNN graph builders for the compilation framework.

ResNet-50 (the paper's benchmark, input 256x256 per Table III footnote),
small synthetic CNNs for tests, and transformer encoders (ViT for the vision
analogue of ResNet-50, LLM block stacks parameterized from ``repro.configs``).
Graphs are built *unfused* (separate Conv / Add / activation nodes, BN folded
into conv weights as usual for INT8 deployment); ``repro.compiler.fusion``
then applies the hardware-aware fusion of Fig. 4(b) extended with the
proj->activation and GEMM->residual-add rules.

Transformer lowering notes: token tensors are (S, D) INT8 activations;
attention scores are (H, S, S). Q/K/V/output projections and FFN matrices are
PROJ GEMMs (weights through URAM, SMOF-streamed when oversized); the score
and context GEMMs are ATTN_* ops whose second operand is an *activation*
streamed through the SA weight port; layernorm / softmax / gating run in the
PU vector units like ReLU and the pools. Embedding lookup, position adds and
the cls token are host-side (free) and omitted.

Autoregressive decode (``transformer_decoder``): one program round processes
one new token; per-block K/V caches are append-only HBM regions
(``TensorInfo.kv_base_rows``) whose attention streams advance in *length*
every round (AddrLen/CYCLE_LEN) — the serving-phase counterpart of the
prefill graphs above.
"""
from __future__ import annotations

from .graph import Graph, OpType, TensorInfo


def _conv(g: Graph, x: TensorInfo, out_ch: int, k: int, stride: int, pad: int,
          name: str) -> TensorInfo:
    c, h, w = x.shape
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    out = g.add_tensor(f"{name}.out", (out_ch, oh, ow))
    g.add_node(
        name=name,
        op=OpType.CONV,
        inputs=[x.tid],
        outputs=[out.tid],
        m=out_ch,
        n=oh * ow,
        k=c * k * k,
        kernel=(k, k),
        stride=(stride, stride),
        padding=(pad, pad),
        scale_shift=7,
    )
    return out


def _relu(g: Graph, x: TensorInfo, name: str) -> TensorInfo:
    out = g.add_tensor(f"{name}.out", x.shape)
    g.add_node(name=name, op=OpType.RELU, inputs=[x.tid], outputs=[out.tid])
    return out


def _add(g: Graph, a: TensorInfo, b: TensorInfo, name: str) -> TensorInfo:
    out = g.add_tensor(f"{name}.out", a.shape)
    g.add_node(name=name, op=OpType.ADD, inputs=[a.tid, b.tid], outputs=[out.tid])
    return out


def _maxpool(g: Graph, x: TensorInfo, k: int, stride: int, pad: int, name: str) -> TensorInfo:
    c, h, w = x.shape
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    out = g.add_tensor(f"{name}.out", (c, oh, ow))
    g.add_node(
        name=name,
        op=OpType.MAXPOOL,
        inputs=[x.tid],
        outputs=[out.tid],
        m=c,
        n=oh * ow,
        k=k * k,  # vector-unit work per output element
        kernel=(k, k),
        stride=(stride, stride),
        padding=(pad, pad),
    )
    return out


def _gap(g: Graph, x: TensorInfo, name: str) -> TensorInfo:
    c, h, w = x.shape
    out = g.add_tensor(f"{name}.out", (c, 1, 1))
    g.add_node(name=name, op=OpType.AVGPOOL, inputs=[x.tid], outputs=[out.tid],
               m=c, n=1, k=h * w)
    return out


def _fc(g: Graph, x: TensorInfo, out_features: int, name: str) -> TensorInfo:
    in_features = 1
    for d in x.shape:
        in_features *= d
    out = g.add_tensor(f"{name}.out", (out_features,))
    g.add_node(name=name, op=OpType.FC, inputs=[x.tid], outputs=[out.tid],
               m=out_features, n=1, k=in_features, scale_shift=7)
    return out


def _bottleneck(g: Graph, x: TensorInfo, mid: int, out_ch: int, stride: int,
                name: str) -> TensorInfo:
    """ResNet-v1 bottleneck: 1x1 -> 3x3 -> 1x1 + shortcut, ReLU after add."""
    in_ch = x.shape[0]
    # shortcut first: the fused Conv+Add node (at conv3's position) consumes
    # it, so it must precede conv3 in the topological order.
    if stride != 1 or in_ch != out_ch:
        sc = _conv(g, x, out_ch, 1, stride, 0, f"{name}.downsample")
    else:
        sc = x
    a = _relu(g, _conv(g, x, mid, 1, 1, 0, f"{name}.conv1"), f"{name}.relu1")
    b = _relu(g, _conv(g, a, mid, 3, stride, 1, f"{name}.conv2"), f"{name}.relu2")
    c = _conv(g, b, out_ch, 1, 1, 0, f"{name}.conv3")
    s = _add(g, c, sc, f"{name}.add")
    return _relu(g, s, f"{name}.relu3")


def resnet50(input_hw: int = 256) -> Graph:
    """ResNet-50, INT8, NCHW (C,H,W tensors; batch handled per program round).

    At 224x224 this graph has the canonical ~3.9 GMACs (7.7 GOPs); the paper
    evaluates with 256x256 inputs."""
    g = Graph(name=f"resnet50_{input_hw}")
    x = g.add_tensor("input", (3, input_hw, input_hw))
    g.input_tensors = [x.tid]

    t = _relu(g, _conv(g, x, 64, 7, 2, 3, "conv1"), "relu1")
    t = _maxpool(g, t, 3, 2, 1, "maxpool")

    spec = [  # (blocks, mid, out, first_stride)
        (3, 64, 256, 1),
        (4, 128, 512, 2),
        (6, 256, 1024, 2),
        (3, 512, 2048, 2),
    ]
    for stage_idx, (blocks, mid, out_ch, stride0) in enumerate(spec, start=1):
        for b in range(blocks):
            t = _bottleneck(g, t, mid, out_ch, stride0 if b == 0 else 1,
                            f"layer{stage_idx}.{b}")

    t = _gap(g, t, "gap")
    t = _fc(g, t, 1000, "fc")
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g


def tiny_cnn(channels: tuple[int, ...] = (8, 16, 16), hw: int = 16,
             residual: bool = True) -> Graph:
    """Small CNN with one residual connection — compiler/simulator tests."""
    g = Graph(name="tiny_cnn")
    x = g.add_tensor("input", (channels[0], hw, hw))
    g.input_tensors = [x.tid]
    t = _relu(g, _conv(g, x, channels[1], 3, 1, 1, "c0"), "r0")
    skip = t
    t = _relu(g, _conv(g, t, channels[2], 3, 1, 1, "c1"), "r1")
    t = _conv(g, t, channels[1], 3, 1, 1, "c2")
    if residual:
        t = _add(g, t, skip, "add")
    t = _relu(g, t, "r2")
    t = _fc(g, t, 10, "fc")
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g


# ------------------------------------------------------- transformer zoo --
def _proj(g: Graph, x: TensorInfo, out_features: int, name: str) -> TensorInfo:
    """Projection GEMM on token tensor x: (S, D) -> (S, out_features)."""
    s, d = x.shape
    assert out_features <= 4095, f"{name}: Compute.M is 12 bits ({out_features})"
    assert d <= 16383, f"{name}: Compute.K is 14 bits ({d})"
    out = g.add_tensor(f"{name}.out", (s, out_features))
    g.add_node(name=name, op=OpType.PROJ, inputs=[x.tid], outputs=[out.tid],
               m=out_features, n=s, k=d, scale_shift=7)
    return out


def _layernorm(g: Graph, x: TensorInfo, name: str) -> TensorInfo:
    s, d = x.shape
    out = g.add_tensor(f"{name}.out", x.shape)
    g.add_node(name=name, op=OpType.LAYERNORM, inputs=[x.tid], outputs=[out.tid],
               m=1, n=s, k=d)
    return out


def _vec_act(g: Graph, x: TensorInfo, name: str, act: str = "gelu") -> TensorInfo:
    """Vector-unit activation node (gelu/silu); fusion folds it into the
    preceding PROJ the way ReLU folds into Conv."""
    s, d = x.shape
    out = g.add_tensor(f"{name}.out", x.shape)
    g.add_node(name=name, op=OpType.GELU, inputs=[x.tid], outputs=[out.tid],
               m=1, n=s, k=d, attrs={"act": act})
    return out


def _mul(g: Graph, a: TensorInfo, b: TensorInfo, name: str) -> TensorInfo:
    s, d = a.shape
    out = g.add_tensor(f"{name}.out", a.shape)
    g.add_node(name=name, op=OpType.MUL, inputs=[a.tid, b.tid], outputs=[out.tid],
               m=1, n=s, k=d)
    return out


def _token_add(g: Graph, a: TensorInfo, b: TensorInfo, name: str) -> TensorInfo:
    s, d = a.shape
    out = g.add_tensor(f"{name}.out", a.shape)
    g.add_node(name=name, op=OpType.ADD, inputs=[a.tid, b.tid], outputs=[out.tid],
               m=1, n=s, k=d)
    return out


def _attention(g: Graph, x: TensorInfo, heads: int, kv_heads: int, head_dim: int,
               name: str) -> TensorInfo:
    """Multi-head (optionally grouped-query) self-attention on (S, D) tokens.

    Q/K/V and the output projection are PROJ GEMMs. The score GEMM
    (Q @ K^T per head, M=S, N=H*S, K=head_dim) and the context GEMM
    (softmax(S) @ V, M=head_dim, N=H*S, K=S) take their second operand from
    an activation tensor streamed through the SA weight port; softmax runs in
    the vector units. MACs: H*S^2*hd each for score and context."""
    s, d = x.shape
    assert s <= 4095, f"{name}: score-GEMM M (seq) is 12 bits ({s})"
    assert heads * s <= 65535, \
        f"{name}: score/context-GEMM N (heads*seq) is 16 bits ({heads * s})"
    q = _proj(g, x, heads * head_dim, f"{name}.wq")
    k = _proj(g, x, kv_heads * head_dim, f"{name}.wk")
    v = _proj(g, x, kv_heads * head_dim, f"{name}.wv")

    scores = g.add_tensor(f"{name}.scores", (heads, s, s))
    g.add_node(name=f"{name}.score", op=OpType.ATTN_SCORE,
               inputs=[q.tid, k.tid], outputs=[scores.tid],
               m=s, n=heads * s, k=head_dim, scale_shift=7)
    probs = g.add_tensor(f"{name}.probs", (heads, s, s))
    g.add_node(name=f"{name}.softmax", op=OpType.SOFTMAX,
               inputs=[scores.tid], outputs=[probs.tid],
               m=1, n=heads * s, k=s)
    ctx = g.add_tensor(f"{name}.ctx", (s, heads * head_dim))
    g.add_node(name=f"{name}.context", op=OpType.ATTN_CONTEXT,
               inputs=[probs.tid, v.tid], outputs=[ctx.tid],
               m=head_dim, n=heads * s, k=s, scale_shift=7)
    return _proj(g, ctx, d, f"{name}.wo")


def _ffn(g: Graph, h: TensorInfo, d_model: int, d_ff: int, mlp: str,
         name: str) -> TensorInfo:
    """Pre-norm FFN sub-block: LN -> (gated) MLP -> +res, shared by the
    prefill encoder and decode blocks."""
    t = _layernorm(g, h, f"{name}.ln2")
    if mlp in ("swiglu", "geglu"):
        act = "silu" if mlp == "swiglu" else "gelu"
        gate = _vec_act(g, _proj(g, t, d_ff, f"{name}.ffn.gate"),
                        f"{name}.ffn.{act}", act=act)
        up = _proj(g, t, d_ff, f"{name}.ffn.up")
        t = _mul(g, gate, up, f"{name}.ffn.mul")
    else:
        t = _vec_act(g, _proj(g, t, d_ff, f"{name}.ffn.up"), f"{name}.ffn.act")
    down = _proj(g, t, d_model, f"{name}.ffn.down")
    return _token_add(g, down, h, f"{name}.add2")


def _encoder_block(g: Graph, x: TensorInfo, heads: int, kv_heads: int,
                   head_dim: int, d_ff: int, mlp: str, name: str) -> TensorInfo:
    """Pre-norm encoder block: LN -> MHA -> +res -> LN -> FFN -> +res."""
    attn_out = _attention(g, _layernorm(g, x, f"{name}.ln1"), heads, kv_heads,
                          head_dim, f"{name}.attn")
    h = _token_add(g, attn_out, x, f"{name}.add1")
    return _ffn(g, h, x.shape[1], d_ff, mlp, name)


def vit(input_hw: int = 224, *, patch: int = 16, d_model: int = 768,
        depth: int = 12, heads: int = 12, d_ff: int = 3072,
        n_classes: int = 1000) -> Graph:
    """ViT-Base/16 (default): the vision analogue of ResNet-50 on the same
    GEMM-centric ISA. Patch embedding is an IM2COL GEMM over 16x16x3
    patches; then ``depth`` pre-norm encoder blocks, mean-pool, classifier."""
    assert input_hw % patch == 0
    n_tokens = (input_hw // patch) ** 2
    assert n_tokens <= 4095, f"token count {n_tokens} exceeds the 12-bit M field"
    g = Graph(name=f"vit{depth}_{input_hw}")
    img = g.add_tensor("input", (3, input_hw, input_hw))
    g.input_tensors = [img.tid]

    # patch embed: conv k=patch s=patch lowered as an IM2COL projection GEMM
    tok = g.add_tensor("patch_embed.out", (n_tokens, d_model))
    g.add_node(name="patch_embed", op=OpType.PROJ,
               inputs=[img.tid], outputs=[tok.tid],
               m=d_model, n=n_tokens, k=3 * patch * patch,
               kernel=(patch, patch), stride=(patch, patch), scale_shift=7)

    t = tok
    for i in range(depth):
        t = _encoder_block(g, t, heads, heads, d_model // heads, d_ff,
                           "gelu", f"block{i}")
    t = _layernorm(g, t, "ln_f")

    pooled = g.add_tensor("pool.out", (d_model,))
    g.add_node(name="pool", op=OpType.AVGPOOL, inputs=[t.tid],
               outputs=[pooled.tid], m=d_model, n=1, k=n_tokens)
    head = _fc(g, pooled, n_classes, "head")
    g.output_tensors = [head.tid]
    g.validate_topological()
    return g


def transformer_encoder(arch="qwen3-0.6b", *, seq_len: int = 256,
                        depth: int | None = None) -> Graph:
    """Decoder-block stack of a ``repro.configs`` architecture as a prefill
    graph: ``depth`` (default: the config's layer count) blocks over a
    (seq_len, d_model) token tensor. ``arch`` is a config name or an
    ``ArchConfig`` instance (e.g. ``get_config("gemma3-4b").reduced()`` for
    architectures whose full dims exceed the ISA field widths). Embedding
    lookup / lm_head stay on the host; causality does not change GEMM shapes
    at this fidelity."""
    from ..configs import get_config

    cfg = get_config(arch) if isinstance(arch, str) else arch
    n_layers = depth if depth is not None else cfg.num_layers
    assert seq_len <= 4095, "ATTN_SCORE M field is 12 bits"
    g = Graph(name=f"{cfg.name.replace('.', '_')}_enc{n_layers}_s{seq_len}")
    x = g.add_tensor("input", (seq_len, cfg.d_model))
    g.input_tensors = [x.tid]

    t = x
    for i in range(n_layers):
        t = _encoder_block(g, t, cfg.num_heads, cfg.num_kv_heads,
                           cfg.resolved_head_dim, cfg.d_ff, cfg.mlp,
                           f"block{i}")
    t = _layernorm(g, t, "ln_f")
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g


# ------------------------------------------------- autoregressive decode --
def _decode_attention(g: Graph, x: TensorInfo, heads: int, kv_heads: int,
                      head_dim: int, base_rows: int, steps: int,
                      name: str) -> TensorInfo:
    """Single-token self-attention against growing K/V cache regions.

    One program round = one decode step. The new token's K/V rows are
    *appended* to per-block cache regions (``kv_base_rows`` rows hold the
    prefill prefix); the score and context GEMMs stream the cache through
    the SA weight port with a per-round advancing length (AddrLen/CYCLE_LEN).
    GEMM dims are *static* in the ISA, so score/context encode the decode
    window's average cache length — the analytic model and the instruction
    stream agree on per-round compute by construction, while the HBM traffic
    executes the true advancing-length semantics."""
    s, d = x.shape
    assert s == 1, f"{name}: decode processes one token per round"
    kv_dim = kv_heads * head_dim
    l_max = base_rows + steps
    n_avg = max(1, round(base_rows + (steps + 1) / 2))  # mean cache length
    assert l_max <= 16383, f"{name}: context-GEMM K (cache len) is 14 bits"
    assert heads * n_avg <= 65535, f"{name}: score-GEMM N is 16 bits"

    q = _proj(g, x, heads * head_dim, f"{name}.wq")
    kcache = g.add_tensor(f"{name}.kcache", (l_max, kv_dim),
                          kv_base_rows=base_rows)
    g.add_node(name=f"{name}.wk", op=OpType.PROJ, inputs=[x.tid],
               outputs=[kcache.tid], m=kv_dim, n=1, k=d, scale_shift=7)
    vcache = g.add_tensor(f"{name}.vcache", (l_max, kv_dim),
                          kv_base_rows=base_rows)
    g.add_node(name=f"{name}.wv", op=OpType.PROJ, inputs=[x.tid],
               outputs=[vcache.tid], m=kv_dim, n=1, k=d, scale_shift=7)

    scores = g.add_tensor(f"{name}.scores", (heads, l_max))
    g.add_node(name=f"{name}.score", op=OpType.ATTN_SCORE,
               inputs=[q.tid, kcache.tid], outputs=[scores.tid],
               m=1, n=heads * n_avg, k=head_dim, scale_shift=7)
    probs = g.add_tensor(f"{name}.probs", (heads, l_max))
    g.add_node(name=f"{name}.softmax", op=OpType.SOFTMAX,
               inputs=[scores.tid], outputs=[probs.tid],
               m=1, n=heads, k=n_avg)
    ctx = g.add_tensor(f"{name}.ctx", (1, heads * head_dim))
    g.add_node(name=f"{name}.context", op=OpType.ATTN_CONTEXT,
               inputs=[probs.tid, vcache.tid], outputs=[ctx.tid],
               m=head_dim, n=heads, k=n_avg, scale_shift=7)
    return _proj(g, ctx, d, f"{name}.wo")


def _decoder_block(g: Graph, x: TensorInfo, heads: int, kv_heads: int,
                   head_dim: int, d_ff: int, mlp: str, base_rows: int,
                   steps: int, name: str) -> TensorInfo:
    """Pre-norm decode block: LN -> cached MHA -> +res -> LN -> FFN -> +res."""
    attn_out = _decode_attention(g, _layernorm(g, x, f"{name}.ln1"), heads,
                                 kv_heads, head_dim, base_rows, steps,
                                 f"{name}.attn")
    h = _token_add(g, attn_out, x, f"{name}.add1")
    return _ffn(g, h, x.shape[1], d_ff, mlp, name)


def _packed_decode_attention(g: Graph, x: TensorInfo, heads: int, kv_heads: int,
                             head_dim: int, slot_rows: tuple[int, ...],
                             steps: int, name: str) -> TensorInfo:
    """Slot-packed self-attention: S concurrent decode sessions, one token
    each per round, against *independent per-slot* K/V cache regions.

    Generalizes :func:`_decode_attention`'s single LEN counter to one
    AddrLen length stream per slot: each session j carries its own prefix
    depth ``slot_rows[j]``, so its cache tensor gets its own
    ``kv_base_rows`` and therefore its own advancing-length read stream and
    append cursor in the compiled programs. The Q/K/V and output projections
    batch all S tokens through one GEMM (N=S) — the continuous-batching
    win: resident weights are streamed once per round for the whole pack —
    while score/softmax/context stay per-slot (each attends over its own
    prefix). A CONCAT vector op gathers the per-slot context rows back into
    the (S, H*hd) token tensor for the shared output projection.

    Per-slot score/context nodes read the full packed Q region at this
    fidelity (one row is live per slot); LD-side traffic of the tiny Q/ctx
    tensors is charged identically by the analytic model and the simulator,
    so conformance is unaffected."""
    s, d = x.shape
    assert s == len(slot_rows), f"{name}: one token per packed slot"
    kv_dim = kv_heads * head_dim

    q = _proj(g, x, heads * head_dim, f"{name}.wq")

    kcaches, vcaches = [], []
    for j, rows in enumerate(slot_rows):
        l_max = rows + steps
        assert l_max <= 16383, \
            f"{name}: slot {j} cache length is 14 bits ({l_max})"
        kcaches.append(g.add_tensor(f"{name}.kcache{j}", (l_max, kv_dim),
                                    kv_base_rows=rows))
        vcaches.append(g.add_tensor(f"{name}.vcache{j}", (l_max, kv_dim),
                                    kv_base_rows=rows))
    # One projection GEMM computes all S new K (resp. V) rows; the store
    # side appends row j to slot j's region (multi-output broadcast store,
    # one row-sized DataMove per slot with the hold bit chaining them).
    g.add_node(name=f"{name}.wk", op=OpType.PROJ, inputs=[x.tid],
               outputs=[kc.tid for kc in kcaches],
               m=kv_dim, n=s, k=d, scale_shift=7)
    g.add_node(name=f"{name}.wv", op=OpType.PROJ, inputs=[x.tid],
               outputs=[vc.tid for vc in vcaches],
               m=kv_dim, n=s, k=d, scale_shift=7)

    ctxs = []
    for j, rows in enumerate(slot_rows):
        l_max = rows + steps
        n_avg = max(1, round(rows + (steps + 1) / 2))  # slot j mean length
        assert heads * n_avg <= 65535, \
            f"{name}: slot {j} score-GEMM N is 16 bits"
        scores = g.add_tensor(f"{name}.scores{j}", (heads, l_max))
        g.add_node(name=f"{name}.score{j}", op=OpType.ATTN_SCORE,
                   inputs=[q.tid, kcaches[j].tid], outputs=[scores.tid],
                   m=1, n=heads * n_avg, k=head_dim, scale_shift=7)
        probs = g.add_tensor(f"{name}.probs{j}", (heads, l_max))
        g.add_node(name=f"{name}.softmax{j}", op=OpType.SOFTMAX,
                   inputs=[scores.tid], outputs=[probs.tid],
                   m=1, n=heads, k=n_avg)
        ctx = g.add_tensor(f"{name}.ctx{j}", (1, heads * head_dim))
        g.add_node(name=f"{name}.context{j}", op=OpType.ATTN_CONTEXT,
                   inputs=[probs.tid, vcaches[j].tid], outputs=[ctx.tid],
                   m=head_dim, n=heads, k=n_avg, scale_shift=7)
        ctxs.append(ctx)

    if s == 1:
        cat = ctxs[0]
    else:
        cat = g.add_tensor(f"{name}.ctxcat", (s, heads * head_dim))
        g.add_node(name=f"{name}.concat", op=OpType.CONCAT,
                   inputs=[c.tid for c in ctxs], outputs=[cat.tid],
                   m=1, n=s, k=heads * head_dim)
    return _proj(g, cat, d, f"{name}.wo")


def _packed_decoder_block(g: Graph, x: TensorInfo, heads: int, kv_heads: int,
                          head_dim: int, d_ff: int, mlp: str,
                          slot_rows: tuple[int, ...], steps: int,
                          name: str) -> TensorInfo:
    """Pre-norm packed decode block: LN -> slot-packed MHA -> +res -> FFN."""
    attn_out = _packed_decode_attention(g, _layernorm(g, x, f"{name}.ln1"),
                                        heads, kv_heads, head_dim, slot_rows,
                                        steps, f"{name}.attn")
    h = _token_add(g, attn_out, x, f"{name}.add1")
    return _ffn(g, h, x.shape[1], d_ff, mlp, name)


def transformer_decoder(arch="qwen3-0.6b", *, seq_len: int = 256,
                        decode_steps: int = 64,
                        depth: int | None = None,
                        slots: tuple[int, ...] | None = None) -> Graph:
    """The decode half of the prefill->decode serving pair: ``depth`` blocks
    processing *one new token per program round* against per-block K/V cache
    regions pre-filled with ``seq_len`` tokens (the matching prefill graph is
    ``transformer_encoder(arch, seq_len=seq_len, depth=depth)`` — a running
    :class:`repro.deploy.System` hot-swaps between the two with no
    reconfiguration). ``decode_steps`` sizes the append-only cache window:
    round r attends over ``seq_len + r + 1`` tokens, and deployments of this
    graph default to ``decode_steps`` rounds (one full decode pass).

    ``slots`` packs S concurrent decode sessions at *different* cache depths
    into the same graph (continuous batching): ``slots=(l0, l1, ...)`` gives
    session j a private per-block K/V cache pre-filled with ``l_j`` tokens
    (``seq_len`` is ignored), batches the weighted projections across all S
    tokens, and keeps attention per-slot via independent AddrLen length
    streams — see :func:`_packed_decode_attention`."""
    from ..configs import get_config

    cfg = get_config(arch) if isinstance(arch, str) else arch
    n_layers = depth if depth is not None else cfg.num_layers
    assert 1 <= decode_steps <= 128, \
        "decode window exceeds the 7-bit AddrCyc NC field (cache append side)"
    if slots is None:
        assert seq_len + decode_steps <= 16383, \
            "max cache length exceeds the 14-bit context-GEMM K field"
        g = Graph(name=f"{cfg.name.replace('.', '_')}_dec{n_layers}"
                       f"_s{seq_len}x{decode_steps}")
        g.attrs.update(phase="decode", prefill_len=seq_len,
                       decode_steps=decode_steps)
        x = g.add_tensor("input", (1, cfg.d_model))
        g.input_tensors = [x.tid]

        t = x
        for i in range(n_layers):
            t = _decoder_block(g, t, cfg.num_heads, cfg.num_kv_heads,
                               cfg.resolved_head_dim, cfg.d_ff, cfg.mlp,
                               seq_len, decode_steps, f"block{i}")
        t = _layernorm(g, t, "ln_f")
        g.output_tensors = [t.tid]
        g.validate_topological()
        return g

    slot_rows = tuple(int(r) for r in slots)
    assert slot_rows and all(r >= 1 for r in slot_rows), \
        "each packed slot needs a non-empty prefill prefix"
    assert len(slot_rows) <= 64, "packed slot count is bounded at 64"
    g = Graph(name=f"{cfg.name.replace('.', '_')}_dec{n_layers}"
                   f"_p{'+'.join(str(r) for r in slot_rows)}x{decode_steps}")
    g.attrs.update(phase="decode", prefill_len=max(slot_rows),
                   decode_steps=decode_steps, slot_prefix_rows=slot_rows)
    x = g.add_tensor("input", (len(slot_rows), cfg.d_model))
    g.input_tensors = [x.tid]

    t = x
    for i in range(n_layers):
        t = _packed_decoder_block(g, t, cfg.num_heads, cfg.num_kv_heads,
                                  cfg.resolved_head_dim, cfg.d_ff, cfg.mlp,
                                  slot_rows, decode_steps, f"block{i}")
    t = _layernorm(g, t, "ln_f")
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g


def linear_chain(n_convs: int = 6, ch: int = 32, hw: int = 32) -> Graph:
    """Plain conv chain (no residuals) — partitioner unit tests."""
    g = Graph(name=f"chain{n_convs}")
    x = g.add_tensor("input", (ch, hw, hw))
    g.input_tensors = [x.tid]
    t = x
    for i in range(n_convs):
        t = _relu(g, _conv(g, t, ch, 3, 1, 1, f"c{i}"), f"r{i}")
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g
