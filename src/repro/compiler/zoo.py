"""DNN graph builders for the compilation framework.

ResNet-50 (the paper's benchmark, input 256x256 per Table III footnote) plus
small synthetic CNNs for tests. Graphs are built *unfused* (separate Conv /
Add / ReLU nodes, BN folded into conv weights as usual for INT8 deployment);
``repro.compiler.fusion`` then applies the hardware-aware fusion of Fig. 4(b).
"""
from __future__ import annotations

from .graph import Graph, Node, OpType, TensorInfo


def _conv(g: Graph, x: TensorInfo, out_ch: int, k: int, stride: int, pad: int,
          name: str) -> TensorInfo:
    c, h, w = x.shape
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    out = g.add_tensor(f"{name}.out", (out_ch, oh, ow))
    g.add_node(
        name=name,
        op=OpType.CONV,
        inputs=[x.tid],
        outputs=[out.tid],
        m=out_ch,
        n=oh * ow,
        k=c * k * k,
        kernel=(k, k),
        stride=(stride, stride),
        padding=(pad, pad),
        scale_shift=7,
    )
    return out


def _relu(g: Graph, x: TensorInfo, name: str) -> TensorInfo:
    out = g.add_tensor(f"{name}.out", x.shape)
    g.add_node(name=name, op=OpType.RELU, inputs=[x.tid], outputs=[out.tid])
    return out


def _add(g: Graph, a: TensorInfo, b: TensorInfo, name: str) -> TensorInfo:
    out = g.add_tensor(f"{name}.out", a.shape)
    g.add_node(name=name, op=OpType.ADD, inputs=[a.tid, b.tid], outputs=[out.tid])
    return out


def _maxpool(g: Graph, x: TensorInfo, k: int, stride: int, pad: int, name: str) -> TensorInfo:
    c, h, w = x.shape
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    out = g.add_tensor(f"{name}.out", (c, oh, ow))
    g.add_node(
        name=name,
        op=OpType.MAXPOOL,
        inputs=[x.tid],
        outputs=[out.tid],
        m=c,
        n=oh * ow,
        k=k * k,  # vector-unit work per output element
        kernel=(k, k),
        stride=(stride, stride),
        padding=(pad, pad),
    )
    return out


def _gap(g: Graph, x: TensorInfo, name: str) -> TensorInfo:
    c, h, w = x.shape
    out = g.add_tensor(f"{name}.out", (c, 1, 1))
    g.add_node(name=name, op=OpType.AVGPOOL, inputs=[x.tid], outputs=[out.tid],
               m=c, n=1, k=h * w)
    return out


def _fc(g: Graph, x: TensorInfo, out_features: int, name: str) -> TensorInfo:
    in_features = 1
    for d in x.shape:
        in_features *= d
    out = g.add_tensor(f"{name}.out", (out_features,))
    g.add_node(name=name, op=OpType.FC, inputs=[x.tid], outputs=[out.tid],
               m=out_features, n=1, k=in_features, scale_shift=7)
    return out


def _bottleneck(g: Graph, x: TensorInfo, mid: int, out_ch: int, stride: int,
                name: str) -> TensorInfo:
    """ResNet-v1 bottleneck: 1x1 -> 3x3 -> 1x1 + shortcut, ReLU after add."""
    in_ch = x.shape[0]
    # shortcut first: the fused Conv+Add node (at conv3's position) consumes
    # it, so it must precede conv3 in the topological order.
    if stride != 1 or in_ch != out_ch:
        sc = _conv(g, x, out_ch, 1, stride, 0, f"{name}.downsample")
    else:
        sc = x
    a = _relu(g, _conv(g, x, mid, 1, 1, 0, f"{name}.conv1"), f"{name}.relu1")
    b = _relu(g, _conv(g, a, mid, 3, stride, 1, f"{name}.conv2"), f"{name}.relu2")
    c = _conv(g, b, out_ch, 1, 1, 0, f"{name}.conv3")
    s = _add(g, c, sc, f"{name}.add")
    return _relu(g, s, f"{name}.relu3")


def resnet50(input_hw: int = 256) -> Graph:
    """ResNet-50, INT8, NCHW (C,H,W tensors; batch handled per program round).

    At 224x224 this graph has the canonical ~3.9 GMACs (7.7 GOPs); the paper
    evaluates with 256x256 inputs."""
    g = Graph(name=f"resnet50_{input_hw}")
    x = g.add_tensor("input", (3, input_hw, input_hw))
    g.input_tensors = [x.tid]

    t = _relu(g, _conv(g, x, 64, 7, 2, 3, "conv1"), "relu1")
    t = _maxpool(g, t, 3, 2, 1, "maxpool")

    spec = [  # (blocks, mid, out, first_stride)
        (3, 64, 256, 1),
        (4, 128, 512, 2),
        (6, 256, 1024, 2),
        (3, 512, 2048, 2),
    ]
    for stage_idx, (blocks, mid, out_ch, stride0) in enumerate(spec, start=1):
        for b in range(blocks):
            t = _bottleneck(g, t, mid, out_ch, stride0 if b == 0 else 1,
                            f"layer{stage_idx}.{b}")

    t = _gap(g, t, "gap")
    t = _fc(g, t, 1000, "fc")
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g


def tiny_cnn(channels: tuple[int, ...] = (8, 16, 16), hw: int = 16,
             residual: bool = True) -> Graph:
    """Small CNN with one residual connection — compiler/simulator tests."""
    g = Graph(name="tiny_cnn")
    x = g.add_tensor("input", (channels[0], hw, hw))
    g.input_tensors = [x.tid]
    t = _relu(g, _conv(g, x, channels[1], 3, 1, 1, "c0"), "r0")
    skip = t
    t = _relu(g, _conv(g, t, channels[2], 3, 1, 1, "c1"), "r1")
    t = _conv(g, t, channels[1], 3, 1, 1, "c2")
    if residual:
        t = _add(g, t, skip, "add")
    t = _relu(g, t, "r2")
    t = _fc(g, t, 10, "fc")
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g


def linear_chain(n_convs: int = 6, ch: int = 32, hw: int = 32) -> Graph:
    """Plain conv chain (no residuals) — partitioner unit tests."""
    g = Graph(name=f"chain{n_convs}")
    x = g.add_tensor("input", (ch, hw, hw))
    g.input_tensors = [x.tid]
    t = x
    for i in range(n_convs):
        t = _relu(g, _conv(g, t, ch, 3, 1, 1, f"c{i}"), f"r{i}")
    g.output_tensors = [t.tid]
    g.validate_topological()
    return g
