"""Pipeline memory optimization (paper Sec. IV-C, Fig. 4(e)).

1. Buffer requirement analysis (stage-distance method): for each tensor T,
   map producer/consumer PUs to pipeline stages and compute

       beta(T) = max over consumers (stage_c - stage_p) + 1

   The +1 buffer lets producers write new data while consumers read
   previously loaded data. Graph inputs/outputs (A/C-regions) get ``n_io``
   cyclic regions coordinated with the PCIe host. K/V cache tensors
   (autoregressive decode) keep the stage-distance *credit* depth for the
   REQ/ACK handshake but occupy a single append-only region sized for the
   full window — per-round writes append one row while reads cover the
   growing valid prefix, so no region copies are needed.

2. Tensor liveness analysis: simulate the steady-state pipeline schedule
   (node-to-PU mappings x profiled times) to find the temporal access window
   of every tensor; tensors with overlapping same-type accesses (read-read /
   write-write) — and cross-PU forks feeding one consumer — must land on
   different HBM channels [33]. Greedy interval-graph coloring assigns
   channels; each PU also gets a dedicated weight-streaming channel.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional

from ..core.pu import N_HBM_CHANNELS
from .graph import Graph
from .partition import Partition
from .profiler import NodeProfile


@dataclass
class TensorPlan:
    tid: int
    beta: int  # number of cyclic buffer regions (sync credit depth)
    region_bytes: int  # 64B-aligned size of one region
    base_addr: int = 0  # HBM base of region 0
    bid_base: int = 0  # global BID range [bid_base, bid_base+beta-1]
    read_channel: int = 0
    write_channel: int = 0
    producer_stage: Optional[int] = None
    consumer_stages: tuple[int, ...] = ()
    kind: str = "intermediate"  # "input" | "output" | "intermediate" | "kv"

    @property
    def n_regions(self) -> int:
        """Physical HBM regions. A K/V cache is *one* append-only region
        regardless of its sync credit depth: rows written this round are
        disjoint from the prefix earlier rounds read, so the REQ/ACK credits
        (beta) pipeline producer and consumer without region copies."""
        return 1 if self.kind == "kv" else self.beta


@dataclass
class MemoryPlan:
    tensors: dict[int, TensorPlan]
    weight_channel: dict[int, int]  # stage index -> dedicated channel
    total_hbm_bytes: int
    n_channels_used: int

    def plan_of(self, tid: int) -> TensorPlan:
        return self.tensors[tid]


def buffer_requirements(g: Graph, part: Partition, n_io: int = 4) -> dict[int, TensorPlan]:
    stage_of = part.stage_of_node()
    plans: dict[int, TensorPlan] = {}
    for tid, tinfo in g.tensors.items():
        producer = g.producer_of(tid)
        consumers = g.consumers_of(tid)
        if tinfo.is_kv_cache and (tid in g.input_tensors or tid in g.output_tensors):
            # host A/C-region cycling (n_io regions) and append-only
            # single-region addressing are mutually exclusive
            raise ValueError(
                f"K/V cache tensor {tinfo.name!r} cannot be a graph input/output"
            )
        if tid in g.input_tensors:
            beta, kind = n_io, "input"
            pstage = None
            cstages = tuple(sorted({stage_of[c.nid] for c in consumers}))
        elif tid in g.output_tensors:
            beta, kind = n_io, "output"
            pstage = stage_of[producer.nid] if producer else None
            cstages = ()
        else:
            if producer is None or not consumers:
                continue  # dead tensor (fused away)
            pstage = stage_of[producer.nid]
            cstages = tuple(sorted({stage_of[c.nid] for c in consumers}))
            dist = max(cs - pstage for cs in cstages)
            beta = dist + 1
            kind = "kv" if tinfo.is_kv_cache else "intermediate"
        plans[tid] = TensorPlan(
            tid=tid,
            beta=beta,
            region_bytes=tinfo.kv_region_bytes if tinfo.is_kv_cache
            else tinfo.nbytes_padded,
            producer_stage=pstage,
            consumer_stages=cstages,
            kind=kind,
        )
    return plans


@dataclass(frozen=True)
class _Access:
    tid: int
    mode: str  # "r" | "w"
    start: float
    end: float
    stage: int


def _steady_state_accesses(
    g: Graph, part: Partition, profiles: dict[str, dict[int, NodeProfile]]
) -> list[_Access]:
    """Per-round access windows, all stages concurrent (steady state).

    Within a stage, node j's LD window precedes its compute; its ST window
    follows. Windows are folded modulo the round time (the max stage time)."""
    accesses: list[_Access] = []
    t_round = part.max_stage_time or 1e-9
    for s in part.stages:
        prof = profiles[s.pu_kind]
        t = 0.0
        for nid in s.nids:
            nd = g.node_by_id(nid)
            p = prof[nid]
            t_next = t + p.t_node
            for tid in nd.inputs:
                accesses.append(_Access(tid, "r", t % t_round, min(t + p.t_load, t_next) % t_round or t_round, s.index))
            if nd.residual_input is not None:
                accesses.append(_Access(nd.residual_input, "r", t % t_round, t_next % t_round or t_round, s.index))
            for tid in nd.outputs:
                st_start = max(t, t_next - p.t_store)
                accesses.append(_Access(tid, "w", st_start % t_round, t_next % t_round or t_round, s.index))
            t = t_next
    return accesses


def _windows_overlap(a: _Access, b: _Access, t_round: float) -> bool:
    """Overlap of two (possibly wrapped) circular intervals."""

    def unwrap(x: _Access) -> list[tuple[float, float]]:
        if x.end >= x.start:
            return [(x.start, x.end)]
        return [(x.start, t_round), (0.0, x.end)]

    for sa, ea in unwrap(a):
        for sb, eb in unwrap(b):
            if sa < eb and sb < ea:
                return True
    return False


def assign_channels(
    g: Graph,
    part: Partition,
    plans: dict[int, TensorPlan],
    profiles: dict[str, dict[int, NodeProfile]],
    n_channels: int = N_HBM_CHANNELS,
    channel_pool: Optional[list[int]] = None,
) -> MemoryPlan:
    """Liveness-driven channel coloring + address allocation.

    ``channel_pool`` restricts this deployment to a subset of the HBM
    channels — multi-batch schedules give each member pipeline a disjoint
    pool so that concurrent batches never contend (Sec. V-A)."""
    chans = channel_pool if channel_pool is not None else list(range(n_channels))
    n_stages = len(part.stages)
    # Dedicated weight-stream channel per stage (PU), from the pool front.
    n_wchan = max(1, min(n_stages, len(chans) // 2))
    weight_channel = {s.index: chans[s.index % n_wchan] for s in part.stages}
    first_tensor_channel = n_wchan if n_wchan < len(chans) - 4 else len(chans) // 2

    accesses = _steady_state_accesses(g, part, profiles)
    t_round = part.max_stage_time or 1e-9

    # Conflict graph over (tid, mode) access streams.
    streams = sorted({(a.tid, a.mode) for a in accesses if a.tid in plans})
    by_stream: dict[tuple[int, str], list[_Access]] = {s: [] for s in streams}
    for a in accesses:
        if (a.tid, a.mode) in by_stream:
            by_stream[(a.tid, a.mode)].append(a)

    conflicts: dict[tuple[int, str], set[tuple[int, str]]] = {s: set() for s in streams}
    for s1, s2 in itertools.combinations(streams, 2):
        # An HBM channel is one port: concurrent transfers serialize on it
        # regardless of direction, so *any* two streams with overlapping
        # steady-state windows — read-read, write-write, or read-write
        # (e.g. a stage's input fetch against its own output store, or a
        # producer's store against the consumer's load of the same tensor)
        # — must land on different channels or the round period stretches
        # by the full transfer time of whichever stream loses arbitration.
        hit = any(
            _windows_overlap(a, b, t_round)
            for a in by_stream[s1]
            for b in by_stream[s2]
        )
        if hit:
            conflicts[s1].add(s2)
            conflicts[s2].add(s1)

    # Cross-PU forks: tensors read by one consumer node from different
    # producers (primary + residual) must use distinct channels.
    for nd in g.nodes:
        ins = [t for t in nd.inputs if t in plans]
        if nd.residual_input is not None and nd.residual_input in plans:
            ins.append(nd.residual_input)
        for t1, t2 in itertools.combinations(ins, 2):
            s1, s2 = (t1, "r"), (t2, "r")
            if s1 in conflicts and s2 in conflicts:
                conflicts[s1].add(s2)
                conflicts[s2].add(s1)

    # Greedy coloring (highest degree first).
    color: dict[tuple[int, str], int] = {}
    pool = chans[first_tensor_channel:]
    if not pool:
        pool = list(chans)
    for s in sorted(streams, key=lambda s: -len(conflicts[s])):
        used = {color[o] for o in conflicts[s] if o in color}
        pick = next((c for c in pool if c not in used), None)
        if pick is None:
            # channel pressure: fall back to least-loaded color
            loads = {c: sum(1 for v in color.values() if v == c) for c in pool}
            pick = min(pool, key=lambda c: loads[c])
        color[s] = pick

    # Address allocation: bump allocator over the HBM space.
    addr = 0x0100_0000  # leave low space for weights/host scratch

    def align(x: int) -> int:
        return (x + 4095) // 4096 * 4096

    for tid, plan in sorted(plans.items()):
        plan.base_addr = addr
        addr += align(plan.region_bytes) * plan.n_regions
        plan.read_channel = color.get((tid, "r"), pool[0])
        plan.write_channel = color.get((tid, "w"), pool[-1])

    return MemoryPlan(
        tensors=plans,
        weight_channel=weight_channel,
        total_hbm_bytes=addr,
        n_channels_used=len(set(color.values())) if color else 0,
    )
