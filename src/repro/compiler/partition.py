"""Node-to-PU partitioning (paper Sec. IV-B, Fig. 4(d1)).

Dynamic programming partitions the topological order of the fused node DAG
into *contiguous* subgraphs, each mapped to one PU, minimizing the maximum
per-PU completion time (the pipeline stage time) while accounting for the
PU1x / PU2x heterogeneity via the profiled execution times.

State: f(i, u1, u2) = minimal achievable max-stage-time for nodes[i:] given
u1 PU1x and u2 PU2x units still available. Transition: give the next stage
nodes[i:j] on either PU type. O(N^2 * a * b) — trivially fast at DNN scale.

The state value is independent of the *total* budget a configuration starts
from, so one memo table serves every (a, b) of a DSE sweep: callers may pass
a shared ``memo`` dict (``repro.compiler.GraphAnalysis`` does) and config
(a', b') reuses every subproblem config (a, b) already solved.

The returned stage order interleaves PU types optimally; empty stages are
allowed (a configuration may leave PUs idle if that is optimal).
"""
from __future__ import annotations

from dataclasses import dataclass

from .graph import Graph
from .profiler import NodeProfile

INF = float("inf")


@dataclass(frozen=True)
class Stage:
    index: int  # pipeline stage position
    pu_kind: str  # "PU1x" | "PU2x"
    nids: tuple[int, ...]  # contiguous node ids (topological order)
    time: float  # profiled steady-state round time


@dataclass
class Partition:
    stages: list[Stage]
    node_order: list[int]

    @property
    def max_stage_time(self) -> float:
        return max((s.time for s in self.stages if s.nids), default=0.0)

    @property
    def n_used(self) -> int:
        return sum(1 for s in self.stages if s.nids)

    def stage_of_node(self) -> dict[int, int]:
        return {nid: s.index for s in self.stages for nid in s.nids}

    def pbe(self, capacity: dict[str, float]) -> float:
        """Pipeline balance efficiency (balance-factor form of [24]): the
        capacity-weighted busy fraction of the used PUs at steady state."""
        used = [s for s in self.stages if s.nids]
        if not used:
            return 0.0
        tmax = self.max_stage_time
        num = sum(s.time * capacity[s.pu_kind] for s in used)
        den = tmax * sum(capacity[s.pu_kind] for s in used)
        return num / den if den else 0.0


def partition(
    g: Graph,
    profiles: dict[str, dict[int, NodeProfile]],
    n_pu1x: int,
    n_pu2x: int,
    *,
    memo: dict | None = None,
) -> Partition:
    """DP partition of the fused graph onto (n_pu1x, n_pu2x) PUs.

    ``memo`` is an optional shared f(i, u1, u2) table; pass the same dict
    for repeated calls over the same (graph, profiles) — e.g. a Step-1
    enumeration — to reuse every overlapping subproblem across configs."""
    order = [nd.nid for nd in g.nodes]
    n = len(order)

    # prefix[kind][i] = cumulative node time of order[:i] on PU kind
    prefix: dict[str, list[float]] = {}
    for kind, prof in profiles.items():
        acc, run = [0.0], 0.0
        for nid in order:
            run += prof[nid].t_node
            acc.append(run)
        prefix[kind] = acc

    def seg_cost(kind: str, i: int, j: int) -> float:
        return prefix[kind][j] - prefix[kind][i]

    cache: dict[tuple[int, int, int], float] = memo if memo is not None else {}

    def f(i: int, u1: int, u2: int) -> float:
        if i >= n:
            return 0.0
        if u1 == 0 and u2 == 0:
            return INF
        key = (i, u1, u2)
        hit = cache.get(key)
        if hit is not None:
            return hit
        best = INF
        for kind, avail in (("PU1x", u1), ("PU2x", u2)):
            if not avail:
                continue
            nu1, nu2 = (u1 - 1, u2) if kind == "PU1x" else (u1, u2 - 1)
            row = prefix[kind]
            base = row[i]
            # j = end of this stage (exclusive); empty stages allowed.
            for j in range(i, n + 1):
                c = row[j] - base
                if c >= best:
                    break  # costs are monotone in j
                val = f(j, nu1, nu2)
                if c > val:
                    val = c
                if val < best:
                    best = val
        cache[key] = best
        return best

    stages = reconstruct_stages(order, seg_cost, f, n_pu1x, n_pu2x)
    return Partition(stages=stages, node_order=order)


def reconstruct_stages(
    order: list[int],
    seg_cost,
    f,
    n_pu1x: int,
    n_pu2x: int,
) -> list[Stage]:
    """Greedy reconstruction of an optimal stage list from the DP value
    function ``f(i, u1, u2)`` and segment costs ``seg_cost(kind, i, j)``.

    Shared by :func:`partition` (memoized recursive ``f``) and the
    dense-table path of ``repro.compiler.tables`` (``f`` reads a
    pre-filled array), so the two engines reconstruct byte-identical
    stage boundaries by construction."""
    n = len(order)
    stages: list[Stage] = []
    i, u1, u2 = 0, n_pu1x, n_pu2x
    target = f(0, u1, u2)
    if target is INF or target == INF:
        raise ValueError("infeasible partition (no PUs?)")
    idx = 0
    while i < n and u1 + u2 > 0:
        placed = False
        # Prefer the faster PU2x and the longest feasible segment, provided
        # the remainder stays on an optimal path (checked against f()).
        for kind, avail in (("PU2x", u2), ("PU1x", u1)):
            if not avail or placed:
                continue
            nu1, nu2 = (u1 - 1, u2) if kind == "PU1x" else (u1, u2 - 1)
            for j in range(n, i, -1):  # prefer the longest feasible segment
                c = seg_cost(kind, i, j)
                if c <= target + 1e-15 and max(c, f(j, nu1, nu2)) <= target + 1e-12:
                    stages.append(Stage(idx, kind, tuple(order[i:j]), c))
                    i, u1, u2 = j, nu1, nu2
                    idx += 1
                    placed = True
                    break
        if not placed:
            # The optimal path may *skip* a PU (empty stage), e.g. when one
            # heavy node dominates and fewer, bigger stages win.
            for kind, avail in (("PU1x", u1), ("PU2x", u2)):
                if not avail:
                    continue
                nu1, nu2 = (u1 - 1, u2) if kind == "PU1x" else (u1, u2 - 1)
                if f(i, nu1, nu2) <= target + 1e-12:
                    u1, u2 = nu1, nu2
                    placed = True
                    break
        if not placed:
            raise RuntimeError("DP reconstruction failed")
    # Drop trailing empty stages; they carry no program.
    stages = [s for s in stages if s.nids]
    return [Stage(i, s.pu_kind, s.nids, s.time) for i, s in enumerate(stages)]
