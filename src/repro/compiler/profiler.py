"""Node execution-time profiling (paper Sec. IV-A, Fig. 4(c)).

Profiles each node under *conflict-free* conditions — weights preloaded in
URAMs, dedicated HBM channels — measuring complete node processing: activation
fetch from HBM, SA computation, output storage. With tile-grained streaming
the PU overlaps these, so the steady-state node time is the slowest of the
three decoupled instruction groups, each charged its own per-instruction
decode overhead (1 sys_clk cycle per instruction, matching the ICU decoder):

    t_node = max(t_residual + t_compute + cp_decode,
                 t_load     + ld_decode,
                 t_store    + st_decode)

Transfers are accounted per ADM DataMove — each transfer pays the
latency-dominated ~40-cycle floor individually (the profiler used to lump
all input bytes into one transfer, which under-counted tiny nodes whose
per-stream floors dominate). The LD group only ever moves the *primary*
input; residual shortcuts and second operands stream through the CP-issued
async ADM engines (``t_residual``) — and they *serialize* with the GEMM on
the CP path: codegen queues the RES_ADD issue together with the Compute, so
it decodes only after the previous node's GEMM releases the CP group, and
the Compute's residual interlock then blocks until the stream lands (the
model used to fold ``t_residual`` into the max as if it overlapped, which
under-predicted every stage containing a shortcut by up to one ADM floor
per node). The second operand of an attention GEMM goes through the SA
weight port instead, whose node-granular stall accounting lives in
``repro.compiler.weights``.

Instruction counts mirror ``repro.compiler.codegen`` (DataMove + AddrCyc +
optional PRM + REQ/ACK handshakes per stream); dynamic weight-chunk issue
decodes are added by the compile driver once the weight schedule is known.

Profiles are computed per PU *type* (PU1x / PU2x); weight-streaming stalls are
handled separately by ``repro.compiler.weights`` (Sec. IV-B). Like fusion,
profiling is config-independent: ``repro.compiler.analyze`` runs it once per
graph content and every (a, b) placement of a DSE sweep reads the same
profile table.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.icu import DECODE_CYCLES  # per-instruction issue overhead (sys_clk)
from ..core.pu import PUSpec
from .graph import Graph, Node, OpType

_ATTN_OPS = (OpType.ATTN_SCORE, OpType.ATTN_CONTEXT)
_IM2COL_OPS = (OpType.CONV, OpType.FUSED_CONV_ADD, OpType.PROJ,
               OpType.FUSED_PROJ_ADD)


@dataclass(frozen=True)
class NodeProfile:
    nid: int
    t_compute: float
    t_load: float
    t_store: float
    t_residual: float
    # per-group instruction decode time (seconds) — see module docstring
    t_ld_decode: float = 0.0
    t_cp_decode: float = 0.0
    t_st_decode: float = 0.0

    @property
    def t_node(self) -> float:
        return max(
            self.t_residual + self.t_compute + self.t_cp_decode,
            self.t_load + self.t_ld_decode,
            self.t_store + self.t_st_decode,
        )


def instruction_counts(g: Graph, nd: Node) -> tuple[int, int, int]:
    """Per-round (LD, CP, ST) instruction counts this node contributes,
    mirroring the emission rules of ``repro.compiler.codegen``."""
    ld = 0
    if nd.inputs:
        ld += 2  # DataMove + AddrCyc for the primary input
        if nd.kernel != (1, 1) and nd.op in _IM2COL_OPS:
            ld += 1  # IM2COL_PRM
        elif nd.stride != (1, 1):
            ld += 1  # STRIDE_PRM
        if nd.inputs[0] not in g.input_tensors:
            ld += 2  # WAIT_REQ + SEND_ACK
        side = list(nd.inputs[1:])
        if nd.residual_input is not None:
            side.append(nd.residual_input)
        ld += 2 * sum(1 for t in side if t not in g.input_tensors)
    cp = 1  # Compute
    if nd.op in _ATTN_OPS:
        cp += 3  # URAM_PRM + WEIGHTS_ADM + AddrCyc (weight-port stream)
    elif nd.residual_input is not None or len(nd.inputs) > 1:
        cp += 3  # RES_ADD PRM + ADM + AddrCyc
    st = 0
    for out in nd.outputs:
        st += 2  # DataMove + AddrCyc
        if out not in g.output_tensors:
            st += 2 * len(g.consumers_of(out))  # WAIT_ACK + SEND_REQ each
    return ld, cp, st


def profile_node(g: Graph, nd: Node, pu: PUSpec) -> NodeProfile:
    t_cp = pu.gemm_seconds(nd.m, nd.n, nd.k) if (nd.m and nd.n and nd.k) else 0.0

    primary = nd.inputs[0] if nd.inputs else None
    t_ld = pu.adm_seconds(g.tensors[primary].nbytes_padded) if primary is not None else 0.0
    # per-round store bytes: a K/V-cache producer appends one row per round
    # (decode), everything else stores the whole tensor. One ADM per output
    # tensor, each paying its own transfer-latency floor (broadcast stores
    # drain the out slot with back-to-back transfers, not one big one).
    t_st = sum(pu.adm_seconds(g.tensors[t].write_bytes) for t in nd.outputs
               if g.tensors[t].write_bytes)

    # CP-issued async side streams, one ADM (with its own floor) each:
    # the residual shortcut plus — for non-attention two-input nodes — the
    # second operand. Attention second operands go through the SA weight
    # port instead (node-granular stall model in repro.compiler.weights).
    side = [nd.residual_input] if nd.residual_input is not None else []
    if nd.op not in _ATTN_OPS and len(nd.inputs) > 1:
        side.append(nd.inputs[1])
    t_res = sum(pu.adm_seconds(g.tensors[t].nbytes_padded) for t in side)

    ld_i, cp_i, st_i = instruction_counts(g, nd)
    dec = DECODE_CYCLES / pu.sys_clk_hz
    return NodeProfile(nd.nid, t_cp, t_ld, t_st, t_res,
                       t_ld_decode=ld_i * dec, t_cp_decode=cp_i * dec,
                       t_st_decode=st_i * dec)


def profile_graph(g: Graph, pu_types: dict[str, PUSpec]) -> dict[str, dict[int, NodeProfile]]:
    """node profiles per PU kind: {kind: {nid: NodeProfile}}."""
    return {
        kind: {nd.nid: profile_node(g, nd, pu) for nd in g.nodes}
        for kind, pu in pu_types.items()
    }


def segment_time(profiles: dict[int, NodeProfile], nids: list[int]) -> float:
    """Steady-state round time of a contiguous node segment on one PU."""
    return sum(profiles[nid].t_node for nid in nids)
