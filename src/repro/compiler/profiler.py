"""Node execution-time profiling (paper Sec. IV-A, Fig. 4(c)).

Profiles each node under *conflict-free* conditions — weights preloaded in
URAMs, dedicated HBM channels — measuring complete node processing: activation
fetch from HBM, SA computation, output storage. With tile-grained streaming
the PU overlaps these, so the steady-state node time is

    t_node = max(t_compute, t_load, t_store, t_residual) + decode overhead

Profiles are computed per PU *type* (PU1x / PU2x); weight-streaming stalls are
handled separately by ``repro.compiler.weights`` (Sec. IV-B).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.pu import PUSpec
from .graph import Graph, Node, OpType

DECODE_OVERHEAD_S = 8 / 300e6  # a few sys_clk cycles of instruction issue


@dataclass(frozen=True)
class NodeProfile:
    nid: int
    t_compute: float
    t_load: float
    t_store: float
    t_residual: float

    @property
    def t_node(self) -> float:
        return max(self.t_compute, self.t_load, self.t_store, self.t_residual) + DECODE_OVERHEAD_S


def profile_node(g: Graph, nd: Node, pu: PUSpec) -> NodeProfile:
    t_cp = pu.gemm_seconds(nd.m, nd.n, nd.k) if (nd.m and nd.n and nd.k) else 0.0
    in_bytes = sum(g.tensors[t].nbytes_padded for t in nd.inputs)
    out_bytes = sum(g.tensors[t].nbytes_padded for t in nd.outputs)
    t_ld = pu.adm_seconds(in_bytes) if in_bytes else 0.0
    t_st = pu.adm_seconds(out_bytes) if out_bytes else 0.0
    t_res = (
        pu.adm_seconds(g.tensors[nd.residual_input].nbytes_padded)
        if nd.residual_input is not None
        else 0.0
    )
    return NodeProfile(nd.nid, t_cp, t_ld, t_st, t_res)


def profile_graph(g: Graph, pu_types: dict[str, PUSpec]) -> dict[str, dict[int, NodeProfile]]:
    """node profiles per PU kind: {kind: {nid: NodeProfile}}."""
    return {
        kind: {nd.nid: profile_node(g, nd, pu) for nd in g.nodes}
        for kind, pu in pu_types.items()
    }


def segment_time(profiles: dict[int, NodeProfile], nids: list[int]) -> float:
    """Steady-state round time of a contiguous node segment on one PU."""
    return sum(profiles[nid].t_node for nid in nids)
