"""Dense-array export of the config-independent compile analysis.

``AnalysisTables`` is the batched-evaluation artifact of ``analyze()``
(paper Sec. V-A): everything ``place()`` reads per (a, b) configuration —
per-(segment, PU-kind) profiled times, SMOF weight-schedule costs, the
partition-DP value table, and the cross-stage tensor-edge geometry of the
credit-loop coupling model — exported once as dense numpy arrays so the
DSE scoring engine (``repro.dse.batched``) can evaluate whole config
batches as array programs instead of one Python ``place()`` call at a
time.

Numerical contract: every value in these tables is produced by the *same*
scalar helpers the per-config path uses (``PUSpec.gemm_seconds`` /
``adm_seconds``, ``NodeProfile.t_node``, the shared
``partition.reconstruct_stages`` and ``weights.node_tile_shapes``), and
every reduction the batched engine performs over them replicates the
scalar op order (sequential left-to-right sums via ``np.cumsum``,
order-free min/max) — which is what makes the batched engine's Pareto
frontiers byte-identical to the scalar engine's, not merely close.

Three exports:

* ``partition_values`` / ``reconstruct`` — the f(i, u1, u2) DP table as a
  dense ``(n+1, U1+1, U2+1)`` array (filled bottom-up with vectorized
  min/max over exactly the scalar recursion's candidate sets) plus the
  shared greedy reconstruction over it.
* ``segment_overheads`` — SMOF weight-schedule stage overheads (stall +
  dynamic-chunk decode) for a batch of node segments, solved by a
  vectorized replica of the greedy deficit allocator of
  ``repro.compiler.weights`` (one chunk pinned per round, identical
  candidate/tile orderings and capacity tests), deduplicated by segment
  shape exactly like the analysis-level shape cache.
* edge tables — per cross-potential tensor edge: producer/consumer node
  positions, per-kind store/load ADM times, and the tensor slot used to
  reduce per-config buffer depths (stage-distance beta).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

import numpy as np

from ..core.icu import DECODE_CYCLES
from ..core.pu import PUSpec
from .graph import Graph, OpType
from .partition import INF, Stage, reconstruct_stages
from .profiler import NodeProfile
from .weights import CHUNK_BYTES, node_tile_shapes

_ATTN_OPS = (OpType.ATTN_SCORE, OpType.ATTN_CONTEXT)


@dataclasses.dataclass
class _KindTables:
    """Per-PU-kind dense node/tile arrays (config-independent)."""

    kind: str
    spec: PUSpec
    # cumulative profiled node time over the topological order; Python
    # floats (list) for exact, fast scalar indexing in the reconstruction
    prefix: list
    node_exec: np.ndarray  # (n,) full-node SA execution seconds
    node_stream: np.ndarray  # (n,) weight-port stream (attention 2nd operand)
    tile_chunks: np.ndarray  # (total_tiles,) URAM chunks per weight tile
    tile_node: np.ndarray  # (total_tiles,) node *position* owning each tile
    tile_prefix: np.ndarray  # (n+1,) tiles of nodes[i:j] = [tp[i], tp[j])
    t_chunk_load: float
    cap_chunks: int


class AnalysisTables:
    """Dense-array view of one ``GraphAnalysis`` (see module docstring).

    Build it via ``GraphAnalysis.tables()``; all arrays are derived from
    the analysis' own fused graph and profiles, so byte-identity with the
    scalar path holds per analysis instance."""

    def __init__(
        self,
        graph: Graph,
        profiles: dict[str, dict[int, NodeProfile]],
        pu_kinds: dict[str, PUSpec],
    ) -> None:
        self.graph = graph
        self.pu_kinds = pu_kinds
        self.order: list[int] = [nd.nid for nd in graph.nodes]
        self.n = len(self.order)
        self.pos: dict[int, int] = {nid: i for i, nid in enumerate(self.order)}
        self.kinds: tuple[str, ...] = tuple(profiles.keys())

        self.by_kind: dict[str, _KindTables] = {}
        nodes = graph.nodes
        # per-node shape rows: what ``schedule_weights`` reads per node —
        # the dedup key of the SMOF cost solver (mirrors segment_shape_key)
        self._shape_rows: list[tuple] = []
        for nd in nodes:
            stream_b = (graph.tensors[nd.inputs[1]].stream_bytes
                        if nd.op in _ATTN_OPS else None)
            self._shape_rows.append((nd.m, nd.n, nd.k, nd.weight_bytes, stream_b))

        for kind, prof in profiles.items():
            spec = pu_kinds[kind]
            acc, run = [0.0], 0.0
            for nid in self.order:
                run += prof[nid].t_node
                acc.append(run)
            n_exec = np.zeros(self.n)
            n_stream = np.zeros(self.n)
            t_chunks: list[int] = []
            t_node_pos: list[int] = []
            t_prefix = np.zeros(self.n + 1, dtype=np.int64)
            for i, nd in enumerate(nodes):
                n_exec[i] = (spec.gemm_seconds(nd.m, nd.n, nd.k)
                             if (nd.m and nd.n and nd.k) else 0.0)
                if nd.op in _ATTN_OPS:
                    n_stream[i] = spec.adm_seconds(
                        graph.tensors[nd.inputs[1]].stream_bytes)
                if nd.weight_bytes:
                    for _, _, n_chunks in node_tile_shapes(nd.m, nd.k, spec.sa_rows):
                        t_chunks.append(n_chunks)
                        t_node_pos.append(i)
                t_prefix[i + 1] = len(t_chunks)
            self.by_kind[kind] = _KindTables(
                kind=kind,
                spec=spec,
                prefix=acc,
                node_exec=n_exec,
                node_stream=n_stream,
                tile_chunks=np.asarray(t_chunks, dtype=np.int64),
                tile_node=np.asarray(t_node_pos, dtype=np.int64),
                tile_prefix=t_prefix,
                t_chunk_load=spec.adm_seconds(CHUNK_BYTES),
                cap_chunks=spec.uram_capacity_bytes // CHUNK_BYTES,
            )

        self._build_edges()

        # partition DP: dense f-table, grown to the largest requested budget
        self._F: Optional[np.ndarray] = None
        self._F_list = None  # .tolist() view for fast scalar indexing
        self._F_budget = (0, 0)
        self._stages_cache: dict[tuple[int, int], list[Stage]] = {}
        # SMOF cost caches: per (i, j, kind) segment and per segment shape
        self._seg_cost: dict[tuple[int, int, str], tuple[float, int]] = {}
        self._shape_cost: dict[tuple, tuple[float, int]] = {}

    # -- coupling edge tables -------------------------------------------------
    def _build_edges(self) -> None:
        """One row per (tensor, consumer-node) pair that can couple stages:
        graph I/O tensors are host-coordinated (no PU-to-PU credit loop)
        and dead tensors carry no edge — the same skips as
        ``buffer_requirements`` + ``coupling_bounds``."""
        g = self.graph
        t_slot: list[int] = []
        prod_pos: list[int] = []
        cons_pos: list[int] = []
        primary: list[bool] = []
        write_bytes: list[int] = []
        read_bytes: list[int] = []
        n_slots = 0
        io = set(g.input_tensors) | set(g.output_tensors)
        for tid, tinfo in g.tensors.items():
            if tinfo.is_kv_cache and tid in io:
                # same invalid-graph contract as buffer_requirements()
                raise ValueError(
                    f"K/V cache tensor {tinfo.name!r} cannot be a graph input/output"
                )
            if tid in io:
                continue
            producer = g.producer_of(tid)
            consumers = g.consumers_of(tid)
            if producer is None or not consumers:
                continue  # dead tensor (fused away)
            slot = n_slots
            n_slots += 1
            for c in consumers:
                t_slot.append(slot)
                prod_pos.append(self.pos[producer.nid])
                cons_pos.append(self.pos[c.nid])
                primary.append(bool(c.inputs) and c.inputs[0] == tid)
                write_bytes.append(tinfo.write_bytes)
                read_bytes.append(tinfo.nbytes_padded)
        self.n_edges = len(t_slot)
        self.n_tensor_slots = n_slots
        self.edge_tensor = np.asarray(t_slot, dtype=np.int64)
        self.edge_prod = np.asarray(prod_pos, dtype=np.int64)
        self.edge_cons = np.asarray(cons_pos, dtype=np.int64)
        prim = np.asarray(primary, dtype=bool)
        # per-kind ADM times: producer store / consumer (primary) load
        self.edge_t_write: dict[str, np.ndarray] = {}
        self.edge_t_read: dict[str, np.ndarray] = {}
        for kind in self.kinds:
            spec = self.pu_kinds[kind]
            tw = np.array([spec.adm_seconds(b) for b in write_bytes])
            tr = np.array([spec.adm_seconds(b) for b in read_bytes])
            self.edge_t_write[kind] = tw
            self.edge_t_read[kind] = np.where(prim, tr, 0.0)

    # -- partition DP ---------------------------------------------------------
    def partition_values(self, n_pu1x: int, n_pu2x: int) -> np.ndarray:
        """Dense DP value table F[i, u1, u2] == the scalar recursion's
        f(i, u1, u2) (min over the same candidate sets with exact float
        min/max), filled bottom-up. Budget-independent subproblems mean
        one table built for the largest requested budget serves all
        smaller (a, b)."""
        u1, u2 = self._F_budget
        if self._F is None or n_pu1x > u1 or n_pu2x > u2:
            U1, U2 = max(n_pu1x, u1), max(n_pu2x, u2)
            n = self.n
            F = np.full((n + 1, U1 + 1, U2 + 1), INF)
            F[n, :, :] = 0.0
            pre = {k: np.asarray(t.prefix) for k, t in self.by_kind.items()}
            for i in range(n - 1, -1, -1):
                best = np.full((U1 + 1, U2 + 1), INF)
                if U1 and "PU1x" in pre:
                    c = pre["PU1x"][i:] - pre["PU1x"][i]
                    cand = np.maximum(c[:, None, None], F[i:, :U1, :]).min(axis=0)
                    np.minimum(best[1:, :], cand, out=best[1:, :])
                if U2 and "PU2x" in pre:
                    c = pre["PU2x"][i:] - pre["PU2x"][i]
                    cand = np.maximum(c[:, None, None], F[i:, :, :U2]).min(axis=0)
                    np.minimum(best[:, 1:], cand, out=best[:, 1:])
                best[0, 0] = INF
                F[i] = best
            self._F = F
            self._F_list = F.tolist()
            self._F_budget = (U1, U2)
            self._stages_cache.clear()
        return self._F

    def reconstruct(self, n_pu1x: int, n_pu2x: int) -> list[Stage]:
        """Optimal stage list for one (a, b) config — the shared greedy
        reconstruction of ``repro.compiler.partition`` reading the dense
        table, so stage boundaries match ``partition()`` exactly."""
        key = (n_pu1x, n_pu2x)
        hit = self._stages_cache.get(key)
        if hit is not None:
            return hit
        self.partition_values(n_pu1x, n_pu2x)
        flist = self._F_list
        prefix = {k: t.prefix for k, t in self.by_kind.items()}

        def f(i: int, u1: int, u2: int) -> float:
            return flist[i][u1][u2]

        def seg_cost(kind: str, i: int, j: int) -> float:
            row = prefix[kind]
            return row[j] - row[i]

        stages = reconstruct_stages(self.order, seg_cost, f, n_pu1x, n_pu2x)
        self._stages_cache[key] = stages
        return stages

    # -- SMOF segment costs ---------------------------------------------------
    def segment_overheads(
        self, segs: Iterable[tuple[int, int, str]]
    ) -> dict[tuple[int, int, str], float]:
        """Stage overhead seconds (weight-stream stall + two CP decodes per
        dynamic chunk) for each ``(i, j, kind)`` node-range segment.

        All segments missing from the cache are deduplicated by shape
        (the ``segment_shape_key`` analog) and solved in one vectorized
        greedy pass; results are exact replicas of
        ``GraphAnalysis.stage_overhead``."""
        segs = list(segs)
        todo: dict[tuple, tuple[int, int, str]] = {}
        for s in segs:
            if s in self._seg_cost:
                continue
            i, j, kind = s
            skey = (kind, tuple(self._shape_rows[i:j]))
            if skey in self._shape_cost:
                self._seg_cost[s] = self._shape_cost[skey]
            elif skey not in todo:
                todo[skey] = s
        if todo:
            solved = _solve_smof_batch(
                [(self.by_kind[kind], i, j) for (i, j, kind) in todo.values()])
            for skey, res in zip(todo, solved):
                self._shape_cost[skey] = res
        out: dict[tuple[int, int, str], float] = {}
        for s in segs:
            res = self._seg_cost.get(s)
            if res is None:
                i, j, kind = s
                skey = (kind, tuple(self._shape_rows[i:j]))
                res = self._shape_cost[skey]
                self._seg_cost[s] = res
            stall, n_dyn = res
            spec = self.by_kind[s[2]].spec
            # exact op order of GraphAnalysis.stage_overhead
            out[s] = stall + 2 * n_dyn * DECODE_CYCLES / spec.sys_clk_hz
        return out


# -- vectorized SMOF greedy ---------------------------------------------------
#
# Replicates schedule_weights() exactly: one chunk pinned per round, to the
# highest-stall node (ties: node order) that has a feasible tile, from that
# node's most-dynamic tile (ties: tile order). The capacity test after a
# trial pin — static+1 plus the worst adjacent dynamic pair after the
# decrement — collapses to a 3-way case split because pair values are
# integers and a pin decrements exactly the two pairs adjacent to the tile:
# the post-pin worst pair is gmax (some untouched pair attains the max) or
# gmax-1 (every argmax pair is adjacent to the pinned tile). With
# slack = cap - static - 1:
#   gmax     <= slack : every tile with dynamic chunks is feasible
#   gmax - 1 >  slack : no tile is feasible -> the segment is done
#   gmax - 1 == slack : tile t feasible iff all argmax pairs are in
#                       {prev(t), t}  (count test, two gathers)
# A single-tile segment has worst = dyn[0]; modeling it as one "pair" of
# value dyn[0] that every pin decrements by one makes the same split apply
# (at the border it is always feasible, matching the scalar allocator).


def _solve_smof_batch(
    items: list[tuple[_KindTables, int, int]]
) -> list[tuple[float, int]]:
    """(total_stall_seconds, n_dynamic_chunks) per (kind-tables, i, j)
    segment. Buckets by tile count so short segments do not pay the
    widest segment's padding."""
    order = sorted(range(len(items)),
                   key=lambda s: int(items[s][0].tile_prefix[items[s][2]]
                                     - items[s][0].tile_prefix[items[s][1]]))
    results: list[Optional[tuple[float, int]]] = [None] * len(items)
    bucket: list[int] = []
    for s in order:
        kt, i, j = items[s]
        n_tiles = int(kt.tile_prefix[j] - kt.tile_prefix[i])
        if bucket:
            kt0, i0, j0 = items[bucket[0]]
            lo = int(kt0.tile_prefix[j0] - kt0.tile_prefix[i0])
            if n_tiles > max(2 * lo, lo + 64) or len(bucket) >= 256:
                for idx, res in zip(bucket, _solve_smof_bucket(
                        [items[b] for b in bucket])):
                    results[idx] = res
                bucket = []
        bucket.append(s)
    if bucket:
        for idx, res in zip(bucket, _solve_smof_bucket(
                [items[b] for b in bucket])):
            results[idx] = res
    return results  # type: ignore[return-value]


def _solve_smof_bucket(
    items: list[tuple[_KindTables, int, int]]
) -> list[tuple[float, int]]:
    S = len(items)
    L = max(j - i for _, i, j in items)
    n_tiles = np.zeros(S, dtype=np.int64)
    n_nodes = np.zeros(S, dtype=np.int64)
    tchunk = np.zeros(S)
    cap = np.zeros(S, dtype=np.int64)
    for s, (kt, i, j) in enumerate(items):
        n_tiles[s] = kt.tile_prefix[j] - kt.tile_prefix[i]
        n_nodes[s] = j - i
        tchunk[s] = kt.t_chunk_load
        cap[s] = kt.cap_chunks
    T = max(1, int(n_tiles.max()))

    nexec = np.zeros((S, L))
    nstream = np.zeros((S, L))
    dyn = np.zeros((S, T), dtype=np.int64)
    tnode = np.zeros((S, T), dtype=np.int64)
    for s, (kt, i, j) in enumerate(items):
        nn = j - i
        nexec[s, :nn] = kt.node_exec[i:j]
        nstream[s, :nn] = kt.node_stream[i:j]
        lo, hi = int(kt.tile_prefix[i]), int(kt.tile_prefix[j])
        nt = hi - lo
        dyn[s, :nt] = kt.tile_chunks[lo:hi]
        tnode[s, :nt] = kt.tile_node[lo:hi] - i

    cols_L = np.arange(L)
    cols_T = np.arange(T)
    nmask = cols_L[None, :] < n_nodes[:, None]
    tmask = cols_T[None, :] < n_tiles[:, None]
    node_dyn = np.zeros((S, L), dtype=np.int64)
    np.add.at(node_dyn, (np.repeat(np.arange(S), T), tnode.ravel()),
              np.where(tmask, dyn, 0).ravel())

    # everything fits -> all chunks static, no greedy pass
    total = dyn.sum(axis=1)
    fits = total <= cap
    dyn[fits] = 0
    node_dyn[fits] = 0
    active = ~fits & (total > 0)

    nt_eff = np.maximum(n_tiles, 1)
    nxt = (cols_T[None, :] + 1) % nt_eff[:, None]
    prv = (cols_T[None, :] - 1) % nt_eff[:, None]
    single = n_tiles == 1
    pair = dyn + np.take_along_axis(dyn, nxt, axis=1)
    pair[single, 0] = dyn[single, 0]  # single-tile: worst = dyn[0]
    # One iteration pins one chunk per still-active row (the scalar
    # allocator's outer loop). Two cost levers keep iterations cheap:
    #   * ``stall``/``load``/``cand`` change only at the pinned node, so
    #     they are maintained incrementally (the recompute uses the exact
    #     expression of the cold build, so floats stay byte-identical);
    #   * ``margin = slack - gmax`` is a lower bound maintained by
    #     decrementing one per pin (slack drops exactly one, gmax by at
    #     most one). While margin >= 0 every dynamic tile is feasible and
    #     the whole (S, T) pair/argmax feasibility machinery is skipped;
    #     rows whose bound goes negative get an exact gmax refresh and,
    #     only at the border, the count-test tile filter.
    overlap = np.concatenate([np.zeros((S, 1)), nexec[:, :-1]], axis=1)
    load = node_dyn * tchunk[:, None] + nstream
    stall = load - overlap
    cand = (load > 0.0) & (stall > 0.0) & (node_dyn > 0) & nmask
    margin = (cap - 1) - np.where(tmask, pair, -1).max(axis=1)

    while active.any():
        border_state = None  # (rows, tile_ok, K, per-node best K)
        need = active & (margin < 0)
        if need.any():
            nb = np.nonzero(need)[0]
            pv_b = np.where(tmask[nb], pair[nb], -1)
            gmax_b = pv_b.max(axis=1)
            margin[nb] = (cap[nb] - 1) - gmax_b
            active[nb[margin[nb] < -1]] = False  # no feasible tile at all
            bsel = margin[nb] == -1
            if bsel.any():
                rb = nb[bsel]
                at_max = pv_b[bsel] == gmax_b[bsel][:, None]
                cnt = (at_max & tmask[rb]).sum(axis=1)
                ok_border = ((np.take_along_axis(at_max, prv[rb], axis=1)
                              .astype(np.int64) + at_max.astype(np.int64))
                             == cnt[:, None])
                ok_border |= single[rb][:, None]
                tile_ok_b = tmask[rb] & (dyn[rb] > 0) & ok_border
                K_b = np.where(tile_ok_b,
                               dyn[rb] * (T + 1) + (T - cols_T[None, :]), 0)
                kbest_b = np.zeros((rb.size, L), dtype=np.int64)
                np.maximum.at(
                    kbest_b,
                    (np.repeat(np.arange(rb.size), T), tnode[rb].ravel()),
                    K_b.ravel())
                border_state = (rb, tile_ok_b, K_b, kbest_b)

        valid = cand & active[:, None]
        if border_state is not None:
            valid[border_state[0]] &= border_state[3] > 0
        stallv = np.where(valid, stall, -np.inf)
        m = stallv.max(axis=1)
        found = m > -np.inf
        active = found
        rows = np.nonzero(found)[0]
        if rows.size == 0:
            break
        wn = np.where(stallv == m[:, None], cols_L[None, :], L).min(axis=1)
        wnode = wn[rows]
        wtile = np.zeros(rows.size, dtype=np.int64)
        is_b = (np.isin(rows, border_state[0]) if border_state is not None
                else np.zeros(rows.size, dtype=bool))
        rs = rows[~is_b]
        if rs.size:  # all-feasible rows: best tile = max dyn, lowest index
            Ks = np.where((tnode[rs] == wn[rs][:, None]) & (dyn[rs] > 0),
                          dyn[rs] * (T + 1) + (T - cols_T[None, :]), 0)
            wtile[~is_b] = Ks.argmax(axis=1)
        if is_b.any():
            rb, tile_ok_b, K_b, kbest_b = border_state
            rbw = rows[is_b]
            loc = np.searchsorted(rb, rbw)
            wnb = wn[rbw]
            kb = kbest_b[loc, wnb]
            match = (tile_ok_b[loc] & (tnode[rbw] == wnb[:, None])
                     & (K_b[loc] == kb[:, None]))
            wtile[is_b] = match.argmax(axis=1)

        dyn[rows, wtile] -= 1
        cap[rows] -= 1  # static_total += 1
        node_dyn[rows, wnode] -= 1
        pt = prv[rows, wtile]
        np.subtract.at(pair, (rows, wtile), 1)
        np.subtract.at(pair, (rows, pt), 1)
        sing = single[rows]
        pair[rows[sing], 0] += 1  # single-tile rows: one decrement only
        margin[rows] -= 1
        ld = node_dyn[rows, wnode] * tchunk[rows] + nstream[rows, wnode]
        st = ld - overlap[rows, wnode]
        load[rows, wnode] = ld
        stall[rows, wnode] = st
        cand[rows, wnode] = (ld > 0.0) & (st > 0.0) & (node_dyn[rows, wnode] > 0)

    load = node_dyn * tchunk[:, None] + nstream
    overlap = np.concatenate([np.zeros((S, 1)), nexec[:, :-1]], axis=1)
    stall = load - overlap
    contrib = np.where((load > 0.0) & (stall > 0.0) & nmask, stall, 0.0)
    # sequential left-to-right sum in node order == the scalar total_stall()
    totals = np.cumsum(contrib, axis=1)[:, -1] if L else np.zeros(S)
    n_dyn = dyn.sum(axis=1)
    return [(float(totals[s]), int(n_dyn[s])) for s in range(S)]
