# DNN compilation framework (paper Sec. IV): model processing + fusion,
# profiling, DP partitioning onto heterogeneous PUs, SMOF-style weight
# transfer scheduling, pipeline memory optimization (stage-distance buffers,
# liveness-driven HBM channel assignment) and instruction generation.
from .graph import Graph, Node, OpType, TensorInfo
from .fusion import fuse
from .profiler import NodeProfile, profile_graph, profile_node
from .partition import Partition, Stage, partition
from .weights import WeightSchedule, schedule_weights, CHUNK_BYTES
from .memory import MemoryPlan, TensorPlan, assign_channels, buffer_requirements
from .codegen import generate_programs
from .compile import (
    STATS,
    CompiledModel,
    CompileStats,
    GraphAnalysis,
    analyze,
    clear_analysis_cache,
    compile_model,
    place,
)
from . import zoo

__all__ = [
    "Graph",
    "Node",
    "OpType",
    "TensorInfo",
    "fuse",
    "NodeProfile",
    "profile_graph",
    "profile_node",
    "Partition",
    "Stage",
    "partition",
    "WeightSchedule",
    "schedule_weights",
    "CHUNK_BYTES",
    "MemoryPlan",
    "TensorPlan",
    "assign_channels",
    "buffer_requirements",
    "generate_programs",
    "STATS",
    "CompiledModel",
    "CompileStats",
    "GraphAnalysis",
    "analyze",
    "clear_analysis_cache",
    "compile_model",
    "place",
    "zoo",
]
