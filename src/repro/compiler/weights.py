"""Weight-transfer scheduling (paper Sec. IV-B, Fig. 4(d2)), SMOF-inspired.

A PU's assigned subgraph often needs more weight data than its URAM capacity.
Weights are split per computational *tile* (64 output channels — the first SA
dimension) into fixed-size chunks; some chunks are allocated *offline*
(resident in URAM), the rest stream *dynamically* from HBM during execution,
scheduled so that chunks for tile t+1 load during tile t's execution.

Greedy deficit-based allocation: iteratively pin chunks of the tile with the
highest *deficit* — the stall its dynamic loads would cause after overlap
hiding — until the capacity constraint binds:

    static_bytes + max over adjacent tile pairs (dyn(t) + dyn(t+1)) <= URAM

(dynamic chunks are evicted after their tile completes, so at most two
adjacent tiles' dynamic footprints coexist).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..core.pu import PUSpec, URAM_BYTES
from .graph import Graph, Node

CHUNK_BYTES = URAM_BYTES  # one URAM per chunk


@dataclass
class Tile:
    nid: int
    tile_idx: int  # index within the node (64-out-channel slices)
    weight_bytes: int
    t_exec: float  # SA execution time of this tile
    n_chunks: int = 0
    static_chunks: int = 0  # allocated offline in URAM

    @property
    def dynamic_chunks(self) -> int:
        return self.n_chunks - self.static_chunks

    def dynamic_bytes(self) -> int:
        return self.dynamic_chunks * CHUNK_BYTES


@dataclass
class WeightSchedule:
    tiles: list[Tile]
    pu_kind: str
    capacity_bytes: int
    t_chunk_load: float  # HBM->URAM time per chunk on the weight channel

    # -- derived -------------------------------------------------------------
    def stall_of(self, idx: int) -> float:
        """Execution stall before tile idx: its dynamic chunks load during
        tile idx-1's execution (cyclically across rounds for idx==0)."""
        t = self.tiles[idx]
        load = t.dynamic_chunks * self.t_chunk_load
        prev_exec = self.tiles[idx - 1].t_exec if self.tiles else 0.0
        return max(0.0, load - prev_exec)

    def total_stall(self) -> float:
        return sum(self.stall_of(i) for i in range(len(self.tiles)))

    def static_bytes(self) -> int:
        return sum(t.static_chunks * CHUNK_BYTES for t in self.tiles)

    def worst_adjacent_dynamic(self) -> int:
        if not self.tiles:
            return 0
        n = len(self.tiles)
        if n == 1:
            return self.tiles[0].dynamic_bytes()
        return max(
            self.tiles[i].dynamic_bytes() + self.tiles[(i + 1) % n].dynamic_bytes()
            for i in range(n)
        )

    def feasible(self) -> bool:
        return self.static_bytes() + self.worst_adjacent_dynamic() <= self.capacity_bytes

    def fully_static(self) -> bool:
        return all(t.dynamic_chunks == 0 for t in self.tiles)

    def node_dynamic_chunks(self) -> dict[int, int]:
        """Dynamic chunk count per node (for Compute.wchunks interlocks)."""
        out: dict[int, int] = {}
        for t in self.tiles:
            out[t.nid] = out.get(t.nid, 0) + t.dynamic_chunks
        return out


def build_tiles(g: Graph, nids: list[int], pu: PUSpec) -> list[Tile]:
    tiles: list[Tile] = []
    for nid in nids:
        nd = g.node_by_id(nid)
        if nd.weight_bytes == 0:
            continue
        n_tiles = max(1, math.ceil(nd.m / pu.sa_rows))
        per_tile_m = pu.sa_rows
        for ti in range(n_tiles):
            m_here = min(per_tile_m, nd.m - ti * per_tile_m)
            wb = m_here * nd.k + 4 * m_here  # int8 weights + int32 bias
            tiles.append(
                Tile(
                    nid=nid,
                    tile_idx=ti,
                    weight_bytes=wb,
                    t_exec=pu.gemm_seconds(m_here, nd.n, nd.k),
                    n_chunks=max(1, math.ceil(wb / CHUNK_BYTES)),
                )
            )
    return tiles


def schedule_weights(g: Graph, nids: list[int], pu: PUSpec) -> WeightSchedule:
    """Greedy deficit-based offline allocation under the URAM capacity."""
    tiles = build_tiles(g, nids, pu)
    sched = WeightSchedule(
        tiles=tiles,
        pu_kind=pu.kind,
        capacity_bytes=pu.uram_capacity_bytes,
        t_chunk_load=pu.adm_seconds(CHUNK_BYTES),
    )
    if not tiles:
        return sched

    total_chunks = sum(t.n_chunks for t in tiles)
    if total_chunks * CHUNK_BYTES <= pu.uram_capacity_bytes:
        # Everything fits: preload all weights offline.
        for t in tiles:
            t.static_chunks = t.n_chunks
        return sched

    # Iteratively pin one chunk of the most deficit-prone tile.
    while True:
        # deficit per tile: stall caused by its remaining dynamic chunks.
        worst_i, worst_stall = -1, 0.0
        for i in range(len(tiles)):
            if tiles[i].dynamic_chunks == 0:
                continue
            s = sched.stall_of(i)
            if s > worst_stall:
                worst_i, worst_stall = i, s
        if worst_i < 0:
            break  # no stalls remain — schedule fully hidden
        tiles[worst_i].static_chunks += 1
        if not sched.feasible():
            tiles[worst_i].static_chunks -= 1  # revert; capacity bound hit
            # try the next most deficit-prone tiles before giving up
            candidates = sorted(
                (i for i in range(len(tiles)) if tiles[i].dynamic_chunks > 0),
                key=sched.stall_of,
                reverse=True,
            )
            progressed = False
            for i in candidates:
                tiles[i].static_chunks += 1
                if sched.feasible():
                    progressed = True
                    break
                tiles[i].static_chunks -= 1
            if not progressed:
                break
    assert sched.feasible()
    return sched
