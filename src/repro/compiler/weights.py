"""Weight-transfer scheduling (paper Sec. IV-B, Fig. 4(d2)), SMOF-inspired.

A PU's assigned subgraph often needs more weight data than its URAM capacity.
Weights are split per computational *tile* (64 output channels — the first SA
dimension) into fixed-size chunks; some chunks are allocated *offline*
(resident in URAM), the rest stream *dynamically* from HBM during execution,
scheduled so that chunks for tile t+1 load during tile t's execution.

Greedy deficit-based allocation: iteratively pin chunks of the node with the
highest *deficit* — the stall its dynamic loads would cause after overlap
hiding — until the capacity constraint binds:

    static_bytes + max over adjacent tile pairs (dyn(t) + dyn(t+1)) <= URAM

(dynamic chunks are evicted after their tile completes, so at most two
adjacent tiles' dynamic footprints coexist).

Stall accounting is *node*-granular, matching the instruction generator: all
of a node's dynamic chunks are issued with one-node lookahead and the node's
single Compute holds the URAM interlock, so the overlap window for node j's
chunk loads is node j-1's SA execution (zero for the first node: its loads
issue at round start, after the previous round's last GEMM has already
drained the CP group). Attention score/context GEMMs additionally stream
their second
operand through the SA weight port under the same interlock; that fixed,
non-pinnable load joins the node's chunk loads in the stall model. A
schedule built without node context (``node_order`` empty) falls back to the
older per-tile overlap estimate.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace

from ..core.pu import PUSpec, URAM_BYTES
from .graph import Graph, OpType

CHUNK_BYTES = URAM_BYTES  # one URAM per chunk

_ATTN_OPS = (OpType.ATTN_SCORE, OpType.ATTN_CONTEXT)


@dataclass
class Tile:
    nid: int
    tile_idx: int  # index within the node (64-out-channel slices)
    weight_bytes: int
    t_exec: float  # SA execution time of this tile
    n_chunks: int = 0
    static_chunks: int = 0  # allocated offline in URAM

    @property
    def dynamic_chunks(self) -> int:
        return self.n_chunks - self.static_chunks

    def dynamic_bytes(self) -> int:
        return self.dynamic_chunks * CHUNK_BYTES


def _node_stalls(
    order: list[int],
    node_exec: dict[int, float],
    node_stream: dict[int, float],
    node_dyn: dict[int, int],
    t_chunk_load: float,
) -> dict[int, float]:
    """Execution stall before each node's GEMM, per the codegen issue order:
    node j's dynamic chunks (and weight-port streams) load during node j-1's
    SA execution; whatever does not fit stalls node j. The *first* node has
    no overlap window at all: its loads are issued at round start, after the
    previous round's final Compute has already released the CP group (the
    Compute instruction holds the group until the GEMM drains, so nothing is
    "still queued behind" across the round boundary). Shared by the analytic
    model (`WeightSchedule.node_stalls`) and the greedy allocator's inner
    loop so the two can never drift."""
    stalls: dict[int, float] = {}
    for j, nid in enumerate(order):
        load = node_dyn.get(nid, 0) * t_chunk_load + node_stream.get(nid, 0.0)
        if load <= 0.0:
            continue
        overlap = node_exec.get(order[j - 1], 0.0) if j > 0 else 0.0
        s = load - overlap
        if s > 0.0:
            stalls[nid] = s
    return stalls


@dataclass
class WeightSchedule:
    tiles: list[Tile]
    pu_kind: str
    capacity_bytes: int
    t_chunk_load: float  # HBM->URAM time per chunk on the weight channel
    # node-granular stall context (the segment's full node order, each
    # node's SA execution time, and fixed weight-port streams — attention
    # second operands); empty for schedules built without node context.
    node_order: list[int] = field(default_factory=list)
    node_exec: dict[int, float] = field(default_factory=dict)
    node_stream: dict[int, float] = field(default_factory=dict)

    # -- derived -------------------------------------------------------------
    def stall_of(self, idx: int) -> float:
        """Per-tile overlap estimate (legacy; used when no node context is
        attached): tile idx's dynamic chunks load during tile idx-1's
        execution (cyclically across rounds for idx==0)."""
        t = self.tiles[idx]
        load = t.dynamic_chunks * self.t_chunk_load
        prev_exec = self.tiles[idx - 1].t_exec if self.tiles else 0.0
        return max(0.0, load - prev_exec)

    def node_stalls(self) -> dict[int, float]:
        """Execution stall before each node's GEMM (see ``_node_stalls``)."""
        return _node_stalls(self.node_order, self.node_exec, self.node_stream,
                            self.node_dynamic_chunks(), self.t_chunk_load)

    def total_stall(self) -> float:
        if self.node_order:
            return sum(self.node_stalls().values())
        return sum(self.stall_of(i) for i in range(len(self.tiles)))

    def static_bytes(self) -> int:
        return sum(t.static_chunks * CHUNK_BYTES for t in self.tiles)

    def worst_adjacent_dynamic(self) -> int:
        if not self.tiles:
            return 0
        n = len(self.tiles)
        if n == 1:
            return self.tiles[0].dynamic_bytes()
        return max(
            self.tiles[i].dynamic_bytes() + self.tiles[(i + 1) % n].dynamic_bytes()
            for i in range(n)
        )

    def feasible(self) -> bool:
        return self.static_bytes() + self.worst_adjacent_dynamic() <= self.capacity_bytes

    def fully_static(self) -> bool:
        return all(t.dynamic_chunks == 0 for t in self.tiles)

    def node_dynamic_chunks(self) -> dict[int, int]:
        """Dynamic chunk count per node (for Compute.wchunks interlocks)."""
        out: dict[int, int] = {}
        for t in self.tiles:
            out[t.nid] = out.get(t.nid, 0) + t.dynamic_chunks
        return out

    def rebound(self, nids: "list[int] | tuple[int, ...]") -> "WeightSchedule":
        """A copy positionally re-keyed onto ``nids`` — valid when the new
        segment's node shapes match this one's (same
        :func:`segment_shape_key`), in which case tiling, allocation and
        times are identical up to nid relabeling."""
        if len(nids) != len(self.node_order):
            raise ValueError("rebound() needs a same-length node segment")
        mapping = dict(zip(self.node_order, nids))
        return WeightSchedule(
            tiles=[replace(t, nid=mapping[t.nid]) for t in self.tiles],
            pu_kind=self.pu_kind,
            capacity_bytes=self.capacity_bytes,
            t_chunk_load=self.t_chunk_load,
            node_order=list(nids),
            node_exec={mapping[n]: v for n, v in self.node_exec.items()},
            node_stream={mapping[n]: v for n, v in self.node_stream.items()},
        )


def segment_shape_key(g: Graph, nids: "list[int] | tuple[int, ...]") -> tuple:
    """Shape signature of a node segment: exactly what ``schedule_weights``
    reads per node (GEMM dims, weight bytes, attention stream-operand
    bytes). Equal keys on the same PU kind yield identical schedules up to
    nid relabeling — the basis of the analysis-level shape cache that makes
    a 28-block transformer pay for one block's SMOF allocation."""
    parts = []
    for nid in nids:
        nd = g.node_by_id(nid)
        stream = (g.tensors[nd.inputs[1]].stream_bytes
                  if nd.op in _ATTN_OPS else None)
        parts.append((nd.m, nd.n, nd.k, nd.weight_bytes, stream))
    return tuple(parts)


def node_tile_shapes(m: int, k: int, sa_rows: int) -> list[tuple[int, int, int]]:
    """The 64-out-channel weight tiling of one node: ``(m_here,
    weight_bytes, n_chunks)`` per tile (int8 weights + int32 bias per
    slice). Single source of the tiling math, shared by :func:`build_tiles`
    and the dense-array export (``repro.compiler.tables``) so the
    vectorized DSE engine can never drift from the schedule builder.
    Returns ``[]`` for weight-less nodes."""
    if m * k + 4 * m == 0:
        return []
    n_tiles = max(1, math.ceil(m / sa_rows))
    out = []
    for ti in range(n_tiles):
        m_here = min(sa_rows, m - ti * sa_rows)
        wb = m_here * k + 4 * m_here
        out.append((m_here, wb, max(1, math.ceil(wb / CHUNK_BYTES))))
    return out


def build_tiles(g: Graph, nids: list[int], pu: PUSpec) -> list[Tile]:
    tiles: list[Tile] = []
    for nid in nids:
        nd = g.node_by_id(nid)
        if nd.weight_bytes == 0:
            continue
        for ti, (m_here, wb, n_chunks) in enumerate(
                node_tile_shapes(nd.m, nd.k, pu.sa_rows)):
            tiles.append(
                Tile(
                    nid=nid,
                    tile_idx=ti,
                    weight_bytes=wb,
                    t_exec=pu.gemm_seconds(m_here, nd.n, nd.k),
                    n_chunks=n_chunks,
                )
            )
    return tiles


def schedule_weights(g: Graph, nids: list[int], pu: PUSpec) -> WeightSchedule:
    """Greedy deficit-based offline allocation under the URAM capacity."""
    tiles = build_tiles(g, nids, pu)
    node_exec: dict[int, float] = {}
    node_stream: dict[int, float] = {}
    for nid in nids:
        nd = g.node_by_id(nid)
        node_exec[nid] = (
            pu.gemm_seconds(nd.m, nd.n, nd.k) if (nd.m and nd.n and nd.k) else 0.0
        )
        if nd.op in _ATTN_OPS:
            # stream_bytes is the average valid prefix for decode K/V caches
            # (the per-round AddrLen lengths average to it over the window)
            # and the whole tensor for prefill attention operands.
            node_stream[nid] = pu.adm_seconds(
                g.tensors[nd.inputs[1]].stream_bytes)
    sched = WeightSchedule(
        tiles=tiles,
        pu_kind=pu.kind,
        capacity_bytes=pu.uram_capacity_bytes,
        t_chunk_load=pu.adm_seconds(CHUNK_BYTES),
        node_order=list(nids),
        node_exec=node_exec,
        node_stream=node_stream,
    )
    if not tiles:
        return sched

    total_chunks = sum(t.n_chunks for t in tiles)
    if total_chunks * CHUNK_BYTES <= pu.uram_capacity_bytes:
        # Everything fits: preload all weights offline.
        for t in tiles:
            t.static_chunks = t.n_chunks
        return sched

    # Iteratively pin one chunk of the most deficit-prone node (the node
    # whose remaining dynamic loads stall its GEMM the longest). The loop
    # below replays exactly the greedy decisions of the straightforward
    # implementation (stable sorts, most-dynamic-tile-first, first feasible
    # pin wins) but keeps the capacity invariant incrementally: per-tile
    # dynamic counts, per-node totals, and a lazy max-heap over the
    # adjacent-pair dynamic footprints replace the O(tiles) rescans that
    # used to dominate DSE sweeps over weight-heavy graphs.
    n = len(tiles)
    dyn = [t.n_chunks for t in tiles]  # all chunks start dynamic
    idx_of_node: dict[int, list[int]] = {}
    for i, t in enumerate(tiles):
        idx_of_node.setdefault(t.nid, []).append(i)
    node_dyn = {nid: sum(dyn[i] for i in ixs) for nid, ixs in idx_of_node.items()}
    static_total = 0
    if n > 1:
        pair = [dyn[i] + dyn[(i + 1) % n] for i in range(n)]
        heap = [(-pair[i], i) for i in range(n)]
        heapq.heapify(heap)

    def worst_pair() -> int:
        if n == 1:
            return dyn[0]
        while heap and -heap[0][0] != pair[heap[0][1]]:
            heapq.heappop(heap)  # stale entry
        return -heap[0][0] if heap else 0

    def feasible_now() -> bool:
        return (static_total + worst_pair()) * CHUNK_BYTES <= sched.capacity_bytes

    def bump(i: int, delta: int) -> None:
        dyn[i] += delta
        if n > 1:
            for p in {i, (i - 1) % n}:
                pair[p] += delta
                heapq.heappush(heap, (-pair[p], p))

    def pin_one(nid: int) -> bool:
        """Pin one chunk of ``nid`` (from its most dynamic tile) if the
        capacity constraint allows it."""
        nonlocal static_total
        for i in sorted(idx_of_node[nid], key=lambda i: -dyn[i]):
            if dyn[i] == 0:
                continue
            bump(i, -1)
            static_total += 1
            if feasible_now():
                tiles[i].static_chunks += 1
                node_dyn[nid] -= 1
                return True
            bump(i, +1)  # revert; capacity bound hit
            static_total -= 1
        return False

    t_load = sched.t_chunk_load
    while True:
        stalls = _node_stalls(nids, node_exec, node_stream, node_dyn, t_load)
        candidates = sorted(
            (nid for nid in stalls if node_dyn.get(nid, 0) > 0),
            key=lambda nid: stalls[nid],
            reverse=True,
        )
        if not any(pin_one(nid) for nid in candidates):
            break  # no pinnable stalls remain, or capacity bound everywhere
    assert sched.feasible()
    return sched
