"""Instruction generation (paper Sec. IV-D, Fig. 4(f)).

Lowers the optimized node-to-PU assignment + memory plan into executable
LD/CP/ST instruction programs per PU:

  * cyclic buffering encoded as BID rotation in Sync instructions and
    AddrCyc region cycling on every DataMove;
  * inter- and intra-PU producer->consumer edges get WAIT_REQ/SEND_ACK
    (consumer LD) <-> WAIT_ACK/SEND_REQ (producer ST) handshakes — intra-PU
    tokens use the 2-cycle same-PU path, and intra-PU REQs are emitted
    *before* the store ADM (stream-start authorization, enabling the
    tile-grained write->read streaming through HBM);
  * consumers pre-authorize producers with an ACK-bypass prologue (one
    SEND_ACK per buffer region, addresses before the ProgCtrl loop base);
  * SMOF dynamic weight chunks are issued with one-node lookahead so chunk
    loads overlap the previous node's GEMM; the Compute.wchunks field
    carries the URAM interlock;
  * graph inputs/outputs use plain cyclic A/C-region access (PCIe host
    coordinated), per Sec. III-C.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..core.isa import (
    AddrCyc,
    AddrLen,
    Compute,
    Config,
    DataMove,
    Group,
    Instruction,
    Opcode,
    Sync,
)
from ..core.program import Program, PUProgram
from ..core.pu import PUSpec
from .graph import Graph, Node, OpType
from .memory import MemoryPlan, TensorPlan
from .partition import Partition
from .weights import CHUNK_BYTES, WeightSchedule


def _align(x: int, a: int = 4096) -> int:
    return (x + a - 1) // a * a


_IM2COL_OPS = (OpType.CONV, OpType.FUSED_CONV_ADD, OpType.PROJ,
               OpType.FUSED_PROJ_ADD)  # PROJ with a kernel = patch embedding


def _adm_op(nd: Node) -> Opcode:
    if nd.kernel != (1, 1) and nd.op in _IM2COL_OPS:
        return Opcode.IM2COL_ADM
    if nd.stride != (1, 1):
        return Opcode.STRIDE_ADM
    return Opcode.LINEAR_ADM


def _adm_prm(op: Opcode, nd: Node) -> Config | None:
    if op is Opcode.IM2COL_ADM:
        return Config(op=Opcode.IM2COL_PRM, param0=nd.kernel[0] * 16 + nd.kernel[1],
                      param1=nd.stride[0], param2=nd.padding[0], param3=0)
    if op is Opcode.STRIDE_ADM:
        return Config(op=Opcode.STRIDE_PRM, param0=nd.stride[0])
    return None


@dataclass
class StageCodegenCtx:
    pid: int
    spec: PUSpec
    ld: list[Instruction] = field(default_factory=list)
    ld_prologue: list[Instruction] = field(default_factory=list)
    cp: list[Instruction] = field(default_factory=list)
    st: list[Instruction] = field(default_factory=list)


def generate_programs(
    g: Graph,
    part: Partition,
    mem: MemoryPlan,
    wscheds: dict[int, WeightSchedule],
    pid_map: dict[int, int],
    pu_specs: dict[int, PUSpec],
    *,
    rounds: int,
) -> list[PUProgram]:
    """Emit one PUProgram per (non-empty) pipeline stage."""
    stage_of = part.stage_of_node()

    # ---- global BID allocation: one contiguous range per tensor -----------
    next_bid = 0
    for tid in sorted(mem.tensors):
        plan = mem.tensors[tid]
        plan.bid_base = next_bid
        next_bid += plan.beta

    producer_pid: dict[int, int] = {}  # tid -> producing PU
    for nd in g.nodes:
        for tid in nd.outputs:
            if nd.nid in stage_of:
                producer_pid[tid] = pid_map[stage_of[nd.nid]]

    ctxs: dict[int, StageCodegenCtx] = {}
    for s in part.stages:
        if not s.nids:
            continue
        pid = pid_map[s.index]
        ctx = StageCodegenCtx(pid=pid, spec=pu_specs[pid])
        ctxs[s.index] = ctx
        wsched = wscheds.get(s.index)
        dyn_chunks = wsched.node_dynamic_chunks() if wsched else {}

        nodes = [g.node_by_id(nid) for nid in s.nids]

        # ---------------- LD + ST streams -------------------------------
        for nd in nodes:
            primary = nd.inputs[0] if nd.inputs else None
            extra_inputs = list(nd.inputs[1:])
            residual = nd.residual_input

            # primary input
            if primary is not None:
                plan = mem.tensors[primary]
                if plan.kind != "input":
                    src = producer_pid[primary]
                    ctx.ld.append(_wait(Opcode.WAIT_REQ, src, plan))
                    _emit_read(ctx.ld, nd, plan)
                    ctx.ld.append(_sync(Opcode.SEND_ACK, src, plan))
                    _prologue_acks(ctx.ld_prologue, src, plan)
                else:
                    _emit_read(ctx.ld, nd, plan)

            # residual / second input: CP does the ADM; LD handles the sync.
            for rtid in ([residual] if residual is not None else []) + extra_inputs:
                plan = mem.tensors[rtid]
                if plan.kind != "input":
                    src = producer_pid[rtid]
                    ctx.ld.append(_wait(Opcode.WAIT_REQ, src, plan))
                    ctx.ld.append(_sync(Opcode.SEND_ACK, src, plan))
                    _prologue_acks(ctx.ld_prologue, src, plan)

            # output stores — every output tensor is written (and, unless it
            # is a graph output, handshaken) per round, matching the
            # profiler's instruction_counts / store-byte accounting.
            for i, out_tid in enumerate(nd.outputs):
                # Broadcast store: one compute result drains to several HBM
                # tensors; every transfer but the node's last HOLDs the
                # output-buffer slot (re-reading it) so the slot accounting
                # stays one-per-compute.
                hold = i < len(nd.outputs) - 1
                oplan = mem.tensors[out_tid]
                otinfo = g.tensors[out_tid]
                consumers = [c for c in g.consumers_of(out_tid) if c.nid in stage_of]
                if oplan.kind == "output" or not consumers:
                    _emit_write(ctx.st, oplan, otinfo, hold=hold)
                    continue
                cons_pids = [pid_map[stage_of[c.nid]] for c in consumers]
                for cpid in cons_pids:
                    ctx.st.append(_wait(Opcode.WAIT_ACK, cpid, oplan))
                # stream-start REQ for same-PU consumers (write->read stream)
                for cpid in cons_pids:
                    if cpid == pid:
                        ctx.st.append(_sync(Opcode.SEND_REQ, cpid, oplan))
                _emit_write(ctx.st, oplan, otinfo, hold=hold)
                for cpid in cons_pids:
                    if cpid != pid:
                        ctx.st.append(_sync(Opcode.SEND_REQ, cpid, oplan))

        # ---------------- CP stream (1-node weight lookahead) ------------
        pending_cp: list[list[Instruction]] = []
        for nd in nodes:
            # 1) issue this node's dynamic weight chunks now (they overlap
            #    the previous node's GEMM, which is still queued behind).
            nchunks = dyn_chunks.get(nd.nid, 0)
            wchan = mem.weight_channel[s.index]
            for c in range(nchunks):
                ctx.cp.append(Config(op=Opcode.URAM_PRM, param0=c))
                ctx.cp.append(
                    DataMove(op=Opcode.WEIGHTS_ADM, cur_ba=0, length=CHUNK_BYTES, channel=wchan)
                )
            # attention GEMMs: the second operand (K for the score GEMM, V
            # for the context GEMM) is an *activation* streamed through the
            # SA weight port — one WEIGHTS_ADM over the producer's cyclic
            # region, counted in Compute.wchunks so the URAM read interlock
            # holds the GEMM until the stream has landed. A K/V cache operand
            # (autoregressive decode) keeps a fixed base address but its
            # transfer *length* advances one row per round (AddrLen).
            if nd.op in (OpType.ATTN_SCORE, OpType.ATTN_CONTEXT):
                splan = mem.tensors[nd.inputs[1]]
                stinfo = g.tensors[nd.inputs[1]]
                ctx.cp.append(Config(op=Opcode.URAM_PRM, param0=0))
                if stinfo.is_kv_cache:
                    row = stinfo.kv_row_stride
                    len0 = (stinfo.kv_base_rows + 1) * row
                    steps = stinfo.kv_steps
                    ctx.cp.append(
                        DataMove(op=Opcode.WEIGHTS_ADM, cur_ba=splan.base_addr,
                                 length=len0, channel=splan.read_channel)
                    )
                    ctx.cp.append(AddrLen(len_base=len0, loffs=row,
                                          nc=steps - 1, ic=steps - 1))
                else:
                    ctx.cp.append(
                        DataMove(op=Opcode.WEIGHTS_ADM, cur_ba=splan.base_addr,
                                 length=splan.region_bytes,
                                 channel=splan.read_channel)
                    )
                    ctx.cp.append(_addrcyc(splan))
                nchunks += 1
            # 2) flush the previous node's compute ops.
            if pending_cp:
                ctx.cp.extend(pending_cp.pop(0))
            # 3) queue this node's compute ops.
            ops: list[Instruction] = []
            if nd.op in (OpType.ATTN_SCORE, OpType.ATTN_CONTEXT):
                rtid = None  # second input already streamed via WEIGHTS_ADM
            else:
                rtid = nd.residual_input if nd.residual_input is not None else (
                    nd.inputs[1] if len(nd.inputs) > 1 else None
                )
            if rtid is not None:
                rplan = mem.tensors[rtid]
                ops.append(Config(op=Opcode.RES_ADD_STRIDE_PRM, param0=1))
                ops.append(
                    DataMove(
                        op=Opcode.RES_ADD_STRIDE_ADM,
                        cur_ba=rplan.base_addr,
                        length=rplan.region_bytes,
                        channel=rplan.read_channel,
                    )
                )
                ops.append(_addrcyc(rplan))
            ops.append(
                Compute(
                    m=nd.m,
                    n=nd.n,
                    k=nd.k,
                    relu=nd.relu,
                    add_enable=rtid is not None,
                    scale_shift=nd.scale_shift,
                    rounds=1,
                    wchunks=nchunks,
                )
            )
            pending_cp.append(ops)
        while pending_cp:
            ctx.cp.extend(pending_cp.pop(0))

    # ---- assemble -----------------------------------------------------------
    programs: list[PUProgram] = []
    for s in part.stages:
        if s.index not in ctxs:
            continue
        ctx = ctxs[s.index]
        ld_body = ctx.ld_prologue + ctx.ld
        ld = Program.assemble(Group.LD, ld_body, rounds=rounds,
                              loop_ba=len(ctx.ld_prologue), name=f"pu{ctx.pid}.LD")
        cp = Program.assemble(Group.CP, ctx.cp, rounds=rounds, name=f"pu{ctx.pid}.CP")
        st = Program.assemble(Group.ST, ctx.st, rounds=rounds, name=f"pu{ctx.pid}.ST")
        prog = PUProgram(ctx.pid, ld, cp, st, label=f"stage{s.index}")
        prog.validate()
        programs.append(prog)
    return programs


# ---------------------------------------------------------------- helpers --
def _sync(op: Opcode, pid: int, plan: TensorPlan) -> Sync:
    return Sync(op=op, pid=pid, bid=plan.bid_base, base_bid=plan.bid_base,
                nc=plan.beta - 1, ic=plan.beta - 1)


_wait = _sync


def _prologue_acks(prologue: list[Instruction], src: int, plan: TensorPlan) -> None:
    """ACK-bypass pre-authorization: one bypass ACK per buffer region."""
    for i in range(plan.beta):
        prologue.append(Sync(op=Opcode.SEND_ACK, pid=src, bid=plan.bid_base + i, nc=0))


def _addrcyc(plan: TensorPlan) -> AddrCyc:
    return AddrCyc(
        ba=plan.base_addr,
        aoffs=_align(plan.region_bytes),
        nc=plan.beta - 1,
        ic=plan.beta - 1,
    )


def _emit_read(body: list[Instruction], nd: Node, plan: TensorPlan) -> None:
    op = _adm_op(nd)
    prm = _adm_prm(op, nd)
    if prm is not None:
        body.append(prm)
    body.append(
        DataMove(op=op, cur_ba=plan.base_addr, length=plan.region_bytes,
                 channel=plan.read_channel)
    )
    body.append(_addrcyc(plan))


def _emit_write(body: list[Instruction], plan: TensorPlan,
                tinfo=None, hold: bool = False) -> None:
    if tinfo is not None and tinfo.is_kv_cache:
        # append-only K/V region: one row per round, the address advancing
        # from the end of the prefill prefix across the decode window, then
        # wrapping for the next sequence.
        row = tinfo.kv_row_stride
        ba = plan.base_addr + tinfo.kv_base_rows * row
        steps = tinfo.kv_steps
        body.append(
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=ba, length=row,
                     channel=plan.write_channel, hold=hold)
        )
        body.append(AddrCyc(ba=ba, aoffs=row, nc=steps - 1, ic=steps - 1))
        return
    body.append(
        DataMove(op=Opcode.LINEAR_ADM, cur_ba=plan.base_addr,
                 length=plan.region_bytes, channel=plan.write_channel,
                 hold=hold)
    )
    body.append(_addrcyc(plan))
