"""Top-level compilation driver: DNN graph -> executable PUPrograms.

The framework phases of Fig. 4 are split along their data dependencies into
three explicit layers, so the DSE (Sec. V-A) never recomputes — or even
runs — work a design point does not need:

``analyze(g, pus)``
    The *config-independent* artifact: fusion, per-PU-kind node profiling,
    and a memo of per-(node-segment, PU-kind) SMOF weight schedules. It is
    computed **once per graph content** (memoized by ``Graph.fingerprint``)
    and shared by every (a, b) configuration a sweep evaluates.

``place(analysis, a, b)``
    The *cheap per-config* step: DP partitioning over the cached profiles,
    weight schedules looked up (or filled in) from the analysis memo, and
    the analytic stage times — everything the DSE cache reads. No memory
    planning, no instruction generation.

``CompiledModel.programs`` / ``CompiledModel.mem``
    *Lazy* codegen: pipeline memory optimization and instruction generation
    run on first access, i.e. only when a deployment actually needs
    executable programs. ``compile_deployment`` forces them at deploy time;
    ``explore``/``explore_multi`` never touch them.

``compile_model(g, a, b)`` remains the one-call form (= ``analyze`` +
``place``) and is what non-DSE callers use. Module-level ``STATS`` counts
phase invocations — ``benchmarks/dse_bench.py`` turns them into the CI-gated
evidence that the sweep does no redundant work.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

from ..core.program import PUProgram
from ..core.pu import PUSpec, make_u50_system
from .codegen import generate_programs
from .coupling import CouplingModel, couple
from .fusion import fuse
from .graph import Graph
from .memory import MemoryPlan, assign_channels, buffer_requirements
from .partition import Partition, partition
from .profiler import DECODE_CYCLES, NodeProfile, profile_graph
from .weights import WeightSchedule, schedule_weights, segment_shape_key


@dataclass
class CompileStats:
    """Process-wide counters of actual phase executions (memo hits excluded).

    ``benchmarks/dse_bench.py`` snapshots these around a sweep to prove the
    engine's work profile: one fuse/profile per graph, zero codegen during
    exploration. ``reset()`` zeroes all counters."""

    fuse_calls: int = 0
    profile_calls: int = 0
    weight_schedule_calls: int = 0
    weight_schedule_shape_hits: int = 0  # rebinds of a shape-equal schedule
    partition_calls: int = 0
    memory_plan_calls: int = 0
    codegen_calls: int = 0
    analysis_hits: int = 0
    analysis_misses: int = 0
    tables_builds: int = 0  # dense AnalysisTables exports (once per analysis)
    batched_score_calls: int = 0  # vectorized scoring passes (repro.dse.batched)

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)

    def snapshot(self) -> dict[str, int]:
        return dataclasses.asdict(self)


STATS = CompileStats()


@dataclass
class GraphAnalysis:
    """Config-independent compile artifact shared across all (a, b) configs.

    Holds the fused graph, the per-PU-kind node profiles, and a lazy memo of
    per-(node-segment, PU-kind) weight schedules with their derived stage
    overheads (stall + dynamic-chunk decode). Everything here depends only
    on graph content and PU *types* — never on how many PUs a configuration
    assigns — which is what makes one analysis serve a whole DSE sweep.
    Cached objects are treated as immutable by all downstream phases."""

    source_graph: Graph
    graph: Graph  # fused
    pu_kinds: dict[str, PUSpec]
    profiles: dict[str, dict[int, NodeProfile]]
    _wscheds: dict[tuple[tuple[int, ...], str], WeightSchedule] = field(
        default_factory=dict)
    _stage_overheads: dict[tuple[tuple[int, ...], str], float] = field(
        default_factory=dict)
    # shared f(i, u1, u2) table of the partition DP — its subproblems are
    # budget-independent, so config (a, b) reuses everything (a', b') solved
    _partition_memo: dict[tuple[int, int, int], float] = field(
        default_factory=dict)
    # lazy dense-array export for the vectorized DSE engine
    _tables: Optional[object] = field(default=None, repr=False, compare=False)

    def weight_schedule(self, nids: tuple[int, ...], pu_kind: str) -> WeightSchedule:
        """SMOF schedule for a contiguous node segment on one PU kind,
        computed once per distinct (segment-*shape*, kind) across every
        config: a segment shape-identical to an already-scheduled one (a
        repeated transformer block under a different partition offset)
        rebinds the cached allocation instead of re-running the greedy
        pass."""
        key = (tuple(nids), pu_kind)
        ws = self._wscheds.get(key)
        if ws is None:
            spec = self.pu_kinds[pu_kind]
            skey = (dataclasses.replace(spec, pid=-1, slr=-1),
                    segment_shape_key(self.graph, key[0]))
            canon = _WSCHED_SHAPE_CACHE.get(skey)
            if canon is not None:
                STATS.weight_schedule_shape_hits += 1
                ws = canon.rebound(key[0])
            else:
                STATS.weight_schedule_calls += 1
                ws = schedule_weights(self.graph, list(key[0]), spec)
                if len(_WSCHED_SHAPE_CACHE) >= _WSCHED_SHAPE_CACHE_MAX:
                    _WSCHED_SHAPE_CACHE.pop(next(iter(_WSCHED_SHAPE_CACHE)))
                _WSCHED_SHAPE_CACHE[skey] = ws
            self._wscheds[key] = ws
        return ws

    def stage_overhead(self, nids: tuple[int, ...], pu_kind: str) -> float:
        """Seconds added to a stage's profiled time: node-granular
        weight-stream stalls plus two CP instruction decodes per dynamic
        chunk (URAM_PRM + WEIGHTS_ADM issue), matching the codegen's
        one-node-lookahead chunk issue."""
        key = (tuple(nids), pu_kind)
        extra = self._stage_overheads.get(key)
        if extra is None:
            ws = self.weight_schedule(key[0], pu_kind)
            spec = self.pu_kinds[pu_kind]
            n_dyn = sum(t.dynamic_chunks for t in ws.tiles)
            extra = ws.total_stall() + 2 * n_dyn * DECODE_CYCLES / spec.sys_clk_hz
            self._stage_overheads[key] = extra
        return extra

    def tables(self) -> "object":
        """Dense-array export of this analysis for the vectorized DSE
        engine (``repro.compiler.tables.AnalysisTables``): per-kind node
        profiles, weight-tile layout, coupling edge geometry and (grown on
        demand) the dense partition-DP value table. Built lazily once per
        analysis and shared by every batched scoring call."""
        if self._tables is None:
            from .tables import AnalysisTables

            STATS.tables_builds += 1
            self._tables = AnalysisTables(self.graph, self.profiles,
                                          self.pu_kinds)
        return self._tables


# graph-fingerprint -> GraphAnalysis memo (bounded; LRU eviction — lookups
# re-insert their key so the front of the dict is always the coldest entry)
_ANALYSIS_CACHE: dict[tuple, GraphAnalysis] = {}
_ANALYSIS_CACHE_MAX = 32

# (normalized PU spec, segment shape key) -> canonical SMOF schedule,
# shared across *analyses*: depth-scaled variants of one architecture (and
# repeated blocks within one graph) are shape-identical per segment, so
# they rebind the canonical allocation (WeightSchedule.rebound) instead of
# re-running the greedy pass. Bounded; insertion-order eviction.
_WSCHED_SHAPE_CACHE: dict[tuple, WeightSchedule] = {}
_WSCHED_SHAPE_CACHE_MAX = 4096


def _kind_key(pus: list[PUSpec]) -> tuple:
    """Cache-key part for the PU *types* (pid/slr placement is irrelevant to
    profiling and weight scheduling). Last spec of each kind wins, matching
    the ``{p.kind: p}`` dict build below."""
    kinds = {p.kind: p for p in pus}
    return tuple(sorted(
        (k, dataclasses.replace(p, pid=-1, slr=-1)) for k, p in kinds.items()
    ))


def clear_analysis_cache() -> None:
    _ANALYSIS_CACHE.clear()
    _WSCHED_SHAPE_CACHE.clear()


def analyze(
    g: Graph,
    pus: Optional[list[PUSpec]] = None,
    *,
    already_fused: bool = False,
    use_cache: bool = True,
) -> GraphAnalysis:
    """Fuse + profile ``g`` for the PU kinds of ``pus``, memoized by graph
    fingerprint — the once-per-graph half of compilation. ``use_cache=False``
    builds (and does not store) a fresh artifact: the brute-force baseline
    path of ``repro.dse`` uses it to reproduce the pre-caching engine."""
    pus = pus if pus is not None else make_u50_system()
    key = (g.fingerprint(), bool(already_fused), _kind_key(pus))
    if use_cache:
        hit = _ANALYSIS_CACHE.get(key)
        if hit is not None:
            STATS.analysis_hits += 1
            # true LRU: re-insert on hit so eviction pops the coldest
            # entry, not simply the oldest-inserted one
            del _ANALYSIS_CACHE[key]
            _ANALYSIS_CACHE[key] = hit
            return hit
    STATS.analysis_misses += 1
    kinds = {p.kind: p for p in pus}
    if already_fused:
        fused = g
    else:
        STATS.fuse_calls += 1
        fused = fuse(g)
    STATS.profile_calls += 1
    profiles = profile_graph(
        fused, {k: kinds[k] for k in ("PU1x", "PU2x") if k in kinds})
    ana = GraphAnalysis(source_graph=g, graph=fused, pu_kinds=kinds,
                        profiles=profiles)
    if use_cache:
        if len(_ANALYSIS_CACHE) >= _ANALYSIS_CACHE_MAX:
            _ANALYSIS_CACHE.pop(next(iter(_ANALYSIS_CACHE)))
        _ANALYSIS_CACHE[key] = ana
    return ana


@dataclass
class CompiledModel:
    """One (a, b) configuration's compile result.

    The analytic model (``stage_times`` and everything derived from it) is
    materialized eagerly by :func:`place`; the executable form — the memory
    plan and the instruction programs — is generated lazily on first access
    of ``mem`` / ``programs``, so a DSE sweep that only reads predicted
    rates never runs memory planning or the 16-round instruction codegen."""

    graph: Graph  # fused
    source_graph: Graph
    part: Partition
    wscheds: dict[int, WeightSchedule]
    pid_map: dict[int, int]
    pu_specs: dict[int, PUSpec]
    rounds: int
    # analytic model
    stage_times: dict[int, float]  # incl. weight-streaming stalls
    analysis: GraphAnalysis
    # cross-stage credit-loop model (repro.compiler.coupling); None only for
    # hand-built instances, which fall back to the uncoupled max-stage view
    coupling: Optional[CouplingModel] = None
    n_pu1x: int = 0
    n_pu2x: int = 0
    # deferred-codegen context
    n_io: int = 4
    channel_pool: Optional[list[int]] = None
    _mem: Optional[MemoryPlan] = None
    _programs: Optional[list[PUProgram]] = None

    # -- lazy executable form ------------------------------------------------
    @property
    def mem(self) -> MemoryPlan:
        """Pipeline memory plan (buffer requirements + channel assignment),
        built on first access."""
        if self._mem is None:
            STATS.memory_plan_calls += 1
            plans = buffer_requirements(self.graph, self.part, n_io=self.n_io)
            self._mem = assign_channels(self.graph, self.part, plans,
                                        self.analysis.profiles,
                                        channel_pool=self.channel_pool)
        return self._mem

    @property
    def programs(self) -> list[PUProgram]:
        """Per-stage instruction programs, generated on first access (the
        deploy layer forces this; the DSE never reaches it)."""
        if self._programs is None:
            STATS.codegen_calls += 1
            self._programs = generate_programs(
                self.graph, self.part, self.mem, self.wscheds,
                self.pid_map, self.pu_specs, rounds=self.rounds,
            )
        return self._programs

    def ensure_programs(self) -> list[PUProgram]:
        """Force codegen now (deploy-time hook); returns the programs."""
        return self.programs

    # -- predicted performance (pre-simulation; the DSE cache) ---------------
    @property
    def predicted_round_time(self) -> float:
        """Steady-state round period: the coupled credit-system rate (max of
        the per-stage serial bounds and every cross-stage credit-loop bound),
        not merely ``max(stage_times)``."""
        if self.coupling is not None:
            return self.coupling.round_seconds
        return max(self.stage_times.values()) if self.stage_times else 0.0

    @property
    def predicted_fps(self) -> float:
        t = self.predicted_round_time
        return 1.0 / t if t else 0.0

    @property
    def predicted_latency(self) -> float:
        lat = sum(self.stage_times.values())
        if self.coupling is not None:
            lat += self.coupling.forward_latency_seconds
        return lat

    @property
    def used_tops(self) -> float:
        return sum(
            self.pu_specs[self.pid_map[s.index]].peak_tops
            for s in self.part.stages
            if s.nids
        )

    def pbe(self) -> float:
        # relative stage capacities from the PU specs themselves (peak_tops),
        # so a non-default PU array weights its stages correctly
        caps = {k: spec.peak_tops for k, spec in self.analysis.pu_kinds.items()}
        used = [s for s in self.part.stages if s.nids]
        tmax = self.predicted_round_time
        if not used or tmax == 0:
            return 0.0
        num = sum(self.stage_times[s.index] * caps[s.pu_kind] for s in used)
        den = tmax * sum(caps[s.pu_kind] for s in used)
        return num / den

    def compute_efficiency(self, peak_tops: Optional[float] = None) -> float:
        """CE = achieved GOPS / peak GOPS (of the PUs given; defaults to the
        PUs used by this configuration)."""
        peak = peak_tops if peak_tops is not None else self.used_tops
        gops = 2.0 * self.graph.total_macs() * self.predicted_fps / 1e9
        return gops / (peak * 1e3) if peak else 0.0


def assign_pids(part: Partition, pus: list[PUSpec]) -> dict[int, int]:
    """Map pipeline stages to physical PU ids by kind, in pipeline order."""
    free = {"PU1x": [p.pid for p in pus if p.kind == "PU1x"],
            "PU2x": [p.pid for p in pus if p.kind == "PU2x"]}
    pid_map: dict[int, int] = {}
    for s in part.stages:
        if not s.nids:
            continue
        if not free[s.pu_kind]:
            raise ValueError(f"no free {s.pu_kind} for stage {s.index}")
        pid_map[s.index] = free[s.pu_kind].pop(0)
    return pid_map


def place(
    analysis: GraphAnalysis,
    n_pu1x: int,
    n_pu2x: int,
    *,
    pus: Optional[list[PUSpec]] = None,
    rounds: int = 16,
    n_io: int = 4,
    pid_offset: dict[str, int] | None = None,
    channel_pool: list[int] | None = None,
) -> CompiledModel:
    """Place a pre-analyzed graph onto a (n_pu1x, n_pu2x) pipeline config.

    The cheap per-config step: DP partition over the analysis' cached
    profiles, weight schedules from the analysis memo, analytic stage times.
    Memory planning and instruction generation are deferred to the returned
    model's lazy ``mem``/``programs``. ``pus`` must carry the same PU kinds
    the analysis was built with (it defaults to the same fixed machine)."""
    pus = pus if pus is not None else make_u50_system()
    if _kind_key(pus) != _kind_key(list(analysis.pu_kinds.values())):
        raise ValueError(
            "place() was given PU specs whose kinds differ from the ones "
            "this GraphAnalysis was built with — re-run analyze(g, pus)"
        )
    fused = analysis.graph
    STATS.partition_calls += 1
    part = partition(fused, analysis.profiles, n_pu1x, n_pu2x,
                     memo=analysis._partition_memo)

    wscheds: dict[int, WeightSchedule] = {}
    stage_times: dict[int, float] = {}
    for s in part.stages:
        if not s.nids:
            continue
        wscheds[s.index] = analysis.weight_schedule(s.nids, s.pu_kind)
        stage_times[s.index] = s.time + analysis.stage_overhead(s.nids, s.pu_kind)

    # Cross-stage credit-loop coupling (repro.compiler.coupling): buffer
    # depths straight from the stage-distance analysis (cheap; the liveness/
    # channel planning behind ``.mem`` stays deferred) and ISU token
    # latencies on the *canonical* stage->pid assignment, so offset-placed
    # multi-batch members predict identically to the DSE cache.
    plans = buffer_requirements(fused, part, n_io=n_io)
    coupling = couple(fused, part, plans, stage_times,
                      assign_pids(part, pus), {p.pid: p for p in pus})

    if pid_offset:
        skip = dict(pid_offset)
        pool = []
        for p in pus:
            if skip.get(p.kind, 0) > 0:
                skip[p.kind] -= 1
                continue
            pool.append(p)
    else:
        pool = pus
    pid_map = assign_pids(part, pool)
    pu_specs = {p.pid: p for p in pus}

    return CompiledModel(
        graph=fused,
        source_graph=analysis.source_graph,
        part=part,
        wscheds=wscheds,
        pid_map=pid_map,
        pu_specs=pu_specs,
        rounds=rounds,
        stage_times=stage_times,
        analysis=analysis,
        coupling=coupling,
        n_pu1x=n_pu1x,
        n_pu2x=n_pu2x,
        n_io=n_io,
        channel_pool=channel_pool,
    )


def compile_model(
    g: Graph,
    n_pu1x: int,
    n_pu2x: int,
    *,
    pus: Optional[list[PUSpec]] = None,
    rounds: int = 16,
    n_io: int = 4,
    already_fused: bool = False,
    pid_offset: dict[str, int] | None = None,
    channel_pool: list[int] | None = None,
) -> CompiledModel:
    """Compile ``g`` for a (n_pu1x, n_pu2x) single-batch pipeline config —
    the one-call form of ``analyze`` + ``place`` (analysis memoized by graph
    fingerprint; programs generated lazily on first ``.programs`` access).

    ``pid_offset`` lets multi-batch deployments place this pipeline on a
    disjoint PU subset (e.g. {"PU1x": 2, "PU2x": 0} starts at the 3rd PU1x);
    ``channel_pool`` likewise gives it a disjoint HBM channel subset.
    """
    pus = pus if pus is not None else make_u50_system()
    return place(
        analyze(g, pus, already_fused=already_fused),
        n_pu1x,
        n_pu2x,
        pus=pus,
        rounds=rounds,
        n_io=n_io,
        pid_offset=pid_offset,
        channel_pool=channel_pool,
    )
