"""Top-level compilation driver: DNN graph -> executable PUPrograms.

Chains the framework phases of Fig. 4: fusion -> parse/profile -> DP
partitioning -> SMOF weight scheduling -> pipeline memory optimization ->
instruction generation. The result carries both the instruction programs
(executable on the discrete-event simulator) and the analytic performance
model used by the DSE (Sec. V-A).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..core.program import PUProgram
from ..core.pu import PUSpec, make_u50_system
from .codegen import generate_programs
from .fusion import fuse
from .graph import Graph
from .memory import MemoryPlan, assign_channels, buffer_requirements
from .partition import Partition, partition
from .profiler import DECODE_CYCLES, profile_graph
from .weights import WeightSchedule, schedule_weights


@dataclass
class CompiledModel:
    graph: Graph  # fused graph
    source_graph: Graph
    part: Partition
    mem: MemoryPlan
    wscheds: dict[int, WeightSchedule]
    programs: list[PUProgram]
    pid_map: dict[int, int]
    pu_specs: dict[int, PUSpec]
    rounds: int
    # analytic model
    stage_times: dict[int, float]  # incl. weight-streaming stalls
    n_pu1x: int = 0
    n_pu2x: int = 0

    # -- predicted performance (pre-simulation; the DSE cache) ---------------
    @property
    def predicted_round_time(self) -> float:
        return max(self.stage_times.values()) if self.stage_times else 0.0

    @property
    def predicted_fps(self) -> float:
        t = self.predicted_round_time
        return 1.0 / t if t else 0.0

    @property
    def predicted_latency(self) -> float:
        return sum(self.stage_times.values())

    @property
    def used_tops(self) -> float:
        return sum(
            self.pu_specs[self.pid_map[s.index]].peak_tops
            for s in self.part.stages
            if s.nids
        )

    def pbe(self) -> float:
        caps = {"PU1x": 1.0, "PU2x": 2.0}
        used = [s for s in self.part.stages if s.nids]
        tmax = self.predicted_round_time
        if not used or tmax == 0:
            return 0.0
        num = sum(self.stage_times[s.index] * caps[s.pu_kind] for s in used)
        den = tmax * sum(caps[s.pu_kind] for s in used)
        return num / den

    def compute_efficiency(self, peak_tops: Optional[float] = None) -> float:
        """CE = achieved GOPS / peak GOPS (of the PUs given; defaults to the
        PUs used by this configuration)."""
        peak = peak_tops if peak_tops is not None else self.used_tops
        gops = 2.0 * self.graph.total_macs() * self.predicted_fps / 1e9
        return gops / (peak * 1e3) if peak else 0.0


def assign_pids(part: Partition, pus: list[PUSpec]) -> dict[int, int]:
    """Map pipeline stages to physical PU ids by kind, in pipeline order."""
    free = {"PU1x": [p.pid for p in pus if p.kind == "PU1x"],
            "PU2x": [p.pid for p in pus if p.kind == "PU2x"]}
    pid_map: dict[int, int] = {}
    for s in part.stages:
        if not s.nids:
            continue
        if not free[s.pu_kind]:
            raise ValueError(f"no free {s.pu_kind} for stage {s.index}")
        pid_map[s.index] = free[s.pu_kind].pop(0)
    return pid_map


def compile_model(
    g: Graph,
    n_pu1x: int,
    n_pu2x: int,
    *,
    pus: Optional[list[PUSpec]] = None,
    rounds: int = 16,
    n_io: int = 4,
    already_fused: bool = False,
    pid_offset: dict[str, int] | None = None,
    channel_pool: list[int] | None = None,
) -> CompiledModel:
    """Compile ``g`` for a (n_pu1x, n_pu2x) single-batch pipeline config.

    ``pid_offset`` lets multi-batch deployments place this pipeline on a
    disjoint PU subset (e.g. {"PU1x": 2, "PU2x": 0} starts at the 3rd PU1x);
    ``channel_pool`` likewise gives it a disjoint HBM channel subset.
    """
    pus = pus if pus is not None else make_u50_system()
    fused = g if already_fused else fuse(g)

    kinds = {p.kind: p for p in pus}
    profiles = profile_graph(fused, {k: kinds[k] for k in ("PU1x", "PU2x") if k in kinds})
    part = partition(fused, profiles, n_pu1x, n_pu2x)

    # Weight-transfer schedules + refined stage times (partitioning and
    # weight scheduling are treated separately, as in the paper). The stall
    # term is node-granular (matching the codegen's one-node-lookahead chunk
    # issue, including attention weight-port streams); each dynamic chunk
    # also costs two CP instruction decodes (URAM_PRM + WEIGHTS_ADM issue).
    spec_of_kind = {p.kind: p for p in pus}
    wscheds: dict[int, WeightSchedule] = {}
    stage_times: dict[int, float] = {}
    for s in part.stages:
        if not s.nids:
            continue
        spec = spec_of_kind[s.pu_kind]
        ws = schedule_weights(fused, list(s.nids), spec)
        wscheds[s.index] = ws
        n_dyn = sum(t.dynamic_chunks for t in ws.tiles)
        chunk_decode = 2 * n_dyn * DECODE_CYCLES / spec.sys_clk_hz
        stage_times[s.index] = s.time + ws.total_stall() + chunk_decode

    plans = buffer_requirements(fused, part, n_io=n_io)
    mem = assign_channels(fused, part, plans, profiles, channel_pool=channel_pool)

    if pid_offset:
        skip = dict(pid_offset)
        pool = []
        for p in pus:
            if skip.get(p.kind, 0) > 0:
                skip[p.kind] -= 1
                continue
            pool.append(p)
    else:
        pool = pus
    pid_map = assign_pids(part, pool)
    pu_specs = {p.pid: p for p in pus}

    programs = generate_programs(
        fused, part, mem, wscheds, pid_map, pu_specs, rounds=rounds
    )

    return CompiledModel(
        graph=fused,
        source_graph=g,
        part=part,
        mem=mem,
        wscheds=wscheds,
        programs=programs,
        pid_map=pid_map,
        pu_specs=pu_specs,
        rounds=rounds,
        stage_times=stage_times,
        n_pu1x=n_pu1x,
        n_pu2x=n_pu2x,
    )
