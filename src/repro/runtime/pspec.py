"""Activation sharding-constraint hook.

Models are sharding-agnostic; the runtime installs a policy (named activation
points -> PartitionSpec) and models call ``constrain(x, name)`` at those
points. Outside a policy context this is a no-op, so models run identically
on a single device, under tests, and in interpret-mode kernels.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_POLICY: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "repro_sharding_policy", default=None
)


@contextlib.contextmanager
def activation_policy(mesh, specs: dict[str, P]):
    """Install named activation PartitionSpecs for the enclosed trace."""
    tok = _POLICY.set({"mesh": mesh, "specs": dict(specs)})
    try:
        yield
    finally:
        _POLICY.reset(tok)


def constrain(x, name: str):
    pol = _POLICY.get()
    if pol is None:
        return x
    spec = pol["specs"].get(name)
    if spec is None or len(spec) > x.ndim:
        return x
    # drop mesh axes that do not divide the dimension (e.g. seq-parallel
    # specs against a decode step's length-1 sequence axis)
    mesh = pol["mesh"]
    fixed = []
    for i, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        fixed.append(ax if x.shape[i] % n == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


def current_policy() -> Optional[dict]:
    return _POLICY.get()
