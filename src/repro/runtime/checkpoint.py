"""Fault-tolerant checkpointing: atomic save (tmp+rename), resume-by-step,
content manifest with config hash, and *elastic resharding* — a checkpoint
written on one mesh restores onto any other device count/topology (arrays
are stored unsharded; the restore path re-places them under the target
policy).

No orbax in this environment; the format is a directory of .npy files plus
a JSON manifest (flattened pytree paths -> files). Works for params,
optimizer state and data-pipeline state alike.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = leaf
    return flat


def config_fingerprint(cfg: Any) -> str:
    import dataclasses

    if dataclasses.is_dataclass(cfg):
        blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    else:
        blob = repr(cfg)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomic: write to tmp dir, fsync, rename to ckpt_<step>."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"ckpt_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory)
    try:
        flat = _flatten(tree)
        manifest = {"step": step, "arrays": {}, "extra": extra or {}}
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["arrays"][key] = {
                "file": fname,
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
            }
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("ckpt_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("ckpt_"))
    # ignore incomplete (un-renamed tmp dirs are dot-prefixed; double check
    # manifest presence for crash-during-rename robustness)
    for d in reversed(ckpts):
        if os.path.exists(os.path.join(directory, d, MANIFEST)):
            return int(d.split("_")[1])
    return None


def restore_checkpoint(
    directory: str,
    template: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple[Any, int, dict]:
    """Restore into the structure of ``template``. ``shardings`` (optional
    matching pytree of NamedSharding) re-places arrays onto the current mesh
    — this is the elastic-resharding path: the checkpoint does not care what
    topology wrote it."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    cdir = os.path.join(directory, f"ckpt_{step:010d}")
    with open(os.path.join(cdir, MANIFEST)) as f:
        manifest = json.load(f)

    flat_template = _flatten(template)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    missing = set(flat_template) - set(manifest["arrays"])
    if missing:
        raise ValueError(f"checkpoint missing arrays: {sorted(missing)[:5]}")

    leaves_by_key = {}
    for key, info in manifest["arrays"].items():
        if key not in flat_template:
            continue  # tolerated: extra arrays (e.g. shrunken config)
        arr = np.load(os.path.join(cdir, info["file"]))
        tmpl = flat_template[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs template {tmpl.shape}"
            )
        if key in flat_shard and flat_shard[key] is not None:
            leaf = jax.device_put(arr.astype(tmpl.dtype), flat_shard[key])
        else:
            leaf = jnp.asarray(arr.astype(tmpl.dtype))
        leaves_by_key[key] = leaf

    # unflatten in template order
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        for p, _ in paths
    ]
    restored = jax.tree_util.tree_unflatten(treedef, [leaves_by_key[k] for k in keys])
    return restored, step, manifest.get("extra", {})
