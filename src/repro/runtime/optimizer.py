"""Optimizers (no optax in this environment): AdamW with configurable
moment dtype, and Adafactor-style factored second moments.

Distributed-optimization notes: optimizer state inherits the parameter
sharding (ZeRO-style when FSDP is active — moments shard over data x model).
``moment_dtype=bfloat16`` halves optimizer HBM (needed for grok-1-314b on
16 GB/chip v5e: bf16 params+m+v = 6N bytes -> 7.3 GB/chip at 256 chips).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32  # bf16 halves optimizer memory
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(c: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - c.warmup_steps) / jnp.maximum(c.total_steps - c.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * warm * (c.min_lr_ratio + (1 - c.min_lr_ratio) * cos)


def adamw_init(c: AdamWConfig, params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, c.moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(c: AdamWConfig, grads: Any, opt_state: dict, params: Any):
    step = opt_state["step"] + 1
    lr = lr_schedule(c, step)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, c.grad_clip / (gnorm + 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m_new = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g
        v_new = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * jnp.square(g)
        mhat = m_new / (1 - c.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - c.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(c.moment_dtype), v_new.astype(c.moment_dtype)

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return params_new, {"step": step, "m": m_new, "v": v_new}, {"lr": lr, "grad_norm": gnorm}


# ------------------------------------------------- Adafactor (factored v) --
@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


def adafactor_init(c: AdafactorConfig, params: Any) -> dict:
    def zeros(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(zeros, params, is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(c: AdafactorConfig, grads: Any, opt_state: dict, params: Any):
    step = opt_state["step"] + 1
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-c.decay)

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + c.eps
        if p.ndim >= 2:
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            r = vr / jnp.mean(vr, axis=-1, keepdims=True)  # normalized rows
            denom = r[..., None] * vc[..., None, :]  # rank-1 estimate of v
            u = g * jax.lax.rsqrt(denom + c.eps)
            v_new = {"vr": vr, "vc": vc}
        else:
            v_full = beta * v["v"] + (1 - beta) * g2
            u = g * jax.lax.rsqrt(v_full)
            v_new = {"v": v_full}
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms / c.clip_threshold)
        p_new = p.astype(jnp.float32) - c.lr * (u + c.weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(opt_state["v"])
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    params_new = jax.tree.unflatten(tdef, [o[0] for o in outs])
    v_new = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return params_new, {"step": step, "v": v_new}, {}
