"""Deterministic synthetic data pipeline with checkpointable state.

Produces next-token-prediction batches from a seeded PRNG "document stream"
(zipfian token distribution with structured repetition so models can reduce
loss). State = (seed, step); capturing it in checkpoints makes restarts
bit-exact — the fault-tolerance tests rely on this. ``shard_for_host``
implements per-process sharding for multi-host feeding (each host generates
only its slice; the dry-run's global arrays are assembled by jit from
per-host shards in a real deployment).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    structure: int = 8  # repetition period that makes the stream learnable


@dataclass
class DataState:
    step: int = 0

    def as_dict(self) -> dict:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: dict) -> "DataState":
        return cls(step=int(d["step"]))


class TokenStream:
    """Stateless-per-step generator: batch(step) is a pure function of
    (config, step) — restart-safe and elastic (host count can change)."""

    def __init__(self, cfg: DataConfig, state: Optional[DataState] = None):
        self.cfg = cfg
        self.state = state or DataState()
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")

    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.n_hosts

    def _batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(np.uint64(c.seed * 1_000_003 + step))
        # zipfian-ish marginals + periodic structure
        base = rng.zipf(1.3, size=(c.global_batch, c.seq_len + 1)).astype(np.int64)
        base = base % (c.vocab_size - 2) + 1
        pos = np.arange(c.seq_len + 1)
        mask = (pos % c.structure) < (c.structure // 2)
        base[:, mask[: c.seq_len + 1]] = (
            np.arange(c.global_batch)[:, None] % 97 + 2
        )
        lo = self.cfg.host_id * self.host_batch
        hi = lo + self.host_batch
        toks = base[lo:hi]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def next(self) -> dict:
        batch = self._batch_at(self.state.step)
        self.state.step += 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()


def shard_for_host(batch: dict, mesh, policy) -> dict:
    """Place a host-local numpy batch onto the mesh under the policy's
    batch sharding (single-process: behaves like device_put)."""
    shardings = policy.inputs_sharding(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch)
    )
    return jax.tree.map(jax.device_put, batch, shardings)
