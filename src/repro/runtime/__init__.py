# TPU-scale distributed runtime: sharding policy (DP/TP/FSDP/SP),
# training/serving step factories, the instruction-program-driven pipeline
# executor (the paper's coordination technique on TPU), checkpointing with
# elastic resharding, and the data pipeline.
from . import checkpoint, data, optimizer, pipeline, pspec, serve, sharding, train

__all__ = [
    "checkpoint",
    "data",
    "optimizer",
    "pipeline",
    "pspec",
    "serve",
    "sharding",
    "train",
]
