"""Serving runtime: prefill + decode step factories (the dry-run's
``serve_step``) and a continuous-batching engine for the examples.

``make_serve_step`` builds the one-new-token step the decode_* shapes lower:
(params, caches, batch, pos) -> (next_token_logits, caches).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import transformer as tf
from .pspec import activation_policy
from .sharding import ShardingPolicy

import contextlib


@contextlib.contextmanager
def _null_ctx():
    yield


def _ctx(policy: Optional[ShardingPolicy]):
    if policy is None:
        return _null_ctx()
    return activation_policy(policy.mesh, policy.activation_specs())


def make_prefill(cfg: ArchConfig, policy: Optional[ShardingPolicy] = None):
    def prefill(params, batch):
        with _ctx(policy):
            logits, _ = tf.forward(cfg, params, batch)
        return logits

    return prefill


def make_serve_step(cfg: ArchConfig, policy: Optional[ShardingPolicy] = None):
    def serve_step(params, caches, batch, pos):
        with _ctx(policy):
            logits, caches = tf.decode_step(cfg, params, caches, batch, pos)
        return logits, caches

    return serve_step


# ---------------------------------------------------------- batching engine --
@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch.

    Requests are queued, assigned to free slots, prefilled one-by-one into
    the shared KV cache at their slot index, and decoded in lockstep; slots
    recycle as requests finish (finished slots keep decoding into a junk
    position, masked out — standard continuous batching on a static shape).
    """

    def __init__(self, cfg: ArchConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 eos_token: Optional[int] = None, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.eos = eos_token
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.caches = tf.init_cache(cfg, batch_slots, max_len, dtype)
        self.pos = [0] * batch_slots
        self._next_rid = 0
        self._decode = jax.jit(
            lambda p, c, b, pos: tf.decode_step(cfg, p, c, b, pos)
        )

    def submit(self, prompt: list[int], max_new_tokens: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(prompt), max_new_tokens,
                                  submitted_at=time.time()))
        return rid

    # -- internals ------------------------------------------------------------
    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.pos[i] = 0
                # prefill token-by-token into this slot's cache lane (simple
                # and uniform across SSM/attention families)
                for t in req.prompt:
                    self._step_slot(i, t)

    def _step_slot(self, i: int, token: int) -> int:
        batch = {"tokens": jnp.full((len(self.slots), 1), token, jnp.int32)}
        logits, caches = self._decode(
            self.params, self.caches, batch, jnp.int32(self.pos[i])
        )
        # Only slot i's cache lane must advance; others re-written with the
        # same values (decode writes every lane, but lanes are independent:
        # we slice the updated lane back in).
        self.caches = jax.tree.map(
            lambda old, new: jax.lax.dynamic_update_index_in_dim(
                old, jax.lax.dynamic_index_in_dim(new, i, 1, keepdims=False), i, 1
            )
            if old.ndim >= 2
            else new,
            self.caches,
            caches,
        )
        self.pos[i] += 1
        return int(jnp.argmax(logits[i, -1]))

    def step(self) -> None:
        """One engine tick: admit + one decode step for every active slot."""
        self._admit()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            last = req.generated[-1] if req.generated else req.prompt[-1]
            nxt = self._step_slot(i, last)
            req.generated.append(nxt)
            if len(req.generated) >= req.max_new_tokens or (
                self.eos is not None and nxt == self.eos
            ):
                req.done = True
                req.finished_at = time.time()
                self.finished.append(req)
                self.slots[i] = None

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while (self.queue or any(self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
