"""Per-architecture sharding policy over the production mesh.

Mesh axes: ("data", "model") single-pod 16x16, ("pod", "data", "model")
multi-pod 2x16x16. The "pod" axis is pure data parallelism; "data" carries
batch (plus FSDP weight sharding for the largest models); "model" carries
tensor parallelism.

Placement rules (chosen per arch by divisibility and size — DESIGN.md §5):
  * q-heads sharded on "model" when H % model_size == 0 ("heads" mode),
    otherwise row-parallel d_model contraction ("dmodel" mode, e.g. gemma3
    with H=8 < 16);
  * GQA k/v projections replicate when G < model_size (they are small);
    decode KV caches shard on head_dim when divisible, else on sequence;
  * MLP hidden / MoE d_ff / vocab dims shard on "model";
  * FSDP: when bf16 params / model_size exceed ~4 GB/chip, weight tensors
    additionally shard their d_model/vocab dim over "data" (grok-1, dbrx,
    internvl2);
  * SSM heads (mamba/rwkv) shard on "model" via activation constraints.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig

FSDP_THRESHOLD_BYTES = 4 << 30  # per-chip bf16 param budget before FSDP


def make_abstract_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]):
    """Device-free mesh carrying only (name, size) metadata.

    Policy construction (``make_policy``) only reads mesh *shape* metadata, so
    tests and planners can use an AbstractMesh without real devices. jax
    changed the AbstractMesh constructor from ``(shape, axis_names)`` to a
    single ``shape_tuple`` of (name, size) pairs (>= 0.4.36); this helper
    speaks whichever form the installed jax expects."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axis_names, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axis_names))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclass
class ShardingPolicy:
    cfg: ArchConfig
    mesh: Mesh
    batch_axes: tuple  # ("data",) or ("pod", "data")
    attn_mode: str  # "heads" | "dmodel"
    fsdp: bool
    model_size: int

    # ---------------------------------------------------------------- specs --
    def param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        """Spec for one parameter. Per-layer stacks under ``blocks/`` carry a
        leading layer dim: compute the spec on the unstacked shape, then
        prepend a replicated axis."""
        if "blocks/" in path and len(shape) >= 1:
            base = self._param_spec_base(path, shape[1:])
            return P(None, *base)
        return self._param_spec_base(path, shape)

    def _param_spec_base(self, path: str, shape: tuple[int, ...]) -> P:
        cfg, M = self.cfg, self.model_size
        fsdp_ax = "data" if self.fsdp else None

        def fs(dim_size):  # fsdp axis only if divisible
            return fsdp_ax if fsdp_ax and dim_size % self._data_size == 0 else None

        if path.endswith("embed"):
            return P("model", fs(shape[-1]))
        if path.endswith("lm_head"):
            return P(fs(shape[0]), "model")
        if path.endswith("patch_proj"):
            return P(None, "model")
        if re.search(r"attn/wq$", path):
            H = shape[-2]
            if self.attn_mode == "heads" and H % M == 0:
                return P(fs(shape[0]), "model", None)
            return P("model", None, None)  # row-parallel
        if re.search(r"attn/w[kv]$", path):
            G = shape[-2]
            if self.attn_mode == "heads" and G % M == 0:
                return P(fs(shape[0]), "model", None)
            if self.attn_mode == "heads":
                return P(fs(shape[0]), None, None)  # small: replicate on model
            return P("model", None, None)
        if re.search(r"attn/wo$", path):
            H = shape[0]
            if self.attn_mode == "heads" and H % M == 0:
                return P("model", None, fs(shape[-1]))
            return P(None, None, "model")
        if re.search(r"(q_norm|k_norm)$", path):
            return P(None)
        if re.search(r"moe/router$", path):
            return P(None, None)
        if re.search(r"moe/w_(in|gate)$", path):
            return P(None, fs(shape[-2]), "model")  # TP over d_ff + FSDP over d
        if re.search(r"moe/w_out$", path):
            return P(None, "model", fs(shape[-1]))
        if re.search(r"mlp/w_(in|gate)$", path) or path.endswith("cm_Wk"):
            return P(fs(shape[-2]), "model")
        if re.search(r"mlp/w_out$", path) or path.endswith("cm_Wv"):
            return P("model", fs(shape[-1]))
        if re.search(r"mamba/w_in$", path):
            return P("model", None)  # row-parallel into the SSD block
        if re.search(r"mamba/w_out$", path):
            return P(None, "model") if shape[-2] % M == 0 else P(None, None)
        if re.search(r"tm/W[rkvg]$", path) or path.endswith("cm_Wr"):
            # column-parallel: output d-sharded == wkv-head-sharded (64 heads
            # / 16 shards = 4 heads each), so the whole time-mix stays local
            # and only Wo's contraction all-reduces once per layer.
            return P(None, "model")
        if path.endswith("tm/Wo"):
            return P("model", None)  # row-parallel: consumes d-sharded y*g
        if re.search(r"tm/(A_mix|A_w|B_mix|B_w)$", path):
            return P(*([None] * len(shape)))  # tiny LoRA mats: replicate
        # norms, biases, scalars, conv kernels, small vectors: replicated
        return P(*([None] * len(shape)))

    @property
    def _data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def params_sharding(self, params_shape: Any) -> Any:
        """Pytree of NamedSharding matching a params(-shaped) pytree."""

        def fn(path, leaf):
            spec = self.param_spec(_path_str(path), leaf.shape)
            # drop axes that do not divide evenly (safety net)
            spec = self._validate(spec, leaf.shape)
            return NamedSharding(self.mesh, spec)

        return jax.tree_util.tree_map_with_path(fn, params_shape)

    def _validate(self, spec: P, shape: tuple[int, ...]) -> P:
        fixed = []
        for i, ax in enumerate(spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([self.mesh.shape[a] for a in axes]))
            fixed.append(ax if i < len(shape) and shape[i] % n == 0 else None)
        return P(*fixed)

    # -------------------------------------------------------------- inputs --
    def batch_spec(self, ndim: int) -> P:
        return P(self.batch_axes, *([None] * (ndim - 1)))

    def inputs_sharding(self, tree: Any) -> Any:
        return jax.tree.map(
            lambda x: NamedSharding(self.mesh, self._validate(self.batch_spec(len(x.shape)), x.shape)),
            tree,
        )

    # --------------------------------------------------------------- cache --
    def cache_spec(self, path: str, shape: tuple[int, ...]) -> P:
        cfg, M = self.cfg, self.model_size
        leaf_name = path.rsplit("/", 1)[-1]
        if leaf_name in ("k", "v"):  # (L, b, t, G, hd)
            L, b, t, G, hd = shape
            # flash-decoding layout: shard the cache SEQUENCE over "model" —
            # decode then gathers the tiny q instead of the huge cache, and
            # softmax only all-reduces per-row stats. (hd-sharding forces an
            # all-gather of the whole cache per layer: measured 1000x worse.)
            if t % M == 0:
                return P(None, self.batch_axes, "model", None, None)
            if hd % M == 0:
                return P(None, self.batch_axes, None, None, "model")
            return P(None, self.batch_axes, None, None, None)
        if leaf_name in ("ssm", "wkv"):  # (L, b, H, N|P, P)
            H = shape[2]
            return P(None, self.batch_axes, "model" if H % M == 0 else None, None, None)
        # conv state / shift registers: batch only
        return P(None, self.batch_axes, *([None] * (len(shape) - 2)))

    def cache_sharding(self, cache_shape: Any) -> Any:
        def fn(path, leaf):
            spec = self._validate(self.cache_spec(_path_str(path), leaf.shape), leaf.shape)
            return NamedSharding(self.mesh, spec)

        return jax.tree_util.tree_map_with_path(fn, cache_shape)

    # ---------------------------------------------------- activation policy --
    def activation_specs(self) -> dict[str, P]:
        B = self.batch_axes
        # sequence parallelism on the residual stream: saved (remat) per-layer
        # activations shard over data x model — constrain() drops the "model"
        # axis automatically when seq doesn't divide (e.g. decode steps).
        # Exception: token-shift families (rwkv) read x[t-1], and XLA lowers
        # the shifted concat on a seq-sharded tensor as a full all-gather
        # per projection — residuals stay seq-replicated there.
        sp_ax = None if self.cfg.family == "ssm" else "model"
        specs = {
            "emb": P(B, sp_ax, None),
            "residual": P(B, sp_ax, None),
            "logits": P(B, None, "model"),
            "ffn_hidden": P(B, None, "model"),
            "moe_dispatch": P(B, None, None, None),
            "moe_expert_in": P(B, None, None, None),
            "moe_hidden": P(B, None, None, "model"),
            "moe_expert_out": P(B, None, None, "model"),
            "decode_scores": P(B, None, None, "model"),
        }
        if self.attn_mode == "heads":
            specs["attn_q"] = P(B, None, "model", None)
            specs["attn_out"] = P(B, None, "model", None)
            specs["attn_chunk"] = P(None, B, "model", None, None)
        if self.cfg.family in ("ssm", "hybrid"):
            H = self.cfg.ssm_heads if self.cfg.family == "hybrid" else self.cfg.d_model // self.cfg.ssm_head_dim
            if H % self.model_size == 0:
                specs["ssm_x"] = P(B, None, "model", None)
                specs["wkv_state"] = P(B, None, "model", None, None)
        return specs


def make_policy(cfg: ArchConfig, mesh: Mesh) -> ShardingPolicy:
    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    model_size = mesh.shape["model"]
    attn_mode = "heads" if cfg.num_heads % model_size == 0 else "dmodel"
    params_bf16 = cfg.param_count() * 2
    fsdp = params_bf16 / model_size > FSDP_THRESHOLD_BYTES
    return ShardingPolicy(
        cfg=cfg,
        mesh=mesh,
        batch_axes=batch_axes,
        attn_mode=attn_mode,
        fsdp=fsdp,
        model_size=model_size,
    )
