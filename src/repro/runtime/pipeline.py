"""Pipeline-parallel inference executor — the paper's instruction-based
multi-PU coordination, adapted to TPU.

The compiler side mirrors Sec. IV: an analytic per-layer profile feeds the
same DP partitioner used for the FPGA (contiguous layer ranges -> stages,
minimizing the max stage time), and the coordination pattern is *emitted as
instruction programs* (LD: WAIT_REQ/SEND_ACK, CP: compute, ST:
WAIT_ACK/SEND_REQ with BID ping-pong) that execute on the discrete-event
simulator for schedule verification. The TPU lowering realizes the same
dependency structure as static dataflow: one jax.lax.scan over schedule
ticks inside shard_map, with lax.ppermute boundary transfers along the
"stage" mesh axis and the double-buffered carry playing the role of the
B0/B1 BID ping-pong.

Runtime strategy switching without reconfiguration (the paper's headline
feature): the same weights + mesh serve any (n_stages x data replicas)
deployment — changing strategy = swapping the compiled instruction schedule
(a re-jit), never re-provisioning the cluster.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map
except ImportError:  # older jax keeps it under experimental
    from jax.experimental.shard_map import shard_map

import inspect

# The replication-check kwarg was renamed check_rep -> check_vma across jax
# versions; pass whichever this jax understands.
_SHMAP_NOCHECK = (
    {"check_vma": False}
    if "check_vma" in inspect.signature(shard_map).parameters
    else {"check_rep": False}
)

from ..configs.base import ArchConfig
from ..core.isa import Compute, Group, Opcode, Sync
from ..core.program import Program, PUProgram
from ..models import transformer as tf
from ..models.layers import embed, rmsnorm, unembed

# ---------------------------------------------------------- analytic costs --
PEAK_FLOPS = 197e12
HBM_BW = 819e9


def layer_cost_seconds(cfg: ArchConfig, seq_len: int, batch: int, chips: int = 1) -> float:
    """Roofline max(compute, memory) for one transformer layer."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.resolved_head_dim
    H, G = cfg.num_heads, cfg.num_kv_heads
    tokens = seq_len * batch
    gate = 2 if cfg.mlp in ("swiglu", "geglu") else 1
    mlp_flops = 2 * tokens * d * f * (gate + 1)
    attn_proj = 2 * tokens * d * hd * (H + 2 * G) + 2 * tokens * H * hd * d
    attn_scores = 4 * tokens * min(seq_len, cfg.window if cfg.attn == "swa" else seq_len) * H * hd
    if cfg.family == "moe":
        mlp_flops *= cfg.top_k
    flops = (mlp_flops + attn_proj + attn_scores) / chips
    w_bytes = 2 * (d * f * (gate + 1) * (cfg.n_experts or 1) + d * hd * (H + 2 * G) + H * hd * d) / chips
    act_bytes = 2 * tokens * d * 6 / chips
    return max(flops / PEAK_FLOPS, (w_bytes + act_bytes) / HBM_BW)


# ----------------------------------------------------------------- planner --
@dataclass
class PipelinePlan:
    cfg: ArchConfig
    n_stages: int
    microbatches: int
    layers_per_stage: int  # padded (uniform for SPMD execution)
    boundaries: list[int]  # DP-optimal contiguous layer ranges
    stage_time_s: float  # analytic steady-state stage time
    programs: list[PUProgram] = field(default_factory=list)

    @property
    def predicted_throughput(self) -> float:
        return 1.0 / self.stage_time_s if self.stage_time_s else 0.0

    @property
    def predicted_latency(self) -> float:
        return (self.n_stages + self.microbatches - 1) * self.stage_time_s


def plan_pipeline(cfg: ArchConfig, *, n_stages: int, microbatches: int,
                  seq_len: int, microbatch_size: int,
                  chips_per_stage: int = 1) -> PipelinePlan:
    """DP-partition the layer stack into contiguous stages (Sec. IV-B with a
    homogeneous PU pool; heterogeneous stage widths = chips_per_stage lists
    are supported by the underlying partitioner in repro.compiler)."""
    L = cfg.num_layers
    per = layer_cost_seconds(cfg, seq_len, microbatch_size, chips_per_stage)
    # uniform layers => optimal contiguous cut is the balanced one
    base = L // n_stages
    extra = L % n_stages
    boundaries, acc = [0], 0
    for s in range(n_stages):
        acc += base + (1 if s < extra else 0)
        boundaries.append(acc)
    lps = math.ceil(L / n_stages)
    stage_time = lps * per
    plan = PipelinePlan(
        cfg=cfg,
        n_stages=n_stages,
        microbatches=microbatches,
        layers_per_stage=lps,
        boundaries=boundaries,
        stage_time_s=stage_time,
    )
    plan.programs = emit_stage_programs(plan)
    return plan


def emit_stage_programs(plan: PipelinePlan) -> list[PUProgram]:
    """The coordination pattern as ISA instruction programs (one PU per
    stage): verifiable on the discrete-event simulator, and the ground truth
    the shard_map lowering must realize."""
    from ..core.isa import AddrCyc, DataMove

    progs = []
    S, M = plan.n_stages, plan.microbatches
    cfg = plan.cfg
    mb_bytes = 64 * 1024  # symbolic microbatch activation footprint
    region = lambda s: 0x100_0000 * (s + 1)  # boundary tensor base per edge

    for s in range(S):
        first, last = s == 0, s == S - 1
        n_layers = plan.boundaries[s + 1] - plan.boundaries[s]

        ld_ops: list = []
        if not first:
            ld_ops.append(Sync(op=Opcode.WAIT_REQ, pid=s - 1, bid=0, base_bid=0, nc=1, ic=1))
        ld_ops += [
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=region(s), length=mb_bytes, channel=(2 * s) % 32),
            AddrCyc(ba=region(s), aoffs=mb_bytes, nc=1, ic=1),
        ]
        if not first:
            ld_ops.append(Sync(op=Opcode.SEND_ACK, pid=s - 1, bid=0, base_bid=0, nc=1, ic=1))

        # one aggregate GEMM per round (layer count folds into n)
        cp_ops = [
            Compute(
                m=min(cfg.d_model, 4095),
                n=min(1024 * max(1, n_layers), 65535),
                k=min(cfg.d_ff, 16383),
            )
        ]

        st_ops: list = []
        if not last:
            st_ops.append(Sync(op=Opcode.WAIT_ACK, pid=s + 1, bid=0, base_bid=0, nc=1, ic=1))
        st_ops += [
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=region(s + 1), length=mb_bytes, channel=(2 * s + 1) % 32),
            AddrCyc(ba=region(s + 1), aoffs=mb_bytes, nc=1, ic=1),
        ]
        if not last:
            st_ops.append(Sync(op=Opcode.SEND_REQ, pid=s + 1, bid=0, base_bid=0, nc=1, ic=1))

        # ACK-bypass prologue: this stage pre-authorizes its upstream
        # producer's two boundary buffers (Fig. 3 pattern).
        prologue = (
            [Sync(op=Opcode.SEND_ACK, pid=s - 1, bid=b, nc=0) for b in (0, 1)]
            if not first
            else []
        )
        ld = Program.assemble(Group.LD, prologue + ld_ops, rounds=M,
                              loop_ba=len(prologue), name=f"stage{s}.LD")
        cp = Program.assemble(Group.CP, cp_ops, rounds=M, name=f"stage{s}.CP")
        st = Program.assemble(Group.ST, st_ops, rounds=M, name=f"stage{s}.ST")
        progs.append(PUProgram(s, ld, cp, st, label=f"stage{s}"))
    return progs


# ---------------------------------------------------------------- executor --
def make_pipeline_mesh(n_stages: int, n_data: int = 1, n_model: int = 1):
    return jax.make_mesh((n_stages, n_data, n_model), ("stage", "data", "model"))


def stack_stage_params(cfg: ArchConfig, params: dict, plan: PipelinePlan) -> dict:
    """Restack per-layer params (L, ...) -> (S, layers_per_stage, ...) with
    zero padding for ragged final stages (padded layers are skipped by the
    validity mask in the stage body)."""
    blocks = params["blocks"]
    assert len(blocks) == 1, "pipeline executor supports uniform-stack archs"
    stacked = blocks[0]
    S, lps = plan.n_stages, plan.layers_per_stage

    def restack(x):
        L = x.shape[0]
        pad = S * lps - L
        xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
        return xp.reshape(S, lps, *x.shape[1:])

    out = dict(params)
    out["blocks"] = [jax.tree.map(restack, stacked)]
    return out


def make_pipeline_forward(cfg: ArchConfig, plan: PipelinePlan, mesh: Mesh):
    """Pipelined forward: (stage-stacked params, tokens (M, mb, s)) -> logits.

    SPMD over the "stage" axis; each tick every stage runs its layer block
    and ppermutes its activation to the next stage (BID ping-pong == the
    scan carry's double buffer). M microbatches drain in M + S - 1 ticks."""
    S, M, lps = plan.n_stages, plan.microbatches, plan.layers_per_stage
    L = cfg.num_layers

    def stage_body(params, x, stage_id):
        """Run this stage's layers on x (mb, s, d)."""
        layer_base = stage_id * lps

        def body(h, inp):
            li, p = inp
            valid = (layer_base + li) < L
            h_new, _ = tf._layer_forward(cfg, "dense", cfg.attn == "swa", p, h)
            h = jnp.where(valid, h_new, h)
            return h, None

        bparams = params["blocks"][0]
        x, _ = jax.lax.scan(body, x, (jnp.arange(lps), bparams))
        return x

    def _is_block_path(path) -> bool:
        return any(str(getattr(k, "key", "")) == "blocks" for k in path)

    def fn(params, tokens):
        # params: stage-stacked; tokens: (M, mb, s)
        def shard_fn(params_s, tokens_s):
            # block params arrive as (1, lps, ...) stage slices; embeddings /
            # head / norms are replicated across stages
            params_local = jax.tree_util.tree_map_with_path(
                lambda p, x: x[0] if _is_block_path(p) else x,
                params_s,
            )
            stage_id = jax.lax.axis_index("stage")
            mb, s = tokens_s.shape[1], tokens_s.shape[2]
            d = cfg.d_model
            dtype = params_local["embed"].dtype

            n_ticks = M + S - 1
            carry_in = jnp.zeros((mb, s, d), dtype)
            outputs = jnp.zeros((M, mb, s, cfg.vocab_size), jnp.float32)

            def tick(state, t):
                carry, outs = state
                mb_idx = jnp.clip(t, 0, M - 1)
                x_first = embed(params_local["embed"], tokens_s[mb_idx])
                x = jnp.where(stage_id == 0, x_first, carry)
                h = stage_body(params_local, x, stage_id)
                # emit logits at the last stage for valid ticks
                out_idx = jnp.clip(t - (S - 1), 0, M - 1)
                hn = rmsnorm(h, params_local["final_norm"], cfg.norm_eps)
                logits = unembed(
                    params_local["embed"] if cfg.tie_embeddings else params_local["lm_head"],
                    hn, tied=cfg.tie_embeddings,
                ).astype(jnp.float32)
                emit = (stage_id == S - 1) & (t >= S - 1)
                outs = jax.lax.dynamic_update_index_in_dim(
                    outs, jnp.where(emit, logits, outs[out_idx]), out_idx, 0
                )
                # boundary transfer: stage i -> i+1 (the SEND_REQ/WAIT_REQ pair)
                nxt = jax.lax.ppermute(
                    h, "stage", [(i, (i + 1) % S) for i in range(S)]
                )
                return (nxt, outs), None

            (carry, outputs), _ = jax.lax.scan(
                tick, (carry_in, outputs), jnp.arange(n_ticks)
            )
            return outputs[None]  # re-add stage dim for the out_spec

        pspec_params = jax.tree_util.tree_map_with_path(
            lambda p, _: P("stage") if _is_block_path(p) else P(), params
        )
        out = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(pspec_params, P()),
            out_specs=P("stage"),
            **_SHMAP_NOCHECK,
        )(params, tokens)
        # logits live on the last stage; slice it out
        return out[-1]

    return fn
