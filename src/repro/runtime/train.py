"""Training step factory: loss, grads, optimizer update — sharded via the
policy, remat'd scan-over-layers, optional microbatch gradient accumulation
(compute/comm overlap falls out of XLA's async collectives over the
accumulation loop).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import transformer as tf
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .pspec import activation_policy
from .sharding import ShardingPolicy

Z_LOSS = 1e-4
MOE_AUX_WEIGHT = 1e-2


def loss_fn(cfg: ArchConfig, params: Any, batch: dict, *, remat: bool = True):
    logits, aux = tf.forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    loss = jnp.sum(nll) / denom
    # z-loss stabilizes the softmax normalizer at scale
    zl = Z_LOSS * jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)))
    total = loss + zl + MOE_AUX_WEIGHT * aux["moe_aux"]
    return total, {"nll": loss, "z_loss": zl, "moe_aux": aux["moe_aux"]}


def make_train_step(
    cfg: ArchConfig,
    policy: Optional[ShardingPolicy],
    opt_cfg: AdamWConfig,
    *,
    remat: bool = True,
    microbatch: int = 1,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatch > 1`` splits the per-step batch into that many accumulation
    chunks (scan), trading HBM for serialization — the knob the weight-
    streaming scheduler of the paper corresponds to at TPU scale."""

    def compute_grads(params, batch):
        if microbatch <= 1:
            (tot, met), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch, remat=remat), has_aux=True
            )(params)
            return grads, met

        def split(x):
            return x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:])

        chunks = jax.tree.map(split, batch)

        def acc_body(carry, chunk):
            gsum, _ = carry
            (tot, met), g = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, chunk, remat=remat), has_aux=True
            )(params)
            gsum = jax.tree.map(jnp.add, gsum, g)
            return (gsum, met), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        met0 = {"nll": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32),
                "moe_aux": jnp.zeros((), jnp.float32)}
        (gsum, met), _ = jax.lax.scan(acc_body, (zero, met0), chunks)
        grads = jax.tree.map(lambda g: g / microbatch, gsum)
        return grads, met

    def train_step(params, opt_state, batch):
        ctx = (
            activation_policy(policy.mesh, policy.activation_specs())
            if policy is not None
            else _null_ctx()
        )
        with ctx:
            grads, met = compute_grads(params, batch)
            params_new, opt_new, stats = adamw_update(opt_cfg, grads, opt_state, params)
        return params_new, opt_new, {**met, **stats}

    return train_step


def init_train_state(cfg: ArchConfig, opt_cfg: AdamWConfig, key, dtype=jnp.bfloat16):
    params = tf.init_params(cfg, key, dtype)
    opt_state = adamw_init(opt_cfg, params)
    return params, opt_state


import contextlib


@contextlib.contextmanager
def _null_ctx():
    yield
