"""Three-step Design Space Exploration (paper Sec. V-A, Fig. 5).

Step 1 — enumerate all feasible single-batch configurations (a, b): a PU1x +
b PU2x units pipelining one batch. With 5+5 PUs this yields 35 configs; each
is compiled through the full framework and its performance cached.

Step 2 — compose multi-batch schedules: all unordered combinations of
single-batch configurations within the PU resource constraint. Each batch is
processed by a disjoint PU subset with internal pipeline parallelism (hybrid
parallelism). Schedule metrics: aggregated throughput, system latency (the
slowest member), cumulative TOPS of assigned PUs.

Step 3 — Pareto analysis (repro.dse.pareto) + application constraints.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

from ..compiler.compile import CompiledModel, compile_model
from ..compiler.graph import Graph
from ..core.pu import PUSpec, make_u50_system
from .pareto import pareto_front

PU1X_TOPS = 0.3072
PU2X_TOPS = 0.6144


@dataclass(frozen=True)
class SingleBatchPoint:
    a: int  # PU1x units
    b: int  # PU2x units
    fps: float
    latency: float
    tops: float
    pbe: float

    @property
    def config(self) -> tuple[int, int]:
        return (self.a, self.b)

    # uniform schedule-like view (shared with MultiBatchSchedule) so DSE
    # consumers can read throughput/batch/configs off any design point
    @property
    def throughput(self) -> float:
        return self.fps

    @property
    def batch(self) -> int:
        return 1

    @property
    def configs(self) -> tuple[tuple[int, int], ...]:
        return (self.config,)


@dataclass(frozen=True)
class MultiBatchSchedule:
    configs: tuple[tuple[int, int], ...]  # sorted (a,b) per concurrent batch
    throughput: float  # aggregated fps
    latency: float  # slowest member pipeline
    tops: float  # cumulative DSP TOPS
    system_pbe: float  # capacity-weighted busy fraction across all members

    @property
    def batch(self) -> int:
        return len(self.configs)

    @property
    def total_a(self) -> int:
        return sum(c[0] for c in self.configs)

    @property
    def total_b(self) -> int:
        return sum(c[1] for c in self.configs)


def enumerate_single_batch(
    g: Graph,
    *,
    n_pu1x: int = 5,
    n_pu2x: int = 5,
    pus: Optional[list[PUSpec]] = None,
    keep_compiled: bool = False,
) -> tuple[list[SingleBatchPoint], dict[tuple[int, int], CompiledModel]]:
    """Step 1: compile every (a, b) and cache its characteristics."""
    pus = pus if pus is not None else make_u50_system()
    points: list[SingleBatchPoint] = []
    compiled: dict[tuple[int, int], CompiledModel] = {}
    for a in range(n_pu1x + 1):
        for b in range(n_pu2x + 1):
            if a + b == 0:
                continue
            cm = compile_model(g, a, b, pus=pus)
            pt = SingleBatchPoint(
                a=a,
                b=b,
                fps=cm.predicted_fps,
                latency=cm.predicted_latency,
                tops=cm.used_tops,
                pbe=cm.pbe(),
            )
            points.append(pt)
            if keep_compiled:
                compiled[(a, b)] = cm
    return points, compiled


def enumerate_multi_batch(
    points: list[SingleBatchPoint],
    *,
    n_pu1x: int = 5,
    n_pu2x: int = 5,
) -> list[MultiBatchSchedule]:
    """Step 2: all unordered combinations under the PU resource constraint."""
    by_cfg = {p.config: p for p in points}
    cfgs = sorted(by_cfg)  # deterministic order for unordered enumeration
    schedules: list[MultiBatchSchedule] = []

    def rec(idx: int, rem_a: int, rem_b: int, chosen: list[tuple[int, int]]) -> None:
        if chosen:
            members = [by_cfg[c] for c in chosen]
            thr = sum(m.fps for m in members)
            lat = max(m.latency for m in members)
            tops = sum(m.tops for m in members)
            # system PBE: capacity-weighted utilization across members; each
            # member's PUs are busy pbe fraction of its round.
            pbe = sum(m.pbe * m.tops for m in members) / tops if tops else 0.0
            schedules.append(
                MultiBatchSchedule(
                    configs=tuple(sorted(chosen)),
                    throughput=thr,
                    latency=lat,
                    tops=tops,
                    system_pbe=pbe,
                )
            )
        for i in range(idx, len(cfgs)):
            a, b = cfgs[i]
            if a <= rem_a and b <= rem_b:
                chosen.append((a, b))
                rec(i, rem_a - a, rem_b - b, chosen)  # multiset: reuse i
                chosen.pop()

    rec(0, n_pu1x, n_pu2x, [])
    return schedules


@dataclass(frozen=True)
class ValidationRecord:
    """Analytic-cache cross-check: one schedule simulated end to end."""

    configs: tuple[tuple[int, int], ...]
    analytic_fps: float
    simulated_fps: float

    @property
    def rel_err(self) -> float:
        if not self.analytic_fps:
            return float("inf")
        return abs(self.simulated_fps - self.analytic_fps) / self.analytic_fps


@dataclass
class DSEResult:
    single: list[SingleBatchPoint]
    multi: list[MultiBatchSchedule]
    single_frontier: list[SingleBatchPoint]
    multi_frontier: list[MultiBatchSchedule]
    # deployment context: what was explored, on which machine
    graph: Optional[Graph] = None
    pus: Optional[list[PUSpec]] = None
    validation: list[ValidationRecord] = field(default_factory=list)

    def deploy(self, point_or_schedule, *, rounds: int = 16):
        """Compile any Step-1 point / Step-2 schedule (or raw config tuple)
        of this exploration into an executable Deployment — every DSE design
        point is one call away from the simulator."""
        if self.graph is None:
            raise ValueError("this DSEResult carries no graph to deploy")
        from ..deploy import Strategy, compile_deployment

        return compile_deployment(
            self.graph, Strategy.of(point_or_schedule), pus=self.pus, rounds=rounds
        )

    def simulate(self, point_or_schedule, *, rounds: int = 5):
        """Deploy + execute on a fresh fixed system; returns the SimResult."""
        from ..deploy import System

        dep = self.deploy(point_or_schedule, rounds=rounds)
        return System(pus=self.pus).load(dep).run()

    # paper design points -----------------------------------------------------
    @property
    def dp_a(self) -> SingleBatchPoint:
        """Highest single-batch throughput (pipeline across all PUs)."""
        return max(self.single, key=lambda p: p.fps)

    @property
    def dp_b(self) -> MultiBatchSchedule:
        """Max system throughput at the smallest batch achieving it."""
        best = max(self.multi, key=lambda s: s.throughput)
        near = [s for s in self.multi if s.throughput >= 0.995 * best.throughput]
        return min(near, key=lambda s: (s.batch, s.latency))

    @property
    def dp_c(self) -> MultiBatchSchedule:
        """Maximum batch-level parallelism: one PU per batch."""
        target = tuple(sorted([(1, 0)] * 5 + [(0, 1)] * 5))
        for s in self.multi:
            if s.configs == target:
                return s
        raise LookupError("one-PU-per-batch schedule missing")


def explore(g: Graph, *, n_pu1x: int = 5, n_pu2x: int = 5,
            tolerance: float = 0.0, pus: Optional[list[PUSpec]] = None,
            validate: int = 0, validate_rounds: int = 5) -> DSEResult:
    """Run the three DSE steps; optionally cross-check the analytic cache.

    ``validate=N`` deploys + simulates up to N schedules (the design points
    DP-A/C/B first, then the throughput-ordered multi-batch frontier) and
    records analytic-vs-simulated throughput in ``DSEResult.validation``."""
    pus = pus if pus is not None else make_u50_system()
    single, _ = enumerate_single_batch(g, n_pu1x=n_pu1x, n_pu2x=n_pu2x, pus=pus)
    multi = enumerate_multi_batch(single, n_pu1x=n_pu1x, n_pu2x=n_pu2x)
    sf = pareto_front(
        single, [lambda p: p.fps, lambda p: -p.latency], tolerance=tolerance
    )
    mf = pareto_front(
        multi, [lambda s: s.throughput, lambda s: -s.latency], tolerance=tolerance
    )
    res = DSEResult(single=single, multi=multi, single_frontier=sf,
                    multi_frontier=mf, graph=g, pus=pus)
    if validate > 0:
        candidates: list = []
        for dp in ("dp_a", "dp_c", "dp_b"):
            try:
                candidates.append(getattr(res, dp))
            except LookupError:
                pass
        seen = {c.configs for c in candidates}
        for s in sorted(mf, key=lambda s: -s.throughput):
            if s.configs not in seen:
                candidates.append(s)
                seen.add(s.configs)
        for cand in candidates[:validate]:
            sim = res.simulate(cand, rounds=validate_rounds)
            res.validation.append(
                ValidationRecord(
                    configs=cand.configs,
                    analytic_fps=cand.throughput,
                    simulated_fps=sim.aggregate_fps(warmup=2),
                )
            )
    return res
