"""Three-step Design Space Exploration (paper Sec. V-A, Fig. 5).

Step 1 — enumerate all feasible single-batch configurations (a, b): a PU1x +
b PU2x units pipelining one batch. With 5+5 PUs this yields 35 configs. The
config-independent compile work (fusion, profiling, per-segment weight
scheduling) is done **once per graph** (``repro.compiler.analyze``, memoized
by graph fingerprint) and every config is evaluated by the cheap
``repro.compiler.place`` — no memory planning and no instruction codegen
happens anywhere in the sweep; programs are generated lazily only when a
design point is actually deployed.

Step 2 — compose multi-batch schedules: all unordered combinations of
single-batch configurations within the PU resource constraint. Each batch is
processed by a disjoint PU subset with internal pipeline parallelism (hybrid
parallelism). Schedule metrics: aggregated throughput, system latency (the
slowest member), cumulative TOPS of assigned PUs. Member configs that are
strictly Pareto-dominated at equal-or-lower PU cost are pruned from the
composition (frontier- and DP-point-preserving at tolerance 0; margin-aware
at tolerance > 0; see ``_cost_dominated_configs``).

Step 3 — Pareto analysis (repro.dse.pareto; sort-based O(n log n) for the
2-objective case) + application constraints.

Multi-tenant co-exploration (``explore_multi``) generalizes Step 2 across
*models*: each tenant graph gets its own Step-1 cache (tenants referencing
the same graph content share one), joint placements assign every tenant a
disjoint (a, b) slice of the one machine, and the Pareto front is taken over
the vector of per-tenant rates — the FPGA-virtualization scenario (different
models serving different tenants) on the paper's fixed PU array. The joint
recursion is bounded by remaining-budget best-case throughput: a partial
placement whose optimistic completion is already strictly dominated by a
found point is abandoned.

``explore``/``explore_multi`` accept three engines. ``engine="batched"``
(the default; ``"fast"`` is kept as an alias) scores every Step-1 config in
one vectorized pass over the dense ``AnalysisTables`` export
(``repro.dse.batched``); ``engine="scalar"`` runs the same analytic model
one ``place()`` call per config; ``engine="reference"`` is the pre-caching
brute-force engine (full recompile incl. eager codegen per config, unpruned
composition, O(n²) Pareto) — the oracle the equivalence tests and
``benchmarks/dse_bench.py`` measure the other two against. All three
produce byte-identical frontiers and design points at tolerance 0.

``explore_multi(prev=...)`` re-explores incrementally: Step-1 caches of
tenants already present in a prior result are reused (matched by graph
fingerprint under the same PU array and budget) and the prior frontier
seeds the joint recursion's incumbent set, so a one-tenant change re-scores
only the changed tenant — exactly frontier-preserving.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..compiler.compile import analyze, place
from ..compiler.graph import Graph
from ..core.pu import PUSpec, make_u50_system
from .pareto import pareto_front, pareto_front_bruteforce

PU1X_TOPS = 0.3072
PU2X_TOPS = 0.6144


@dataclass(frozen=True)
class SingleBatchPoint:
    a: int  # PU1x units
    b: int  # PU2x units
    fps: float
    latency: float
    tops: float
    pbe: float

    @property
    def config(self) -> tuple[int, int]:
        return (self.a, self.b)

    # uniform schedule-like view (shared with MultiBatchSchedule) so DSE
    # consumers can read throughput/batch/configs off any design point
    @property
    def throughput(self) -> float:
        return self.fps

    @property
    def batch(self) -> int:
        return 1

    @property
    def configs(self) -> tuple[tuple[int, int], ...]:
        return (self.config,)


@dataclass(frozen=True)
class MultiBatchSchedule:
    configs: tuple[tuple[int, int], ...]  # sorted (a,b) per concurrent batch
    throughput: float  # aggregated fps
    latency: float  # slowest member pipeline
    tops: float  # cumulative DSP TOPS
    system_pbe: float  # capacity-weighted busy fraction across all members

    @property
    def batch(self) -> int:
        return len(self.configs)

    @property
    def total_a(self) -> int:
        return sum(c[0] for c in self.configs)

    @property
    def total_b(self) -> int:
        return sum(c[1] for c in self.configs)


def _point_of(cm, a: int, b: int) -> SingleBatchPoint:
    return SingleBatchPoint(a=a, b=b, fps=cm.predicted_fps,
                            latency=cm.predicted_latency, tops=cm.used_tops,
                            pbe=cm.pbe())


def _normalize_engine(engine: str) -> str:
    """Canonical engine name: "batched" (vectorized scorer, the default),
    "scalar" (per-config ``place()``), "reference" (pre-caching brute
    force). "fast" is the deprecated historical alias of the default."""
    if engine == "fast":
        from .._deprecation import warn_deprecated
        warn_deprecated(
            'engine="fast" is deprecated; use engine="batched" (the '
            "default vectorized scorer)", skip=("repro.dse.explorer",))
        return "batched"
    if engine not in ("batched", "scalar", "reference"):
        raise ValueError(f"unknown engine {engine!r}")
    return engine


def enumerate_single_batch(
    g: Graph,
    *,
    n_pu1x: int = 5,
    n_pu2x: int = 5,
    pus: Optional[list[PUSpec]] = None,
    engine: str = "batched",
) -> list[SingleBatchPoint]:
    """Step 1: evaluate every (a, b) against one shared graph analysis.

    Fusion/profiling/weight-scheduling results come from the memoized
    ``analyze`` artifact; no instructions are generated. With the default
    ``engine="batched"`` the whole sweep is one vectorized scoring pass
    over the dense analysis tables (``repro.dse.batched``);
    ``engine="scalar"`` pays one ``place()`` call per config. The two
    return byte-identical points."""
    if engine not in ("batched", "scalar"):
        raise ValueError(f"unknown Step-1 engine {engine!r}")
    pus = pus if pus is not None else make_u50_system()
    ana = analyze(g, pus)
    configs = [(a, b)
               for a in range(n_pu1x + 1)
               for b in range(n_pu2x + 1)
               if a + b > 0]
    if engine == "batched":
        from .batched import score_single_batch

        return score_single_batch(ana, configs, pus=pus)
    return [_point_of(place(ana, a, b, pus=pus), a, b) for a, b in configs]


def enumerate_single_batch_reference(
    g: Graph,
    *,
    n_pu1x: int = 5,
    n_pu2x: int = 5,
    pus: Optional[list[PUSpec]] = None,
) -> list[SingleBatchPoint]:
    """The pre-caching Step 1: re-run the *entire* compiler — fusion,
    profiling, weight scheduling, memory planning and eager instruction
    codegen whose programs are immediately discarded — once per config.
    Kept as the brute-force baseline for the equivalence suite and the
    before/after measurements of ``benchmarks/dse_bench.py``."""
    pus = pus if pus is not None else make_u50_system()
    points: list[SingleBatchPoint] = []
    for a in range(n_pu1x + 1):
        for b in range(n_pu2x + 1):
            if a + b == 0:
                continue
            ana = analyze(g, pus, use_cache=False)
            cm = place(ana, a, b, pus=pus)
            cm.ensure_programs()  # eager codegen, as the old engine did
            points.append(_point_of(cm, a, b))
    return points


def _cost_dominated_configs(
    by_cfg: dict[tuple[int, int], SingleBatchPoint],
    *,
    use_latency: bool,
    fps_margin: float = 0.0,
) -> set[tuple[int, int]]:
    """Member configs strictly dominated at equal-or-lower PU cost: another
    config uses no more PU1x and no more PU2x yet achieves *strictly* higher
    fps — by more than ``fps_margin`` — (and, with ``use_latency``, no worse
    latency).

    Composing with such a config can never help: swapping in the dominating
    config yields a feasible schedule with the same batch and strictly
    higher throughput (throughput — per schedule or per tenant — is a sum
    resp. a vector component, so the member-level improvement is never
    masked) — so at tolerance 0 every schedule containing a dominated config
    is strictly dominated (off the frontier) and DP-B's tie-breaks resolve
    to the surviving, earlier-enumerated schedule. The fps *strictness* is
    load-bearing: a config better only in latency must be kept, because
    schedule latency is a max over members and another member can mask the
    improvement, leaving the two schedules exactly tied — and tied schedules
    are all frontier members. Exact fps ties (common when extra PUs add
    nothing) are therefore never pruned, which keeps frontiers byte-identical
    to the brute-force path.

    ``use_latency=True`` (single-model Step 2) additionally requires the
    dominating config not to worsen latency, since schedule latency is an
    objective there; ``use_latency=False`` (multi-tenant joint placements)
    ignores latency because the joint frontier is over fps vectors only.

    ``fps_margin > 0`` is the tolerance-aware mode (see
    ``enumerate_multi_batch``): with margin ``tolerance * T_max`` (``T_max``
    the best achievable schedule throughput) every schedule containing a
    pruned config has a kept swap-in counterpart *strictly beyond its
    throughput tolerance threshold* at no worse latency — so the exact
    frontier, every DP point, and the tolerant-frontier membership of every
    kept schedule are preserved (the tolerant frontier of the pruned set is
    the reference tolerant frontier restricted to kept schedules). Exact
    set-equality of tolerant frontiers is unattainable for *any* engaged
    config prune: schedule latency is a max over members, so another member
    can mask the latency axis of the tolerance-dominance test."""
    dead: set[tuple[int, int]] = set()
    for c, p in by_cfg.items():
        for c2, q in by_cfg.items():
            if (c2 != c and c2[0] <= c[0] and c2[1] <= c[1]
                    and q.fps > p.fps + fps_margin
                    and (not use_latency or q.latency <= p.latency)):
                dead.add(c)
                break
    return dead


def _max_schedule_throughput(
    by_cfg: dict[tuple[int, int], SingleBatchPoint],
    n_pu1x: int,
    n_pu2x: int,
) -> float:
    """Best achievable total fps of any multi-batch schedule under the PU
    budget (unbounded 2-D knapsack over member configs). Upper-bounds every
    composed schedule's throughput — the normalizer that turns the relative
    Pareto ``tolerance`` into the absolute ``fps_margin`` of
    ``_cost_dominated_configs``."""
    dp = [[0.0] * (n_pu2x + 1) for _ in range(n_pu1x + 1)]
    for (a, b), p in by_cfg.items():
        if p.fps <= 0.0:
            continue
        for ra in range(a, n_pu1x + 1):
            row = dp[ra]
            src = dp[ra - a]
            for rb in range(b, n_pu2x + 1):
                cand = src[rb - b] + p.fps
                if cand > row[rb]:
                    row[rb] = cand
    return dp[n_pu1x][n_pu2x]


def enumerate_multi_batch(
    points: list[SingleBatchPoint],
    *,
    n_pu1x: int = 5,
    n_pu2x: int = 5,
    prune: bool = True,
    tolerance: float = 0.0,
) -> list[MultiBatchSchedule]:
    """Step 2: all unordered combinations under the PU resource constraint.

    ``prune=True`` drops member configs that are strictly dominated at
    equal-or-lower cost before composing (see ``_cost_dominated_configs``) —
    pass ``prune=False`` for the exhaustive brute-force composition.

    ``tolerance`` is the Pareto tolerance of the downstream frontier
    extraction: at ``tolerance > 0`` the dominance test demands an fps
    margin of ``tolerance * T_max`` so pruning stays engaged without
    touching the exact frontier, the DP points, or the tolerant-frontier
    membership of any kept schedule (a dropped schedule always has a kept
    counterpart more than ``tolerance`` ahead in throughput at no worse
    latency)."""
    by_cfg = {p.config: p for p in points}
    cfgs = sorted(by_cfg)  # deterministic order for unordered enumeration
    if prune:
        margin = (tolerance * _max_schedule_throughput(by_cfg, n_pu1x, n_pu2x)
                  if tolerance > 0.0 else 0.0)
        dead = _cost_dominated_configs(by_cfg, use_latency=True,
                                       fps_margin=margin)
        cfgs = [c for c in cfgs if c not in dead]
    schedules: list[MultiBatchSchedule] = []

    def rec(idx: int, rem_a: int, rem_b: int, chosen: list[tuple[int, int]]) -> None:
        if chosen:
            members = [by_cfg[c] for c in chosen]
            thr = sum(m.fps for m in members)
            lat = max(m.latency for m in members)
            tops = sum(m.tops for m in members)
            # system PBE: capacity-weighted utilization across members; each
            # member's PUs are busy pbe fraction of its round.
            pbe = sum(m.pbe * m.tops for m in members) / tops if tops else 0.0
            schedules.append(
                MultiBatchSchedule(
                    configs=tuple(sorted(chosen)),
                    throughput=thr,
                    latency=lat,
                    tops=tops,
                    system_pbe=pbe,
                )
            )
        for i in range(idx, len(cfgs)):
            a, b = cfgs[i]
            if a <= rem_a and b <= rem_b:
                chosen.append((a, b))
                rec(i, rem_a - a, rem_b - b, chosen)  # multiset: reuse i
                chosen.pop()

    rec(0, n_pu1x, n_pu2x, [])
    return schedules


@dataclass(frozen=True)
class ValidationRecord:
    """Analytic-cache cross-check: one schedule simulated end to end."""

    configs: tuple[tuple[int, int], ...]
    analytic_fps: float
    simulated_fps: float

    @property
    def rel_err(self) -> float:
        if not self.analytic_fps:
            return float("inf")
        return abs(self.simulated_fps - self.analytic_fps) / self.analytic_fps


@dataclass
class DSEResult:
    single: list[SingleBatchPoint]
    multi: list[MultiBatchSchedule]
    single_frontier: list[SingleBatchPoint]
    multi_frontier: list[MultiBatchSchedule]
    # deployment context: what was explored, on which machine — ``workload``
    # preserves an explored Workload's label/rounds overrides for deploys
    graph: Optional[Graph] = None
    pus: Optional[list[PUSpec]] = None
    workload: "Optional[object]" = None  # repro.deploy.Workload when given
    # the PU budget that was explored (DP-C's one-PU-per-batch target and
    # any other budget-derived design point read these, so non-default PU
    # arrays resolve correctly instead of raising LookupError)
    n_pu1x: int = 5
    n_pu2x: int = 5
    validation: list[ValidationRecord] = field(default_factory=list)

    def deploy(self, point_or_schedule, *, rounds: Optional[int] = None):
        """Compile any Step-1 point / Step-2 schedule (or raw config tuple)
        of this exploration into an executable Deployment — every DSE design
        point is one call away from the simulator. Instruction programs are
        generated here (and only here): the exploration itself never runs
        codegen. ``rounds=None`` keeps the per-workload default (explicit
        Workload.rounds, else one full decode window for decode graphs,
        else 16)."""
        if self.graph is None:
            raise ValueError("this DSEResult carries no graph to deploy")
        from ..deploy import Strategy, compile_deployment

        return compile_deployment(
            self.workload if self.workload is not None else self.graph,
            Strategy.of(point_or_schedule), pus=self.pus, rounds=rounds
        )

    def simulate(self, point_or_schedule, *, rounds: Optional[int] = None):
        """Deploy + execute on a fresh fixed system; returns the SimResult."""
        from ..deploy import System

        dep = self.deploy(point_or_schedule, rounds=rounds)
        return System(pus=self.pus).load(dep).run()

    # paper design points -----------------------------------------------------
    @property
    def dp_a(self) -> SingleBatchPoint:
        """Highest single-batch throughput (pipeline across all PUs)."""
        return max(self.single, key=lambda p: p.fps)

    @property
    def dp_b(self) -> MultiBatchSchedule:
        """Max system throughput at the smallest batch achieving it."""
        best = max(self.multi, key=lambda s: s.throughput)
        near = [s for s in self.multi if s.throughput >= 0.995 * best.throughput]
        return min(near, key=lambda s: (s.batch, s.latency))

    @property
    def dp_c(self) -> MultiBatchSchedule:
        """Maximum batch-level parallelism: one PU per batch, for the PU
        budget this exploration actually ran with."""
        target = tuple(sorted([(1, 0)] * self.n_pu1x + [(0, 1)] * self.n_pu2x))
        for s in self.multi:
            if s.configs == target:
                return s
        raise LookupError("one-PU-per-batch schedule missing")


@dataclass(frozen=True)
class MultiTenantPoint:
    """One joint placement: tenant ``i`` runs on its own ``configs[i]``
    slice, with per-tenant analytic rate/latency from that tenant's own
    Step-1 cache."""

    configs: tuple[tuple[int, int], ...]  # (a, b) per tenant, tenant order
    fps: tuple[float, ...]
    latency: tuple[float, ...]
    tops: float

    @property
    def batch(self) -> int:
        return len(self.configs)

    @property
    def total_a(self) -> int:
        return sum(c[0] for c in self.configs)

    @property
    def total_b(self) -> int:
        return sum(c[1] for c in self.configs)

    @property
    def system_latency(self) -> float:
        return max(self.latency)

    def __str__(self) -> str:
        body = " | ".join(
            f"({a},{b})@{f:.1f}fps" for (a, b), f in zip(self.configs, self.fps))
        return f"tenants[{body}]"


@dataclass(frozen=True)
class MultiTenantValidationRecord:
    """One joint placement simulated end to end: per-tenant simulated rate
    cross-checked against that tenant's own analytic model."""

    configs: tuple[tuple[int, int], ...]
    analytic_fps: tuple[float, ...]
    simulated_fps: tuple[float, ...]

    @property
    def rel_errs(self) -> tuple[float, ...]:
        return tuple(
            abs(s - a) / a if a else float("inf")
            for a, s in zip(self.analytic_fps, self.simulated_fps)
        )

    @property
    def max_rel_err(self) -> float:
        return max(self.rel_errs)


@dataclass
class MultiDSEResult:
    """Co-exploration result: joint placements of several tenants on one
    machine, Pareto-filtered by the vector of per-tenant rates."""

    workloads: tuple  # tuple[Workload, ...]
    singles: list[list[SingleBatchPoint]]  # Step-1 cache per tenant
    points: list[MultiTenantPoint]
    frontier: list[MultiTenantPoint]
    pus: Optional[list[PUSpec]] = None
    # the budget this co-exploration ran with — ``explore_multi(prev=...)``
    # reuses a prior result only when machine and budget are unchanged
    n_pu1x: int = 5
    n_pu2x: int = 5
    # per-tenant graph fingerprints at result time — ``prev=`` reuse keys
    # Step-1 caches on these (the content the caches were computed from)
    # instead of re-hashing possibly-mutated prev graph objects.
    fingerprints: tuple = ()  # tuple[str, ...]
    validation: list[MultiTenantValidationRecord] = field(default_factory=list)

    @property
    def n_tenants(self) -> int:
        return len(self.workloads)

    def best_solo_fps(self, i: int) -> float:
        """Tenant ``i``'s best rate with the whole machine to itself — the
        normalizer for fairness metrics."""
        return max(p.fps for p in self.singles[i])

    @property
    def balanced(self) -> MultiTenantPoint:
        """The max-min-fair joint placement: maximize the worst tenant's
        rate relative to what it could do alone on the full machine."""
        return max(
            self.frontier,
            key=lambda p: min(
                p.fps[i] / self.best_solo_fps(i) for i in range(self.n_tenants)
            ),
        )

    def strategy(self, point: MultiTenantPoint):
        """The joint placement as a workload-bound deploy Strategy."""
        from ..deploy import Strategy

        return Strategy.tenants(
            [(w, a, b) for w, (a, b) in zip(self.workloads, point.configs)],
            name=str(point),
        )

    def deploy(self, point: MultiTenantPoint, *, rounds: Optional[int] = None):
        """Compile the joint placement into an executable multi-tenant
        Deployment — every co-exploration point is one call away from the
        simulator, exactly like single-model DSE points. ``rounds=None``
        keeps each tenant's own default (Workload.rounds, else one full
        decode window for decode tenants, else 16)."""
        from ..deploy import compile_deployment

        return compile_deployment(None, self.strategy(point), pus=self.pus,
                                  rounds=rounds)

    def simulate(self, point: MultiTenantPoint, *, rounds: Optional[int] = None):
        from ..deploy import System

        dep = self.deploy(point, rounds=rounds)
        return System(pus=self.pus).load(dep).run()


def _best_case_fps(
    points: list[SingleBatchPoint], n_pu1x: int, n_pu2x: int
) -> list[list[float]]:
    """best[ra][rb] = max fps this tenant can reach with a budget of
    (ra PU1x, rb PU2x) — the optimistic completion bound of the joint
    recursion. -inf where nothing fits."""
    best = [[-math.inf] * (n_pu2x + 1) for _ in range(n_pu1x + 1)]
    by_cfg = {p.config: p for p in points}
    for ra in range(n_pu1x + 1):
        for rb in range(n_pu2x + 1):
            v = -math.inf
            if ra > 0:
                v = max(v, best[ra - 1][rb])
            if rb > 0:
                v = max(v, best[ra][rb - 1])
            p = by_cfg.get((ra, rb))
            if p is not None:
                v = max(v, p.fps)
            best[ra][rb] = v
    return best


def explore_multi(graphs, *, n_pu1x: int = 5, n_pu2x: int = 5,
                  tolerance: float = 0.0, pus: Optional[list[PUSpec]] = None,
                  validate: int = 0, validate_rounds: int = 5,
                  engine: str = "batched",
                  prev: Optional[MultiDSEResult] = None) -> MultiDSEResult:
    """Co-explore joint placements of several tenant models on one machine.

    ``graphs`` is a list of Graphs (or deploy ``Workload``s), one per tenant.
    Every tenant is compiled through its own Step-1 enumeration — tenants
    whose graphs have identical content (by fingerprint) share one — joint
    placements give each tenant one disjoint (a, b) member pipeline under
    the shared PU budget, and the returned frontier is Pareto-optimal in the
    vector of per-tenant rates (tenant-A fps, tenant-B fps, ...). The joint
    recursion abandons partial placements whose best-case completion (each
    remaining tenant granted the whole remaining budget) is already
    dominated beyond the tolerance threshold by a found placement — exactly
    frontier-preserving at any tolerance >= 0; at tolerance 0 it
    additionally pre-prunes per-tenant configs that are strictly
    fps-dominated at equal-or-lower cost (sound only under exact dominance:
    the other tenants' unchanged rates mask any margin version).
    ``engine="reference"`` disables both and runs the brute-force engine;
    ``engine="scalar"`` keeps them but scores Step 1 per-config instead of
    through the batched engine.

    ``prev`` makes the co-exploration incremental: any tenant whose graph
    fingerprint appears in ``prev`` (same PU array, same budget) reuses its
    prior Step-1 cache verbatim, and the prior frontier is projected onto
    the new tenant list to seed the joint recursion's incumbent set — so
    adding, dropping or swapping one tenant re-scores only that tenant's
    candidate slice. Every seed is an achievable placement of *this* run's
    search space, so the bound stays exactly frontier-preserving and the
    result equals the from-scratch exploration.

    ``validate=N`` deploys + simulates up to N joint placements (the
    max-min-fair ``balanced`` point first, then the frontier by normalized
    rate product) and cross-checks each tenant's simulated rate against its
    own analytic model in ``MultiDSEResult.validation``. When any tenant
    carries its own round semantics (explicit ``Workload.rounds`` or a
    decode window), validation keeps the per-member defaults instead of
    forcing ``validate_rounds``, so decode tenants are cross-checked over
    their full advancing-length cycle."""
    from ..deploy import Workload

    engine = _normalize_engine(engine)
    workloads = tuple(Workload.of(g) for g in graphs)
    if len(workloads) < 2:
        raise ValueError("explore_multi needs at least two tenant graphs")
    pus = pus if pus is not None else make_u50_system()
    fast = engine != "reference"
    # The per-tenant config pre-prune is sound only under exact dominance:
    # swapping one tenant's config leaves every *other* tenant's rate
    # unchanged, and a tolerant dominator must clear the threshold on every
    # component — masked axes make a margin version impossible. The
    # incumbent bound below, by contrast, is margin-aware and stays engaged
    # at any tolerance >= 0 (an incumbent clearing the tolerance-scaled
    # threshold of an *optimistic* completion excludes every actual
    # completion from the tolerant frontier — exactly frontier-preserving).
    cfg_prune = fast and tolerance == 0.0
    bound = fast and tolerance >= 0.0

    # Incremental re-exploration: a prior result's Step-1 caches carry over
    # for any tenant still present (matched by graph fingerprint), provided
    # machine and budget are unchanged — the points are a pure function of
    # (graph, pus, budget).
    fps_order = [w.graph.fingerprint() for w in workloads]
    prev_fps: list[str] = []
    step1_by_fp: dict[str, list[SingleBatchPoint]] = {}
    if prev is not None and fast and prev.pus == pus \
            and prev.n_pu1x == n_pu1x and prev.n_pu2x == n_pu2x:
        prev_fps = (list(prev.fingerprints) if prev.fingerprints
                    else [w.graph.fingerprint() for w in prev.workloads])
        for fp, pts in zip(prev_fps, prev.singles):
            step1_by_fp.setdefault(fp, pts)
    else:
        prev = None

    singles: list[list[SingleBatchPoint]] = []
    caches: list[dict[tuple[int, int], SingleBatchPoint]] = []
    for w, fp in zip(workloads, fps_order):
        pts = step1_by_fp.get(fp) if fast else None
        if pts is None:
            if fast:
                pts = enumerate_single_batch(w.graph, n_pu1x=n_pu1x,
                                             n_pu2x=n_pu2x, pus=pus,
                                             engine=engine)
            else:
                pts = enumerate_single_batch_reference(
                    w.graph, n_pu1x=n_pu1x, n_pu2x=n_pu2x, pus=pus)
            step1_by_fp[fp] = pts
        singles.append(pts)
        caches.append({p.config: p for p in pts})

    # Joint enumeration: one ordered config per tenant, disjoint PU budgets.
    points: list[MultiTenantPoint] = []
    if cfg_prune:
        cfg_lists = []
        for cache in caches:
            dead = _cost_dominated_configs(cache, use_latency=False)
            cfg_lists.append(sorted(c for c in cache if c not in dead))
    else:
        cfg_lists = [sorted(c) for c in caches]
    best_case = [_best_case_fps(s, n_pu1x, n_pu2x) for s in singles]
    n_tenants = len(workloads)
    # Non-dominated incumbent fps vectors live in ``inc_arr[:inc_n]``: a
    # grow-on-demand row array so the dominance tests below run as one
    # vectorized comparison per call instead of Python loops — on deep
    # joint recursions the incumbent checks are the hot path.
    inc_arr = np.empty((64, max(n_tenants, 1)))
    inc_n = 0

    def bounded_out(i: int, rem_a: int, rem_b: int, got: list[float]) -> bool:
        """True when this partial placement cannot contribute a frontier
        point: a remaining tenant cannot fit at all, or the optimistic
        completion is strictly dominated by an already-found placement."""
        if rem_a + rem_b < n_tenants - i:  # every tenant needs >= 1 PU
            return True
        opt = list(got)
        for j in range(i, n_tenants):
            b = best_case[j][rem_a][rem_b]
            if b == -math.inf:
                return True
            opt.append(b)
        if not bound or not inc_n:
            return False
        A = inc_arr[:inc_n]
        o = np.array(opt)
        if tolerance == 0.0:
            # finite rates: sign(A - o) encodes both comparisons, so the
            # dominance test is one subtract plus two reductions.
            D = A - o
            return bool(((D.min(axis=1) >= 0.0)
                         & (D.max(axis=1) > 0.0)).any())
        thr = np.where(o >= 0.0, o * (1.0 + tolerance), o * (1.0 - tolerance))
        return bool(((A >= thr).all(axis=1) & (A > o).any(axis=1)).any())

    def note_incumbent(fps: tuple[float, ...]) -> None:
        nonlocal inc_arr, inc_n
        f = np.array(fps)
        if inc_n:
            # sign(f - A) per row: mn >= 0 & mx > 0 means f dominates the
            # incumbent; mx <= 0 means the incumbent weakly dominates f
            # (disjoint conditions, so one pass serves both tests).
            D = f - inc_arr[:inc_n]
            mx = D.max(axis=1)
            dominated = (D.min(axis=1) >= 0.0) & (mx > 0.0)
            if (mx <= 0.0).any():
                return  # weakly dominated by a surviving incumbent
            if dominated.any():
                kept = inc_arr[:inc_n][~dominated]  # fancy index copies
                inc_n = len(kept)
                inc_arr[:inc_n] = kept
        if inc_n == len(inc_arr):
            inc_arr = np.concatenate([inc_arr, np.empty_like(inc_arr)])
        inc_arr[inc_n] = f
        inc_n += 1

    if prev is not None and bound and prev.frontier:
        # Project each prior frontier point onto the new tenant list:
        # tenants matched by fingerprint keep their prior config, new
        # tenants greedily take their best-rate config that still fits.
        # Every successful projection is an achievable placement of *this*
        # run's search space, so seeding its rate vector prunes only
        # partial placements a real point dominates beyond tolerance — the
        # incumbent bound stays exactly frontier-preserving while the
        # recursion starts warm instead of rediscovering the old frontier.
        for pt in prev.frontier:
            pool: dict[str, list[tuple[int, int]]] = {}
            for fp, cfg in zip(prev_fps, pt.configs):
                pool.setdefault(fp, []).append(cfg)
            chosen: list[Optional[tuple[int, int]]] = []
            for fp in fps_order:
                cfgs = pool.get(fp)
                chosen.append(cfgs.pop(0) if cfgs else None)
            rem_a = n_pu1x - sum(c[0] for c in chosen if c is not None)
            rem_b = n_pu2x - sum(c[1] for c in chosen if c is not None)
            ok = rem_a >= 0 and rem_b >= 0
            if ok:
                for i, cfg in enumerate(chosen):
                    if cfg is not None:
                        continue
                    best_cfg, best_fps = None, -math.inf
                    for (a, b), p in caches[i].items():
                        if a <= rem_a and b <= rem_b and p.fps > best_fps:
                            best_cfg, best_fps = (a, b), p.fps
                    if best_cfg is None:
                        ok = False
                        break
                    chosen[i] = best_cfg
                    rem_a -= best_cfg[0]
                    rem_b -= best_cfg[1]
            if ok:
                note_incumbent(tuple(
                    caches[i][cfg].fps for i, cfg in enumerate(chosen)))

    def rec(i: int, rem_a: int, rem_b: int, chosen: list[tuple[int, int]],
            got: list[float]) -> None:
        if bounded_out(i, rem_a, rem_b, got):
            return
        if i == n_tenants - 1:
            # Last tenant: every fitting config completes the same prefix,
            # so the completions differ only in the final rate — all but
            # the best are weakly dominated by it and one note_incumbent
            # call covers the whole group (no pruning check can run
            # between siblings, so the incumbent set evolves identically).
            pre = [caches[j][c] for j, c in enumerate(chosen)]
            pre_fps = tuple(got)
            pre_lat = tuple(m.latency for m in pre)
            pre_tops = sum(m.tops for m in pre)
            prefix = tuple(chosen)
            best = -math.inf
            for a, b in cfg_lists[i]:
                if a <= rem_a and b <= rem_b:
                    m = caches[i][(a, b)]
                    points.append(
                        MultiTenantPoint(
                            configs=prefix + ((a, b),),
                            fps=pre_fps + (m.fps,),
                            latency=pre_lat + (m.latency,),
                            tops=pre_tops + m.tops,
                        )
                    )
                    if m.fps > best:
                        best = m.fps
            if bound and best > -math.inf:
                note_incumbent(pre_fps + (best,))
            return
        for a, b in cfg_lists[i]:
            if a <= rem_a and b <= rem_b:
                chosen.append((a, b))
                got.append(caches[i][(a, b)].fps)
                rec(i + 1, rem_a - a, rem_b - b, chosen, got)
                got.pop()
                chosen.pop()

    rec(0, n_pu1x, n_pu2x, [], [])
    if not points:
        raise ValueError(
            f"no joint placement fits {len(workloads)} tenants in "
            f"{n_pu1x}x PU1x + {n_pu2x}x PU2x"
        )

    objectives = [
        (lambda p, i=i: p.fps[i]) for i in range(len(workloads))
    ]
    front = pareto_front if fast else pareto_front_bruteforce
    frontier = front(points, objectives, tolerance=tolerance)

    res = MultiDSEResult(workloads=workloads, singles=singles, points=points,
                         frontier=frontier, pus=pus,
                         n_pu1x=n_pu1x, n_pu2x=n_pu2x,
                         fingerprints=tuple(fps_order))
    if validate > 0:
        # tenants with their own round semantics (explicit Workload.rounds
        # or a decode window) validate on per-member defaults, so decode
        # rates are measured over the full advancing-length cycle.
        has_own_rounds = any(
            w.rounds is not None or w.graph.decode_steps for w in workloads)
        val_rounds = None if has_own_rounds else validate_rounds
        norm = [res.best_solo_fps(i) for i in range(res.n_tenants)]
        candidates = [res.balanced]
        ranked = sorted(
            frontier,
            key=lambda p: -sum(
                (f / n if n else 0.0) for f, n in zip(p.fps, norm)),
        )
        seen = {candidates[0].configs}
        for p in ranked:
            if p.configs not in seen:
                candidates.append(p)
                seen.add(p.configs)
        for cand in candidates[:validate]:
            sim = res.simulate(cand, rounds=val_rounds)
            res.validation.append(
                MultiTenantValidationRecord(
                    configs=cand.configs,
                    analytic_fps=cand.fps,
                    simulated_fps=tuple(
                        m.throughput_fps(warmup=2) for m in sim.members),
                )
            )
    return res


def explore(g, *, n_pu1x: int = 5, n_pu2x: int = 5,
            tolerance: float = 0.0, pus: Optional[list[PUSpec]] = None,
            validate: int = 0, validate_rounds: int = 5,
            engine: str = "batched") -> DSEResult:
    """Run the three DSE steps; optionally cross-check the analytic cache.

    ``g`` is a Graph or a deploy ``Workload`` — any frontend graph flows
    through unchanged, including decode-phase graphs
    (``zoo.transformer_decoder``) whose K/V-cache scheduling is entirely a
    compiler/ISA concern: a decode tenant enumerates, composes and deploys
    exactly like a prefill or CNN tenant.

    The default ``engine="batched"`` shares one memoized graph analysis
    across all Step-1 configs, scores the whole config sweep in one
    vectorized pass (``repro.dse.batched``), generates **zero** instructions
    (codegen runs only when a point is deployed), prunes cost-dominated
    member configs from the Step-2 composition (margin-aware at
    ``tolerance > 0``, see ``enumerate_multi_batch``), and extracts the
    frontier with the sort-based O(n log n) Pareto. ``engine="scalar"``
    (alias ``"fast"``: the historical default) is identical except Step 1
    runs one ``place()`` per config; ``engine="reference"`` is the
    pre-caching brute-force engine. At tolerance 0 all three produce
    identical frontiers and design points, at tolerance > 0 the fast
    frontiers are the reference one restricted to kept schedules and still
    contain the entire exact frontier and every DP point (locked by the
    equivalence suite in tests/test_dse.py).

    ``validate=N`` deploys + simulates up to N schedules (the design points
    DP-A/C/B first, then the throughput-ordered multi-batch frontier) and
    records analytic-vs-simulated throughput in ``DSEResult.validation``;
    decode workloads validate over one full decode window (not
    ``validate_rounds``) so the cross-check covers the whole
    advancing-length cycle."""
    engine = _normalize_engine(engine)
    workload = None
    if not isinstance(g, Graph):
        from ..deploy import Workload

        workload = Workload.of(g)
        g = workload.graph
    pus = pus if pus is not None else make_u50_system()
    fast = engine != "reference"
    if fast:
        single = enumerate_single_batch(g, n_pu1x=n_pu1x, n_pu2x=n_pu2x,
                                        pus=pus, engine=engine)
    else:
        single = enumerate_single_batch_reference(g, n_pu1x=n_pu1x,
                                                  n_pu2x=n_pu2x, pus=pus)
    # margin-aware pruning stays engaged at tolerance > 0 (see
    # enumerate_multi_batch); a negative tolerance shrinks the frontier and
    # would make any prune unsound, so only that degenerate case sweeps
    # exhaustively.
    multi = enumerate_multi_batch(single, n_pu1x=n_pu1x, n_pu2x=n_pu2x,
                                  prune=fast and tolerance >= 0.0,
                                  tolerance=tolerance)
    front = pareto_front if fast else pareto_front_bruteforce
    sf = front(
        single, [lambda p: p.fps, lambda p: -p.latency], tolerance=tolerance
    )
    mf = front(
        multi, [lambda s: s.throughput, lambda s: -s.latency], tolerance=tolerance
    )
    res = DSEResult(single=single, multi=multi, single_frontier=sf,
                    multi_frontier=mf, graph=g, pus=pus, workload=workload,
                    n_pu1x=n_pu1x, n_pu2x=n_pu2x)
    if validate > 0:
        # decode workloads (or explicit Workload.rounds) validate over their
        # own full window; everything else uses the quick validate_rounds.
        has_own_rounds = (workload is not None and workload.rounds is not None
                          ) or bool(g.decode_steps)
        val_rounds = None if has_own_rounds else validate_rounds
        candidates: list = []
        for dp in ("dp_a", "dp_c", "dp_b"):
            try:
                candidates.append(getattr(res, dp))
            except LookupError:
                pass
        seen = {c.configs for c in candidates}
        for s in sorted(mf, key=lambda s: -s.throughput):
            if s.configs not in seen:
                candidates.append(s)
                seen.add(s.configs)
        for cand in candidates[:validate]:
            sim = res.simulate(cand, rounds=val_rounds)
            res.validation.append(
                ValidationRecord(
                    configs=cand.configs,
                    analytic_fps=cand.throughput,
                    simulated_fps=sim.aggregate_fps(warmup=2),
                )
            )
    return res
