"""Pareto analysis for the DSE methodology (paper Sec. V-A, step 3)."""
from __future__ import annotations

from typing import Callable, Iterable, Sequence, TypeVar

T = TypeVar("T")


def pareto_front(
    points: Sequence[T],
    objectives: Sequence[Callable[[T], float]],
    *,
    tolerance: float = 0.0,
) -> list[T]:
    """Maximizing Pareto frontier over ``objectives`` (negate for minimize).

    ``tolerance`` (relative) admits near-frontier points, as in Fig. 6(b)
    ("applied with a small tolerance")."""
    vals = [[obj(p) for obj in objectives] for p in points]

    def dominates(i: int, j: int) -> bool:
        ge = all(vals[i][k] >= vals[j][k] * (1 + tolerance) if vals[j][k] >= 0
                 else vals[i][k] >= vals[j][k] * (1 - tolerance)
                 for k in range(len(objectives)))
        gt = any(vals[i][k] > vals[j][k] for k in range(len(objectives)))
        return ge and gt

    out = []
    for j in range(len(points)):
        if not any(dominates(i, j) for i in range(len(points)) if i != j):
            out.append(points[j])
    return out


def constrained(
    points: Iterable[T],
    *,
    max_latency: float | None = None,
    min_throughput: float | None = None,
    max_batch: int | None = None,
    latency_of: Callable[[T], float] = lambda p: p.latency,
    throughput_of: Callable[[T], float] = lambda p: p.throughput,
    batch_of: Callable[[T], int] = lambda p: p.batch,
) -> list[T]:
    """Application-constraint filtering (max latency / min throughput /
    target batch), per the paper's configuration-selection step."""
    out = []
    for p in points:
        if max_latency is not None and latency_of(p) > max_latency:
            continue
        if min_throughput is not None and throughput_of(p) < min_throughput:
            continue
        if max_batch is not None and batch_of(p) > max_batch:
            continue
        out.append(p)
    return out
