"""Pareto analysis for the DSE methodology (paper Sec. V-A, step 3).

Step 2 of the DSE produces thousands of multi-batch schedules, so the
frontier extraction is on the interactive path. For the common 2-objective
case (throughput vs. -latency) ``pareto_front`` runs a sort-based
O(n log n) sweep; the O(n²) pairwise scan is kept for >= 3 objectives (the
multi-tenant per-tenant-rate vectors) and — as
``pareto_front_bruteforce`` — serves as the oracle for the equivalence
property tests. Both paths return the kept points in input order and agree
bit-for-bit, including the tolerance semantics and exact-tie handling
(mutually non-dominating duplicates are all kept).
"""
from __future__ import annotations

import math
from bisect import bisect_right
from typing import Callable, Iterable, Sequence, TypeVar

T = TypeVar("T")


def _threshold(v: float, tolerance: float) -> float:
    """The value a dominator must reach in one objective: relative
    ``tolerance`` scales away from ``v`` (sign-aware, as in Fig. 6(b)'s
    'applied with a small tolerance')."""
    return v * (1 + tolerance) if v >= 0 else v * (1 - tolerance)


def _vectorized_keep(vals: list[list[float]], tolerance: float) -> list[int]:
    """NumPy pairwise dominance scan — the same O(n²·k) comparisons as
    ``_bruteforce_keep`` as array ops (identical float arithmetic and
    comparison semantics, NaN/inf included), for the >= 3-objective path
    (multi-tenant rate vectors) where n reaches the thousands. Blocked over
    the candidate axis to bound the broadcast to ~n·512·k."""
    import numpy as np

    n = len(vals)
    V = np.asarray(vals, dtype=np.float64)
    T = np.where(V >= 0.0, V * (1.0 + tolerance), V * (1.0 - tolerance))
    keep: list[int] = []
    for j0 in range(0, n, 512):
        ge = (V[:, None, :] >= T[None, j0:j0 + 512, :]).all(axis=2)
        gt = (V[:, None, :] > V[None, j0:j0 + 512, :]).any(axis=2)
        dom = (ge & gt).any(axis=0)
        keep.extend(int(j0 + k) for k in np.nonzero(~dom)[0])
    return keep


def _bruteforce_keep(vals: list[list[float]], tolerance: float) -> list[int]:
    """O(n²) pairwise dominance scan; returns kept indices in input order."""
    n = len(vals)
    n_obj = len(vals[0]) if vals else 0

    def dominates(i: int, j: int) -> bool:
        ge = all(vals[i][k] >= _threshold(vals[j][k], tolerance)
                 for k in range(n_obj))
        gt = any(vals[i][k] > vals[j][k] for k in range(n_obj))
        return ge and gt

    return [j for j in range(n)
            if not any(dominates(i, j) for i in range(n) if i != j)]


def _sorted_keep_2d(vals: list[list[float]], tolerance: float) -> list[int]:
    """O(n log n) keep-set for exactly two maximizing objectives.

    Sort by (f1 desc, f2 desc); a point's potential dominators in f1 are a
    prefix of that order (everything with f1 >= its tolerance-scaled
    threshold), so one prefix-max array of f2 answers the ge-condition and
    the per-f1-group maxima resolve the strict-inequality tie cases exactly
    as the pairwise oracle does."""
    n = len(vals)
    order = sorted(range(n), key=lambda i: (-vals[i][0], -vals[i][1]))
    f1_desc = [vals[i][0] for i in order]
    neg_f1 = [-x for x in f1_desc]  # ascending, for bisect

    # prefix_max[k] = max f2 over the first k points of ``order``
    prefix_max = [-math.inf] * (n + 1)
    for k, i in enumerate(order):
        prefix_max[k + 1] = max(prefix_max[k], vals[i][1])

    # per-f1-group f2 maxima and the max f2 of strictly-greater-f1 points
    group_max: dict[float, float] = {}
    best_before: dict[float, float] = {}
    running = -math.inf
    k = 0
    while k < n:
        f1 = f1_desc[k]
        j = k
        gmax = -math.inf
        while j < n and f1_desc[j] == f1:
            gmax = max(gmax, vals[order[j]][1])
            j += 1
        best_before[f1] = running
        group_max[f1] = gmax
        running = max(running, gmax)
        k = j

    keep = []
    for j in range(n):
        f1_j, f2_j = vals[j]
        thr1 = _threshold(f1_j, tolerance)
        thr2 = _threshold(f2_j, tolerance)
        if thr1 > f1_j:
            # every candidate with f1 >= thr1 is strictly greater in f1, so
            # the gt-condition holds via f1 and only the ge-check remains.
            cnt = bisect_right(neg_f1, -thr1)
            dominated = prefix_max[cnt] >= thr2
        else:
            # thr1 == f1_j (tolerance 0 or f1_j == 0): strictly-greater-f1
            # dominators need f2 >= thr2; equal-f1 dominators additionally
            # need strictly greater f2.
            gmax = group_max[f1_j]
            dominated = (best_before[f1_j] >= thr2
                         or (gmax >= thr2 and gmax > f2_j))
        if not dominated:
            keep.append(j)
    return keep


def pareto_front(
    points: Sequence[T],
    objectives: Sequence[Callable[[T], float]],
    *,
    tolerance: float = 0.0,
) -> list[T]:
    """Maximizing Pareto frontier over ``objectives`` (negate for minimize).

    ``tolerance`` (relative) admits near-frontier points, as in Fig. 6(b)
    ("applied with a small tolerance"). Two objectives take the sort-based
    O(n log n) path; anything else (or a negative tolerance, or non-finite
    values) falls back to the pairwise scan. Output order is input order."""
    vals = [[obj(p) for obj in objectives] for p in points]
    if (len(objectives) == 2 and tolerance >= 0.0
            and all(math.isfinite(v) for row in vals for v in row)):
        keep = _sorted_keep_2d(vals, tolerance)
    elif (len(vals) >= 32
          and all(isinstance(v, float) for row in vals for v in row)):
        # float64 round-trips losslessly, so the numpy scan's comparisons
        # are the exact Python ones; non-float objectives (e.g. huge ints)
        # stay on the pure-Python scan to avoid conversion rounding.
        keep = _vectorized_keep(vals, tolerance)
    else:
        keep = _bruteforce_keep(vals, tolerance)
    return [points[j] for j in keep]


def pareto_front_bruteforce(
    points: Sequence[T],
    objectives: Sequence[Callable[[T], float]],
    *,
    tolerance: float = 0.0,
) -> list[T]:
    """Reference O(n²) frontier — the property-test oracle the sort-based
    path is verified against (and the ≥3-objective workhorse)."""
    vals = [[obj(p) for obj in objectives] for p in points]
    return [points[j] for j in _bruteforce_keep(vals, tolerance)]


def constrained(
    points: Iterable[T],
    *,
    max_latency: float | None = None,
    min_throughput: float | None = None,
    max_batch: int | None = None,
    latency_of: Callable[[T], float] = lambda p: p.latency,
    throughput_of: Callable[[T], float] = lambda p: p.throughput,
    batch_of: Callable[[T], int] = lambda p: p.batch,
) -> list[T]:
    """Application-constraint filtering (max latency / min throughput /
    target batch), per the paper's configuration-selection step."""
    out = []
    for p in points:
        if max_latency is not None and latency_of(p) > max_latency:
            continue
        if min_throughput is not None and throughput_of(p) < min_throughput:
            continue
        if max_batch is not None and batch_of(p) > max_batch:
            continue
        out.append(p)
    return out
