"""Online re-placement policy: the serving loop's interface to the DSE.

The serving control plane (:mod:`repro.serve`) re-places tenants whenever
membership changes (join/leave) or an SLO violation persists. This module
is the thin policy layer between that loop and the explorer: it picks the
joint placement — the max-min-fair ``balanced`` point of
:func:`repro.dse.explore_multi` for two or more tenants, the best
single-batch pipeline (DP-A) for one — and threads the previous
:class:`~repro.dse.MultiDSEResult` back in as ``prev`` so consecutive
replans are incremental: tenants whose placement graphs are unchanged
(matched by fingerprint) reuse their Step-1 caches, and the result is
*exactly* the from-scratch exploration (the incremental path is equality-
preserving, not approximate — the serving tests assert byte-equality).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

from .explorer import explore, explore_multi


@dataclass
class Placement:
    """One joint placement decision over the active tenant set.

    ``configs`` gives each workload (in ``workloads`` order) its member
    pipeline shape ``(a, b)``; ``point`` is the underlying DSE point
    (:class:`~repro.dse.MultiTenantPoint` or
    :class:`~repro.dse.SingleBatchPoint`); ``result`` is the full
    :class:`~repro.dse.MultiDSEResult` when two or more tenants were
    co-explored — pass it back as ``prev`` on the next replan.
    """

    workloads: tuple[Any, ...]
    configs: tuple[tuple[int, int], ...]
    point: Any
    result: Any = None

    def config_for(self, label: str) -> tuple[int, int]:
        for w, cfg in zip(self.workloads, self.configs):
            if w.label == label:
                return cfg
        raise KeyError(f"no placement for tenant {label!r}")


def plan_placement(workloads, *, pus=None, n_pu1x: int = 5, n_pu2x: int = 5,
                   prev: Optional[Any] = None, engine: str = "batched",
                   available: Optional[Any] = None) -> Placement:
    """Place the active tenant set on the fixed machine.

    ``workloads`` is a non-empty list of deploy ``Workload``s (or graphs).
    ``prev`` is the ``result`` of the previous multi-tenant placement (or
    ``None``); it only accelerates — the returned placement equals the
    from-scratch one.

    ``available`` is the degraded-array mask: an iterable of still-healthy
    pids. The per-kind PU budget is capped to the healthy counts, which is
    all the explorer needs (members bind to concrete healthy pids at
    deploy time, via ``compile_deployment(available=...)``). A mask that
    changes the budget inherently differs from ``prev``'s budget, so the
    explorer's ``prev=`` reuse check rejects it and the placement takes
    the safe from-scratch path — degraded placements are byte-equal to a
    fresh ``explore_multi`` on the masked budget by construction.
    """
    from ..core.pu import make_u50_system
    from ..deploy import Workload

    ws = tuple(Workload.of(w) for w in workloads)
    if not ws:
        raise ValueError("plan_placement needs at least one tenant workload")
    if available is not None:
        avail = set(available)
        machine = pus if pus is not None else make_u50_system()
        n_pu1x = min(n_pu1x, sum(1 for p in machine
                                 if p.kind == "PU1x" and p.pid in avail))
        n_pu2x = min(n_pu2x, sum(1 for p in machine
                                 if p.kind == "PU2x" and p.pid in avail))
        if n_pu1x + n_pu2x == 0:
            raise ValueError("no available PUs to place tenants on")
    if len(ws) == 1:
        res = explore(ws[0], n_pu1x=n_pu1x, n_pu2x=n_pu2x, pus=pus,
                      engine=engine)
        pt = res.dp_a  # best single-batch pipeline over the whole machine
        return Placement(workloads=ws, configs=(pt.config,), point=pt)
    res = explore_multi(list(ws), n_pu1x=n_pu1x, n_pu2x=n_pu2x, pus=pus,
                        prev=prev, engine=engine)
    pt = res.balanced  # max-min-fair over the joint frontier
    return Placement(workloads=res.workloads, configs=pt.configs, point=pt,
                     result=res)
