"""The paper's DSE methodology on the TPU target: enumerate deployments of
an architecture over a fixed chip pool (pipeline stages x data replicas x
tensor shards), cost each from the analytic roofline, Pareto-filter — the
exact Fig. 5 three-step recipe with TPU chips standing in for PUs.

A deployment = (S stages, R replicas, T tensor shards), S*R*T = chips.
Each replica pipelines microbatches through S stages of L/S layers computed
on T chips; batch-level parallelism across the R replicas = the paper's
hybrid parallelism. Runtime switching between deployments is a re-jit on
the same mesh (instruction-program swap), never a reconfiguration.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from ..configs.base import ArchConfig
from ..runtime.pipeline import layer_cost_seconds
from .pareto import pareto_front

ICI_BW = 50e9  # bytes/s/link


@dataclass(frozen=True)
class Deployment:
    stages: int
    replicas: int
    tensor: int
    throughput: float  # sequences/s aggregate
    latency: float  # end-to-end per batch
    batch: int  # concurrent sequences in flight

    @property
    def label(self) -> str:
        return f"S{self.stages}xR{self.replicas}xT{self.tensor}"


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def enumerate_deployments(
    cfg: ArchConfig,
    *,
    chips: int = 256,
    seq_len: int = 4096,
    microbatch: int = 4,
    microbatches: int = 8,
) -> list[Deployment]:
    out = []
    L = cfg.num_layers
    hbm_budget = 14e9  # usable bytes/chip (v5e 16 GB minus runtime)
    for S in _divisors(chips):
        if S > L:
            continue
        for T in _divisors(chips // S):
            R = chips // (S * T)
            # weights replicate across replicas: must fit S x T chips
            w_per_chip = 2.0 * cfg.param_count() / (S * T)
            kv_per_chip = (  # in-flight microbatch activations (rough)
                2.0 * microbatch * microbatches * seq_len * cfg.d_model / T
            )
            if w_per_chip + kv_per_chip > hbm_budget:
                continue
            per_layer = layer_cost_seconds(cfg, seq_len, microbatch, T)
            # TP collectives: ~2 all-reduces of the (mb, s, d) activation per
            # layer, ring cost 2(T-1)/T on the ICI
            if T > 1:
                ar = 2 * (2 * (T - 1) / T) * microbatch * seq_len * cfg.d_model * 2 / ICI_BW
                per_layer += ar
            lps = math.ceil(L / S)
            stage_t = lps * per_layer
            # boundary transfer per microbatch between stages
            boundary = 2 * microbatch * seq_len * cfg.d_model / T / ICI_BW
            stage_t = max(stage_t, boundary)
            thr = R * microbatch / stage_t
            lat = (S + microbatches - 1) * stage_t
            out.append(
                Deployment(
                    stages=S,
                    replicas=R,
                    tensor=T,
                    throughput=thr,
                    latency=lat,
                    batch=R * microbatches * microbatch,
                )
            )
    return out


def explore_tpu(cfg: ArchConfig, **kw):
    points = enumerate_deployments(cfg, **kw)
    frontier = pareto_front(
        points, [lambda p: p.throughput, lambda p: -p.latency]
    )
    return points, frontier
