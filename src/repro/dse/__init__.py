# DSE methodology (paper Sec. V-A): single-batch enumeration, multi-batch
# hybrid-parallel composition, Pareto analysis.
from .explorer import (
    DSEResult,
    MultiBatchSchedule,
    SingleBatchPoint,
    ValidationRecord,
    enumerate_multi_batch,
    enumerate_single_batch,
    explore,
)
from .pareto import constrained, pareto_front

__all__ = [
    "DSEResult",
    "MultiBatchSchedule",
    "SingleBatchPoint",
    "ValidationRecord",
    "enumerate_multi_batch",
    "enumerate_single_batch",
    "explore",
    "constrained",
    "pareto_front",
]
