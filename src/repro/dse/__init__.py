# DSE methodology (paper Sec. V-A): single-batch enumeration, multi-batch
# hybrid-parallel composition, Pareto analysis — plus multi-tenant
# co-exploration (joint placements of several models on one machine).
from .batched import BatchedScores, score_details, score_single_batch
from .explorer import (
    DSEResult,
    MultiBatchSchedule,
    MultiDSEResult,
    MultiTenantPoint,
    MultiTenantValidationRecord,
    SingleBatchPoint,
    ValidationRecord,
    enumerate_multi_batch,
    enumerate_single_batch,
    enumerate_single_batch_reference,
    explore,
    explore_multi,
)
from .pareto import constrained, pareto_front, pareto_front_bruteforce
from .replan import Placement, plan_placement

__all__ = [
    "Placement",
    "plan_placement",
    "BatchedScores",
    "DSEResult",
    "score_details",
    "score_single_batch",
    "MultiBatchSchedule",
    "MultiDSEResult",
    "MultiTenantPoint",
    "MultiTenantValidationRecord",
    "SingleBatchPoint",
    "ValidationRecord",
    "enumerate_multi_batch",
    "enumerate_single_batch",
    "enumerate_single_batch_reference",
    "explore",
    "explore_multi",
    "constrained",
    "pareto_front",
    "pareto_front_bruteforce",
]
