"""Batched placement scoring — the vectorized DSE evaluation engine.

Scores a whole batch of (a, b) pipeline configurations against one shared
``GraphAnalysis`` as array programs over the dense ``AnalysisTables``
export (``repro.compiler.tables``), instead of one Python ``place()`` call
per config. This is the engine behind ``explore(engine="batched")`` /
``explore_multi(engine="batched")`` — the default — and the piece that
makes fleet-scale sweeps and in-the-loop re-exploration viable (ROADMAP
item 5(b)).

Per config the evaluation replicates ``place()``'s analytic path end to
end: partition lookup from the dense DP table, stage-time assembly
(profiled segment times + SMOF weight-stream overheads), the credit-loop
coupling bound of ``repro.compiler.coupling`` over the config-independent
edge tables, and the derived point metrics (fps, latency, used TOPS, PBE).

Two backends:

* ``backend="numpy"`` (default) — byte-identical to the scalar path. All
  reductions replicate the scalar op order (``np.cumsum`` for sequential
  left-to-right sums, order-exact min/max, no fused multiply-adds — numpy
  ufuncs never FMA-contract), so the resulting ``SingleBatchPoint``s, and
  therefore every frontier and design point downstream, compare equal
  with ``==`` against ``engine="scalar"`` and ``engine="reference"``.
* ``backend="jax"`` — the same evaluation as one ``vmap``-over-configs,
  ``jit``-compiled XLA program under ``jax_enable_x64``. XLA reassociates
  and FMA-fuses float chains, so this path is *tolerance*-accurate (it is
  locked to the scalar path by allclose property tests, not byte
  equality); it exists for accelerator offload of very large candidate
  batches and is never the default.

``PROFILE`` accumulates per-phase wall times (table build / partition DP /
reconstruction / SMOF solve / assembly / jit trace) for the ``--profile``
mode of ``benchmarks/dse_bench.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from ..compiler.compile import STATS, GraphAnalysis
from ..core.icu import DECODE_CYCLES
from ..core.isu import BASE_HOP_LATENCY, SAME_PU_LATENCY, SLR_CROSS_PENALTY
from ..core.pu import PUSpec, make_u50_system

# wall-seconds per evaluation phase, accumulated across calls (see
# benchmarks/dse_bench.py --profile); reset with reset_profile()
PROFILE: dict[str, float] = {}


def reset_profile() -> None:
    PROFILE.clear()


def _tick(phase: str, t0: float) -> float:
    now = time.perf_counter()
    PROFILE[phase] = PROFILE.get(phase, 0.0) + (now - t0)
    return now


@dataclass
class BatchedScores:
    """Dense per-config results of one batched scoring call (config order
    preserved). ``binding_bound``/``uncoupled_seconds`` expose the coupling
    decomposition for the equivalence property tests."""

    configs: list[tuple[int, int]]
    fps: np.ndarray
    latency: np.ndarray
    tops: np.ndarray
    pbe: np.ndarray
    round_seconds: np.ndarray
    uncoupled_seconds: np.ndarray
    binding_bound: np.ndarray  # worst credit-loop bound; 0.0 when no edges


def _stage_pid_tables(pus: list[PUSpec], kinds: Sequence[str]):
    """Canonical per-(kind, rank) PU attributes: the k-th same-kind stage in
    pipeline order gets the k-th free PU of that kind (``assign_pids``)."""
    pid, slr, clk, peak = {}, {}, {}, {}
    for ki, kind in enumerate(kinds):
        specs = [p for p in pus if p.kind == kind]
        pid[ki] = np.array([p.pid for p in specs], dtype=np.int64)
        slr[ki] = np.array([p.slr for p in specs], dtype=np.int64)
        clk[ki] = np.array([p.sys_clk_hz for p in specs])
        peak[ki] = np.array([p.peak_tops for p in specs])
    return pid, slr, clk, peak


def score_details(
    analysis: GraphAnalysis,
    configs: Sequence[tuple[int, int]],
    *,
    pus: Optional[list[PUSpec]] = None,
    backend: str = "numpy",
) -> BatchedScores:
    """Evaluate every (a, b) in ``configs`` in one vectorized pass.

    Returns the full metric decomposition; ``score_single_batch`` is the
    ``SingleBatchPoint``-producing wrapper the explorer uses."""
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    pus = pus if pus is not None else make_u50_system()
    configs = [(int(a), int(b)) for a, b in configs]
    STATS.batched_score_calls += 1

    t0 = time.perf_counter()
    tab = analysis.tables()
    t0 = _tick("tables_build", t0)
    tab.partition_values(max(a for a, _ in configs), max(b for _, b in configs))
    t0 = _tick("partition_dp", t0)

    kinds = tab.kinds
    kidx = {k: i for i, k in enumerate(kinds)}
    stage_lists = [tab.reconstruct(a, b) for a, b in configs]
    t0 = _tick("reconstruct", t0)

    # one batched SMOF solve for every segment any config uses
    segs = []
    seen = set()
    for stages in stage_lists:
        for s in stages:
            i = tab.pos[s.nids[0]]
            key = (i, i + len(s.nids), s.pu_kind)
            if key not in seen:
                seen.add(key)
                segs.append(key)
    overheads = tab.segment_overheads(segs)
    t0 = _tick("smof", t0)

    B = len(configs)
    S = max((len(st) for st in stage_lists), default=0)
    n = tab.n
    st_time = np.zeros((B, S))
    st_kind = np.zeros((B, S), dtype=np.int64)
    st_rank = np.zeros((B, S), dtype=np.int64)
    st_mask = np.zeros((B, S), dtype=bool)
    stage_of = np.zeros((B, n), dtype=np.int64)
    for bi, stages in enumerate(stage_lists):
        seen_k = [0] * len(kinds)
        for s in stages:
            i = tab.pos[s.nids[0]]
            j = i + len(s.nids)
            ki = kidx[s.pu_kind]
            # stage time: profiled segment time + SMOF overhead (one add,
            # matching place()'s `s.time + stage_overhead(...)`)
            st_time[bi, s.index] = s.time + overheads[(i, j, s.pu_kind)]
            st_kind[bi, s.index] = ki
            st_rank[bi, s.index] = seen_k[ki]
            seen_k[ki] += 1
            st_mask[bi, s.index] = True
            stage_of[bi, i:j] = s.index

    pid_t, slr_t, clk_t, peak_t = _stage_pid_tables(pus, kinds)
    for ki in range(len(kinds)):
        need = int(np.where(st_kind == ki, st_rank + 1, 0).max(initial=0))
        if need > len(pid_t[ki]):
            raise ValueError(f"no free {kinds[ki]} for stage (budget exceeds "
                             f"the {len(pid_t[ki])} available)")
    st_pid = np.zeros((B, S), dtype=np.int64)
    st_slr = np.zeros((B, S), dtype=np.int64)
    st_clk = np.ones((B, S))
    st_peak = np.zeros((B, S))
    for ki in range(len(kinds)):
        m = st_mask & (st_kind == ki)
        st_pid[m] = pid_t[ki][st_rank[m]]
        st_slr[m] = slr_t[ki][st_rank[m]]
        st_clk[m] = clk_t[ki][st_rank[m]]
        st_peak[m] = peak_t[ki][st_rank[m]]

    if backend == "jax":
        out = _score_jax(tab, configs, st_time, st_kind, st_mask, stage_of,
                         st_pid, st_slr, st_clk, st_peak, analysis)
        _tick("score", t0)
        return out

    # -- numpy scoring (byte-identical to the scalar path) -------------------
    uncoupled = np.where(st_mask, st_time, -np.inf).max(axis=1, initial=-np.inf)
    uncoupled = np.where(np.isfinite(uncoupled), uncoupled, 0.0)

    E = tab.n_edges
    if E:
        ps = np.take_along_axis(stage_of, tab.edge_prod[None, :].repeat(B, 0), 1)
        cs = np.take_along_axis(stage_of, tab.edge_cons[None, :].repeat(B, 0), 1)
        dist = cs - ps
        # credit depth = stage-distance beta of the tensor (max over all of
        # its consumer edges, same-stage ones included), never below 1
        beta = np.zeros((B, tab.n_tensor_slots), dtype=np.int64)
        rowsB = np.repeat(np.arange(B), E)
        colsE = np.tile(tab.edge_tensor, B)
        np.maximum.at(beta, (rowsB, colsE), dist.ravel())
        depth = (beta + 1)[np.arange(B)[:, None], tab.edge_tensor[None, :]]

        pk = np.take_along_axis(st_kind, ps, 1)
        ck = np.take_along_axis(st_kind, cs, 1)
        ppid = np.take_along_axis(st_pid, ps, 1)
        cpid = np.take_along_axis(st_pid, cs, 1)
        pslr = np.take_along_axis(st_slr, ps, 1)
        cslr = np.take_along_axis(st_slr, cs, 1)
        pclk = np.take_along_axis(st_clk, ps, 1)
        cclk = np.take_along_axis(st_clk, cs, 1)

        tw = np.stack([tab.edge_t_write[k] for k in kinds])  # (K, E)
        tr = np.stack([tab.edge_t_read[k] for k in kinds])
        t_write = np.take_along_axis(tw, pk, 0)
        t_read = np.take_along_axis(tr, ck, 0)

        # token_latency_cycles, vectorized (symmetric in src/dst)
        hops = np.abs(ppid - cpid)
        lat_cyc = np.where(
            hops == 0, SAME_PU_LATENCY,
            BASE_HOP_LATENCY + (hops > 2).astype(np.int64)
            + SLR_CROSS_PENALTY * (pslr != cslr).astype(np.int64))
        l_req = lat_cyc / pclk
        l_ack = lat_cyc / cclk
        t_dec = (4 * DECODE_CYCLES) / pclk  # _HANDSHAKE_DECODES
        # exact left-to-right op order of coupling_bounds()
        cycle = (((t_write + l_req) + t_read) + l_ack) + t_dec
        bound = cycle / depth
        cross = dist > 0
        worst = np.where(cross, bound, 0.0).max(axis=1)  # max(bounds, 0.0)
        round_s = np.maximum(uncoupled, worst)

        # forward latency: min one-way REQ latency per distinct stage hop,
        # summed in canonical ascending (producer, consumer) order
        req_lat = l_req + (2 * DECODE_CYCLES) / pclk
        H = (S + 1) * (S + 1)
        hid = ps * (S + 1) + cs
        hop_min = np.full((B, H), np.inf)
        np.minimum.at(hop_min, (rowsB, hid.ravel()),
                      np.where(cross, req_lat, np.inf).ravel())
        fwd = np.cumsum(np.where(np.isfinite(hop_min), hop_min, 0.0),
                        axis=1)[:, -1] if H else np.zeros(B)
    else:
        worst = np.zeros(B)
        round_s = np.maximum(uncoupled, worst)
        fwd = np.zeros(B)

    # sequential sums in stage order (zero-padded tails are exact no-ops)
    times_m = np.where(st_mask, st_time, 0.0)
    lat = (np.cumsum(times_m, axis=1)[:, -1] if S else np.zeros(B)) + fwd
    tops = (np.cumsum(np.where(st_mask, st_peak, 0.0), axis=1)[:, -1]
            if S else np.zeros(B))

    caps_kind = np.array([analysis.pu_kinds[k].peak_tops for k in kinds])
    st_caps = np.where(st_mask, caps_kind[st_kind], 0.0)
    num = np.cumsum(times_m * st_caps, axis=1)[:, -1] if S else np.zeros(B)
    den = round_s * (np.cumsum(st_caps, axis=1)[:, -1] if S else np.zeros(B))
    with np.errstate(divide="ignore", invalid="ignore"):
        pbe = np.where((st_mask.any(axis=1)) & (round_s != 0.0), num / den, 0.0)
        fps = np.where(round_s != 0.0, 1.0 / round_s, 0.0)

    _tick("score", t0)
    return BatchedScores(
        configs=configs, fps=fps, latency=lat, tops=tops, pbe=pbe,
        round_seconds=round_s, uncoupled_seconds=uncoupled,
        binding_bound=worst,
    )


def score_single_batch(
    analysis: GraphAnalysis,
    configs: Sequence[tuple[int, int]],
    *,
    pus: Optional[list[PUSpec]] = None,
    backend: str = "numpy",
):
    """Score a config batch and return ``SingleBatchPoint``s in input order
    — the drop-in vectorized equivalent of one ``place()`` + ``_point_of``
    per config."""
    from .explorer import SingleBatchPoint

    sc = score_details(analysis, configs, pus=pus, backend=backend)
    return [
        SingleBatchPoint(a=a, b=b, fps=float(sc.fps[i]),
                         latency=float(sc.latency[i]), tops=float(sc.tops[i]),
                         pbe=float(sc.pbe[i]))
        for i, (a, b) in enumerate(sc.configs)
    ]


# -- JAX backend --------------------------------------------------------------

_JAX_FN = None


def _jax_fn():
    """Build (once) the jit-compiled, vmapped scoring kernel. Import is
    deferred and failure degrades to an ImportError at call time — the
    numpy backend never touches jax."""
    global _JAX_FN
    if _JAX_FN is not None:
        return _JAX_FN
    import jax
    import jax.numpy as jnp

    def one(st_time, st_mask, stage_of, st_pid, st_slr, st_clk, st_peak,
            st_caps, e_prod, e_cons, e_tensor, e_tw, e_tr, n_slots_arr):
        uncoupled = jnp.max(jnp.where(st_mask, st_time, -jnp.inf))
        uncoupled = jnp.where(jnp.isfinite(uncoupled), uncoupled, 0.0)
        ps = stage_of[e_prod]
        cs = stage_of[e_cons]
        dist = cs - ps
        beta = jnp.zeros(n_slots_arr.shape[0], dtype=jnp.int64)
        beta = beta.at[e_tensor].max(dist)
        depth = beta[e_tensor] + 1
        hops = jnp.abs(st_pid[ps] - st_pid[cs])
        lat_cyc = jnp.where(
            hops == 0, SAME_PU_LATENCY,
            BASE_HOP_LATENCY + (hops > 2).astype(jnp.int64)
            + SLR_CROSS_PENALTY * (st_slr[ps] != st_slr[cs]).astype(jnp.int64))
        pclk = st_clk[ps]
        l_req = lat_cyc / pclk
        l_ack = lat_cyc / st_clk[cs]
        t_dec = (4 * DECODE_CYCLES) / pclk
        cycle = (((e_tw + l_req) + e_tr) + l_ack) + t_dec
        bound = cycle / depth
        cross = dist > 0
        worst = jnp.max(jnp.where(cross, bound, 0.0), initial=0.0)
        round_s = jnp.maximum(uncoupled, worst)
        req_lat = l_req + (2 * DECODE_CYCLES) / pclk
        S1 = st_time.shape[0] + 1
        hid = ps * S1 + cs
        hop_min = jnp.full(S1 * S1, jnp.inf).at[hid].min(
            jnp.where(cross, req_lat, jnp.inf))
        fwd = jnp.sum(jnp.where(jnp.isfinite(hop_min), hop_min, 0.0))
        times_m = jnp.where(st_mask, st_time, 0.0)
        lat = jnp.sum(times_m) + fwd
        tops = jnp.sum(jnp.where(st_mask, st_peak, 0.0))
        num = jnp.sum(times_m * st_caps)
        den = round_s * jnp.sum(jnp.where(st_mask, st_caps, 0.0))
        pbe = jnp.where((jnp.any(st_mask)) & (round_s != 0.0),
                        num / jnp.where(den != 0.0, den, 1.0), 0.0)
        fps = jnp.where(round_s != 0.0,
                        1.0 / jnp.where(round_s != 0.0, round_s, 1.0), 0.0)
        return fps, lat, tops, pbe, round_s, uncoupled, worst

    _JAX_FN = (jax, jnp, one)
    return _JAX_FN


def _score_jax(tab, configs, st_time, st_kind, st_mask, stage_of,
               st_pid, st_slr, st_clk, st_peak, analysis) -> BatchedScores:
    """JAX backend: one jit-compiled vmap over the config batch. Tolerance
    path (XLA may fuse/reassociate float chains) — see module docstring."""
    jax, jnp, one = _jax_fn()
    t0 = time.perf_counter()
    kinds = tab.kinds
    B, S = st_time.shape
    E = tab.n_edges
    caps_kind = np.array([analysis.pu_kinds[k].peak_tops for k in kinds])
    st_caps = caps_kind[st_kind]
    if E == 0:
        # degenerate: no scorable edges; the numpy path is already exact
        sc = score_details(analysis, configs, backend="numpy")
        return sc
    tw = np.stack([tab.edge_t_write[k] for k in kinds])
    tr = np.stack([tab.edge_t_read[k] for k in kinds])
    ps = np.take_along_axis(stage_of, tab.edge_prod[None, :].repeat(B, 0), 1)
    cs = np.take_along_axis(stage_of, tab.edge_cons[None, :].repeat(B, 0), 1)
    e_tw = np.take_along_axis(tw, np.take_along_axis(st_kind, ps, 1), 0)
    e_tr = np.take_along_axis(tr, np.take_along_axis(st_kind, cs, 1), 0)
    n_slots = np.zeros(max(tab.n_tensor_slots, 1))

    fn = jax.jit(jax.vmap(
        one,
        in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None, None, None, 0, 0, None)))
    from jax.experimental import enable_x64

    # x64 is scoped to this evaluation — flipping the global flag would
    # silently re-dtype every float32 jax model built after a DSE call.
    with enable_x64():
        out = fn(st_time, st_mask, stage_of, st_pid, st_slr, st_clk,
                 st_peak, st_caps, jnp.asarray(tab.edge_prod),
                 jnp.asarray(tab.edge_cons), jnp.asarray(tab.edge_tensor),
                 e_tw, e_tr, jnp.asarray(n_slots))
        fps, lat, tops, pbe, round_s, uncoupled, worst = (
            np.asarray(o) for o in out)
    _tick("jit_trace", t0)
    return BatchedScores(
        configs=list(configs), fps=fps, latency=lat, tops=tops, pbe=pbe,
        round_seconds=round_s, uncoupled_seconds=uncoupled,
        binding_bound=worst,
    )
