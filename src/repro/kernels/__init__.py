# Pallas TPU kernels for the compute hot-spots, each with:
#   kernel.py -- pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
#   ops.py    -- jit'd dispatch wrapper (TPU kernel / jnp reference fallback)
#   ref.py    -- pure-jnp oracle used by tests and CPU lowering
#
#   gemm_int8        -- the paper's PU compute op: INT8 GEMM, power-of-two
#                       requantization, fused residual-add + ReLU (MXU-tiled)
#   flash_attention  -- blockwise causal/windowed GQA attention
#   ssd_scan         -- Mamba2 SSD chunked scan
#   rwkv6            -- RWKV6 wkv recurrence (chunk-tiled state updates)
