"""Pure-jnp oracles for blockwise GQA attention (causal / sliding window).

``mha_reference``      -- dense O(S^2)-memory oracle (small shapes, tests).
``chunked_attention``  -- online-softmax double-scan in pure jnp: O(S*block)
                          memory, lowers to while loops. This is the XLA
                          fallback the models use for long sequences (the
                          dense oracle would materialize 32k^2 score tensors
                          at prefill). KV heads are repeated to q-heads up
                          front so head-dim sharding propagates cleanly.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def repeat_kv(k: jax.Array, rep: int) -> jax.Array:
    """(b, t, G, hd) -> (b, t, G*rep, hd); XLA fuses the broadcast."""
    if rep == 1:
        return k
    b, t, G, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, G, rep, hd)).reshape(
        b, t, G * rep, hd
    )


def chunked_attention(
    q: jax.Array,  # (b, s, H, hd)
    k: jax.Array,  # (b, t, G, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    b, s, H, hd = q.shape
    t, G = k.shape[1], k.shape[2]
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    k = repeat_kv(k, H // G)
    v = repeat_kv(v, H // G)

    bq = min(block_q, s)
    bk = min(block_k, t)
    nq, nk = -(-s // bq), -(-t // bk)
    pad_q, pad_k = nq * bq - s, nk * bk - t
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # (nq, b, H, bq, hd) / (nk, b, H, bk, hd) — pinned batch+head sharded:
    # without the constraint, the remat'd backward of the double scan loses
    # the sharding and all-gathers kv blocks at *global* batch size
    from ...runtime.pspec import constrain

    qs = qp.reshape(b, nq, bq, H, hd).transpose(1, 0, 3, 2, 4) * sc
    ks = kp.reshape(b, nk, bk, H, hd).transpose(1, 0, 3, 2, 4)
    vs = vp.reshape(b, nk, bk, H, hd).transpose(1, 0, 3, 2, 4)
    qs = constrain(qs, "attn_chunk")
    ks = constrain(ks, "attn_chunk")
    vs = constrain(vs, "attn_chunk")

    def q_block(carry, qi_q):
        qi, qb = qi_q  # (), (b, H, bq, hd)

        def kv_block(state, ki_kv):
            m, l, acc = state
            ki, kb, vb = ki_kv
            sqk = jnp.einsum("bhqd,bhkd->bhqk", qb, kb).astype(jnp.float32)
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = (cols < t) & (rows < s)
            if causal:
                mask &= cols <= rows
            if window is not None:
                mask &= cols > rows - window
            sqk = jnp.where(mask[None, None], sqk, -1e30)
            m_new = jnp.maximum(m, jnp.max(sqk, axis=-1, keepdims=True))
            p = jnp.where(mask[None, None], jnp.exp(sqk - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, H, bq, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, H, bq, 1), jnp.float32)
        a0 = jnp.zeros((b, H, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), ks, vs)
        )
        out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
        return carry, out

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * bq, H, hd)
    return out[:, :s]


def banded_attention(
    q: jax.Array,  # (b, s, H, hd)
    k: jax.Array,  # (b, s, G, hd)  (self-attention: t == s)
    v: jax.Array,
    *,
    window: int,
    scale: Optional[float] = None,
    block_q: int = 512,
) -> jax.Array:
    """Sliding-window causal attention computed on the band only: each q
    chunk attends a fixed (window + block) kv slice — O(S * window) compute
    instead of masked O(S^2) (the windowed layers of gemma3 / danube3)."""
    b, s, H, hd = q.shape
    G = k.shape[2]
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    k = repeat_kv(k, H // G)
    v = repeat_kv(v, H // G)

    bq = min(block_q, s)
    nq = -(-s // bq)
    pad_q = nq * bq - s
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    # left-pad kv by `window` so chunk i's band starts at padded index i*bq
    kp = jnp.pad(k, ((0, 0), (window, pad_q), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, pad_q), (0, 0), (0, 0)))
    band = window + bq

    qs = qp.reshape(b, nq, bq, H, hd).transpose(1, 0, 3, 2, 4) * sc  # (nq,b,H,bq,hd)

    def chunk(carry, qi_qb):
        qi, qb = qi_qb
        kb = jax.lax.dynamic_slice_in_dim(kp, qi * bq, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, qi * bq, band, axis=1)
        kb = kb.transpose(0, 2, 1, 3)  # (b,H,band,hd)
        vb = vb.transpose(0, 2, 1, 3)
        sqk = jnp.einsum("bhqd,bhkd->bhqk", qb, kb).astype(jnp.float32)
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, band), 0)
        cols = qi * bq - window + jax.lax.broadcasted_iota(jnp.int32, (bq, band), 1)
        mask = (cols >= 0) & (cols <= rows) & (cols > rows - window) & (rows < s)
        sqk = jnp.where(mask[None, None], sqk, -1e30)
        p = jax.nn.softmax(sqk, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(chunk, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * bq, H, hd)
    return out[:, :s]


def mha_reference(
    q: jax.Array,  # (b, s, H, hd)
    k: jax.Array,  # (b, t, G, hd)
    v: jax.Array,  # (b, t, G, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    b, s, H, hd = q.shape
    t, G = k.shape[1], k.shape[2]
    rep = H // G
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(b, s, G, rep, hd)
    scores = jnp.einsum("bsgrq,btgq->bgrst", qh, k).astype(jnp.float32) * sc

    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrst,btgq->bsgrq", probs, v)
    return out.reshape(b, s, H, hd)
