"""Pallas TPU flash attention (blockwise online-softmax), GQA + causal +
sliding-window.

Tiling: grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is
"arbitrary" (sequential) so the VMEM scratch accumulators (m, l, acc) carry
across kv blocks. Block shapes default to (128, head_dim) — MXU-aligned on
the 128 lane dimension; the (Bq, Bk) score tile hits the 128x128 MXU.

HBM->VMEM movement per (q_block): q once, k/v streamed per kv block — the
same URAM/BRAM streaming discipline as the paper's PU, re-derived for the
TPU memory hierarchy (HBM -> VMEM -> MXU).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (Bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (Bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    # zero padded kv rows: ragged final blocks are padded out-of-bounds and
    # 0 * pad_garbage would still poison the p @ v matmul.
    kv_valid = (ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)) < kv_len
    k = jnp.where(kv_valid, k, 0.0)
    v = jnp.where(kv_valid, v, 0.0)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (Bq, Bk)

    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (Bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # 0 for fully-masked rows
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, :, 0, :] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_tpu(
    q: jax.Array,  # (b, s, H, hd)
    k: jax.Array,  # (b, t, G, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, s, H, hd = q.shape
    t, G = k.shape[1], k.shape[2]
    rep = H // G
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)

    bq = min(block_q, s)
    bk = min(block_k, t)
    nq = pl.cdiv(s, bq)
    nk = pl.cdiv(t, bk)

    kernel = functools.partial(
        _attn_kernel,
        scale=sc, causal=causal, window=window,
        block_q=bq, block_k=bk, kv_len=t,
    )
    grid = (b, H, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda bb, h, qi, ki: (bb, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bb, h, qi, ki, _rep=rep: (bb, ki, h // _rep, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bb, h, qi, ki, _rep=rep: (bb, ki, h // _rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda bb, h, qi, ki: (bb, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out
