"""Dispatch wrapper: Pallas kernel on TPU, jnp reference elsewhere.

``REPRO_FORCE_REF=1`` forces the reference path (used to validate the
dispatcher itself); tests exercise the kernel explicitly via interpret=True.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from .kernel import flash_attention_tpu
from .ref import banded_attention, chunked_attention, mha_reference

# above this many kv positions, the XLA fallback uses the chunked
# online-softmax path (O(S*block) memory) instead of the dense oracle
CHUNKED_THRESHOLD = 2048


def _use_kernel() -> bool:
    if os.environ.get("REPRO_FORCE_REF"):
        return False
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None):
    if _use_kernel():
        return flash_attention_tpu(q, k, v, causal=causal, window=window, scale=scale)
    if (
        causal
        and window is not None
        and q.shape[1] == k.shape[1]
        and k.shape[1] >= 2 * window
    ):
        return banded_attention(q, k, v, window=window, scale=scale)
    if k.shape[1] > CHUNKED_THRESHOLD:
        return chunked_attention(q, k, v, causal=causal, window=window, scale=scale)
    return mha_reference(q, k, v, causal=causal, window=window, scale=scale)
