"""Pallas TPU kernel for the RWKV6 wkv recurrence, chunk-tiled.

Grid = (batch, heads, seq_chunks); the chunk dimension is sequential
("arbitrary") so the (P, P) fp32 state matrix lives in VMEM scratch across
chunks — the TPU analogue of keeping the recurrence state resident (URAM-
resident accumulators in the paper's PU). Within a chunk the recurrence
steps run as an unrolled loop of (1,P)x(P,P) VPU/MXU ops on VMEM-resident
tiles; HBM traffic is one stream of r/k/v/w tiles per chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                 state_scr, *, chunk: int, seq_len: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    u_col = u_ref[0].astype(jnp.float32)[:, None]  # (P, 1): scales the k-dim

    def step(t, S):
        rt = r_ref[0, t, 0, :].astype(jnp.float32)[None, :]  # (1, P)
        kt = k_ref[0, t, 0, :].astype(jnp.float32)[None, :]
        vt = v_ref[0, t, 0, :].astype(jnp.float32)[None, :]
        wt = w_ref[0, t, 0, :].astype(jnp.float32)[None, :]
        kv = kt.T @ vt  # (P, P)
        y = rt @ (S + u_col * kv)  # (1, P)
        pos = ci * chunk + t
        @pl.when(pos < seq_len)
        def _store():
            y_ref[0, t, 0, :] = y[0].astype(y_ref.dtype)
        S = S * wt.T + kv
        return S

    S = state_scr[...]
    S = jax.lax.fori_loop(0, chunk, step, S)
    state_scr[...] = S

    @pl.when(ci == nc - 1)
    def _finish():
        sout_ref[0, 0] = S.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_tpu(r, k, v, w, u, state, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False):
    """r/k/v/w: (b, s, h, p); u: (h, p); state: (b, h, p, p) fp32."""
    b, s, h, p = r.shape
    ch = min(chunk, s)
    nc = pl.cdiv(s, ch)
    pad = nc * ch - s
    if pad:
        padfn = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = padfn(r), padfn(k), padfn(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)

    kernel = functools.partial(_wkv6_kernel, chunk=ch, seq_len=s)
    seq_spec = pl.BlockSpec((1, ch, 1, p), lambda bb, hh, cc: (bb, cc, hh, 0))
    y, s_out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, p), lambda bb, hh, cc: (hh, 0)),
            pl.BlockSpec((1, 1, p, p), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, p, p), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc * ch, h, p), r.dtype),
            jax.ShapeDtypeStruct((b, h, p, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y[:, :s], s_out
