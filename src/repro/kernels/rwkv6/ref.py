"""Pure-jnp oracle + chunked parallel form for the RWKV6 wkv recurrence.

    y_t = r_t . (S + u * k_t v_t^T)
    S   = diag(w_t) S + k_t v_t^T

``wkv6_reference`` is the sequential oracle (scan over time). ``wkv6_chunked``
is the GLA-style chunked form: with prefix decays P_t = prod_{tau<=t} w_tau,

    y_t = (r_t*P_{t-1}) . S_in                       (inter-chunk, matmul)
        + sum_{s<t} ((r_t*P_{t-1}).(k_s/P_s)) v_s    (intra-chunk, masked A @ V)
        + ((r_t*u).k_t) v_t                          (bonus diagonal)
    S_out = D(P_L) (S_in + (k/P)^T V)

All L-length chunk terms become MXU matmuls; the sequential dependence drops
from seq_len steps to seq_len/chunk state hops — this is the optimization
that removes the 4096-step scan from the XLA-lowered rwkv6 train/prefill
graphs (see EXPERIMENTS.md section Perf) and mirrors the Pallas kernel's
blocking.

Validity regime: the separable r*P / k/P factorization is exact while the
per-chunk cumulative log-decay stays within +/-CLAMP (=60). With chunk=16
that admits mean per-step decay down to w ~ e^-3.75 ~ 0.023 — far below
anything a trained RWKV6 uses (w = exp(-exp(x)) with x ~ [-8, 1]). Beyond
that, clamped terms mis-weight contributions that are themselves < e^-60.
The sequential oracle remains the ground truth in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CLAMP = 60.0


def wkv6_chunked(r, k, v, w, u, state, chunk: int = 16):
    """Same contract as wkv6_reference; r/k/v/w: (b,s,h,p) fp32, w in (0,1);
    u: (h,p); state: (b,h,p,p). Returns (y, final_state)."""
    b, s, h, p = r.shape
    ch = min(chunk, s)
    nc = -(-s // ch)
    pad = nc * ch - s
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)

    def cshape(a):
        return a.reshape(b, nc, ch, h, p)

    rc, kc, vc, wc = cshape(r), cshape(k), cshape(v), cshape(w)
    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cum = jnp.cumsum(logw, axis=2)  # logP_t (within chunk)
    excl = cum - logw  # logP_{t-1}
    r_dec = rc * jnp.exp(jnp.clip(excl, -CLAMP, CLAMP))
    k_dec = kc * jnp.exp(jnp.clip(-cum, -CLAMP, CLAMP))

    # intra-chunk: A[t,s] = r_dec_t . k_dec_s, strictly causal
    A = jnp.einsum("bclhp,bcmhp->bchlm", r_dec, k_dec)
    mask = jnp.tril(jnp.ones((ch, ch), bool), k=-1)
    A = jnp.where(mask[None, None, None], A, 0.0)
    y = jnp.einsum("bchlm,bcmhq->bclhq", A, vc)
    # bonus diagonal
    d = jnp.einsum("bclhp,hp,bclhp->bclh", rc, u, kc)
    y = y + d[..., None] * vc

    # inter-chunk state recurrence (chunk states stay head-sharded: they are
    # huge — (b, nc, h, p, p) — and must never be gathered)
    from ...runtime.pspec import constrain

    s_local = jnp.einsum("bclhp,bclhq->bchpq", k_dec, vc)  # (k/P)^T V
    s_local = constrain(s_local, "wkv_state")
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1], -CLAMP, CLAMP))  # (b,nc,h,p)

    def hop(S, inp):
        s_loc, dec = inp  # (b,h,p,q), (b,h,p)
        S_out = dec[..., None] * (S + s_loc)
        return S_out, S  # emit state entering the chunk

    Sf, S_in = jax.lax.scan(
        hop, state,
        (s_local.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    S_in = constrain(S_in.swapaxes(0, 1), "wkv_state")  # (b,nc,h,p,q)
    y = y + jnp.einsum("bclhp,bchpq->bclhq", r_dec, S_in)

    y = y.reshape(b, nc * ch, h, p)[:, :s]
    return y, Sf


def wkv6_reference(r, k, v, w, u, state):
    """r/k/v/w: (b, s, h, p) fp32 (w in (0,1)); u: (h, p); state: (b, h, p, p).
    Returns (y: (b, s, h, p), final_state)."""

    def step(S, inp):
        rt, kt, vt, wt = inp  # (b, h, p)
        kv = jnp.einsum("bhp,bhq->bhpq", kt, vt)
        y = jnp.einsum("bhp,bhpq->bhq", rt, S + u[None, :, :, None] * kv)
        S = S * wt[..., None] + kv
        return S, y

    seq = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, seq)
    return ys.swapaxes(0, 1), state
