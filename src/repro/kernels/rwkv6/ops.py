"""Dispatch wrapper for the wkv6 recurrence."""
from __future__ import annotations

import os

import jax

from .kernel import wkv6_tpu
from .ref import wkv6_chunked, wkv6_reference


def _use_kernel() -> bool:
    if os.environ.get("REPRO_FORCE_REF"):
        return False
    return jax.default_backend() == "tpu"


def wkv6(r, k, v, w, u, state):
    if _use_kernel():
        return wkv6_tpu(r, k, v, w, u, state)
    if os.environ.get("REPRO_FORCE_REF"):
        return wkv6_reference(r, k, v, w, u, state)
    if r.shape[1] > 1:
        # chunked parallel form: seq/chunk state hops instead of a
        # seq-length sequential scan (exact up to fp reassociation)
        return wkv6_chunked(r, k, v, w, u, state)
    return wkv6_reference(r, k, v, w, u, state)
