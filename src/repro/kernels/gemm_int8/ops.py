"""Dispatch wrapper for the INT8 PU GEMM."""
from __future__ import annotations

import os

import jax

from .kernel import gemm_int8_tpu
from .ref import gemm_int8_reference


def _use_kernel() -> bool:
    if os.environ.get("REPRO_FORCE_REF"):
        return False
    return jax.default_backend() == "tpu"


def gemm_int8(a, w, bias=None, *, shift: int = 7, relu: bool = False,
              residual=None):
    if _use_kernel():
        import jax.numpy as jnp

        b = bias if bias is not None else jnp.zeros((w.shape[1],), jnp.int32)
        return gemm_int8_tpu(a, w, b, residual, shift=shift, relu=relu)
    return gemm_int8_reference(a, w, bias, shift=shift, relu=relu, residual=residual)
