"""Pallas TPU kernel for the INT8 PU GEMM (the paper's SA compute op,
re-tiled for the MXU).

The paper's PU streams 64-output-channel tiles through a 64x4/64x8 systolic
array with URAM-resident weights. On TPU the analogous blocking is
(bm, bn, bk) = (128, 128, 512) MXU tiles with VMEM-resident accumulators:

  grid = (M/bm, N/bn, K/bk), K sequential ("arbitrary") so the int32
  accumulator tile lives in VMEM scratch across K steps — the URAM
  accumulation of the SA, mapped onto the TPU memory hierarchy.

Epilogue (the PU post-processing block, fused): +bias, power-of-two
requantization shift, optional residual add, optional ReLU, saturate to
INT8. Residual fusion = the paper's FusedConvAdd(ReLU) node.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 512


def _gemm_kernel(a_ref, w_ref, bias_ref, res_ref, o_ref, acc_scr,
                 *, shift: int, relu: bool, has_res: bool, n_k: int,
                 k_len: int, bk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = a_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    # ragged final K block: zero the padded reduction columns
    k_valid = (ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)) < k_len
    a = jnp.where(k_valid, a, 0)
    acc_scr[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(ki == n_k - 1)
    def _epilogue():
        acc = acc_scr[...] + bias_ref[...].astype(jnp.int32)
        if shift > 0:
            acc = (acc + (1 << (shift - 1))) >> shift
        if has_res:
            acc = acc + res_ref[...].astype(jnp.int32)
        if relu:
            acc = jnp.maximum(acc, 0)
        o_ref[...] = jnp.clip(acc, -128, 127).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("shift", "relu", "bm", "bn", "bk", "interpret")
)
def gemm_int8_tpu(
    a: jax.Array,  # (M, K) int8
    w: jax.Array,  # (K, N) int8
    bias: jax.Array,  # (N,) int32
    residual: Optional[jax.Array] = None,  # (M, N) int8
    *,
    shift: int = 7,
    relu: bool = False,
    bm: int = BM,
    bn: int = BN,
    bk: int = BK,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    N = w.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    gm, gn, gk = pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk)
    has_res = residual is not None
    res = residual if has_res else jnp.zeros((1, 1), jnp.int8)

    kernel = functools.partial(
        _gemm_kernel, shift=shift, relu=relu, has_res=has_res, n_k=gk,
        k_len=K, bk=bk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            (
                pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
                if has_res
                else pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))
            ),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, w, bias.reshape(1, N), res)
    return out
