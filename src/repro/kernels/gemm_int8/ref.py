"""Pure-jnp oracle for the paper's PU compute op: INT8 GEMM with INT32
accumulation, power-of-two requantization (round-half-up shift), optional
fused residual-add + ReLU, saturating INT8 output — the FusedConvAdd(ReLU)
dataflow of the PU post-processing block."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def gemm_int8_reference(
    a: jax.Array,  # (M, K) int8 activations
    w: jax.Array,  # (K, N) int8 weights
    bias: Optional[jax.Array] = None,  # (N,) int32
    *,
    shift: int = 7,  # power-of-two scale: out = acc >> shift
    relu: bool = False,
    residual: Optional[jax.Array] = None,  # (M, N) int8, added post-scale
) -> jax.Array:
    acc = jnp.dot(a.astype(jnp.int32), w.astype(jnp.int32))
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[None, :]
    if shift > 0:  # round-half-up requantization
        acc = (acc + (1 << (shift - 1))) >> shift
    if residual is not None:
        acc = acc + residual.astype(jnp.int32)
    if relu:
        acc = jnp.maximum(acc, 0)
    return jnp.clip(acc, -128, 127).astype(jnp.int8)
