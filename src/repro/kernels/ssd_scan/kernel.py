"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid = (batch, heads, chunks); chunk dim sequential, carrying the (N, P)
fp32 state in VMEM scratch. Per chunk (all MXU matmuls on VMEM tiles):

  y_diag = (C B^T  .  L  .  dt) @ X        intra-chunk causal contribution
  y_off  = exp(cum) * (C @ h_in)           inter-chunk via carried state
  h_out  = exp(cum_last) h_in + B^T @ (exp(cum_last - cum) dt X)

The (chunk x chunk) decay matrix L stays in registers/VMEM — never HBM —
which is exactly the memory-hierarchy win over the XLA-lowered reference
(the reference materializes L per (b, chunk, head) in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (l, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (l,)
    A = a_ref[0]  # scalar decay rate (negative)
    B = b_ref[0].astype(jnp.float32)  # (l, N)
    C = c_ref[0].astype(jnp.float32)  # (l, N)

    dA = dt * A  # (l,)
    cum = jnp.cumsum(dA)  # (l,)
    # intra-chunk decay matrix L[i,j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, None] - cum[None, :]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    # mask before exp: exp(diff) overflows above the diagonal, and masking
    # afterwards leaves 0 * inf = NaN in the VJP (same fix as models/ssm.py)
    L = jnp.exp(jnp.where(causal, diff, -jnp.inf))

    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (l, l)
    W = CB * L * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (l, P)

    h = h_scr[...]  # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    decay_to_end = jnp.exp(cum[-1] - cum) * dt  # (l,)
    h_new = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        B, decay_to_end[:, None] * x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h_scr[...] = h_new
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_tpu(xh, dt, A, B, C, *, chunk: int = DEFAULT_CHUNK,
                 interpret: bool = False):
    """xh: (b,s,H,P); dt: (b,s,H); A: (H,); B/C: (b,s,N).
    Returns (y: (b,s,H,P) fp32, h_final: (b,H,N,P) fp32)."""
    b, s, H, P = xh.shape
    N = B.shape[-1]
    ch = min(chunk, s)
    nc = pl.cdiv(s, ch)
    pad = nc * ch - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_ssd_kernel, chunk=ch)
    y, h_out = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, ch, 1, P), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, ch, 1), lambda bb, hh, cc: (bb, cc, hh)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,)),
            pl.BlockSpec((1, ch, N), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1, ch, N), lambda bb, hh, cc: (bb, cc, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, 1, P), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc * ch, H, P), jnp.float32),
            jax.ShapeDtypeStruct((b, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xh, dt, A, B, C)
    return y[:, :s], h_out
