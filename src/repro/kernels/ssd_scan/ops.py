"""Dispatch wrapper for the SSD scan."""
from __future__ import annotations

import os

import jax

from .kernel import ssd_scan_tpu
from .ref import ssd_reference


def _use_kernel() -> bool:
    if os.environ.get("REPRO_FORCE_REF"):
        return False
    return jax.default_backend() == "tpu"


def ssd_scan(xh, dt, A, B, C):
    """Returns y only (state handling is the model's concern in the jnp path)."""
    if _use_kernel():
        y, _ = ssd_scan_tpu(xh, dt, A, B, C)
        return y
    return ssd_reference(xh, dt, A, B, C)
