"""Pure-jnp oracle for the Mamba2 SSD scan: the *sequential* recurrence
(ground truth for both the chunked jnp path and the Pallas kernel).

    h_t = exp(dt_t * A) h_{t-1} + dt_t B_t x_t^T      (per head)
    y_t = C_t . h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(xh, dt, A, B, C):
    """xh: (b,s,H,P); dt: (b,s,H) > 0; A: (H,) < 0; B/C: (b,s,N).
    Returns y: (b,s,H,P) fp32."""
    b, s, H, P = xh.shape
    N = B.shape[-1]

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (b,H,P), (b,H), (b,N), (b,N)
        decay = jnp.exp(dt_t * A[None, :])  # (b,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t, B_t, x_t)
        h = h * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", C_t, h)
        return h, y

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    seq = (
        xh.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
        B.swapaxes(0, 1).astype(jnp.float32),
        C.swapaxes(0, 1).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, h0, seq)
    return ys.swapaxes(0, 1)
