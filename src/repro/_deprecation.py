"""Deprecation helper: warnings attributed to the true external caller.

Legacy spellings (tuple-only strategies, ``engine="fast"``) funnel through
normalization shims several frames below the code that actually wrote the
old form. :func:`warn_deprecated` walks the stack past the named shim
modules so the ``DeprecationWarning`` carries the *caller's* module — which
is what makes the CI policy work: pytest escalates deprecation warnings
originating from ``repro.*`` modules to errors (see ``pyproject.toml``),
so no repo-internal code can keep using a deprecated form, while external
callers just see an ordinary attributed warning.
"""
from __future__ import annotations

import sys
import warnings


def warn_deprecated(message: str, *, skip: tuple[str, ...] = ()) -> None:
    """Emit a ``DeprecationWarning`` attributed past the shim modules.

    ``skip`` lists module names (``__name__`` values) that are pass-through
    normalization layers; the warning is attributed to the nearest frame
    belonging to none of them (nor to this module).
    """
    skipped = set(skip) | {__name__}
    level = 2
    frame = sys._getframe(1)
    while frame is not None and frame.f_globals.get("__name__") in skipped:
        frame = frame.f_back
        level += 1
    warnings.warn(message, DeprecationWarning, stacklevel=level)
