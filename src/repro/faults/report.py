"""Structured fault diagnostics: the runtime counterpart of
:class:`repro.verify.Diagnostic`.

The static verifier proves properties of *programs*; the watchdog observes
*executions*. Both report through the same idiom — a typed code, a severity
and a precise location — so a serving operator reads "which PU, which
channel, which instruction" off a :class:`FaultReport` exactly like off a
compile-time diagnostic, and the recovery policy
(:meth:`repro.serve.Server` quarantine) consumes ``suspect_pid`` /
``suspect_channel`` without parsing strings.
"""
from __future__ import annotations

import enum
import re
from dataclasses import dataclass
from typing import Optional

from ..verify.report import Severity


class FaultCode(enum.Enum):
    """Typed runtime fault codes, one per detection path.

    The first four come from the per-process WAIT watchdog (classified by
    the effect the stuck process is parked on), HEARTBEAT from the
    per-member round-progress monitor, DEADLOCK from a drained event heap
    or a ``DeadlockError`` converted into reports.
    """

    PU_HANG = "fault-pu-hang"            # injected/physical PU stops decoding
    SYNC_TIMEOUT = "fault-sync-timeout"  # WAIT_REQ/ACK starved on a channel
    HBM_TIMEOUT = "fault-hbm-timeout"    # HBM channel held beyond timeout
    STALL = "fault-stall"                # stuck on an intra-PU interlock
    HEARTBEAT = "fault-heartbeat"        # member made no round progress
    DEADLOCK = "fault-deadlock"          # event heap drained with parked procs


@dataclass(frozen=True)
class FaultReport:
    """One detected runtime fault, located as precisely as the watchdog can.

    ``pid``/``group``/``index`` locate the stuck decoder down to the
    instruction; ``channel`` is the starved REQ/ACK coordination channel
    ``(src_pid, bid)`` for sync timeouts; ``hbm_channel`` the stalled HBM
    channel; ``member`` the owning deployment member (tenant) label;
    ``cycle`` the simulated cycle the victim parked at.
    """

    code: FaultCode
    message: str
    severity: Severity = Severity.ERROR
    member: str = ""
    pid: Optional[int] = None
    group: Optional[str] = None          # "LD" | "CP" | "ST"
    index: Optional[int] = None          # instruction index within the group
    channel: Optional[tuple[int, int]] = None  # (src_pid, bid) sync channel
    hbm_channel: Optional[int] = None
    cycle: float = 0.0

    @property
    def location(self) -> str:
        parts = []
        if self.member:
            parts.append(self.member)
        if self.pid is not None:
            loc = f"pu{self.pid}"
            if self.group:
                loc += f".{self.group}"
            if self.index is not None:
                loc += f"[{self.index}]"
            parts.append(loc)
        if self.channel is not None:
            parts.append(f"channel(src_pid={self.channel[0]}, bid={self.channel[1]})")
        if self.hbm_channel is not None:
            parts.append(f"hbm{self.hbm_channel}")
        return ":".join(parts)

    @property
    def suspect_pid(self) -> Optional[int]:
        """The PU the recovery policy should quarantine: the source side of
        a starved sync channel (it stopped providing tokens), otherwise the
        stuck PU itself."""
        if self.channel is not None and self.code in (
                FaultCode.SYNC_TIMEOUT, FaultCode.DEADLOCK):
            return self.channel[0]
        return self.pid

    @property
    def suspect_hbm_channel(self) -> Optional[int]:
        return self.hbm_channel

    def __str__(self) -> str:
        loc = self.location
        where = f" at {loc}" if loc else ""
        return (f"[{self.severity.value}] {self.code.value}{where} "
                f"@cycle {self.cycle:.0f}: {self.message}")


_CHANNEL_RE = re.compile(r"\(src_pid=(\d+), bid=(\d+)\)")
_PROC_RE = re.compile(r"^pu(\d+)\.(\w+)$")


def _parse_proc_name(name: str) -> tuple[Optional[int], Optional[str]]:
    """``pu3.LD`` -> (3, "LD"); ``pu3.wadm`` -> (3, None); else (None, None)."""
    m = _PROC_RE.match(name)
    if not m:
        return None, None
    pid = int(m.group(1))
    group = m.group(2)
    return pid, group if group in ("LD", "CP", "ST") else None


def reports_from_blocked(blocked, *, code: FaultCode = FaultCode.DEADLOCK,
                         now: float = 0.0) -> list[FaultReport]:
    """Convert :class:`repro.core.events.BlockedProc` entries (a drained
    heap or a ``DeadlockError``) into :class:`FaultReport` diagnostics, so
    deadlocks flow through the same recovery path as watchdog detections."""
    out: list[FaultReport] = []
    for b in blocked:
        pid, group = _parse_proc_name(b.name)
        channel = None
        m = _CHANNEL_RE.search(b.desc)
        if m:
            channel = (int(m.group(1)), int(m.group(2)))
        out.append(FaultReport(
            code=code,
            message=f"{b.name} parked: {b.desc}",
            member=b.member,
            pid=pid,
            group=group,
            channel=channel,
            cycle=b.cycle if b.cycle else now,
        ))
    return out
