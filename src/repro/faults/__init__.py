# Fault tolerance for the multi-PU machine: seeded deterministic fault
# injection into the event kernel (hang a PU mid-round, drop/corrupt sync
# tokens, stall an HBM channel, spike an ISU link), watchdog detection that
# turns silent hangs into structured FaultReports (PU/channel/instruction
# location, mirroring the repro.verify diagnostic idiom), and the value
# types the serving loop's quarantine/replan/replay recovery consumes.
from .inject import FaultInjector
from .report import FaultCode, FaultReport, reports_from_blocked
from .spec import (FAULT_CLASSES, FaultSchedule, FaultSpec, HBMStall,
                   LinkSpike, PUHang, TokenCorrupt, TokenDrop)
from .watchdog import Watchdog, spawn_monitor

__all__ = [
    "FAULT_CLASSES",
    "FaultCode",
    "FaultInjector",
    "FaultReport",
    "FaultSchedule",
    "FaultSpec",
    "HBMStall",
    "LinkSpike",
    "PUHang",
    "TokenCorrupt",
    "TokenDrop",
    "Watchdog",
    "reports_from_blocked",
    "spawn_monitor",
]
