"""Fault injection into a :class:`repro.core.simulator.MultiPUSimulator`.

The injector attaches a :class:`~repro.faults.FaultSchedule` to the
*per-run* objects the simulator rebuilds on every ``reset()`` — hang gates
on the fresh ICUs, a fault hook on the fresh ISU fabric, daemon stall
processes in the fresh kernel. Nothing outlives a reset except the frozen
schedule itself, so a simulator whose schedule is cleared
(``clear_faults()``) is indistinguishable from one that was never faulted,
and re-arming the same schedule every window keeps seeded runs
deterministic.

All injector processes are *daemons*: they never count as pending work
(a stall holding an unused channel forever must not deadlock a healthy
run) and the watchdog skips them when scanning for victims.
"""
from __future__ import annotations

import math
from dataclasses import replace
from typing import Optional

from ..core.events import Acquire, Delay, Release, WaitCond
from ..core.isu import Token
from .spec import (FaultSchedule, HBMStall, LinkSpike, PUHang, TokenCorrupt,
                   TokenDrop)

# BID field width (Table I(b)): corrupted BIDs wrap inside the field.
_BID_SPACE = 1 << 12


class FaultInjector:
    """Arms one frozen schedule onto one simulator's current run state."""

    def __init__(self, sim, schedule: FaultSchedule) -> None:
        self.sim = sim
        self.schedule = schedule
        # (cycle, description) per engaged fault, for this run only.
        self.log: list[tuple[float, str]] = []

    def install(self) -> None:
        """Attach every spec to the simulator's *current* kernel/ICU/ISU
        (called from ``MultiPUSimulator.reset()``)."""
        token_faults: list[list] = []  # [spec, match_count, fired]
        for f in self.schedule:
            if isinstance(f, PUHang):
                icu = self.sim.icus.get(f.pid)
                if icu is not None:
                    icu.hang_at = f.at_cycle
            elif isinstance(f, HBMStall):
                self.sim.kernel.spawn(
                    self._hbm_stall(f), name=f"fault.hbm{f.channel}",
                    daemon=True)
            elif isinstance(f, (TokenDrop, TokenCorrupt, LinkSpike)):
                token_faults.append([f, 0, False])
            else:
                raise TypeError(f"unknown fault spec {f!r}")
        if token_faults:
            self.sim.isu.fault_hook = self._make_hook(token_faults)

    # -- HBM channel stall ---------------------------------------------------
    def _hbm_stall(self, f: HBMStall):
        if f.at_cycle > 0:
            yield Delay(f.at_cycle)
        chan = self.sim.hbm_channels[f.channel]
        yield Acquire(chan)
        self.log.append((self.sim.kernel.now,
                         f"hbm-stall engaged on channel {f.channel}"))
        if math.isinf(f.duration):
            # Hold the channel forever: park on a key nobody notifies.
            yield WaitCond(("fault", "hbm-stall", f.channel),
                           pred=lambda: False,
                           desc=f"injected HBM stall holding channel {f.channel}")
        yield Delay(f.duration)
        yield Release(chan)

    # -- token-level faults (drop / corrupt / link spike) --------------------
    def _make_hook(self, token_faults: list[list]):
        sim = self.sim

        def hook(token: Token, latency: float) -> tuple[Optional[Token], float]:
            now = sim.kernel.now
            for state in token_faults:
                f = state[0]
                if isinstance(f, LinkSpike):
                    if (token.src_pid == f.src_pid
                            and token.dst_pid == f.dst_pid
                            and f.at_cycle <= now < f.at_cycle + f.duration):
                        if not state[2]:
                            state[2] = True
                            self.log.append(
                                (now, f"link-spike engaged on "
                                      f"{f.src_pid}->{f.dst_pid} "
                                      f"(+{f.extra_cycles:.0f} cycles)"))
                        latency += f.extra_cycles
                    continue
                if state[2] or token.src_pid != f.src_pid:
                    continue
                if f.bid is not None and token.bid != f.bid:
                    continue
                if f.kind != "any" and token.kind != f.kind:
                    continue
                state[1] += 1
                if state[1] < f.nth:
                    continue
                state[2] = True  # one-shot within this run
                if isinstance(f, TokenDrop):
                    self.log.append((now, f"token-drop engaged: lost {token!r}"))
                    return None, latency
                bad_bid = (token.bid + f.bid_offset) % _BID_SPACE
                self.log.append(
                    (now, f"token-corrupt engaged: {token!r} "
                          f"BID rewritten to {bad_bid}"))
                token = replace(token, bid=bad_bid)
            return token, latency

        return hook
