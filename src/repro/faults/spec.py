"""Deterministic fault specifications and seeded fault schedules.

Each spec describes one injectable hardware failure of the paper's
machine model — a PU whose ICU decoders stop issuing (PUHang), a sync
token lost or corrupted in the ISU fabric (TokenDrop / TokenCorrupt), an
HBM pseudo-channel that stops serving transfers (HBMStall), a congested
ISU link (LinkSpike). A :class:`FaultSchedule` bundles specs; it is a
frozen value, so re-arming it on every ``MultiPUSimulator.reset()`` (the
serving loop resets per window) is idempotent and two runs with the same
schedule are byte-identical.

:meth:`FaultSchedule.random` derives a schedule from a seed alone
(``random.Random(seed)``), which is what the chaos-determinism tests and
the CI smoke drive: same seed, same faults, same event log.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Optional, Union

from ..core.pu import N_HBM_CHANNELS, PUSpec, make_u50_system


@dataclass(frozen=True)
class PUHang:
    """PU ``pid`` stops decoding instructions once the clock reaches
    ``at_cycle`` (mid-round: the gate is checked per instruction)."""

    pid: int
    at_cycle: float = 0.0


@dataclass(frozen=True)
class TokenDrop:
    """The ``nth`` matching sync token from ``src_pid`` is lost in the
    fabric. ``bid``/``kind`` narrow the match to one coordination channel
    (``None``/``"any"`` match every BID / both REQ and ACK)."""

    src_pid: int
    bid: Optional[int] = None
    kind: str = "any"  # "req" | "ack" | "any"
    nth: int = 1


@dataclass(frozen=True)
class TokenCorrupt:
    """The ``nth`` matching token arrives with its BID rewritten by
    ``bid_offset`` — it lands in the wrong LUTRAM entry, so the intended
    waiter starves while a bogus entry accumulates."""

    src_pid: int
    bid: Optional[int] = None
    kind: str = "any"
    nth: int = 1
    bid_offset: int = 1024


@dataclass(frozen=True)
class HBMStall:
    """HBM channel ``channel`` stops serving at ``at_cycle`` for
    ``duration`` cycles (infinite by default): the injector holds the
    channel semaphore, so every ADM transfer routed there parks."""

    channel: int
    at_cycle: float = 0.0
    duration: float = math.inf


@dataclass(frozen=True)
class LinkSpike:
    """Tokens on the directed ISU link ``src_pid -> dst_pid`` take
    ``extra_cycles`` additional latency while the clock is inside
    ``[at_cycle, at_cycle + duration)`` — a congested/flaky register
    slice rather than a dead one."""

    src_pid: int
    dst_pid: int
    extra_cycles: float
    at_cycle: float = 0.0
    duration: float = math.inf


FaultSpec = Union[PUHang, TokenDrop, TokenCorrupt, HBMStall, LinkSpike]

FAULT_CLASSES = ("pu-hang", "token-drop", "token-corrupt", "hbm-stall",
                 "link-spike")


def _describe(f: FaultSpec) -> str:
    if isinstance(f, PUHang):
        return f"pu-hang(pid={f.pid}, at={f.at_cycle:.0f})"
    if isinstance(f, TokenDrop):
        bid = "*" if f.bid is None else f.bid
        return f"token-drop(src={f.src_pid}, bid={bid}, {f.kind}, nth={f.nth})"
    if isinstance(f, TokenCorrupt):
        bid = "*" if f.bid is None else f.bid
        return (f"token-corrupt(src={f.src_pid}, bid={bid}, {f.kind}, "
                f"nth={f.nth}, +{f.bid_offset})")
    if isinstance(f, HBMStall):
        dur = "inf" if math.isinf(f.duration) else f"{f.duration:.0f}"
        return f"hbm-stall(ch={f.channel}, at={f.at_cycle:.0f}, dur={dur})"
    if isinstance(f, LinkSpike):
        return (f"link-spike({f.src_pid}->{f.dst_pid}, "
                f"+{f.extra_cycles:.0f}cyc, at={f.at_cycle:.0f})")
    return repr(f)  # pragma: no cover - exhaustive above


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable bundle of fault specs, optionally tagged with the seed
    that generated it. Frozen so the simulator can re-arm it on every
    reset without fired-once bookkeeping leaking across runs."""

    faults: tuple[FaultSpec, ...] = field(default_factory=tuple)
    seed: Optional[int] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "faults", tuple(self.faults))

    def __bool__(self) -> bool:
        return bool(self.faults)

    def __iter__(self):
        return iter(self.faults)

    def describe(self) -> str:
        tag = f"seed={self.seed} " if self.seed is not None else ""
        return tag + "; ".join(_describe(f) for f in self.faults) or "empty"

    @classmethod
    def random(cls, seed: int, *, pus: Optional[list[PUSpec]] = None,
               n: int = 1, classes=FAULT_CLASSES,
               cycle_range: tuple[float, float] = (1_000.0, 50_000.0),
               spike_cycles: float = 5_000_000.0) -> "FaultSchedule":
        """A schedule derived from ``seed`` alone: ``n`` faults drawn
        uniformly over ``classes`` and over the machine's PUs / HBM
        channels / links, engaging at a cycle inside ``cycle_range``.
        Deterministic: same arguments, same schedule."""
        rng = random.Random(seed)
        pids = [p.pid for p in (pus if pus is not None else make_u50_system())]
        out: list[FaultSpec] = []
        for _ in range(n):
            klass = rng.choice(list(classes))
            at = rng.uniform(*cycle_range)
            if klass == "pu-hang":
                out.append(PUHang(pid=rng.choice(pids), at_cycle=at))
            elif klass == "token-drop":
                out.append(TokenDrop(src_pid=rng.choice(pids),
                                     nth=rng.randint(1, 8)))
            elif klass == "token-corrupt":
                out.append(TokenCorrupt(src_pid=rng.choice(pids),
                                        nth=rng.randint(1, 8)))
            elif klass == "hbm-stall":
                out.append(HBMStall(channel=rng.randrange(N_HBM_CHANNELS),
                                    at_cycle=at))
            elif klass == "link-spike":
                src = rng.choice(pids)
                dst = rng.choice([p for p in pids if p != src])
                out.append(LinkSpike(src_pid=src, dst_pid=dst,
                                     extra_cycles=spike_cycles, at_cycle=at))
            else:
                raise ValueError(f"unknown fault class {klass!r}")
        return cls(faults=tuple(out), seed=seed)
