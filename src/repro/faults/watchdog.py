"""Runtime fault detection: WAIT watchdogs and round-progress heartbeats.

A hung PU, a lost sync token or a dead HBM channel all look the same from
inside the event kernel: some process parks forever while simulated time
stops advancing for its member. The watchdog is a *daemon* monitor process
that ticks every ``check_interval_cycles`` and converts that silence into
structured :class:`~repro.faults.FaultReport` diagnostics:

* **per-channel WAIT timeouts** — any non-daemon process parked longer
  than ``wait_timeout_cycles`` is classified by the effect it is parked
  on: the injected hang gate (PU_HANG), a REQ/ACK LUTRAM wait with its
  exact ``(src_pid, bid)`` channel (SYNC_TIMEOUT), an HBM channel
  semaphore (HBM_TIMEOUT), anything else (STALL);
* **per-member heartbeats** — a member whose exit PU completes no round
  for ``heartbeat_cycles`` (and has not halted) raises HEARTBEAT.

On the first non-empty scan the monitor appends its reports and halts the
kernel — detection bounds the simulation instead of ``max_events``.
Timeouts default generous (legitimate waits in deep pipelines reach tens
of thousands of cycles); because the simulation is event-driven, idle
watchdog ticks are nearly free.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.events import Acquire, WaitCond
from ..core.isa import Group
from .report import FaultCode, FaultReport, _parse_proc_name

_GROUPS = {g.name: g for g in Group}


@dataclass(frozen=True)
class Watchdog:
    """Detection thresholds, in sys_clk cycles."""

    wait_timeout_cycles: float = 1_000_000.0
    heartbeat_cycles: float = 5_000_000.0
    check_interval_cycles: float = 100_000.0


def _classify(sim, proc, waited: float, now: float) -> FaultReport:
    """One parked process -> one located FaultReport."""
    pid, group = _parse_proc_name(proc.name)
    index = None
    if pid is not None and group is not None:
        icu = sim.icus.get(pid)
        if icu is not None:
            index = icu.cur_index.get(_GROUPS[group])
    eff = proc.pending
    cycle = proc.blocked_since if proc.blocked_since is not None else now
    common = dict(member=proc.member, pid=pid, group=group, index=index,
                  cycle=cycle)
    if isinstance(eff, WaitCond):
        key = eff.key
        if isinstance(key, tuple) and key and key[0] == "fault":
            return FaultReport(
                code=FaultCode.PU_HANG,
                message=f"{proc.name} stopped decoding "
                        f"({waited:.0f} cycles ago): {eff.desc}",
                **common)
        if isinstance(key, tuple) and len(key) == 4 and key[0] == "lut":
            channel = key[3]  # the (src_pid, bid) LUTRAM address
            return FaultReport(
                code=FaultCode.SYNC_TIMEOUT,
                message=f"{proc.name} starved {waited:.0f} cycles in "
                        f"{eff.desc or 'a sync WAIT'}",
                channel=channel, **common)
        return FaultReport(
            code=FaultCode.STALL,
            message=f"{proc.name} parked {waited:.0f} cycles on "
                    f"{eff.desc or repr(key)}",
            **common)
    if isinstance(eff, Acquire):
        name = eff.sem.name or ""
        if name.startswith("hbm"):
            return FaultReport(
                code=FaultCode.HBM_TIMEOUT,
                message=f"{proc.name} waited {waited:.0f} cycles for HBM "
                        f"channel {name[3:]}",
                hbm_channel=int(name[3:]), **common)
        return FaultReport(
            code=FaultCode.STALL,
            message=f"{proc.name} waited {waited:.0f} cycles for "
                    f"semaphore {name or '<anon>'}",
            **common)
    return FaultReport(  # pragma: no cover - parked implies an effect
        code=FaultCode.STALL,
        message=f"{proc.name} unresponsive for {waited:.0f} cycles",
        **common)


def _scan(sim, wd: Watchdog, members, hb_state: dict) -> list[FaultReport]:
    now = sim.kernel.now
    reports: list[FaultReport] = []
    for p in sim.kernel._procs:
        if p.done or p.daemon or p.pending is None:
            continue
        since = p.blocked_since if p.blocked_since is not None else now
        waited = now - since
        if waited >= wd.wait_timeout_cycles:
            reports.append(_classify(sim, p, waited, now))
    # Round-progress heartbeats, one per member that has not halted.
    from ..core.isa import Group as G
    for m in members:
        st = sim.icus[m.last_pid].stats[G.ST]
        if st.halted_at is not None:
            continue
        rounds = st.rounds_done
        label = m.workload or m.label or f"member@pu{m.last_pid}"
        prev = hb_state.get(label)
        if prev is None or prev[0] != rounds:
            hb_state[label] = (rounds, now)
            continue
        if now - prev[1] >= wd.heartbeat_cycles:
            reports.append(FaultReport(
                code=FaultCode.HEARTBEAT,
                message=f"member {label!r} completed no round for "
                        f"{now - prev[1]:.0f} cycles "
                        f"(stuck after round {rounds})",
                member=label, pid=m.last_pid,
                cycle=prev[1]))
    return reports


def _monitor(sim, wd: Watchdog, members, out: list):
    from ..core.events import Delay

    hb_state: dict = {}
    while True:
        yield Delay(wd.check_interval_cycles)
        reports = _scan(sim, wd, members, hb_state)
        if reports:
            out.extend(sorted(reports, key=lambda r: (r.cycle, str(r))))
            sim.kernel.halt()
            return


def spawn_monitor(sim, wd: Watchdog, members, out: list) -> None:
    """Spawn the daemon watchdog into the simulator's current kernel.
    Detected faults are appended to ``out`` (the run's fault list) and the
    kernel is halted on first detection."""
    sim.kernel.spawn(_monitor(sim, wd, members, out), name="faults.watchdog",
                     daemon=True)
