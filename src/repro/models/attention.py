"""Grouped-query attention: full / sliding-window / local-global, optional
qk-norm, RoPE; prefill (full-sequence) and single-token decode paths.

The full-sequence path routes through ``repro.kernels.flash_attention.ops``
which dispatches to the Pallas TPU kernel on TPU and the pure-jnp reference
elsewhere (so CPU dry-runs and tests always lower).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..runtime.pspec import constrain
from .layers import apply_rope, normal, rmsnorm


def init_attn(key, cfg: ArchConfig, dtype) -> dict:
    d, H, G, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": normal(k1, (d, H, hd), s, dtype),
        "wk": normal(k2, (d, G, hd), s, dtype),
        "wv": normal(k3, (d, G, hd), s, dtype),
        "wo": normal(k4, (H, hd, d), 1.0 / math.sqrt(H * hd), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"])
    k = jnp.einsum("bsd,dgq->bsgq", x, p["wk"])
    v = jnp.einsum("bsd,dgq->bsgq", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def full_attention(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    local: bool,
    window: Optional[int] = None,
) -> jax.Array:
    """Causal (optionally windowed) self-attention over the full sequence."""
    from ..kernels.flash_attention import ops as flash

    b, s, d = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    q = constrain(q, "attn_q")
    w = (window or cfg.window) if local else None
    out = flash.flash_attention(q, k, v, causal=True, window=w)
    out = constrain(out, "attn_out")
    return jnp.einsum("bshq,hqd->bsd", out, p["wo"])


# ------------------------------------------------------------- decode path --
def init_kv_cache(cfg: ArchConfig, n_layers: int, batch: int, length: int, dtype) -> dict:
    G, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, batch, length, G, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(
    p: dict,
    cfg: ArchConfig,
    x: jax.Array,  # (b, 1, d)
    layer_cache: dict,  # {"k": (b, S, g, q), "v": ...} single layer slice
    pos: jax.Array,  # scalar int32 current position
    *,
    local: bool,
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    cache_len = layer_cache["k"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)  # q:(b,1,H,hd) k/v:(b,1,G,hd)

    # ring-buffer slot for windowed layers; plain slot otherwise
    slot = jnp.where(jnp.array(local), pos % cache_len, jnp.minimum(pos, cache_len - 1))
    ck = jax.lax.dynamic_update_slice(layer_cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(layer_cache["v"], v, (0, slot, 0, 0))

    from ..kernels.flash_attention.ref import repeat_kv

    kr = repeat_kv(ck, H // G)  # (b, t, H, hd); broadcast fuses, no copy
    vr = repeat_kv(cv, H // G)
    # preferred_element_type keeps the cache operand bf16 (an .astype(f32)
    # on the output makes XLA materialize an f32 copy of the whole cache)
    scores = jnp.einsum("buhq,bthq->bhut", q, kr,
                        preferred_element_type=jnp.float32)
    scores = constrain(scores, "decode_scores")  # t-sharded (flash-decoding)
    scores *= 1.0 / math.sqrt(hd)
    valid = jnp.arange(cache_len)[None, :] <= jnp.minimum(pos, cache_len - 1)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bhut,bthq->buhq", probs, vr)
    y = jnp.einsum("bshq,hqd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}
