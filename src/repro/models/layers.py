"""Shared layer primitives: norms, RoPE, MLPs, initializers.

Conventions:
  * params are nested dicts of jnp arrays; per-layer stacks carry a leading
    layer axis and are consumed by jax.lax.scan;
  * compute dtype is bf16 (configurable), norm/softmax statistics in fp32;
  * einsum dim letters: b=batch s/t=seq d=d_model h=q-heads g=kv-heads
    q=head_dim f=d_ff e=experts c=capacity v=vocab.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "sqrelu": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ----------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLPs ----
def init_mlp(key, d: int, f: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {"w_out": normal(k2, (f, d), s_out, dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_in"] = normal(k1, (d, f), s_in, dtype)
        p["w_gate"] = normal(k3, (d, f), s_in, dtype)
    else:  # dense
        p["w_in"] = normal(k1, (d, f), s_in, dtype)
    return p


def mlp(p: dict, x: jax.Array, kind: str, act: str) -> jax.Array:
    from ..runtime.pspec import constrain

    a = act_fn("silu" if kind == "swiglu" else act)
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = a(g) * h
    else:
        h = a(h)
    h = constrain(h, "ffn_hidden")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


# ------------------------------------------------------------- embedding ----
def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return normal(key, (vocab, d), 1.0, dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jax.Array, x: jax.Array, tied: bool) -> jax.Array:
    from ..runtime.pspec import constrain

    if tied:
        logits = jnp.einsum("bsd,vd->bsv", x, table_or_head)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, table_or_head)
    return constrain(logits, "logits")
