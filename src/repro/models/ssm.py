"""Mamba2 (SSD — state-space duality) block: chunked parallel scan for
training/prefill, O(1) recurrent state for decode.

Follows Mamba-2 [arXiv:2405.21060]: per-head scalar decay A, input-dependent
dt (softplus), shared B/C of size ``ssm_state``, depthwise conv on (x, B, C),
gated output. The chunked SSD propagates inter-chunk state h with per-chunk
decays; ``repro.kernels.ssd_scan`` provides the Pallas TPU kernel and this
module's chunked jnp path is its reference semantics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..runtime.pspec import constrain
from .layers import normal, rmsnorm


def init_mamba(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    conv_dim = di + 2 * N
    return {
        # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
        "w_in": normal(ks[0], (d, 2 * di + 2 * N + H), s, dtype),
        "conv_w": normal(ks[1], (cfg.ssm_conv, conv_dim), 0.5, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": normal(ks[2], (H,), 0.5, jnp.float32),  # A = -exp(A_log)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "w_out": normal(ks[3], (di, d), 1.0 / math.sqrt(di), dtype),
    }


def _split_proj(cfg: ArchConfig, proj: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * N]
    dt = proj[..., di + di + 2 * N :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq: xbc (b, s, c), w (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(xh, dt, A, B, C, chunk: int = 128):
    """Chunked SSD scan (pure jnp reference; kernels/ssd_scan mirrors it).

    xh: (b, s, H, P) inputs; dt: (b, s, H) positive step sizes;
    A: (H,) negative decay rates; B, C: (b, s, N).
    Returns y: (b, s, H, P).
    """
    b, s, H, P = xh.shape
    N = B.shape[-1]
    if s % chunk:
        pad = chunk - s % chunk
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S = xh.shape[1]
    nc = S // chunk
    xc = xh.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)

    dA = dtc * A[None, None, None, :]  # (b,nc,l,H) negative increments
    cums = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay

    # intra-chunk (diagonal) term: causal decay matrix L. Mask *before* the
    # exp: above the diagonal Ldiff > 0 grows with |sum dt*A| and overflows
    # to inf; where(causal, exp(Ldiff), 0) is fine in the forward pass but
    # its backward computes 0 * inf = NaN. exp(-inf) = 0 keeps both passes
    # finite.
    Ldiff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (b,nc,l,l,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.exp(jnp.where(causal[None, None, :, :, None], Ldiff, -jnp.inf))
    CB = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)  # (b,nc,l,l)
    y_diag = jnp.einsum("bclm,bclmh,bcmh,bcmhp->bclhp", CB, L, dtc, xc)

    # chunk-boundary states: h_c = sum_m exp(cums_last - cums_m) dt_m B_m x_m
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)  # (b,nc,l,H)
    states = jnp.einsum("bclh,bclh,bcln,bclhp->bchnp", decay_to_end, dtc, Bc, xc)

    # inter-chunk recurrence over h (scan over chunks)
    chunk_decay = jnp.exp(cums[:, :, -1, :])  # (b,nc,H)

    def step(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    _, h_prev = jax.lax.scan(
        step,
        h0,
        (states.astype(jnp.float32).swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    h_prev = h_prev.swapaxes(0, 1)  # (b,nc,H,N,P) state entering each chunk

    # off-diagonal contribution: y_off = C_l . (decay_from_start * h_prev)
    decay_from_start = jnp.exp(cums)  # (b,nc,l,H)
    y_off = jnp.einsum(
        "bcln,bclh,bchnp->bclhp", Cc, decay_from_start, h_prev.astype(Cc.dtype)
    )
    y = (y_diag + y_off).reshape(b, S, H, P)[:, :s]
    return y


def mamba_forward(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Full-sequence Mamba2 block. x: (b, s, d)."""
    b, s, d = x.shape
    H, P, N, di = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.d_inner
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dtr = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xin, B, C = xbc[..., :di], xbc[..., di : di + N], xbc[..., di + N :]
    xh = xin.reshape(b, s, H, P)
    xh = constrain(xh, "ssm_x")
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y = ssd_chunked(xh, dt, A, B.astype(jnp.float32), C.astype(jnp.float32))
    y = y + p["D"][None, None, :, None] * xh.astype(y.dtype)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"])


# ------------------------------------------------------------- decode path --
def init_mamba_cache(cfg: ArchConfig, n_layers: int, batch: int, dtype) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((n_layers, batch, H, N, P), jnp.float32),
    }


def mamba_decode_step(p: dict, cfg: ArchConfig, x: jax.Array, cache: dict):
    """x: (b, 1, d); cache: single-layer {"conv": (b,k-1,c), "ssm": (b,H,N,P)}."""
    b = x.shape[0]
    H, P, N, di = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.d_inner
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dtr = _split_proj(cfg, proj)
    xbc_t = xbc[:, 0]  # (b, c)

    hist = jnp.concatenate([cache["conv"], xbc_t[:, None]], axis=1)  # (b,k,c)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:]

    xin, B, C = conv_out[..., :di], conv_out[..., di : di + N], conv_out[..., di + N :]
    xh = xin.reshape(b, H, P)
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (b,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, B.astype(jnp.float32), xh.astype(jnp.float32))
    h = cache["ssm"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), h)
    y = y + p["D"][None, :, None] * xh.astype(y.dtype)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"]), {"conv": new_conv, "ssm": h}
