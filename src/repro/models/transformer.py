"""LM assembly: builds any assigned architecture from its ArchConfig.

A model is a sequence of *blocks*; each block is a scan over ``n`` stacked
layers of one kind:

  dense        pre-norm GQA attention + pre-norm MLP
  moe          pre-norm GQA attention + pre-norm MoE FFN
  mamba        Mamba2 (SSD) block
  rwkv         RWKV6 time-mix + channel-mix
  shared_attn  zamba2-style shared transformer block (params shared across
               occurrences, cache per occurrence)

Block plans express heterogeneous stacks (gemma3 5:1 local:global, zamba2
Mamba-with-shared-attention) while keeping scan-over-layers everywhere, which
bounds HLO size at 512-device dry-runs.

API:
  init_params(cfg, key, dtype)                  -> params
  forward(cfg, params, batch, remat=False)      -> (logits, aux)
  init_cache(cfg, batch, max_len, dtype)        -> cache
  decode_step(cfg, params, cache, batch, pos)   -> (logits, cache)
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..runtime.pspec import constrain
from . import attention as attn
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .layers import embed, init_embedding, init_mlp, mlp, normal, rmsnorm, unembed


@dataclass(frozen=True)
class BlockSpec:
    kind: str  # dense | moe | mamba | rwkv | shared_attn
    n: int  # stacked layers in this block (1 for shared_attn)
    local: bool = False  # windowed attention
    shared_idx: int = -1  # which shared param set (zamba2 alternates 2)


def layer_plan(cfg: ArchConfig) -> list[BlockSpec]:
    L = cfg.num_layers
    if cfg.family == "hybrid":
        plan: list[BlockSpec] = []
        done = 0
        grp = 0
        while done < L:
            n = min(cfg.attn_every, L - done)
            plan.append(BlockSpec("mamba", n))
            done += n
            if done < L or n == cfg.attn_every:
                plan.append(BlockSpec("shared_attn", 1, shared_idx=grp % cfg.n_shared_attn))
                grp += 1
        return plan
    if cfg.family == "ssm":
        return [BlockSpec("rwkv", L)]
    kind = "moe" if cfg.family == "moe" else "dense"
    if cfg.attn == "local_global":
        plan = []
        done = 0
        while done < L:
            n_local = min(cfg.global_every - 1, L - done)
            if n_local:
                plan.append(BlockSpec(kind, n_local, local=True))
                done += n_local
            if done < L:
                plan.append(BlockSpec(kind, 1, local=False))
                done += 1
        return plan
    return [BlockSpec(kind, L, local=(cfg.attn == "swa"))]


# ------------------------------------------------------------------- init --
def _init_layer(key, cfg: ArchConfig, kind: str, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("dense", "shared_attn"):
        return {
            "norm1": jnp.zeros((d,), dtype),
            "attn": attn.init_attn(k1, cfg, dtype),
            "norm2": jnp.zeros((d,), dtype),
            "mlp": init_mlp(k2, d, cfg.d_ff, cfg.mlp, dtype),
        }
    if kind == "moe":
        return {
            "norm1": jnp.zeros((d,), dtype),
            "attn": attn.init_attn(k1, cfg, dtype),
            "norm2": jnp.zeros((d,), dtype),
            "moe": moe_mod.init_moe(k2, cfg, dtype),
        }
    if kind == "mamba":
        return {"norm": jnp.zeros((d,), dtype), "mamba": ssm_mod.init_mamba(k1, cfg, dtype)}
    if kind == "rwkv":
        return {
            "norm1": jnp.zeros((d,), dtype),
            "tm": rwkv_mod.init_rwkv(k1, cfg, dtype),  # includes cm params
            "norm2": jnp.zeros((d,), dtype),
        }
    raise ValueError(kind)


def _stack(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    plan = layer_plan(cfg)
    keys = jax.random.split(key, len(plan) + 4)
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "blocks": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal(keys[1], (cfg.d_model, cfg.vocab_size),
                                   cfg.d_model ** -0.5, dtype)
    if cfg.frontend == "patch_embed":
        params["patch_proj"] = normal(keys[2], (cfg.d_model, cfg.d_model),
                                      cfg.d_model ** -0.5, dtype)
    shared: dict[int, dict] = {}
    for i, blk in enumerate(plan):
        bkey = keys[4 + i]
        if blk.kind == "shared_attn":
            if blk.shared_idx not in shared:
                shared[blk.shared_idx] = _init_layer(bkey, cfg, "shared_attn", dtype)
            params["blocks"].append({})  # params live in params["shared"]
        else:
            layers = [
                _init_layer(k, cfg, blk.kind, dtype)
                for k in jax.random.split(bkey, blk.n)
            ]
            params["blocks"].append(_stack(layers))
    if shared:
        params["shared"] = [shared[i] for i in sorted(shared)]
    return params


# ---------------------------------------------------------------- forward --
def _layer_forward(cfg: ArchConfig, kind: str, local: bool, p: dict, x: jax.Array):
    """One full-sequence layer. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe", "shared_attn"):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        x = x + attn.full_attention(p["attn"], cfg, h, local=local)
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            y, aux = moe_mod.moe_mlp(p["moe"], cfg, h)
        else:
            y = mlp(p["mlp"], h, cfg.mlp, cfg.act)
        x = x + y
    elif kind == "mamba":
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        x = x + ssm_mod.mamba_forward(p["mamba"], cfg, h)
    elif kind == "rwkv":
        b, s, d = x.shape
        zeros_shift = jnp.zeros((b, d), x.dtype)
        H = d // cfg.ssm_head_dim
        state0 = jnp.zeros((b, H, cfg.ssm_head_dim, cfg.ssm_head_dim), jnp.float32)
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, _, _ = rwkv_mod.rwkv_time_mix(p["tm"], cfg, h, zeros_shift, state0)
        x = x + y
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        y, _ = rwkv_mod.rwkv_channel_mix(p["tm"], cfg, h, zeros_shift)
        x = x + y
    else:
        raise ValueError(kind)
    x = constrain(x, "residual")
    return x, aux


def _embed_input(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    if cfg.frontend == "frame_embed":
        return batch["frame_embeds"]
    x = embed(params["embed"], batch["tokens"])
    if (
        cfg.frontend == "patch_embed"
        and "patch_embeds" in batch
        and batch["patch_embeds"].shape[1] <= x.shape[1]  # prefill only
    ):
        pe = jnp.einsum("bpd,de->bpe", batch["patch_embeds"], params["patch_proj"])
        x = jax.lax.dynamic_update_slice(x, pe.astype(x.dtype), (0, 0, 0))
    return constrain(x, "emb")


def forward(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool = False):
    """Full-sequence forward (training teacher-forcing / prefill).

    Returns (logits, aux) — aux carries the MoE load-balancing loss."""
    plan = layer_plan(cfg)
    x = _embed_input(cfg, params, batch)
    aux_total = jnp.zeros((), jnp.float32)

    for blk, bparams in zip(plan, params["blocks"]):
        if blk.kind == "shared_attn":
            p = params["shared"][blk.shared_idx]
            fn = partial(_layer_forward, cfg, "shared_attn", blk.local)
            if remat:
                fn = jax.checkpoint(fn)
            x, aux = fn(p, x)
            aux_total += aux
        else:
            def body(carry, p, _kind=blk.kind, _local=blk.local):
                h, acc = carry
                fn = partial(_layer_forward, cfg, _kind, _local)
                if remat:
                    fn = jax.checkpoint(fn)
                h, aux = fn(p, h)
                return (h, acc + aux), None

            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), bparams)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(
        params["embed"] if cfg.tie_embeddings else params["lm_head"], x,
        tied=cfg.tie_embeddings,
    )
    return logits, {"moe_aux": aux_total}


# ------------------------------------------------------------------ cache --
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> list:
    """Per-block decode caches. Windowed attention blocks get ring buffers
    of ``window`` slots; full attention gets ``max_len``; SSM/RWKV O(1)."""
    caches: list[Any] = []
    for blk in layer_plan(cfg):
        if blk.kind in ("dense", "moe", "shared_attn"):
            length = min(cfg.window, max_len) if blk.local else max_len
            caches.append(attn.init_kv_cache(cfg, blk.n, batch, length, dtype))
        elif blk.kind == "mamba":
            caches.append(ssm_mod.init_mamba_cache(cfg, blk.n, batch, dtype))
        elif blk.kind == "rwkv":
            caches.append(rwkv_mod.init_rwkv_cache(cfg, blk.n, batch, dtype))
    return caches


def _layer_decode(cfg: ArchConfig, kind: str, local: bool, p: dict, x, lcache, pos):
    if kind in ("dense", "moe", "shared_attn"):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, lcache = attn.decode_attention(p["attn"], cfg, h, lcache, pos, local=local)
        x = x + y
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            y, _ = moe_mod.moe_mlp(p["moe"], cfg, h)
        else:
            y = mlp(p["mlp"], h, cfg.mlp, cfg.act)
        x = x + y
    elif kind == "mamba":
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        y, lcache = ssm_mod.mamba_decode_step(p["mamba"], cfg, h, lcache)
        x = x + y
    elif kind == "rwkv":
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, new_tm, new_wkv = rwkv_mod.rwkv_time_mix(
            p["tm"], cfg, h, lcache["shift_tm"], lcache["wkv"]
        )
        x = x + y
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        y, new_cm = rwkv_mod.rwkv_channel_mix(p["tm"], cfg, h, lcache["shift_cm"])
        x = x + y
        lcache = {"shift_tm": new_tm, "shift_cm": new_cm, "wkv": new_wkv}
    return x, lcache


def decode_step(cfg: ArchConfig, params: dict, caches: list, batch: dict, pos):
    """One-token decode. batch: {"tokens": (b,1)} or {"frame_embeds": (b,1,d)}.
    ``pos`` is the current sequence position (scalar int32)."""
    x = _embed_input(cfg, params, batch)
    plan = layer_plan(cfg)
    new_caches: list[Any] = []
    for blk, bparams, cache in zip(plan, params["blocks"], caches):
        if blk.kind == "shared_attn":
            p = params["shared"][blk.shared_idx]
            lcache = jax.tree.map(lambda a: a[0], cache)
            x, lcache = _layer_decode(cfg, "shared_attn", blk.local, p, x, lcache, pos)
            new_caches.append(jax.tree.map(lambda a: a[None], lcache))
        else:
            def body(h, inp, _kind=blk.kind, _local=blk.local):
                p, lcache = inp
                h, lcache = _layer_decode(cfg, _kind, _local, p, h, lcache, pos)
                return h, lcache

            x, cache_out = jax.lax.scan(body, x, (bparams, cache))
            new_caches.append(cache_out)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(
        params["embed"] if cfg.tie_embeddings else params["lm_head"], x,
        tied=cfg.tie_embeddings,
    )
    return logits, new_caches
