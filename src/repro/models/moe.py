"""Mixture-of-Experts FFN: top-k routing with capacity-factor dispatch
(GShard/Switch style einsum dispatch — sharding-friendly under pjit; the
expert dimension shards for expert parallelism, d_ff for tensor parallelism).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..runtime.pspec import constrain
from .layers import act_fn, normal


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": normal(k1, (d, E), s_in, jnp.float32),
        "w_in": normal(k2, (E, d, f), s_in, dtype),
        "w_out": normal(k3, (E, f, d), s_out, dtype),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = normal(k4, (E, d, f), s_in, dtype)
    return p


def _capacity(cfg: ArchConfig, group_len: int) -> int:
    c = int(math.ceil(group_len * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(4, min(group_len, c))


def moe_mlp(p: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y, aux_loss). Tokens are regrouped to bounded-size
    dispatch groups so the one-hot dispatch einsum stays O(group_len^2)."""
    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    tokens = x.reshape(b * s, d)
    n = tokens.shape[0]
    gl = min(cfg.moe_group, n)
    n_groups = max(1, n // gl)
    gl = n // n_groups  # exact division (shapes here are powers of two)
    xt = tokens[: n_groups * gl].reshape(n_groups, gl, d)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gates, normalized over the selected experts
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (g, t, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    C = _capacity(cfg, gl)
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (g,t,K,E)
    flatoh = onehot.reshape(n_groups, gl * K, E)
    pos_in_e = (jnp.cumsum(flatoh, axis=1) - flatoh).reshape(n_groups, gl, K, E)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)  # (g,t,K)
    keep = pos < C
    gate_vals = gate_vals * keep

    # dispatch/combine tensors — kept in the activation dtype: an f32
    # combine promotes the whole capacity-expanded expert path to f32 and
    # doubles the row-parallel all-reduce bytes (measured on grok-1)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32) * keep[..., None]  # (g,t,K,C)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate_vals, onehot, pos_oh).astype(x.dtype)
    dispatch = (combine > 0).astype(x.dtype)
    dispatch = constrain(dispatch, "moe_dispatch")

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xt)  # (g,E,C,d)
    xe = constrain(xe, "moe_expert_in")
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_in"])
    if "w_gate" in p:
        gt = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
        h = act_fn("silu" if cfg.mlp == "swiglu" else cfg.act)(gt) * h
    else:
        h = act_fn(cfg.act)(h)
    h = constrain(h, "moe_hidden")
    # NOTE(perf): constraining ye to d-sharded (hoping for a reduce-scatter
    # lowering of the row-parallel partial sum) was measured 7% WORSE on
    # grok-1 — XLA adds a resharding for the combine einsum instead
    # (EXPERIMENTS.md §Perf, grok iteration 2: refuted).
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"])
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(onehot[:, :, 0, :], axis=1)  # top-1 assignment fraction
    pe = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(me * pe, axis=-1))

    y = y.reshape(n_groups * gl, d)
    if n_groups * gl < n:  # ragged tail (shouldn't occur at our shapes)
        y = jnp.concatenate([y, tokens[n_groups * gl:]], axis=0)
    return y.reshape(b, s, d), aux
