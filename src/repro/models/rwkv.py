"""RWKV-6 "Finch" block [arXiv:2404.05892]: attention-free time-mix with
data-dependent decay (low-rank dynamic lerp + decay LoRA) and squared-ReLU
channel-mix. Sequential recurrence is the reference semantics; the chunked
Pallas kernel (repro.kernels.rwkv6) computes the same recurrence blockwise.

State per layer: token-shift registers (last hidden) for time/channel mix +
the (heads, dk, dv) wkv matrix state -> O(1) decode memory (long_500k runs).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..runtime.pspec import constrain
from .layers import normal

LORA_R = 32  # low-rank dim for the dynamic mix / decay projections


def init_rwkv(key, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 16)
    s = 1.0 / math.sqrt(d)
    return {
        # time-mix
        "mu_x": jnp.full((d,), 0.5, dtype),
        "mu": normal(ks[0], (5, d), 0.1, dtype),  # r,k,v,w,g static mix
        "A_mix": normal(ks[1], (d, 5 * LORA_R), s, dtype),
        "B_mix": normal(ks[2], (5, LORA_R, d), 0.05, dtype),
        "w0": normal(ks[3], (d,), 0.5, jnp.float32),
        "A_w": normal(ks[4], (d, LORA_R), s, dtype),
        "B_w": normal(ks[5], (LORA_R, d), 0.05, dtype),
        "u": normal(ks[6], (d,), 0.5, jnp.float32),  # bonus for current token
        "Wr": normal(ks[7], (d, d), s, dtype),
        "Wk": normal(ks[8], (d, d), s, dtype),
        "Wv": normal(ks[9], (d, d), s, dtype),
        "Wg": normal(ks[10], (d, d), s, dtype),
        "Wo": normal(ks[11], (d, d), s, dtype),
        "ln_x": jnp.ones((d,), jnp.float32),  # per-head group norm scale
        # channel-mix
        "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_mu_k": jnp.full((d,), 0.5, dtype),
        "cm_Wr": normal(ks[12], (d, d), s, dtype),
        "cm_Wk": normal(ks[13], (d, f), s, dtype),
        "cm_Wv": normal(ks[14], (f, d), 1.0 / math.sqrt(f), dtype),
    }


def _ddlerp(p, x, xx):
    """Data-dependent token-shift mixing -> r,k,v,w,g inputs (RWKV6)."""
    dx = xx - x
    xxx = x + dx * p["mu_x"]
    lora = jnp.tanh(jnp.einsum("...d,dr->...r", xxx, p["A_mix"]))
    lora = lora.reshape(*lora.shape[:-1], 5, LORA_R)
    dyn = jnp.einsum("...er,erd->...ed", lora, p["B_mix"])  # (...,5,d)
    mixed = x[..., None, :] + dx[..., None, :] * (p["mu"] + dyn)
    return [mixed[..., i, :] for i in range(5)]


def _decay(p, xw):
    lw = jnp.einsum("...d,dr->...r", xw, p["A_w"])
    w = p["w0"] + jnp.einsum("...r,rd->...d", jnp.tanh(lw), p["B_w"]).astype(jnp.float32)
    return jnp.exp(-jnp.exp(w))  # in (0, 1), data-dependent per channel


def _group_norm(y, scale, H, eps=64e-5):
    """Head-wise normalization of the wkv output."""
    yh = y.reshape(*y.shape[:-1], H, -1).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(y.shape) * scale).astype(y.dtype)


def rwkv_time_mix(p: dict, cfg: ArchConfig, x: jax.Array, shift: jax.Array,
                  state: jax.Array):
    """x: (b,s,d); shift: (b,d) last token of the previous call;
    state: (b,H,P,P). Returns (y, new_shift, new_state)."""
    b, s, d = x.shape
    H = d // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    xx = jnp.concatenate([shift[:, None, :], x[:, :-1, :]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xx)
    r = jnp.einsum("bsd,de->bse", xr, p["Wr"]).reshape(b, s, H, P)
    k = jnp.einsum("bsd,de->bse", xk, p["Wk"]).reshape(b, s, H, P)
    v = jnp.einsum("bsd,de->bse", xv, p["Wv"]).reshape(b, s, H, P)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["Wg"]))
    w = _decay(p, xw).reshape(b, s, H, P)
    u = p["u"].reshape(H, P)

    from ..kernels.rwkv6 import ops as wkv_ops

    r32, k32, v32 = (constrain(a.astype(jnp.float32), "ssm_x") for a in (r, k, v))
    y, new_state = wkv_ops.wkv6(r32, k32, v32, w, u, state)
    y = constrain(y, "ssm_x").reshape(b, s, d)
    y = _group_norm(y, p["ln_x"], H).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y * g, p["Wo"])
    return out, x[:, -1, :], new_state


def rwkv_channel_mix(p: dict, cfg: ArchConfig, x: jax.Array, shift: jax.Array):
    xx = jnp.concatenate([shift[:, None, :], x[:, :-1, :]], axis=1)
    xr = x + (xx - x) * p["cm_mu_r"]
    xk = x + (xx - x) * p["cm_mu_k"]
    kk = jnp.einsum("bsd,df->bsf", xk, p["cm_Wk"])
    kk = jnp.square(jax.nn.relu(kk))
    kk = constrain(kk, "ffn_hidden")
    vv = jnp.einsum("bsf,fd->bsd", kk, p["cm_Wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_Wr"]))
    return rr * vv, x[:, -1, :]


def init_rwkv_cache(cfg: ArchConfig, n_layers: int, batch: int, dtype) -> dict:
    d = cfg.d_model
    H, P = d // cfg.ssm_head_dim, cfg.ssm_head_dim
    return {
        "shift_tm": jnp.zeros((n_layers, batch, d), dtype),
        "shift_cm": jnp.zeros((n_layers, batch, d), dtype),
        "wkv": jnp.zeros((n_layers, batch, H, P, P), jnp.float32),
    }
