"""Minimal discrete-event simulation kernel (simpy-like, dependency-free).

Used by the cycle-approximate multi-PU simulator (``repro.core.simulator``) to
model ICU instruction streams, ISU token routing and buffer handshakes.

Processes are Python generators that ``yield`` effect objects:

  Delay(dt)          -- advance this process by ``dt`` time units
  WaitCond(key)      -- block until ``Kernel.notify(key)`` fires AND the
                        registered predicate (optional) evaluates true
  Acquire(sem)       -- P() on a counting semaphore
  Release(sem)       -- V() on a counting semaphore (non-blocking)

Time is float (we use cycles of ``sys_clk``). Deterministic: ties broken by
(priority, sequence number).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Generator, NamedTuple, Optional


class Effect:
    pass


@dataclass
class Delay(Effect):
    dt: float


@dataclass
class WaitCond(Effect):
    """Block until ``notify(key)`` is called and ``pred()`` is true.

    The predicate is re-checked on every notify; it must be side-effect free.
    If ``pred()`` is already true at yield time the process continues
    immediately (same timestamp). ``desc`` is an optional human-readable
    description of what is being awaited (surfaced by
    :class:`DeadlockError`)."""

    key: Any
    pred: Optional[Callable[[], bool]] = None
    desc: Optional[str] = None


@dataclass
class Acquire(Effect):
    sem: "Semaphore"
    n: int = 1


@dataclass
class Release(Effect):
    sem: "Semaphore"
    n: int = 1


class BlockedProc(NamedTuple):
    """One process stuck in the event loop: its name, a description of the
    effect it awaits, the simulated cycle at which it parked, and the label
    of the deployment member that owns it ("" for unowned processes).

    Unpacks as the historical ``(name, desc)`` pair plus the two new
    fields, so ``for name, desc, *_ in blocked`` keeps working."""

    name: str
    desc: str
    cycle: float
    member: str


class DeadlockError(RuntimeError):
    """Raised when the event loop exceeds ``max_events``: a deadlock or
    livelock. ``blocked`` lists a :class:`BlockedProc` for every process
    still pending — for an ICU decoder blocked in a WAIT_* the description
    names the instruction and its ``(pid, bid)`` channel, ``cycle`` the
    simulated time it parked, and ``member`` the owning pipeline member."""

    def __init__(self, message: str, blocked: list[BlockedProc]) -> None:
        super().__init__(message)
        self.blocked = blocked


class Semaphore:
    """Counting semaphore with FIFO wakeup."""

    def __init__(self, kernel: "Kernel", value: int, name: str = "") -> None:
        self.kernel = kernel
        self.value = value
        self.name = name
        self.waiters: list["_Proc"] = []

    def try_acquire(self, n: int) -> bool:
        if self.value >= n:
            self.value -= n
            return True
        return False

    def release(self, n: int = 1) -> None:
        self.value += n
        # Wake all waiters; they re-attempt acquisition in FIFO order.
        waiters, self.waiters = self.waiters, []
        for proc in waiters:
            self.kernel._schedule(self.kernel.now, proc)


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    proc: "_Proc" = field(compare=False)


class _Proc:
    __slots__ = ("gen", "name", "pending", "done", "result", "member",
                 "daemon", "blocked_since")

    def __init__(self, gen: Generator, name: str, member: str = "",
                 daemon: bool = False) -> None:
        self.gen = gen
        self.name = name
        self.pending: Optional[Effect] = None  # effect we are blocked on
        self.done = False
        self.result = None
        self.member = member  # owning deployment member label ("" = unowned)
        # Daemon processes (watchdog monitors, injected fault generators)
        # never count as pending work: the loop stops when only daemon
        # events remain and no non-daemon process is parked, and they are
        # excluded from deadlock reporting.
        self.daemon = daemon
        self.blocked_since: Optional[float] = None  # cycle we parked at

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Proc {self.name} done={self.done}>"


class Kernel:
    """Discrete event loop."""

    def __init__(self) -> None:
        self.now: float = 0.0
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self._cond_waiters: dict[Any, list[_Proc]] = {}
        self._procs: list[_Proc] = []
        self._nondaemon_events = 0  # scheduled events of non-daemon procs
        self._halted = False
        self.trace: list[tuple[float, str, Any]] = []
        self.trace_enabled = False

    # -- public API ---------------------------------------------------------
    def semaphore(self, value: int, name: str = "") -> Semaphore:
        return Semaphore(self, value, name)

    def spawn(self, gen: Generator, name: str = "proc", *, member: str = "",
              daemon: bool = False) -> _Proc:
        proc = _Proc(gen, name, member=member, daemon=daemon)
        self._procs.append(proc)
        self._schedule(self.now, proc)
        return proc

    def halt(self) -> None:
        """Stop the event loop after the current step (a watchdog that has
        diagnosed a fault calls this instead of letting the simulation spin
        until ``max_events``)."""
        self._halted = True

    def notify(self, key: Any) -> None:
        """Wake processes blocked on WaitCond(key)."""
        waiters = self._cond_waiters.pop(key, None)
        if waiters:
            for proc in waiters:
                self._schedule(self.now, proc)

    def log(self, who: str, what: Any) -> None:
        if self.trace_enabled:
            self.trace.append((self.now, who, what))

    def run(self, until: float = float("inf"), max_events: int = 50_000_000) -> float:
        events = 0
        self._halted = False
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.time > until:
                heapq.heappush(self._heap, ev)
                break
            if ev.proc.daemon and self._nondaemon_events == 0 and not any(
                    not p.done and not p.daemon for p in self._procs):
                # Only daemon events remain and every non-daemon process has
                # finished: the simulation is complete, don't let a periodic
                # monitor keep the clock running forever.
                heapq.heappush(self._heap, ev)
                break
            if not ev.proc.daemon:
                self._nondaemon_events -= 1
            events += 1
            if events > max_events:
                blocked = self.blocked_procs()
                detail = "; ".join(f"{b.name}: {b.desc}" for b in blocked)
                raise DeadlockError(
                    f"simulation exceeded max_events={max_events} "
                    f"(deadlock/livelock?). {len(blocked)} blocked process(es)"
                    + (f": {detail}" if detail else ""),
                    blocked,
                )
            self.now = ev.time
            self._step(ev.proc)
            if self._halted:
                break
        return self.now

    def deadlocked(self) -> list[_Proc]:
        """Non-daemon processes still blocked after run() drained the heap."""
        return [p for p in self._procs if not p.done and not p.daemon]

    def blocked_procs(self) -> list[BlockedProc]:
        """A :class:`BlockedProc` for every non-done, non-daemon process,
        using the pending effect's own description where available."""
        out: list[BlockedProc] = []
        for p in self._procs:
            if p.done or p.daemon:
                continue
            eff = p.pending
            if isinstance(eff, WaitCond):
                desc = eff.desc or f"WaitCond({eff.key!r})"
            elif isinstance(eff, Acquire):
                desc = f"Acquire({eff.sem.name or 'semaphore'})"
            else:
                desc = "runnable (livelock suspect)"
            cycle = p.blocked_since if p.blocked_since is not None else self.now
            out.append(BlockedProc(p.name, desc, cycle, p.member))
        return out

    # -- internals ----------------------------------------------------------
    def _schedule(self, time: float, proc: _Proc) -> None:
        if not proc.daemon:
            self._nondaemon_events += 1
        heapq.heappush(self._heap, _Event(time, next(self._seq), proc))

    def _step(self, proc: _Proc) -> None:
        if proc.done:
            return
        # If blocked on a condition/semaphore, re-check before resuming.
        eff = proc.pending
        if isinstance(eff, WaitCond):
            if eff.pred is not None and not eff.pred():
                self._cond_waiters.setdefault(eff.key, []).append(proc)
                return
        elif isinstance(eff, Acquire):
            if not eff.sem.try_acquire(eff.n):
                eff.sem.waiters.append(proc)
                return
        proc.pending = None
        proc.blocked_since = None
        try:
            nxt = proc.gen.send(None)
        except StopIteration as stop:
            proc.done = True
            proc.result = stop.value
            return
        self._dispatch(proc, nxt)

    def _dispatch(self, proc: _Proc, eff: Effect) -> None:
        if isinstance(eff, Delay):
            self._schedule(self.now + eff.dt, proc)
        elif isinstance(eff, WaitCond):
            if eff.pred is None or not eff.pred():
                proc.pending = eff
                proc.blocked_since = self.now
                if eff.pred is not None and eff.pred():
                    # racy predicate became true: run now
                    self._schedule(self.now, proc)
                else:
                    self._cond_waiters.setdefault(eff.key, []).append(proc)
            else:
                self._schedule(self.now, proc)
        elif isinstance(eff, Acquire):
            if eff.sem.try_acquire(eff.n):
                self._schedule(self.now, proc)
            else:
                proc.pending = eff
                proc.blocked_since = self.now
                eff.sem.waiters.append(proc)
        elif isinstance(eff, Release):
            eff.sem.release(eff.n)
            self._schedule(self.now, proc)
        else:
            raise TypeError(f"unknown effect {eff!r}")
