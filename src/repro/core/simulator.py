"""Cycle-approximate multi-PU system simulator.

Wires together: PU specs (timing), ICUs (instruction decoding + LUTRAM
coordination state), the ISU token network (deterministic latencies), and the
shared HBM channels. Executes the instruction programs produced by the
compilation framework and reports throughput / latency / efficiency — this is
the executable model behind the paper's Figs. 3, 6 and Table III.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .events import Kernel, Semaphore
from .icu import ICU, GroupStats
from .isa import Group
from .isu import ISUNetwork
from .program import PUProgram
from .pu import N_HBM_CHANNELS, PUSpec, SYS_CLK_HZ, make_u50_system, system_peak_tops


@dataclass
class SimResult:
    sys_clk_hz: float
    end_cycles: float
    rounds: int
    pu_stats: dict[int, dict[Group, GroupStats]]
    tokens_sent: int
    deadlocked: bool
    # round r latency: first-PU LD round start -> last-PU ST round end
    round_latencies_cycles: list[float] = field(default_factory=list)
    round_end_cycles: list[float] = field(default_factory=list)

    # -- derived metrics -----------------------------------------------------
    @property
    def end_seconds(self) -> float:
        return self.end_cycles / self.sys_clk_hz

    def throughput_fps(self, warmup: int = 1) -> float:
        """Steady-state rounds/s measured after ``warmup`` rounds."""
        ends = self.round_end_cycles
        if len(ends) <= warmup:
            if not ends:
                return 0.0
            return self.rounds / self.end_seconds
        n = len(ends) - warmup
        dt = (ends[-1] - ends[warmup - 1]) / self.sys_clk_hz if warmup > 0 else ends[-1] / self.sys_clk_hz
        return n / dt if dt > 0 else 0.0

    def latency_seconds(self, skip_warmup: int = 1) -> float:
        lats = self.round_latencies_cycles[skip_warmup:] or self.round_latencies_cycles
        if not lats:
            return 0.0
        return (sum(lats) / len(lats)) / self.sys_clk_hz

    def busy_fraction(self, pid: int) -> float:
        cp = self.pu_stats[pid][Group.CP]
        return cp.busy / self.end_cycles if self.end_cycles else 0.0


class MultiPUSimulator:
    """Discrete-event execution of PUPrograms on the heterogeneous system."""

    def __init__(self, pus: Optional[list[PUSpec]] = None, trace: bool = False) -> None:
        self.pus = pus if pus is not None else make_u50_system()
        self.kernel = Kernel()
        self.kernel.trace_enabled = trace
        self.isu = ISUNetwork(self.kernel, self.pus)
        self.hbm_channels: dict[int, Semaphore] = {
            c: self.kernel.semaphore(1, f"hbm{c}") for c in range(N_HBM_CHANNELS)
        }
        self.icus: dict[int, ICU] = {
            p.pid: ICU(self.kernel, p, self.isu, self.hbm_channels) for p in self.pus
        }
        self.isu.deliver = lambda dst, tok: self.icus[dst].deliver(tok)

    @property
    def peak_tops(self) -> float:
        return system_peak_tops(self.pus)

    def run(
        self,
        programs: list[PUProgram],
        *,
        until_cycles: float = float("inf"),
        first_pid: Optional[int] = None,
        last_pid: Optional[int] = None,
    ) -> SimResult:
        """Load + start all programs, run to completion (or ``until_cycles``).

        ``first_pid``/``last_pid`` identify the pipeline entry/exit PUs for
        latency accounting (default: first/last program in the list)."""
        if not programs:
            raise ValueError("no programs")
        for prog in programs:
            self.icus[prog.pid].start(prog)
        end = self.kernel.run(until=until_cycles)

        first = first_pid if first_pid is not None else programs[0].pid
        last = last_pid if last_pid is not None else programs[-1].pid
        stats = {p.pid: self.icus[p.pid].stats for p in self.pus}

        ld_starts = stats[first][Group.LD].round_start_times
        st_ends = stats[last][Group.ST].round_end_times
        nrounds = min(len(ld_starts), len(st_ends))
        latencies = [st_ends[r] - ld_starts[r] for r in range(nrounds)]

        # Deadlock: processes still pending but no events left before horizon.
        dead = bool(self.kernel.deadlocked()) and end < until_cycles

        return SimResult(
            sys_clk_hz=self.pus[0].sys_clk_hz if self.pus else SYS_CLK_HZ,
            end_cycles=end,
            rounds=len(st_ends),
            pu_stats=stats,
            tokens_sent=self.isu.tokens_sent,
            deadlocked=dead,
            round_latencies_cycles=latencies,
            round_end_cycles=list(st_ends),
        )


def simulate(programs: list[PUProgram], pus: Optional[list[PUSpec]] = None,
             **kw) -> SimResult:
    return MultiPUSimulator(pus).run(programs, **kw)
