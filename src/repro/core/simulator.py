"""Cycle-approximate multi-PU system simulator.

Wires together: PU specs (timing), ICUs (instruction decoding + LUTRAM
coordination state), the ISU token network (deterministic latencies), and the
shared HBM channels. Executes the instruction programs produced by the
compilation framework and reports throughput / latency / efficiency — this is
the executable model behind the paper's Figs. 3, 6 and Table III.

Deployments may comprise several concurrent member pipelines on disjoint PU
subsets (batch-level / hybrid parallelism, Sec. V-A). ``run`` therefore takes
a list of :class:`PipelineMember` descriptors and the :class:`SimResult`
carries per-member round accounting plus system aggregates; the single
``first_pid``/``last_pid`` form remains as the one-member special case.
Members carry the label of the workload (model) they run, so mixed-model
(multi-tenant) runs stay attributable — ``SimResult.fps_by_workload`` splits
the aggregate rate per tenant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .events import Kernel, Semaphore
from .icu import ICU, GroupStats
from .isa import Group
from .isu import ISUNetwork
from .program import PUProgram
from .pu import N_HBM_CHANNELS, PUSpec, SYS_CLK_HZ, make_u50_system, system_peak_tops


@dataclass(frozen=True)
class PipelineMember:
    """Entry/exit PUs of one member pipeline, for latency accounting.

    ``workload`` names the model this member runs (empty for legacy
    single-model deployments) so per-member results of a mixed-model run
    remain attributable to their tenant. ``slots`` names the decode
    sessions packed into this member (empty for unpacked members): one
    program round then advances *every* packed session by one token, so
    round accounting scales to token accounting by the slot count.
    ``pids`` lists every PU the member occupies (not just entry/exit, which
    need not bracket the set under kind-interleaved stage orders) — fault
    diagnostics attribute a stuck PU to its owning member through it; empty
    means unknown (legacy callers), which only degrades attribution."""

    first_pid: int
    last_pid: int
    label: str = ""
    workload: str = ""
    slots: tuple[str, ...] = ()
    pids: tuple[int, ...] = ()


def _steady_fps(round_ends: list[float], warmup: int, sys_clk_hz: float,
                fallback_rounds: int, end_cycles: float) -> float:
    """Steady-state rounds/s measured after ``warmup`` rounds."""
    if len(round_ends) <= warmup:
        if not round_ends:
            return 0.0
        if not end_cycles:
            # Rounds completed but no run-end timestamp was recorded:
            # estimate from the rounds themselves instead of reporting 0.
            if not round_ends[-1]:
                return 0.0
            return len(round_ends) / (round_ends[-1] / sys_clk_hz)
        return fallback_rounds / (end_cycles / sys_clk_hz)
    n = len(round_ends) - warmup
    if warmup > 0:
        dt = (round_ends[-1] - round_ends[warmup - 1]) / sys_clk_hz
    else:
        dt = round_ends[-1] / sys_clk_hz
    return n / dt if dt > 0 else 0.0


def _mean_latency(latencies: list[float], skip_warmup: int, sys_clk_hz: float) -> float:
    lats = latencies[skip_warmup:] or latencies
    if not lats:
        return 0.0
    return (sum(lats) / len(lats)) / sys_clk_hz


@dataclass
class MemberSimResult:
    """Round accounting of one member pipeline of a deployment."""

    member: PipelineMember
    sys_clk_hz: float
    end_cycles: float
    rounds: int
    # round r latency: first-PU LD round start -> last-PU ST round end
    round_latencies_cycles: list[float] = field(default_factory=list)
    round_end_cycles: list[float] = field(default_factory=list)

    @property
    def label(self) -> str:
        return self.member.label

    @property
    def workload(self) -> str:
        """Label of the workload (model) this member ran."""
        return self.member.workload

    def throughput_fps(self, warmup: int = 1) -> float:
        return _steady_fps(self.round_end_cycles, warmup, self.sys_clk_hz,
                           self.rounds, self.end_cycles)

    def latency_seconds(self, skip_warmup: int = 1) -> float:
        return _mean_latency(self.round_latencies_cycles, skip_warmup, self.sys_clk_hz)

    # -- slot-level accounting (packed decode members) -----------------------
    @property
    def n_slots(self) -> int:
        """Decode sessions packed into this member (1 when unpacked)."""
        return max(1, len(self.member.slots))

    @property
    def tokens(self) -> int:
        """Tokens produced: every round advances each packed slot by one."""
        return self.rounds * self.n_slots

    def token_rate(self, warmup: int = 1) -> float:
        """Steady-state tokens/s: the member round rate times the number of
        packed sessions (equals ``throughput_fps`` for unpacked members)."""
        return self.throughput_fps(warmup) * self.n_slots

    def slot_tokens(self) -> dict[str, int]:
        """Per-session token counts keyed by slot name."""
        return {slot: self.rounds for slot in self.member.slots}


@dataclass
class SimResult:
    sys_clk_hz: float
    end_cycles: float
    rounds: int
    pu_stats: dict[int, dict[Group, GroupStats]]
    tokens_sent: int
    deadlocked: bool
    # Merged over members (identical to the member's own lists when there is
    # only one member pipeline, which keeps the historical single-pipeline
    # semantics of these fields).
    round_latencies_cycles: list[float] = field(default_factory=list)
    round_end_cycles: list[float] = field(default_factory=list)
    members: list[MemberSimResult] = field(default_factory=list)
    # Watchdog detections (repro.faults.FaultReport); a faulted run is not
    # "deadlocked" — the fault IS the diagnosis, and the run was halted by
    # detection rather than by draining the heap.
    faults: list = field(default_factory=list)
    # BlockedProc entries captured when the run deadlocked or faulted.
    blocked: list = field(default_factory=list)

    @property
    def faulted(self) -> bool:
        return bool(self.faults)

    # -- derived metrics -----------------------------------------------------
    @property
    def end_seconds(self) -> float:
        return self.end_cycles / self.sys_clk_hz

    def throughput_fps(self, warmup: int = 1) -> float:
        """Steady-state rounds/s measured after ``warmup`` rounds (over the
        merged round-completion stream of all member pipelines)."""
        return _steady_fps(self.round_end_cycles, warmup, self.sys_clk_hz,
                           self.rounds, self.end_cycles)

    def aggregate_fps(self, warmup: int = 1) -> float:
        """System throughput: the sum of the members' steady-state rates —
        the multi-batch metric of Fig. 6(b) / Table III."""
        if not self.members:
            return self.throughput_fps(warmup)
        return sum(m.throughput_fps(warmup) for m in self.members)

    def fps_by_workload(self, warmup: int = 1) -> dict[str, float]:
        """Aggregate throughput split per workload label — the per-tenant
        rates of a mixed-model (multi-tenant) deployment. Members without a
        workload label fall under ``""``."""
        out: dict[str, float] = {}
        for m in self.members:
            out[m.workload] = out.get(m.workload, 0.0) + m.throughput_fps(warmup)
        if not out:
            out[""] = self.throughput_fps(warmup)
        return out

    def aggregate_token_rate(self, warmup: int = 1) -> float:
        """System tokens/s: member round rates scaled by packed slot counts
        (equals ``aggregate_fps`` when nothing is slot-packed)."""
        if not self.members:
            return self.throughput_fps(warmup)
        return sum(m.token_rate(warmup) for m in self.members)

    def tokens_by_workload(self) -> dict[str, int]:
        """Token counts split per workload label (slot-aware rounds)."""
        out: dict[str, int] = {}
        for m in self.members:
            out[m.workload] = out.get(m.workload, 0) + m.tokens
        return out

    def latency_seconds(self, skip_warmup: int = 1) -> float:
        return _mean_latency(self.round_latencies_cycles, skip_warmup, self.sys_clk_hz)

    def member_latency_seconds(self, skip_warmup: int = 1) -> float:
        """System latency: the slowest member pipeline (paper Sec. V-A)."""
        if not self.members:
            return self.latency_seconds(skip_warmup)
        return max(m.latency_seconds(skip_warmup) for m in self.members)

    def busy_fraction(self, pid: int) -> float:
        cp = self.pu_stats[pid][Group.CP]
        return cp.busy / self.end_cycles if self.end_cycles else 0.0


class MultiPUSimulator:
    """Discrete-event execution of PUPrograms on the heterogeneous system."""

    def __init__(self, pus: Optional[list[PUSpec]] = None, trace: bool = False) -> None:
        self.pus = pus if pus is not None else make_u50_system()
        self._trace = trace
        self.fault_schedule = None  # repro.faults.FaultSchedule, or None
        self.injector = None        # per-run FaultInjector when armed
        self.reset()

    def reset(self) -> None:
        """Fresh kernel/ICU/ISU/HBM state on the *same fixed hardware*.

        This is the simulator analogue of the paper's headline feature: the
        PU array (the FPGA bitstream) never changes; switching deployment
        strategies only swaps the instruction programs loaded next.

        All injected-fault state (hang gates, fabric hooks, stall
        processes) lives on the per-run objects rebuilt here, so reset
        always starts clean; an attached fault *schedule* is re-armed onto
        the fresh state (the schedule models broken hardware, which does
        not heal on a program swap) until :meth:`clear_faults`."""
        self.kernel = Kernel()
        self.kernel.trace_enabled = self._trace
        self.isu = ISUNetwork(self.kernel, self.pus)
        self.hbm_channels: dict[int, Semaphore] = {
            c: self.kernel.semaphore(1, f"hbm{c}") for c in range(N_HBM_CHANNELS)
        }
        self.icus: dict[int, ICU] = {
            p.pid: ICU(self.kernel, p, self.isu, self.hbm_channels) for p in self.pus
        }
        self.isu.deliver = lambda dst, tok: self.icus[dst].deliver(tok)
        self._arm()

    # -- fault injection (repro.faults) -------------------------------------
    def inject(self, schedule) -> None:
        """Attach a :class:`repro.faults.FaultSchedule`; it arms onto fresh
        run state now and re-arms on every reset until cleared."""
        self.fault_schedule = schedule
        self.reset()

    def clear_faults(self) -> None:
        """Detach the fault schedule and rebuild clean run state."""
        self.fault_schedule = None
        self.reset()

    def _arm(self) -> None:
        if self.fault_schedule:
            from ..faults.inject import FaultInjector

            self.injector = FaultInjector(self, self.fault_schedule)
            self.injector.install()
        else:
            self.injector = None

    @property
    def peak_tops(self) -> float:
        return system_peak_tops(self.pus)

    def run(
        self,
        programs: list[PUProgram],
        *,
        until_cycles: float = float("inf"),
        first_pid: Optional[int] = None,
        last_pid: Optional[int] = None,
        members: Optional[list[PipelineMember]] = None,
        watchdog=None,
    ) -> SimResult:
        """Load + start all programs, run to completion (or ``until_cycles``).

        ``members`` lists the entry/exit PUs of each concurrent member
        pipeline for latency accounting. Without it, the programs form one
        pipeline whose entry/exit default to ``first_pid``/``last_pid`` (or
        the first/last program in the list).

        ``watchdog`` (a :class:`repro.faults.Watchdog`) spawns the fault
        monitor: silent hangs halt the run and come back as structured
        ``SimResult.faults`` instead of an unbounded simulation."""
        if not programs:
            raise ValueError("no programs")
        if members is not None and (first_pid is not None or last_pid is not None):
            raise ValueError("pass either members or first_pid/last_pid, not both")
        if members is None:
            first = first_pid if first_pid is not None else programs[0].pid
            last = last_pid if last_pid is not None else programs[-1].pid
            members = [PipelineMember(first_pid=first, last_pid=last,
                                      pids=tuple(p.pid for p in programs))]
        # pid -> owning member label, threaded onto every spawned process so
        # deadlock/fault diagnostics stay attributable to their tenant.
        label_of: dict[int, str] = {}
        for m in members:
            for pid in m.pids:
                label_of[pid] = m.workload or m.label
        for prog in programs:
            self.icus[prog.pid].start(prog, member=label_of.get(prog.pid, ""))
        faults: list = []
        if watchdog is not None:
            from ..faults.watchdog import spawn_monitor

            spawn_monitor(self, watchdog, members, faults)
        end = self.kernel.run(until=until_cycles)

        stats = {p.pid: self.icus[p.pid].stats for p in self.pus}
        clk = self.pus[0].sys_clk_hz if self.pus else SYS_CLK_HZ

        member_results: list[MemberSimResult] = []
        for m in members:
            ld_starts = stats[m.first_pid][Group.LD].round_start_times
            st_ends = stats[m.last_pid][Group.ST].round_end_times
            nrounds = min(len(ld_starts), len(st_ends))
            latencies = [st_ends[r] - ld_starts[r] for r in range(nrounds)]
            member_results.append(
                MemberSimResult(
                    member=m,
                    sys_clk_hz=clk,
                    end_cycles=end,
                    rounds=len(st_ends),
                    round_latencies_cycles=latencies,
                    round_end_cycles=list(st_ends),
                )
            )

        # System-level view: the merged round-completion stream, with each
        # round's latency carried along so warmup skipping stays aligned.
        tagged: list[tuple[float, Optional[float]]] = []
        for mr in member_results:
            lats = mr.round_latencies_cycles
            for r, end_c in enumerate(mr.round_end_cycles):
                tagged.append((end_c, lats[r] if r < len(lats) else None))
        tagged.sort(key=lambda t: t[0])
        merged_ends = [t[0] for t in tagged]
        merged_lats = [t[1] for t in tagged if t[1] is not None]

        # Deadlock: processes still pending but no events left before horizon.
        # A watchdog-detected fault is its own diagnosis, not a deadlock.
        dead = (bool(self.kernel.deadlocked()) and end < until_cycles
                and not faults)

        return SimResult(
            sys_clk_hz=clk,
            end_cycles=end,
            rounds=len(merged_ends),
            pu_stats=stats,
            tokens_sent=self.isu.tokens_sent,
            deadlocked=dead,
            round_latencies_cycles=merged_lats,
            round_end_cycles=merged_ends,
            members=member_results,
            faults=faults,
            blocked=(self.kernel.blocked_procs() if (dead or faults) else []),
        )


def simulate(programs: list[PUProgram], pus: Optional[list[PUSpec]] = None,
             **kw) -> SimResult:
    return MultiPUSimulator(pus).run(programs, **kw)
