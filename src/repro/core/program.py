"""Instruction programs: per-ICU-group BRAM images + round semantics.

A :class:`Program` is the content of one ICU group's dual-port BRAM. A
*program round* iterates instructions sequentially until an instruction with
PRG_END set, then the ``ProgCtrl`` (which must be that terminal instruction in
our assembler convention, matching PRG_PRM placement in Table I(c)) decides:
jump to ICU_BA for the next round, or halt after NR rounds.

Programs are runtime-mutable: dynamic instructions (AddrCyc, Sync, DataMove
CUR_BA) write their state back into the BRAM, exactly as in the hardware.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Iterator

from .isa import (
    AddrCyc,
    AddrLen,
    Config,
    DataMove,
    Group,
    Instruction,
    ProgCtrl,
    validate_group,
)


@dataclass
class Program:
    group: Group
    instructions: list[Instruction] = field(default_factory=list)
    name: str = ""

    def __post_init__(self) -> None:
        for inst in self.instructions:
            validate_group(inst, self.group)

    # -- assembly -----------------------------------------------------------
    @classmethod
    def assemble(cls, group: Group, body: list[Instruction], *, rounds: int = 1,
                 loop_ba: int = 0, name: str = "") -> "Program":
        """Append the terminal ProgCtrl (PRG_END) controlling round looping.

        ``loop_ba`` is the instruction address execution jumps to at the end
        of each round — a nonzero value skips a one-shot prologue (e.g. the
        ACK-bypass pre-authorization of Fig. 3)."""
        insts = list(body) + [ProgCtrl(nr=rounds, icu_ba=loop_ba, prg_end=True)]
        return cls(group, insts, name=name)

    def encode(self) -> list[int]:
        return [i.encode() for i in self.instructions]

    @classmethod
    def decode(cls, group: Group, words: list[int], name: str = "") -> "Program":
        return cls(group, [Instruction.decode(w) for w in words], name=name)

    def clone(self) -> "Program":
        """Fresh runtime image (dynamic state will be mutated in place)."""
        return Program(self.group, copy.deepcopy(self.instructions), self.name)

    @property
    def progctrl(self) -> ProgCtrl:
        for inst in self.instructions:
            if isinstance(inst, ProgCtrl):
                return inst
        raise ValueError(f"program {self.name!r} has no ProgCtrl")

    def validate(self) -> None:
        if not self.instructions:
            raise ValueError("empty program")
        if not self.instructions[-1].prg_end:
            raise ValueError("last instruction must set PRG_END")
        pc = self.progctrl
        if not (0 <= pc.icu_ba < len(self.instructions)):
            raise ValueError("ICU_BA out of range")
        # Config instructions must precede a DataMove (mandatory sequence ->).
        for idx, inst in enumerate(self.instructions):
            if isinstance(inst, Config):
                nxt = self.instructions[idx + 1] if idx + 1 < len(self.instructions) else None
                if not isinstance(nxt, DataMove):
                    raise ValueError(f"Config at {idx} lacks successor DataMove")
            if isinstance(inst, (AddrCyc, AddrLen)):
                prev = self.instructions[idx - 1] if idx > 0 else None
                if not isinstance(prev, DataMove):
                    raise ValueError(
                        f"{type(inst).__name__} at {idx} lacks predecessor DataMove"
                    )

    def __len__(self) -> int:
        return len(self.instructions)

    def __iter__(self) -> Iterator[Instruction]:
        return iter(self.instructions)

    def disassemble(self) -> str:
        lines = [f"; {self.group.value} program {self.name!r}"]
        for i, inst in enumerate(self.instructions):
            end = " [PRG_END]" if inst.prg_end else ""
            lines.append(f"{i:4d}: {inst!r}{end}")
        return "\n".join(lines)


@dataclass
class PUProgram:
    """The full instruction image of one PU: LD + CP + ST programs."""

    pid: int
    ld: Program
    cp: Program
    st: Program
    label: str = ""

    def clone(self) -> "PUProgram":
        return PUProgram(self.pid, self.ld.clone(), self.cp.clone(), self.st.clone(), self.label)

    def validate(self) -> None:
        for prog in (self.ld, self.cp, self.st):
            prog.validate()

    def encode(self) -> dict[str, list[int]]:
        return {"LD": self.ld.encode(), "CP": self.cp.encode(), "ST": self.st.encode()}

    def total_instructions(self) -> int:
        return len(self.ld) + len(self.cp) + len(self.st)
