"""Custom ISA for instruction-based multi-PU coordination (paper Table I).

Six instruction types organized into three ICU groups (Load, Compute, Store):

  ProgCtrl  PRG_PRM        -- program loop control; NR rounds, ICU_BA jump base
  Config    *_PRM          -- stride / IM2COL / URAM addressing parameters
  DataMove  *_ADM          -- AXI DataMover transfers; CUR_BA latched for a
                              successor AddrCyc
  AddrCyc   CYCLE_ADDR     -- cyclic addressing (BA, AOFFS, NC, IC) with
                              write-back to the *predecessor* DataMove CUR_BA
            CYCLE_LEN      -- the length-advance mode of the AddrCyc family
                              (:class:`AddrLen`): per-round LEN counter over a
                              cyclic append-only region (K/V caches of
                              autoregressive decode), written back to the
                              predecessor DataMove LEN
  Sync      SEND/WAIT_REQ/ACK -- peer-to-peer REQ/ACK coordination (BID,
                              DST/SRC_PID, BASE_BID, NC, IC) with BID cycling
  Compute   GEMM           -- systolic-array + vector ops (ReLU, scales,
                              residual add enable, rounds)

All instructions are 64-bit; every encoding carries OPCD (6b) and PRG_END (1b).
``ProgCtrl``, ``Config`` and ``Compute`` are *static*; ``DataMove`` (its
CUR_BA), ``AddrCyc`` and ``Sync`` are *dynamic* — their state is written back
into the ICU BRAM by the decoder (Table I(b) algorithms, implemented in
:meth:`AddrCyc.step` / :meth:`Sync.step`).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import ClassVar


class Group(enum.Enum):
    LD = "LD"
    CP = "CP"
    ST = "ST"


class Opcode(enum.IntEnum):
    # ProgCtrl
    PRG_PRM = 0x01
    # Config
    IM2COL_PRM = 0x04
    STRIDE_PRM = 0x05
    URAM_PRM = 0x06
    RES_ADD_STRIDE_PRM = 0x07
    # DataMove
    LINEAR_ADM = 0x10
    IM2COL_ADM = 0x11
    STRIDE_ADM = 0x12
    WEIGHTS_ADM = 0x13
    RES_ADD_ADM = 0x14
    RES_ADD_STRIDE_ADM = 0x15
    # AddrCyc family (address cycling + the length-advance mode)
    CYCLE_ADDR = 0x20
    CYCLE_LEN = 0x21
    # Sync
    SEND_REQ = 0x28
    SEND_ACK = 0x29
    WAIT_REQ = 0x2A
    WAIT_ACK = 0x2B
    # Compute
    GEMM = 0x30


# Which opcodes are legal in which ICU group (paper Table I(c)).
GROUP_OPCODES: dict[Group, frozenset[Opcode]] = {
    Group.LD: frozenset(
        {
            Opcode.LINEAR_ADM,
            Opcode.IM2COL_PRM,
            Opcode.IM2COL_ADM,
            Opcode.STRIDE_PRM,
            Opcode.STRIDE_ADM,
            Opcode.SEND_ACK,
            Opcode.WAIT_REQ,
            Opcode.CYCLE_ADDR,
            Opcode.CYCLE_LEN,
            Opcode.PRG_PRM,
        }
    ),
    Group.CP: frozenset(
        {
            Opcode.URAM_PRM,
            Opcode.WEIGHTS_ADM,
            Opcode.RES_ADD_STRIDE_PRM,
            Opcode.RES_ADD_STRIDE_ADM,
            Opcode.RES_ADD_ADM,
            Opcode.CYCLE_ADDR,
            Opcode.CYCLE_LEN,
            Opcode.GEMM,
            Opcode.PRG_PRM,
        }
    ),
    Group.ST: frozenset(
        {
            Opcode.LINEAR_ADM,
            Opcode.STRIDE_PRM,
            Opcode.STRIDE_ADM,
            Opcode.SEND_REQ,
            Opcode.WAIT_ACK,
            Opcode.CYCLE_ADDR,
            Opcode.CYCLE_LEN,
            Opcode.PRG_PRM,
        }
    ),
}

_SYNC_SEND = frozenset({Opcode.SEND_REQ, Opcode.SEND_ACK})
_SYNC_WAIT = frozenset({Opcode.WAIT_REQ, Opcode.WAIT_ACK})
SYNC_OPCODES = _SYNC_SEND | _SYNC_WAIT


def _check(value: int, bits: int, name: str) -> int:
    if not (0 <= value < (1 << bits)):
        raise ValueError(f"field {name}={value} does not fit in {bits} bits")
    return value


BEAT = 64  # HBM addresses/lengths are encoded in 64-byte AXI beats


def _to_beats(value: int, name: str, round_up: bool = False) -> int:
    if round_up:
        return (value + BEAT - 1) // BEAT
    if value % BEAT:
        raise ValueError(f"{name}={value} must be {BEAT}-byte aligned")
    return value // BEAT


class _Packer:
    """Sequential MSB-first bitfield packer for the 64-bit encoding."""

    def __init__(self) -> None:
        self.word = 0
        self.pos = 64

    def put(self, value: int, bits: int, name: str) -> "_Packer":
        _check(value, bits, name)
        self.pos -= bits
        if self.pos < 0:
            raise ValueError("instruction encoding exceeds 64 bits")
        self.word |= value << self.pos
        return self


class _Unpacker:
    def __init__(self, word: int) -> None:
        self.word = word
        self.pos = 64

    def get(self, bits: int) -> int:
        self.pos -= bits
        return (self.word >> self.pos) & ((1 << bits) - 1)


@dataclass
class Instruction:
    """Base: OPCD(6) | PRG_END(1) | type-specific payload."""

    opcode: ClassVar[Opcode]
    prg_end: bool = False

    @property
    def is_static(self) -> bool:
        return True

    def encode(self) -> int:
        p = _Packer()
        p.put(int(self.opcode), 6, "OPCD")
        p.put(int(self.prg_end), 1, "PRG_END")
        self._encode_payload(p)
        return p.word

    def _encode_payload(self, p: _Packer) -> None:  # pragma: no cover
        pass

    @staticmethod
    def decode(word: int) -> "Instruction":
        op = Opcode((word >> 58) & 0x3F)
        u = _Unpacker(word)
        u.get(6)
        prg_end = bool(u.get(1))
        cls = _DECODERS[op]
        inst = cls._decode_payload(op, u)
        inst.prg_end = prg_end
        return inst


@dataclass
class ProgCtrl(Instruction):
    """PRG_PRM: NR==0 -> infinite loop; else run NR rounds, jumping to ICU_BA
    at the end of each round (Table I(b))."""

    opcode: ClassVar[Opcode] = Opcode.PRG_PRM
    nr: int = 1  # number of rounds; 0 = infinite
    icu_ba: int = 0  # jump base address for rounds >= 2

    def _encode_payload(self, p: _Packer) -> None:
        p.put(self.nr, 24, "NR")
        p.put(self.icu_ba, 12, "ICU_BA")

    @classmethod
    def _decode_payload(cls, op: Opcode, u: _Unpacker) -> "ProgCtrl":
        return cls(nr=u.get(24), icu_ba=u.get(12))


@dataclass
class Config(Instruction):
    """*_PRM: establishes stride pattern / IM2COL / URAM context for the next
    DataMove. Payload packs (param0..param3) whose meaning depends on OPCD:

      STRIDE_PRM / RES_ADD_STRIDE_PRM: stride, burst_len, n_bursts, -
      IM2COL_PRM:                      kernel(4b k_h<<2|k_w? packed), stride,
                                       pad, in_w
      URAM_PRM:                        uram_addr, -, -, -
    """

    opcode: ClassVar[Opcode] = Opcode.STRIDE_PRM
    op: Opcode = Opcode.STRIDE_PRM
    param0: int = 0
    param1: int = 0
    param2: int = 0
    param3: int = 0

    def __post_init__(self) -> None:
        assert self.op in {
            Opcode.STRIDE_PRM,
            Opcode.IM2COL_PRM,
            Opcode.URAM_PRM,
            Opcode.RES_ADD_STRIDE_PRM,
        }

    def encode(self) -> int:
        p = _Packer()
        p.put(int(self.op), 6, "OPCD")
        p.put(int(self.prg_end), 1, "PRG_END")
        p.put(self.param0, 20, "param0")
        p.put(self.param1, 14, "param1")
        p.put(self.param2, 12, "param2")
        p.put(self.param3, 11, "param3")
        return p.word

    @classmethod
    def _decode_payload(cls, op: Opcode, u: _Unpacker) -> "Config":
        return cls(op=op, param0=u.get(20), param1=u.get(14), param2=u.get(12), param3=u.get(11))


@dataclass
class DataMove(Instruction):
    """*_ADM: drives one AXI DataMover transfer of LEN bytes at CUR_BA.

    CUR_BA is *latched* for an optional successor AddrCyc which rewrites it
    (dynamic behavior). ``buffer`` names the on-chip target/source buffer for
    the simulator ("act_in", "weights", "res", "act_out")."""

    opcode: ClassVar[Opcode] = Opcode.LINEAR_ADM
    op: Opcode = Opcode.LINEAR_ADM
    cur_ba: int = 0  # HBM byte address
    length: int = 0  # transfer bytes
    channel: int = 0  # HBM channel id (from liveness analysis)
    # Broadcast stores (a node with several output tensors): HOLD keeps the
    # output-buffer slot acquired across the node's remaining ST transfers —
    # they re-read the same slot — and only the final transfer (HOLD=0)
    # frees it back to the compute engine.
    hold: bool = False

    def __post_init__(self) -> None:
        assert self.op in {
            Opcode.LINEAR_ADM,
            Opcode.IM2COL_ADM,
            Opcode.STRIDE_ADM,
            Opcode.WEIGHTS_ADM,
            Opcode.RES_ADD_ADM,
            Opcode.RES_ADD_STRIDE_ADM,
        }

    @property
    def is_static(self) -> bool:
        return False  # CUR_BA is rewritten by successor AddrCyc

    def encode(self) -> int:
        p = _Packer()
        p.put(int(self.op), 6, "OPCD")
        p.put(int(self.prg_end), 1, "PRG_END")
        p.put(_to_beats(self.cur_ba, "CUR_BA"), 26, "CUR_BA")
        p.put(_to_beats(self.length, "LEN", round_up=True), 22, "LEN")
        p.put(self.channel, 5, "CHANNEL")
        p.put(int(self.hold), 1, "HOLD")
        return p.word

    @classmethod
    def _decode_payload(cls, op: Opcode, u: _Unpacker) -> "DataMove":
        return cls(op=op, cur_ba=u.get(26) * BEAT, length=u.get(22) * BEAT,
                   channel=u.get(5), hold=bool(u.get(1)))


@dataclass
class AddrCyc(Instruction):
    """CYCLE_ADDR: cyclic addressing over NC+1 regions (Table I(b)).

        if IC == 0: IC, CUR_BA = NC, BA
        else:       IC, CUR_BA = IC-1, CUR_BA + AOFFS

    Write-back: *predecessor* DataMove.cur_ba := CUR_BA (next round's address),
    own IC. NC=1 yields the two-region ping-pong used for B-buffers; NC=n-1
    cycles over n A/C-regions. IC initialises to NC when loaded offline.
    """

    opcode: ClassVar[Opcode] = Opcode.CYCLE_ADDR
    ba: int = 0
    aoffs: int = 0
    nc: int = 0
    ic: int = 0  # iteration counter; loaded as NC offline

    @property
    def is_static(self) -> bool:
        return False

    def step(self, pred_cur_ba: int) -> int:
        """Advance one program round; returns the new CUR_BA to write back
        into the predecessor DataMove."""
        if self.ic == 0:
            self.ic = self.nc
            new_ba = self.ba
        else:
            self.ic -= 1
            new_ba = pred_cur_ba + self.aoffs
        return new_ba

    def _encode_payload(self, p: _Packer) -> None:
        p.put(_to_beats(self.ba, "BA"), 26, "BA")
        p.put(_to_beats(self.aoffs, "AOFFS", round_up=True), 17, "AOFFS")
        p.put(self.nc, 7, "NC")
        p.put(self.ic, 7, "IC")

    @classmethod
    def _decode_payload(cls, op: Opcode, u: _Unpacker) -> "AddrCyc":
        return cls(ba=u.get(26) * BEAT, aoffs=u.get(17) * BEAT, nc=u.get(7), ic=u.get(7))


@dataclass
class AddrLen(Instruction):
    """CYCLE_LEN: the length-advance mode of the AddrCyc family.

        if IC == 0: IC, CUR_LEN = NC, LEN_BASE
        else:       IC, CUR_LEN = IC-1, CUR_LEN + LOFFS

    Write-back: *predecessor* DataMove.length := CUR_LEN (next round's
    transfer length), own IC. This drives transfers over an *append-only*
    cyclic region whose valid prefix grows every program round — the K/V
    cache of autoregressive decode: round r of a decode window reads
    LEN_BASE + r*LOFFS bytes, then the counter wraps for the next sequence.
    IC initialises to NC when loaded offline, exactly like AddrCyc.
    """

    opcode: ClassVar[Opcode] = Opcode.CYCLE_LEN
    len_base: int = 0  # bytes of the first round's transfer
    loffs: int = 0  # bytes appended per round
    nc: int = 0
    ic: int = 0  # iteration counter; loaded as NC offline

    @property
    def is_static(self) -> bool:
        return False

    def step(self, pred_length: int) -> int:
        """Advance one program round; returns the new LEN to write back into
        the predecessor DataMove."""
        if self.ic == 0:
            self.ic = self.nc
            new_len = self.len_base
        else:
            self.ic -= 1
            new_len = pred_length + self.loffs
        return new_len

    def _encode_payload(self, p: _Packer) -> None:
        p.put(_to_beats(self.len_base, "LEN_BASE", round_up=True), 22, "LEN_BASE")
        p.put(_to_beats(self.loffs, "LOFFS", round_up=True), 17, "LOFFS")
        p.put(self.nc, 9, "NC")
        p.put(self.ic, 9, "IC")

    @classmethod
    def _decode_payload(cls, op: Opcode, u: _Unpacker) -> "AddrLen":
        return cls(len_base=u.get(22) * BEAT, loffs=u.get(17) * BEAT,
                   nc=u.get(9), ic=u.get(9))


@dataclass
class Sync(Instruction):
    """SEND_REQ / SEND_ACK / WAIT_REQ / WAIT_ACK (Table I(b)).

    BID cycling across program rounds:

        if NC == 0:  BID = BID              (bypass)
        elif IC == 0: BID, IC = BASE_BID, NC (reset)
        else:        BID, IC = BID+1, IC-1   (increment)

    SEND_* transmit a control token to PU ``pid`` (DST_PID); WAIT_* poll the
    REQ/ACK LUTRAM for a token from PU ``pid`` (SRC_PID) with buffer id BID,
    then clear the entry. IC initialises to NC when loaded offline.
    """

    opcode: ClassVar[Opcode] = Opcode.SEND_REQ
    op: Opcode = Opcode.SEND_REQ
    pid: int = 0  # DST_PID for SEND_*, SRC_PID for WAIT_*
    bid: int = 0
    base_bid: int = 0
    nc: int = 0
    ic: int = 0

    def __post_init__(self) -> None:
        assert self.op in SYNC_OPCODES

    @property
    def is_static(self) -> bool:
        return False

    @property
    def is_send(self) -> bool:
        return self.op in _SYNC_SEND

    @property
    def kind(self) -> str:
        """'req' or 'ack' -- which LUTRAM this instruction touches."""
        return "req" if self.op in (Opcode.SEND_REQ, Opcode.WAIT_REQ) else "ack"

    def step(self) -> None:
        """Advance BID state one program round (after the token action)."""
        if self.nc == 0:
            return  # bypass
        if self.ic == 0:
            self.bid, self.ic = self.base_bid, self.nc
        else:
            self.bid, self.ic = self.bid + 1, self.ic - 1

    def encode(self) -> int:
        p = _Packer()
        p.put(int(self.op), 6, "OPCD")
        p.put(int(self.prg_end), 1, "PRG_END")
        p.put(self.pid, 6, "PID")
        p.put(self.bid, 12, "BID")
        p.put(self.base_bid, 12, "BASE_BID")
        p.put(self.nc, 12, "NC")
        p.put(self.ic, 12, "IC")
        return p.word

    @classmethod
    def _decode_payload(cls, op: Opcode, u: _Unpacker) -> "Sync":
        return cls(op=op, pid=u.get(6), bid=u.get(12), base_bid=u.get(12), nc=u.get(12), ic=u.get(12))


@dataclass
class Compute(Instruction):
    """GEMM: drives the systolic array + vector post-processing.

    m/n/k give the GEMM dims for this node tile set (out-ch, spatial, in-dim);
    scale_shift is the power-of-two requantization shift; relu/add_enable
    configure the post-processing block; rounds is the number of SA waves;
    wchunks is the number of dynamically-streamed weight chunks this GEMM
    consumes (the URAM read interlock of the SMOF-style weight streaming —
    the decoder blocks the GEMM until that many preceding WEIGHTS_ADM
    transfers have landed in URAM).
    """

    opcode: ClassVar[Opcode] = Opcode.GEMM
    m: int = 0
    n: int = 0
    k: int = 0
    relu: bool = False
    add_enable: bool = False  # fused residual shortcut addition
    scale_shift: int = 0  # right-shift amount (po2 scale)
    rounds: int = 1
    wchunks: int = 0  # streamed weight chunks consumed (0 = fully preloaded)

    def _encode_payload(self, p: _Packer) -> None:
        p.put(self.m, 12, "M")
        p.put(self.n, 16, "N")
        p.put(self.k, 14, "K")
        p.put(int(self.relu), 1, "RELU")
        p.put(int(self.add_enable), 1, "ADD_EN")
        p.put(self.scale_shift, 5, "SCALE")
        p.put(self.rounds, 1, "ROUNDS")
        p.put(self.wchunks, 7, "WCHUNKS")

    @classmethod
    def _decode_payload(cls, op: Opcode, u: _Unpacker) -> "Compute":
        return cls(
            m=u.get(12),
            n=u.get(16),
            k=u.get(14),
            relu=bool(u.get(1)),
            add_enable=bool(u.get(1)),
            scale_shift=u.get(5),
            rounds=u.get(1),
            wchunks=u.get(7),
        )


_DECODERS: dict[Opcode, type] = {
    Opcode.PRG_PRM: ProgCtrl,
    Opcode.IM2COL_PRM: Config,
    Opcode.STRIDE_PRM: Config,
    Opcode.URAM_PRM: Config,
    Opcode.RES_ADD_STRIDE_PRM: Config,
    Opcode.LINEAR_ADM: DataMove,
    Opcode.IM2COL_ADM: DataMove,
    Opcode.STRIDE_ADM: DataMove,
    Opcode.WEIGHTS_ADM: DataMove,
    Opcode.RES_ADD_ADM: DataMove,
    Opcode.RES_ADD_STRIDE_ADM: DataMove,
    Opcode.CYCLE_ADDR: AddrCyc,
    Opcode.CYCLE_LEN: AddrLen,
    Opcode.SEND_REQ: Sync,
    Opcode.SEND_ACK: Sync,
    Opcode.WAIT_REQ: Sync,
    Opcode.WAIT_ACK: Sync,
    Opcode.GEMM: Compute,
}


def effective_opcode(inst: Instruction) -> Opcode:
    return getattr(inst, "op", inst.opcode)


def validate_group(inst: Instruction, group: Group) -> None:
    op = effective_opcode(inst)
    if op not in GROUP_OPCODES[group]:
        raise ValueError(f"opcode {op.name} not permitted in ICU group {group.value}")
