# The paper's primary contribution: instruction-based coordination of
# heterogeneous PUs. ISA (isa/program), ICU + ISU coordination architecture
# (icu/isu), PU timing model (pu) and the discrete-event system simulator
# (simulator). The compilation framework lives in repro.compiler; the
# TPU-scale adaptation (shard_map pipeline runtime) in repro.runtime.
from .isa import (
    AddrCyc,
    AddrLen,
    Compute,
    Config,
    DataMove,
    Group,
    Instruction,
    Opcode,
    ProgCtrl,
    Sync,
)
from .program import Program, PUProgram
from .pu import PUSpec, make_u50_system, system_peak_tops
from .isu import ISUNetwork, Token, latency_matrix, token_latency_cycles
from .icu import ICU
from .simulator import MemberSimResult, MultiPUSimulator, PipelineMember, SimResult, simulate

__all__ = [
    "AddrCyc",
    "AddrLen",
    "Compute",
    "Config",
    "DataMove",
    "Group",
    "Instruction",
    "Opcode",
    "ProgCtrl",
    "Sync",
    "Program",
    "PUProgram",
    "PUSpec",
    "make_u50_system",
    "system_peak_tops",
    "ISUNetwork",
    "Token",
    "latency_matrix",
    "token_latency_cycles",
    "ICU",
    "MemberSimResult",
    "MultiPUSimulator",
    "PipelineMember",
    "SimResult",
    "simulate",
]
