"""Hand-coded instruction programs for the paper's two-PU pipeline example
(Sec. III-C, Fig. 3).

Two Conv layers (as GEMM) map to PU_a (producer) and PU_b (consumer):

  PU_a: LD reads input from the cyclic A-regions, CP computes, ST writes the
        intermediate tensor into ping-pong B-buffers (BID 0/1), guarded by
        WAIT_ACK / SEND_REQ.
  PU_b: LD waits REQ, reads B[bid], sends ACK (with the two-ACK *bypass
        prologue* pre-authorizing B0/B1 before the loop), CP computes, ST
        writes results to the cyclic C-regions.

Used by tests (Fig. 3 cases 1-3: balanced / consumer-limited / producer-
limited) and by ``benchmarks/two_pu_pipeline.py``.
"""
from __future__ import annotations

from dataclasses import dataclass

from .isa import AddrCyc, Compute, DataMove, Opcode, Sync
from .program import Program, PUProgram
from .isa import Group


@dataclass(frozen=True)
class GemmShape:
    m: int  # output channels
    n: int  # spatial positions
    k: int  # reduction (in_ch * kh * kw)

    @property
    def out_bytes(self) -> int:
        return self.m * self.n  # INT8

    @property
    def in_bytes(self) -> int:
        return self.k * self.n  # upper bound (im2col view)


def build_two_pu_pipeline(
    pid_a: int,
    pid_b: int,
    shape_a: GemmShape,
    shape_b: GemmShape,
    *,
    rounds: int,
    n_io_regions: int = 4,
    a_region_base: int = 0x000_0000,
    b_region_base: int = 0x400_0000,
    c_region_base: int = 0x800_0000,
    chan_a: int = 0,
    chan_b_w: int = 1,
    chan_b_r: int = 2,
    chan_c: int = 3,
) -> list[PUProgram]:
    """Construct the Fig. 3 instruction programs. Intermediate tensor is
    shape_a's output == shape_b's input."""
    la = shape_a.in_bytes
    lb = shape_a.out_bytes
    lc = shape_b.out_bytes
    n = n_io_regions

    # ---- PU_a (producer) ----------------------------------------------------
    ld_a = Program.assemble(
        Group.LD,
        [
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=a_region_base, length=la, channel=chan_a),
            AddrCyc(ba=a_region_base, aoffs=la, nc=n - 1, ic=n - 1),
        ],
        rounds=rounds,
        name=f"pu{pid_a}.LD",
    )
    cp_a = Program.assemble(
        Group.CP,
        [Compute(m=shape_a.m, n=shape_a.n, k=shape_a.k, relu=True)],
        rounds=rounds,
        name=f"pu{pid_a}.CP",
    )
    st_a = Program.assemble(
        Group.ST,
        [
            Sync(op=Opcode.WAIT_ACK, pid=pid_b, bid=0, base_bid=0, nc=1, ic=1),
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=b_region_base, length=lb, channel=chan_b_w),
            AddrCyc(ba=b_region_base, aoffs=lb, nc=1, ic=1),
            Sync(op=Opcode.SEND_REQ, pid=pid_b, bid=0, base_bid=0, nc=1, ic=1),
        ],
        rounds=rounds,
        name=f"pu{pid_a}.ST",
    )

    # ---- PU_b (consumer) ----------------------------------------------------
    # ACK-bypass prologue at addresses {0,1}: pre-authorize both B buffers,
    # then loop from ICU_BA=2 (the prologue runs exactly once).
    ld_b = Program.assemble(
        Group.LD,
        [
            Sync(op=Opcode.SEND_ACK, pid=pid_a, bid=0, nc=0),  # bypass: BID fixed
            Sync(op=Opcode.SEND_ACK, pid=pid_a, bid=1, nc=0),
            Sync(op=Opcode.WAIT_REQ, pid=pid_a, bid=0, base_bid=0, nc=1, ic=1),
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=b_region_base, length=lb, channel=chan_b_r),
            AddrCyc(ba=b_region_base, aoffs=lb, nc=1, ic=1),
            Sync(op=Opcode.SEND_ACK, pid=pid_a, bid=0, base_bid=0, nc=1, ic=1),
        ],
        rounds=rounds,
        loop_ba=2,
        name=f"pu{pid_b}.LD",
    )
    cp_b = Program.assemble(
        Group.CP,
        [Compute(m=shape_b.m, n=shape_b.n, k=shape_b.k, relu=True)],
        rounds=rounds,
        name=f"pu{pid_b}.CP",
    )
    st_b = Program.assemble(
        Group.ST,
        [
            DataMove(op=Opcode.LINEAR_ADM, cur_ba=c_region_base, length=lc, channel=chan_c),
            AddrCyc(ba=c_region_base, aoffs=lc, nc=n - 1, ic=n - 1),
        ],
        rounds=rounds,
        name=f"pu{pid_b}.ST",
    )

    return [
        PUProgram(pid_a, ld_a, cp_a, st_a, label="producer"),
        PUProgram(pid_b, ld_b, cp_b, st_b, label="consumer"),
    ]
