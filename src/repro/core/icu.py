"""Instruction Controller Unit (paper Sec. III-B, Fig. 2(d)).

Each PU's ICU holds three independent dual-port BRAMs (LD / CP / ST programs)
with a dedicated decoder FSM per group — memory access is decoupled from
compute, enabling overlapped pipelining inside the PU.

Coordination state lives in the REQ and ACK LUTRAMs, addressed by
(SRC_PID, BID). Incoming ISU tokens set entries; WAIT_* instructions act as
barriers polling an entry, then clear it. SEND_* instructions push tokens into
the local ISU through a small FIFO so the decoder never blocks on the fabric.

Intra-PU dataflow interlocks (all hardware-implicit, modeled with counting
semaphores):

  LD  --(act ping-pong BRAM slots)-->  CP  --(output buffer slots)-->  ST
  WEIGHTS_ADM / RES_ADD_ADM are issued asynchronously (the ADM engines run
  independently); a GEMM blocks until its ``wchunks`` weight chunks and any
  preceding residual transfers have landed (URAM/BRAM read interlock).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from .events import Acquire, Delay, Kernel, Release, Semaphore, WaitCond
from .isa import (
    AddrCyc,
    AddrLen,
    Compute,
    Config,
    DataMove,
    Group,
    Opcode,
    ProgCtrl,
    Sync,
    effective_opcode,
)
from .isu import ISUNetwork, Token
from .program import Program, PUProgram
from .pu import PUSpec

DECODE_CYCLES = 1  # instruction issue overhead (sys_clk)


@dataclass
class GroupStats:
    busy: float = 0.0  # cycles in ADM transfers / GEMM execution
    sync_wait: float = 0.0  # cycles blocked in WAIT_REQ/WAIT_ACK
    buffer_wait: float = 0.0  # cycles blocked on intra-PU buffer slots
    rounds_done: int = 0
    round_start_times: list[float] = field(default_factory=list)
    round_end_times: list[float] = field(default_factory=list)
    instructions: int = 0
    halted_at: Optional[float] = None


class ICU:
    """Per-PU instruction controller: three decoder processes + LUTRAMs."""

    def __init__(
        self,
        kernel: Kernel,
        spec: PUSpec,
        isu: ISUNetwork,
        hbm_channels: dict[int, Semaphore],
    ) -> None:
        self.kernel = kernel
        self.spec = spec
        self.isu = isu
        self.hbm_channels = hbm_channels

        # REQ/ACK LUTRAMs: (src_pid, bid) -> outstanding token count.
        self.req_lutram: dict[tuple[int, int], int] = {}
        self.ack_lutram: dict[tuple[int, int], int] = {}

        # Intra-PU buffer interlocks.
        self.act_free = kernel.semaphore(spec.act_buf_slots, f"pu{spec.pid}.act_free")
        self.act_full = kernel.semaphore(0, f"pu{spec.pid}.act_full")
        self.out_free = kernel.semaphore(spec.out_buf_slots, f"pu{spec.pid}.out_free")
        self.out_full = kernel.semaphore(0, f"pu{spec.pid}.out_full")

        # Async ADM completion counters (weights / residual streams).
        self.weights_done = 0
        self.res_issued = 0
        self.res_done = 0
        # Expected stream-completion times of in-flight LD transfers, one
        # entry per filled act slot (FIFO pairing with GEMM consumption).
        self.ld_stream_ends: "deque[float]" = deque()

        self.stats: dict[Group, GroupStats] = {g: GroupStats() for g in Group}
        self.program: Optional[PUProgram] = None
        self.member = ""  # owning deployment member label (set by start)
        # Injected fault state (repro.faults): when set, every decoder of
        # this PU parks forever once the clock reaches ``hang_at`` — the
        # model of a hardware PU that silently stops issuing instructions.
        self.hang_at: Optional[float] = None
        # pc of the instruction each decoder group is currently executing
        # (fault reports locate a stuck decoder down to the instruction).
        self.cur_index: dict[Group, int] = {}

    # -- token delivery (installed into ISUNetwork by the simulator) --------
    def deliver(self, token: Token) -> None:
        lut = self.req_lutram if token.kind == "req" else self.ack_lutram
        key = (token.src_pid, token.bid)
        lut[key] = lut.get(key, 0) + 1
        self.kernel.notify(("lut", self.spec.pid, token.kind, key))

    def preset_ack(self, src_pid: int, bid: int) -> None:
        """Host-side LUTRAM preset (used by tests; Fig. 3 instead uses the
        ACK-bypass prologue, which achieves the same effect in-band)."""
        key = (src_pid, bid)
        self.ack_lutram[key] = self.ack_lutram.get(key, 0) + 1

    # -- program start -------------------------------------------------------
    def start(self, program: PUProgram, member: str = "") -> None:
        self.program = program.clone()
        self.program.validate()
        self.member = member
        pid = self.spec.pid
        for group, prog in ((Group.LD, self.program.ld),
                            (Group.CP, self.program.cp),
                            (Group.ST, self.program.st)):
            self.kernel.spawn(self._decoder(group, prog),
                              name=f"pu{pid}.{group.name}", member=member)

    # -- decoder FSM ----------------------------------------------------------
    def _decoder(self, group: Group, prog: Program):
        st = self.stats[group]
        pc = 0
        rounds = 0
        weights_issued = 0  # monotone count of WEIGHTS_ADM issued by CP
        gemm_wtarget = 0  # cumulative weight chunks required by GEMMs so far
        st_holding = False  # ST holds an out slot across a broadcast store
        insts = prog.instructions

        at_round_start = True
        while True:
            if self.hang_at is not None and self.kernel.now >= self.hang_at:
                # Injected PU hang: the decoder stops issuing instructions
                # mid-round, silently — exactly what the watchdog must turn
                # into a structured FaultReport. The key is never notified
                # and the predicate never true, so the process parks forever.
                self.cur_index[group] = pc
                yield WaitCond(
                    ("fault", "hang", self.spec.pid, group.name),
                    pred=lambda: False,
                    desc=f"injected PU hang (pu{self.spec.pid} issues no "
                         "further instructions)",
                )
            inst = insts[pc]
            self.cur_index[group] = pc
            if at_round_start:
                st.round_start_times.append(self.kernel.now)
                at_round_start = False
            st.instructions += 1
            yield Delay(DECODE_CYCLES)
            op = effective_opcode(inst)

            if isinstance(inst, ProgCtrl):
                pass  # round bookkeeping handled at PRG_END below

            elif isinstance(inst, Config):
                pass  # context for the successor ADM; zero extra latency

            elif isinstance(inst, DataMove):
                if group is Group.CP:
                    # Async issue: the CP ADM engines run decoupled.
                    # length/channel snapshot at issue: a successor AddrCyc/
                    # AddrLen rewrites the BRAM fields for the *next* round
                    # and must not retroactively resize an in-flight transfer.
                    if op is Opcode.WEIGHTS_ADM:
                        weights_issued += 1
                        self.kernel.spawn(
                            self._async_adm(inst.length, inst.channel,
                                            kind="weights", addr=inst.cur_ba),
                            name=f"pu{self.spec.pid}.wadm",
                            member=self.member,
                        )
                    else:  # RES_ADD_* : residual shortcut stream
                        self.res_issued += 1
                        self.kernel.spawn(
                            self._async_adm(inst.length, inst.channel,
                                            kind="res", addr=inst.cur_ba),
                            name=f"pu{self.spec.pid}.radm",
                            member=self.member,
                        )
                elif group is Group.LD:
                    # Fill one input activation ping-pong slot, *streaming*:
                    # the slot is usable by the SA once the first tile lands
                    # (ld_stream_ends lets the GEMM rate-match the remainder).
                    t0 = self.kernel.now
                    yield Acquire(self.act_free)
                    st.buffer_wait += self.kernel.now - t0
                    chan = self.hbm_channels[inst.channel]
                    t0 = self.kernel.now
                    yield Acquire(chan)
                    st.buffer_wait += self.kernel.now - t0
                    total = self.spec.adm_sys_cycles(inst.length)
                    delta = min(total, self.spec.stream_tile_cycles(inst.length))
                    self.kernel.log(
                        f"pu{self.spec.pid}.LD",
                        ("xfer", "r", inst.channel, inst.cur_ba, inst.length,
                         self.kernel.now + total),
                    )
                    yield Delay(delta)
                    self.ld_stream_ends.append(self.kernel.now + (total - delta))
                    yield Release(self.act_full)
                    yield Delay(total - delta)
                    st.busy += total
                    yield Release(chan)
                else:  # ST: drain one output buffer slot.
                    # A broadcast store (multi-output node) re-reads the
                    # slot the node's first transfer acquired: HOLD keeps
                    # it, only the final transfer (hold=0) frees it.
                    if not st_holding:
                        t0 = self.kernel.now
                        yield Acquire(self.out_full)
                        st.buffer_wait += self.kernel.now - t0
                    yield from self._blocking_adm(inst, st)
                    st_holding = inst.hold
                    if not st_holding:
                        yield Release(self.out_free)

            elif isinstance(inst, AddrCyc):
                pred = insts[pc - 1]
                assert isinstance(pred, DataMove)
                pred.cur_ba = inst.step(pred.cur_ba)  # dynamic write-back

            elif isinstance(inst, AddrLen):
                # length-advance mode: the predecessor transfer grows per
                # round (append-only K/V region of autoregressive decode).
                pred = insts[pc - 1]
                assert isinstance(pred, DataMove)
                pred.length = inst.step(pred.length)

            elif isinstance(inst, Sync):
                if inst.is_send:
                    self.isu.send(
                        Token(self.spec.pid, inst.pid, inst.bid, inst.kind)
                    )
                else:
                    lut = self.req_lutram if inst.kind == "req" else self.ack_lutram
                    key = (inst.pid, inst.bid)
                    t0 = self.kernel.now
                    yield WaitCond(
                        ("lut", self.spec.pid, inst.kind, key),
                        pred=lambda lut=lut, key=key: lut.get(key, 0) > 0,
                        desc=(f"{op.name} on channel (src_pid={inst.pid}, "
                              f"bid={inst.bid})"),
                    )
                    lut[key] -= 1  # clear the entry, barrier passed
                    st.sync_wait += self.kernel.now - t0
                inst.step()  # BID cycling write-back (Table I(b))

            elif isinstance(inst, Compute):
                gemm_wtarget += inst.wchunks
                # URAM interlock: streamed weight chunks must have landed.
                t0 = self.kernel.now
                yield WaitCond(
                    ("weights", self.spec.pid),
                    pred=lambda t=gemm_wtarget: self.weights_done >= t,
                    desc=(f"URAM weight interlock ({gemm_wtarget} cumulative "
                          "chunk(s))"),
                )
                # Residual stream interlock.
                if inst.add_enable:
                    tgt = self.res_issued
                    yield WaitCond(
                        ("res", self.spec.pid),
                        pred=lambda t=tgt: self.res_done >= t,
                        desc=f"residual stream interlock ({tgt} transfer(s))",
                    )
                yield Acquire(self.act_full)  # consume one input slot
                yield Acquire(self.out_free)  # claim one output slot
                st.buffer_wait += self.kernel.now - t0
                dur = self.spec.gemm_sys_cycles(inst.m, inst.n, inst.k) * max(1, inst.rounds)
                # Rate-match a still-streaming input: the SA cannot finish
                # before the LD transfer delivers its last tile.
                if self.ld_stream_ends:
                    ld_end = self.ld_stream_ends.popleft()
                    dur = max(dur, ld_end - self.kernel.now)
                yield Delay(dur)
                st.busy += dur
                yield Release(self.act_free)
                yield Release(self.out_full)

            else:  # pragma: no cover
                raise TypeError(f"unhandled instruction {inst!r}")

            if inst.prg_end:
                rounds += 1
                st.rounds_done = rounds
                st.round_end_times.append(self.kernel.now)
                ctrl = prog.progctrl
                if ctrl.nr != 0 and rounds >= ctrl.nr:
                    st.halted_at = self.kernel.now
                    return
                pc = ctrl.icu_ba
                at_round_start = True
            else:
                pc += 1

    # -- ADM helpers ----------------------------------------------------------
    def _blocking_adm(self, inst: DataMove, st: GroupStats):
        chan = self.hbm_channels[inst.channel]
        t0 = self.kernel.now
        yield Acquire(chan)
        st.buffer_wait += self.kernel.now - t0
        dur = self.spec.adm_sys_cycles(inst.length)
        self.kernel.log(
            f"pu{self.spec.pid}.ST",
            ("xfer", "w", inst.channel, inst.cur_ba, inst.length,
             self.kernel.now + dur),
        )
        yield Delay(dur)
        st.busy += dur
        yield Release(chan)

    def _async_adm(self, length: int, channel: int, kind: str, addr: int = 0):
        chan = self.hbm_channels[channel]
        yield Acquire(chan)
        dur = self.spec.adm_sys_cycles(length)
        self.kernel.log(
            f"pu{self.spec.pid}.CP",
            ("xfer", "r", channel, addr, length, self.kernel.now + dur),
        )
        yield Delay(dur)
        yield Release(chan)
        if kind == "weights":
            self.weights_done += 1
            self.kernel.notify(("weights", self.spec.pid))
        else:
            self.res_done += 1
            self.kernel.notify(("res", self.spec.pid))
