"""Instruction Synchronization Unit network (paper Sec. III-A, Fig. 2(b,c)).

Distributed switch fabric routing single-beat control tokens (REQ/ACK)
between PUs over AXIS channels. Each ISU is an AXIS switch with local
injection (S0) / delivery (M0) ports and directional forwarding (S1,S2 /
M1,M2) — i.e. the PUs of one SLR form a chain, and chains are bridged by
SLR-crossing register slices.

Token latency model, calibrated to the measured matrix of Fig. 2(c):

  same PU                 : 2 cycles  (bypasses the switch fabric)
  same SLR                : 2 + ~1/2 per extra hop  -> 2-3 cycles
  cross SLR               : + 13-cycle SLR boundary penalty

Tokens are single-beat: TDATA = {BID, SRC_PID, type}, TDEST = DST_PID. With a
single token in transit the fabric is contention-free; one-transfer
round-robin arbitration resolves simultaneous injections (modeled as +1 cycle
per conflicting token ahead in the queue — negligible at DNN timescales, as
the paper argues).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from .events import Delay, Kernel
from .pu import PUSpec

SLR_CROSS_PENALTY = 13
SAME_PU_LATENCY = 2
BASE_HOP_LATENCY = 2


@dataclass(frozen=True)
class Token:
    """Single-beat AXIS control token."""

    src_pid: int
    dst_pid: int
    bid: int
    kind: str  # "req" | "ack"

    def __repr__(self) -> str:
        return f"<{self.kind.upper()} {self.src_pid}->{self.dst_pid} BID={self.bid}>"


def token_latency_cycles(src: PUSpec, dst: PUSpec) -> int:
    """Deterministic token latency (sys_clk cycles), per Fig. 2(c)."""
    if src.pid == dst.pid:
        return SAME_PU_LATENCY
    hops = abs(src.pid - dst.pid)
    lat = BASE_HOP_LATENCY + (1 if hops > 2 else 0)
    if src.slr != dst.slr:
        lat += SLR_CROSS_PENALTY
    return lat


def latency_matrix(pus: list[PUSpec]) -> list[list[int]]:
    """The full PU-to-PU token latency matrix (benchmarks/isu_latency.py)."""
    return [[token_latency_cycles(s, d) for d in pus] for s in pus]


class ISUNetwork:
    """Routes tokens between ICUs with the deterministic latency model.

    ``deliver`` is installed by the simulator: deliver(dst_pid, token) updates
    the destination ICU's REQ/ACK LUTRAM and wakes waiting decoders.
    """

    def __init__(self, kernel: Kernel, pus: list[PUSpec]) -> None:
        self.kernel = kernel
        self.pus = {p.pid: p for p in pus}
        self.deliver: Optional[Callable[[int, Token], None]] = None
        self.tokens_sent = 0
        self.tokens_dropped = 0
        # Injected fault hook (repro.faults): maps (token, latency) to a
        # possibly corrupted token and latency, or to (None, _) to drop the
        # token in the fabric. Installed per reset; None on a healthy fabric.
        self.fault_hook: Optional[
            Callable[[Token, float], tuple[Optional[Token], float]]] = None
        self._inflight: dict[tuple[int, int], int] = {}  # crude contention model

    def send(self, token: Token) -> None:
        """Inject a token at the source ISU (non-blocking for the ICU: the
        S0 FIFO decouples the decoder from the fabric)."""
        src = self.pus[token.src_pid]
        dst = self.pus[token.dst_pid]
        base = token_latency_cycles(src, dst)
        if self.fault_hook is not None:
            faulted, base = self.fault_hook(token, base)
            if faulted is None:  # dropped in the fabric
                self.tokens_dropped += 1
                return
            token = faulted
        # one-transfer round-robin: a token queued behind k in-flight tokens
        # on the same directed link waits k extra cycles.
        link = (token.src_pid, token.dst_pid)
        backlog = self._inflight.get(link, 0)
        self._inflight[link] = backlog + 1
        self.tokens_sent += 1
        self.kernel.spawn(self._transit(token, base + backlog, link), name=f"isu:{token}")

    def _transit(self, token: Token, cycles: float, link: tuple[int, int]):
        yield Delay(cycles)
        self._inflight[link] -= 1
        assert self.deliver is not None, "ISUNetwork.deliver not installed"
        self.kernel.log("isu", ("deliver", token))
        self.deliver(token.dst_pid, token)
