"""Processing Unit model: heterogeneous systolic-array PUs of the baseline
architecture [16] that this paper builds on.

The Alveo U50 system instantiates 5x PU_1x (64x4 SA) + 5x PU_2x (64x8 SA)
across the two SLRs; DSPs run at dsp_clk = 600 MHz (2x sys_clk = 300 MHz).

    peak MACs/cycle = sa_rows * sa_cols          (64*4=256 / 64*8=512)
    peak TOPS       = rows*cols * 2 * dsp_clk    (0.3072 / 0.6144)
    system peak     = 5*0.3072 + 5*0.6144 = 4.608 TOPS   (Table III "DP-*")

Timing model (cycle-approximate, validated against the paper's 98 % CE on
ResNet-50): a GEMM of (M out-channels x N positions x K reduction) executes in

    dsp_cycles = ceil(M/rows) * ( ceil(N/cols) * K  + WAVE_FILL )

i.e. output channels tile over the 64-row dimension ("computational tiles
matching the first SA dimension", Sec. IV-A), spatial positions stream over
the columns, and each wave pays a fixed pipeline-fill overhead. Efficiency
losses are exactly the M/N tiling quantization + fill — which reproduces
~98 % on ResNet-50 conv layers and the FC-layer inefficiency.

Memory: each PU owns 64 URAMs x 36 KiB = 2.25 MiB of weight storage (640
URAMs system-wide = 100 % utilization, Table II) and talks to HBM through
dedicated AXI DataMover channels at ~14.4 GB/s/channel (256-bit @ 450 MHz,
consistent with Shuhai [33] measurements).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

SYS_CLK_HZ = 300e6
DSP_CLK_HZ = 600e6
HBM_CHANNEL_BW = 14.4e9  # bytes/s per AXI channel
URAM_BYTES = 36 * 1024  # one URAM: 4K x 72b = 36 KiB
WAVE_FILL_CYCLES = 96  # SA pipeline fill+drain per output-channel wave (dsp_clk)
N_HBM_CHANNELS = 32  # HBM2 pseudo-channels on the U50
STREAM_TILE_BYTES = 4096  # granularity of the BRAM ping-pong tile streaming


@dataclass(frozen=True)
class PUSpec:
    pid: int
    kind: str  # "PU1x" | "PU2x"
    sa_rows: int
    sa_cols: int
    slr: int
    n_urams: int = 64
    act_buf_slots: int = 2  # ping-pong input activation BRAM buffers
    out_buf_slots: int = 2  # output buffers drained by the ST group
    dsp_clk_hz: float = DSP_CLK_HZ
    sys_clk_hz: float = SYS_CLK_HZ
    hbm_channel_bw: float = HBM_CHANNEL_BW

    # -- capability ----------------------------------------------------------
    @property
    def macs_per_dsp_cycle(self) -> int:
        return self.sa_rows * self.sa_cols

    @property
    def peak_tops(self) -> float:
        return self.macs_per_dsp_cycle * 2 * self.dsp_clk_hz / 1e12

    @property
    def n_dsps(self) -> int:
        # one DSP48E2 per SA MAC plus a small vector-unit allowance is folded
        # into the SA count for the CE metric, consistent with [16].
        return self.sa_rows * self.sa_cols

    @property
    def uram_capacity_bytes(self) -> int:
        return self.n_urams * URAM_BYTES

    # -- timing --------------------------------------------------------------
    def gemm_dsp_cycles(self, m: int, n: int, k: int) -> float:
        """Cycle count (dsp_clk) for an M x N x K GEMM on the SA."""
        waves = math.ceil(m / self.sa_rows)
        per_wave = math.ceil(n / self.sa_cols) * k + WAVE_FILL_CYCLES
        return waves * per_wave

    def gemm_sys_cycles(self, m: int, n: int, k: int) -> float:
        return self.gemm_dsp_cycles(m, n, k) * self.sys_clk_hz / self.dsp_clk_hz

    def gemm_seconds(self, m: int, n: int, k: int) -> float:
        return self.gemm_dsp_cycles(m, n, k) / self.dsp_clk_hz

    def gemm_efficiency(self, m: int, n: int, k: int) -> float:
        useful = m * n * k
        return useful / (self.gemm_dsp_cycles(m, n, k) * self.macs_per_dsp_cycle)

    def adm_sys_cycles(self, nbytes: int) -> float:
        """sys_clk cycles for one ADM transfer of ``nbytes`` over one HBM
        channel (latency-dominated floor of ~40 cycles for tiny bursts)."""
        return max(40.0, nbytes / self.hbm_channel_bw * self.sys_clk_hz)

    def adm_seconds(self, nbytes: int) -> float:
        return self.adm_sys_cycles(nbytes) / self.sys_clk_hz

    def stream_tile_cycles(self, nbytes: int) -> float:
        """Time until the *first tile* of a streamed transfer is usable by
        the SA (the BRAM ping-pong buffers stream tiles, so compute starts
        after one tile, not after the full transfer)."""
        tile = min(nbytes, STREAM_TILE_BYTES)
        return max(40.0, tile / self.hbm_channel_bw * self.sys_clk_hz)


def make_u50_system() -> list[PUSpec]:
    """The paper's 10-PU Alveo U50 configuration: 5x PU1x + 5x PU2x.

    PIDs 0-4 are PU1x on SLR0, PIDs 5-9 are PU2x on SLR1 (Fig. 2(a) places
    the PU types across the two SLRs; the exact floorplan only affects the
    Fig. 2(c) token-latency matrix, not throughput)."""
    pus = [PUSpec(pid=i, kind="PU1x", sa_rows=64, sa_cols=4, slr=0) for i in range(5)]
    pus += [PUSpec(pid=5 + i, kind="PU2x", sa_rows=64, sa_cols=8, slr=1) for i in range(5)]
    return pus


def system_peak_tops(pus: list[PUSpec]) -> float:
    return sum(p.peak_tops for p in pus)
